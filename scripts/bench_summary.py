"""Render ``benchmarks.run --json`` outputs as a GitHub step-summary table.

CI runs each budget-guarded stage with ``--json artifacts/bench/<name>_stage.json``
and then appends this script's stdout to ``$GITHUB_STEP_SUMMARY``:

    python scripts/bench_summary.py artifacts/bench/*_stage.json >> "$GITHUB_STEP_SUMMARY"

Missing or unparseable files are reported as rows rather than crashing the
step — the summary must render even when an earlier stage failed.
"""

import json
import sys


def _fmt_metrics(metrics):
    if not metrics:
        return ""
    parts = []
    for key in sorted(metrics):
        val = metrics[key]
        if isinstance(val, float):
            val = f"{val:g}"
        parts.append(f"{key}={val}")
    return ", ".join(parts)


def rows_from_file(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [(path, "ERROR", "", f"unreadable: {e}")]
    out = []
    for rec in doc.get("stages", []):
        status = "pass" if rec.get("ok") else "FAIL"
        wall = f"{rec.get('wall_s', 0):.1f}"
        detail = rec.get("error") or _fmt_metrics(rec.get("metrics"))
        out.append((rec.get("stage", "?"), status, wall, detail))
    return out


def main(argv=None):
    paths = argv if argv is not None else sys.argv[1:]
    print("## Benchmark ledger")
    print()
    print("| stage | status | wall (s) | metrics |")
    print("|---|---|---|---|")
    rows = []
    for path in paths:
        rows.extend(rows_from_file(path))
    if not rows:
        rows = [("(no stage JSON found)", "", "", "")]
    for stage, status, wall, detail in rows:
        icon = {"pass": "✅ pass", "FAIL": "❌ FAIL"}.get(status, status)
        print(f"| {stage} | {icon} | {wall} | {detail} |")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
