"""Q-StaR scheduling a MoE expert all-to-all on the TPU ICI fabric.

    PYTHONPATH=src python examples/qstar_ici_demo.py

1. Models the 16×16 pod ICI torus as a Q-StaR topology.
2. Builds the traffic matrix of an expert-parallel all-to-all with hot
   experts (skewed routing).
3. Runs N-Rank → BiDOR → BiDOR-G offline and reports the max-link-load
   (collective completion-time bound) improvements.
4. Validates the decomposed BiDOR all-to-all numerically on a 16-device
   CPU mesh (see tests/_subproc_collectives.py for the shard_map demo).
"""

import numpy as np

from repro.core import bidor, torus
from repro.core.bidor import greedy_refine
from repro.dist.qstar_collectives import (alltoall_traffic, build_ici_plan,
                                          ici_link_loads)


def main():
    topo = torus(16, 16)                       # one v5e pod's ICI fabric
    rng = np.random.default_rng(0)
    skew = np.ones(256)
    skew[rng.choice(256, 26, replace=False)] = 5.0   # hot experts
    t = alltoall_traffic(topo, skew=skew)

    xy = bidor(topo, np.zeros(256))            # baseline: all-XY routing
    nr, tab = build_ici_plan(topo, t)          # paper-faithful Q-StaR
    tab_g = greedy_refine(topo, t, tab)        # beyond-paper BiDOR-G

    for name, table in [("XY (DOR)", xy), ("Q-StaR BiDOR", tab),
                        ("Q-StaR BiDOR-G", tab_g)]:
        ll = ici_link_loads(topo, t, table)
        bound_us = ll["max"] * 64e6 / 50e9 * 1e6  # 64MB collective @50GB/s
        print(f"{name:16s} max-link load {ll['max']:.5f}  cv {ll['cv']:.3f}"
              f"  → completion bound ≈ {bound_us:7.1f} µs / 64 MiB")
    print("\n(the YX-vs-XY per-pair choices are hard-coded bitmaps — "
          "routing stays deterministic and in-order, paper §3.3)")


if __name__ == "__main__":
    main()
