"""Q-StaR scheduling collective traffic on the TPU ICI fabric.

    PYTHONPATH=src python examples/qstar_ici_demo.py [pod_side]
    PYTHONPATH=src python examples/qstar_ici_demo.py --ml qwen2-moe-a2.7b

1. Models a pod's ICI torus (default 16×16) as a Q-StaR topology.
2. Builds a traffic matrix — either the synthetic expert-parallel
   all-to-all with hot experts (``repro.core.traffic.alltoall``), or,
   with ``--ml ARCH``, the REAL collective flows of a sharded model:
   the arch's smoke config is lowered under a 1×8 mesh, its post-SPMD
   HLO collectives extracted and embedded onto the torus
   (``repro.noc.mltraffic``).
3. Runs N-Rank → BiDOR → BiDOR-G offline and reports the max-link-load
   (collective completion-time bound) improvements.  BiDOR-G is seeded
   from the better of the planned table and plain XY, so it never loses
   to DOR — on real ML matrices the plain BiDOR table alone can.
4. Shows the quasi-static control plane reacting to an ICI link that
   retrains at reduced width: the re-planner rebuilds the tables against
   the degraded fabric and cuts the new bottleneck.
"""

import argparse

import numpy as np

from repro.core import (bidor, build_plan, link_load, link_load_stats,
                        torus, traffic)
from repro.core.bidor import greedy_refine


def _loads(topo, t, table):
    s = link_load_stats(topo, t, table)
    return s["max"], s["cv"]


def _ml_matrix(topo, arch: str, phases: tuple[str, ...]):
    """HLO-derived collective flows of ``arch`` embedded onto ``topo``."""
    from repro.noc import WorkloadSpec, derive_workload

    pad = 8 if "moe" in arch or arch.startswith("dbrx") else 0
    spec = WorkloadSpec(arch=arch, data=1, model=8, moe_pad_to=pad,
                        phases=phases)
    wl = derive_workload(spec)
    print(f"derived {wl.name}: phases {'+'.join(phases)}, "
          f"{sum(wl.meta.get('collective_op_counts', {}).values())} "
          f"collective ops in HLO")
    return wl.matrix_for(topo)


def main(side: int = 16, greedy_sweeps: int = 3, ml_arch: str | None = None,
         phases: tuple[str, ...] = ("decode",)):
    topo = torus(side, side)               # one pod's ICI fabric
    n = topo.num_nodes
    if ml_arch:
        t = _ml_matrix(topo, ml_arch, phases)
    else:
        rng = np.random.default_rng(0)
        skew = np.ones(n)
        # hot experts
        skew[rng.choice(n, max(n // 10, 1), replace=False)] = 5.0
        t = traffic.alltoall(topo, skew=skew)

    xy = bidor(topo, np.zeros(n))              # baseline: all-XY routing
    plan = build_plan(topo, t)                 # paper-faithful Q-StaR
    mx_plan, _ = _loads(topo, t, plan.table)
    mx_xy, _ = _loads(topo, t, xy)
    start = plan.table if mx_plan <= mx_xy else xy
    tab_g = greedy_refine(topo, t, start,
                          sweeps=greedy_sweeps)  # beyond-paper BiDOR-G

    for name, table in [("XY (DOR)", xy), ("Q-StaR BiDOR", plan.table),
                        ("Q-StaR BiDOR-G", tab_g)]:
        mx, cv = _loads(topo, t, table)
        bound_us = mx * 64e6 / 50e9 * 1e6  # 64MB collective @50GB/s
        print(f"{name:16s} max-link load {mx:.5f}  cv {cv:.3f}"
              f"  → completion bound ≈ {bound_us:7.1f} µs / 64 MiB")

    # ---- quasi-static replan after a link retrains at 25% width ---- #
    hot = int(np.argmax(link_load(topo, t, tab_g)))
    degraded = topo.degrade([hot], bw_scale=0.25)
    stale_mx, _ = _loads(degraded, t, tab_g)
    replanned = greedy_refine(degraded, t, build_plan(degraded, t).table,
                              sweeps=greedy_sweeps)
    new_mx, _ = _loads(degraded, t, replanned)
    u, v = degraded.channels[hot]
    print(f"\nlink {u}->{v} retrained at 25% width: stale plan bottleneck "
          f"{stale_mx:.5f} → replanned {new_mx:.5f} "
          f"({(1 - new_mx / stale_mx) * 100:+.1f}%)")
    print("(the YX-vs-XY per-pair choices are hard-coded bitmaps — "
          "routing stays deterministic and in-order, paper §3.3)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("side", nargs="?", type=int, default=16,
                    help="pod side: the ICI fabric is a side x side torus")
    ap.add_argument("--sweeps", type=int, default=3,
                    help="BiDOR-G greedy refinement sweeps")
    ap.add_argument("--ml", default=None, metavar="ARCH",
                    help="derive the traffic from this arch's sharded "
                         "HLO instead of the synthetic all-to-all "
                         "(e.g. qwen2-moe-a2.7b)")
    ap.add_argument("--phases", default="decode",
                    help="comma-separated phases for --ml "
                         "(fwd,train,decode)")
    args = ap.parse_args()
    main(side=args.side, greedy_sweeps=args.sweeps, ml_arch=args.ml,
         phases=tuple(args.phases.split(",")))
