"""Q-StaR scheduling a MoE expert all-to-all on the TPU ICI fabric.

    PYTHONPATH=src python examples/qstar_ici_demo.py [pod_side]

1. Models a pod's ICI torus (default 16×16) as a Q-StaR topology.
2. Builds the traffic matrix of an expert-parallel all-to-all with hot
   experts (skewed routing) via ``repro.core.traffic.alltoall``.
3. Runs N-Rank → BiDOR → BiDOR-G offline and reports the max-link-load
   (collective completion-time bound) improvements.
4. Shows the quasi-static control plane reacting to an ICI link that
   retrains at reduced width: the re-planner rebuilds the tables against
   the degraded fabric and cuts the new bottleneck.
"""

import sys

import numpy as np

from repro.core import (bidor, build_plan, link_load, link_load_stats,
                        torus, traffic)
from repro.core.bidor import greedy_refine


def _loads(topo, t, table):
    s = link_load_stats(topo, t, table)
    return s["max"], s["cv"]


def main(side: int = 16, greedy_sweeps: int = 3):
    topo = torus(side, side)               # one pod's ICI fabric
    n = topo.num_nodes
    rng = np.random.default_rng(0)
    skew = np.ones(n)
    skew[rng.choice(n, max(n // 10, 1), replace=False)] = 5.0  # hot experts
    t = traffic.alltoall(topo, skew=skew)

    xy = bidor(topo, np.zeros(n))              # baseline: all-XY routing
    plan = build_plan(topo, t)                 # paper-faithful Q-StaR
    tab_g = greedy_refine(topo, t, plan.table,
                          sweeps=greedy_sweeps)  # beyond-paper BiDOR-G

    for name, table in [("XY (DOR)", xy), ("Q-StaR BiDOR", plan.table),
                        ("Q-StaR BiDOR-G", tab_g)]:
        mx, cv = _loads(topo, t, table)
        bound_us = mx * 64e6 / 50e9 * 1e6  # 64MB collective @50GB/s
        print(f"{name:16s} max-link load {mx:.5f}  cv {cv:.3f}"
              f"  → completion bound ≈ {bound_us:7.1f} µs / 64 MiB")

    # ---- quasi-static replan after a link retrains at 25% width ---- #
    hot = int(np.argmax(link_load(topo, t, tab_g)))
    degraded = topo.degrade([hot], bw_scale=0.25)
    stale_mx, _ = _loads(degraded, t, tab_g)
    replanned = greedy_refine(degraded, t, build_plan(degraded, t).table,
                              sweeps=greedy_sweeps)
    new_mx, _ = _loads(degraded, t, replanned)
    u, v = degraded.channels[hot]
    print(f"\nlink {u}->{v} retrained at 25% width: stale plan bottleneck "
          f"{stale_mx:.5f} → replanned {new_mx:.5f} "
          f"({(1 - new_mx / stale_mx) * 100:+.1f}%)")
    print("(the YX-vs-XY per-pair choices are hard-coded bitmaps — "
          "routing stays deterministic and in-order, paper §3.3)")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 16)
