"""Batched serving demo: prefill + greedy decode with KV caches.

    PYTHONPATH=src python examples/serve_decode.py --arch minicpm3-4b
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import registry
from repro.serve import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch).smoke   # reduced config runs on CPU
    params = registry.init(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg=cfg, params=params,
                         max_len=args.prompt_len + args.tokens + 8)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    enc_out = None
    if cfg.family == "encdec":
        mod = registry.model_module(cfg)
        frames = jax.random.normal(jax.random.PRNGKey(1),
                                   (args.batch, cfg.enc_seq, cfg.d_model))
        enc_out = mod.encode(cfg, params, frames)
    t0 = time.time()
    out = engine.generate(prompts, args.tokens, enc_out=enc_out)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} generated "
          f"{out.shape[1]} tokens/seq in {dt:.1f}s "
          f"({args.batch * out.shape[1] / dt:.1f} tok/s)")
    print("sample:", out[0][:16])
    # decode is deterministic greedy: same prompts → same continuation
    out2 = engine.generate(prompts, args.tokens, enc_out=enc_out)
    assert np.array_equal(out, out2)
    print("determinism check passed")


if __name__ == "__main__":
    main()
