"""Quickstart: the full Q-StaR pipeline on the paper's 5×5 NoC.

    PYTHONPATH=src python examples/quickstart.py [cycles]

Builds N-Rank weights + BiDOR bitmaps offline (paper Fig. 3 workflow),
then simulates XY vs BiDOR and prints the load-balance improvement.
"""

import sys

import numpy as np

from repro.core import build_plan, mesh2d_edge_io, traffic
from repro.noc import Algo, SimConfig, run_sim


def main(cycles: int = 8000):
    topo = mesh2d_edge_io(5, 5)           # paper §4.1 NoC
    t = traffic.uniform(topo)

    # ---- offline: N-Rank + BiDOR (quasi-static, paper §3) ---- #
    plan = build_plan(topo, t)
    print("N-Rank iterations:", plan.nrank.iterations)
    print("w_NR grid:")
    print(np.round(plan.w_nr.reshape(5, 5), 3))
    print("BiDOR bitmap of node 0 (bit=1 ⇒ YX):")
    print(plan.table.bitmaps[0].astype(int))

    # ---- runtime: deterministic table-driven routing ---- #
    cfg = SimConfig(cycles=cycles, warmup=cycles // 3, injection_rate=0.5)
    r_xy = run_sim(topo, t, cfg.replace(algo=Algo.XY))
    r_bd = run_sim(topo, t, cfg.replace(algo=Algo.BIDOR),
                   bidor_table=plan.table)
    print(f"\nXY    : {r_xy.summary()}")
    print(f"BiDOR : {r_bd.summary()}")
    print(f"\nload-balance LCV {r_xy.lcv:.3f} → {r_bd.lcv:.3f} "
          f"(paper Table 1: 0.28 → 0.08)")
    print(f"throughput {r_xy.throughput:.3f} → {r_bd.throughput:.3f} "
          f"flits/cycle/port; reorder {r_xy.reorder_value} → "
          f"{r_bd.reorder_value}")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 8000)
