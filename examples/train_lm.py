"""End-to-end training driver: a ~100M-param LM with the full substrate —
synthetic data pipeline, AdamW, checkpointing with auto-resume, preemption
handling, straggler monitoring.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 30

Kill it mid-run and start it again: it resumes from the last checkpoint.
"""

import argparse

import jax

from repro.models.common import ModelConfig
from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, SyntheticLM
from repro.train.fault_tolerance import (PreemptionHandler,
                                         StragglerMonitor, resume_or_init)
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step

PRESETS = {
    # ~100K — CI smoke scale (tests/test_examples_smoke.py)
    "tiny": ModelConfig(name="lmtiny", family="dense", n_layers=2,
                        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                        vocab=512, dtype="float32", remat=False,
                        attn_q_chunk=32, attn_kv_chunk=32),
    # ~10M — fast on CPU
    "10m": ModelConfig(name="lm10m", family="dense", n_layers=4,
                       d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                       vocab=8192, dtype="float32", remat=False,
                       attn_q_chunk=128, attn_kv_chunk=128),
    # ~100M — the assignment's end-to-end scale
    "100m": ModelConfig(name="lm100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
                        vocab=16384, dtype="float32", remat=False,
                        attn_q_chunk=256, attn_kv_chunk=256),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args(argv)

    cfg = PRESETS[args.preset]
    from repro.models import registry
    print(f"model: {cfg.name} "
          f"({registry.count_params(cfg) / 1e6:.1f}M params)")
    oc = OptConfig(peak_lr=3e-3, warmup_steps=20, decay_steps=args.steps)
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch)
    data = SyntheticLM(dc)

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    fresh = init_train_state(cfg, oc, jax.random.PRNGKey(0))
    state, start = resume_or_init(mgr, fresh)
    if start:
        print(f"resumed from step {start}")
    step_fn = jax.jit(make_train_step(cfg, oc, grad_accum=2))
    handler = PreemptionHandler()
    mon = StragglerMonitor()

    for step in range(start, args.steps):
        mon.start()
        batch = {k: jax.numpy.asarray(v)
                 for k, v in data.get_batch(step).items()}
        state, metrics = step_fn(state, batch)
        straggler = mon.stop()
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"lr {float(metrics['lr']):.2e}"
                  + ("  [straggler]" if straggler else ""))
        if step and step % args.ckpt_every == 0:
            mgr.save(step, state, async_=True)
        if handler.should_stop:
            print("preemption signal — checkpointing and exiting")
            mgr.save(step, state)
            return
    mgr.save(args.steps, state)
    print(f"done; final loss {float(metrics['loss']):.4f} "
          f"(checkpoints in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
