import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape) cell on
the production meshes and record memory/cost/roofline artifacts.

The two lines above MUST precede any jax import (jax pins the device count
at first init); that is why this module must not be imported from code that
already initialized jax — run it as ``python -m repro.launch.dryrun``.

Per cell this driver:
  1. builds abstract (ShapeDtypeStruct) params/opt/batch/cache trees with
     NamedShardings from ``repro.sharding.specs`` — no allocation;
  2. ``jax.jit(step).lower(...).compile()`` — a sharding mismatch, compile
     OOM, or unsupported collective here is a bug in the system;
  3. prints ``compiled.memory_analysis()`` (fits-in-HBM proof) and derives
     the three §Roofline terms from the post-SPMD HLO
     (``repro.analysis.hlo`` — with while-trip-count-correct accounting);
  4. writes a JSON artifact consumed by ``benchmarks/roofline.py``.

Usage:
  python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
  python -m repro.launch.dryrun --arch all --mesh single,multi
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo
from repro.analysis.hlo import analyze_hlo_text, roofline_terms
from repro.configs import SHAPES, get_arch, list_archs
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.sharding import specs as sh
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _sds(tree, mesh, spec_tree):
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=jax.sharding.NamedSharding(mesh, p)),
        tree, spec_tree,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct,
                                         jax.sharding.PartitionSpec)))


def input_specs(arch_id: str, shape_name: str, mesh, variant: str = "base"):
    """Abstract inputs for one cell: (step_kind, fn, args_sds, meta)."""
    from repro.configs.base import optimized_config
    spec = get_arch(arch_id)
    cfg = optimized_config(arch_id) if variant == "opt" else spec.full
    shp = SHAPES[shape_name]
    b, s = shp.global_batch, shp.seq_len
    params_a = registry.abstract_params(cfg)
    pspecs = sh.param_specs(cfg, mesh, params_a)
    params_sds = _sds(params_a, mesh, pspecs)
    mod = registry.model_module(cfg)
    n_active = registry.count_params(cfg, active_only=True)

    if shp.kind == "train":
        opt_cfg = OptConfig(
            moment_dtype="int8" if registry.count_params(cfg) > 5e10
            else "float32")
        grad_accum = {True: 16, False: 4}[registry.count_params(cfg) > 5e10]
        opt_a = jax.eval_shape(lambda: init_opt_state(opt_cfg, params_a))
        ospecs = sh.opt_specs(cfg, mesh, opt_a, pspecs)
        state_sds = {"params": params_sds, "opt": _sds(opt_a, mesh, ospecs)}
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
        }
        if cfg.family == "vlm":
            batch["positions"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
        if cfg.family == "encdec":
            batch["embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), cfg.jdtype)
        batch_sds = _sds(batch, mesh, sh.batch_specs(mesh, batch))
        fn = make_train_step(cfg, opt_cfg, grad_accum=grad_accum)
        model_flops = 6.0 * n_active * b * s
        rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        out_shardings = (
            jax.tree.map(lambda x: x.sharding, state_sds),
            {"loss": rep, "ce": rep, "aux": rep, "grad_norm": rep,
             "lr": rep},
        )
        return "train", fn, (state_sds, batch_sds), dict(
            donate=(0,), model_flops=model_flops, grad_accum=grad_accum,
            out_shardings=out_shardings)

    # serving shapes
    cache_a = jax.eval_shape(
        lambda: registry.init_cache(cfg, b, s))
    seq_par = shape_name == "long_500k"
    cspecs = sh.cache_specs(cfg, mesh, cache_a, seq_parallel=seq_par)
    cache_sds = _sds(cache_a, mesh, cspecs)
    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    if shp.kind == "prefill":
        tokens = jax.ShapeDtypeStruct(
            (b, s), jnp.int32,
            sharding=jax.sharding.NamedSharding(
                mesh, sh.fit_spec(mesh, (b, s), (sh.DATA, None))))
        extra = {}
        if cfg.family == "encdec":
            extra["enc_out"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), cfg.jdtype,
                sharding=jax.sharding.NamedSharding(
                    mesh, sh.fit_spec(mesh, (b, cfg.enc_seq, cfg.d_model),
                                      (sh.DATA, None, None))))

        def prefill_fn(params, tokens, cache, **kw):
            return mod.prefill(cfg, params, tokens, cache, **kw)

        model_flops = 2.0 * n_active * b * s
        return "prefill", prefill_fn, \
            (params_sds, tokens, cache_sds), dict(
                donate=(2,), model_flops=model_flops, extra=extra)

    # decode: one new token against a KV/state cache of length s
    tokens = jax.ShapeDtypeStruct(
        (b, 1), jnp.int32,
        sharding=jax.sharding.NamedSharding(
            mesh, sh.fit_spec(mesh, (b, 1), (sh.DATA, None))))
    index = jax.ShapeDtypeStruct((), jnp.int32, sharding=rep)
    extra = {}
    if cfg.family == "encdec":
        extra["enc_out"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq, cfg.d_model), cfg.jdtype,
            sharding=jax.sharding.NamedSharding(
                mesh, sh.fit_spec(mesh, (b, cfg.enc_seq, cfg.d_model),
                                  (sh.DATA, None, None))))

    def serve_fn(params, tokens, cache, index, **kw):
        logits, cache = mod.decode_step(cfg, params, tokens, cache, index,
                                        **kw)
        return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32), cache

    model_flops = 2.0 * n_active * b
    return "decode", serve_fn, (params_sds, tokens, cache_sds, index), dict(
        donate=(2,), model_flops=model_flops, extra=extra)


def run_cell(arch_id: str, shape_name: str, mesh_name: str,
             out_dir: str | None = None, verbose: bool = True,
             variant: str = "base"):
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    kind, fn, args, meta = input_specs(arch_id, shape_name, mesh, variant)
    t0 = time.time()
    with mesh:
        jitted = jax.jit(fn, donate_argnums=meta.get("donate", ()),
                         out_shardings=meta.get("out_shardings"))
        if meta.get("extra"):
            lowered = jitted.lower(*args, **meta["extra"])
        else:
            lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = hlo.xla_cost_analysis(compiled)
    stats = analyze_hlo_text(compiled.as_text(), n_chips)
    rl = roofline_terms(stats, n_chips, meta["model_flops"])
    record = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "variant": variant, "kind": kind, "num_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_gb": mem.argument_size_in_bytes / 2**30,
            "output_gb": mem.output_size_in_bytes / 2**30,
            "temp_gb": mem.temp_size_in_bytes / 2**30,
            "alias_gb": mem.alias_size_in_bytes / 2**30,
            "peak_gb": (mem.argument_size_in_bytes
                        + mem.output_size_in_bytes
                        + mem.temp_size_in_bytes
                        - mem.alias_size_in_bytes) / 2**30,
        },
        "xla_cost_analysis": {k: cost.get(k) for k in
                              ("flops", "bytes accessed")},
        "per_device": {
            "flops": stats.flops,
            "hbm_bytes": stats.hbm_bytes,
            "collective_bytes": stats.collective_bytes,
            "collective_wire_bytes": stats.collective_wire_bytes,
            "collective_counts": stats.collective_counts,
        },
        "roofline": {
            "compute_s": rl.compute_s,
            "memory_s": rl.memory_s,
            "collective_s": rl.collective_s,
            "dominant": rl.dominant,
            "model_flops": rl.model_flops,
            "useful_flops_ratio": rl.useful_flops_ratio,
            "mfu_bound": rl.mfu_bound,
        },
        "grad_accum": meta.get("grad_accum"),
    }
    if verbose:
        print(f"[{arch_id} × {shape_name} × {mesh_name} × {variant}] {kind}: "
              f"compile {t_compile:.0f}s  peak/device "
              f"{record['memory']['peak_gb']:.2f} GiB")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis(flops/device, body-once): "
              f"{cost.get('flops', 0):.3e}")
        print(f"  roofline: compute {rl.compute_s*1e3:.2f} ms | memory "
              f"{rl.memory_s*1e3:.2f} ms | collective "
              f"{rl.collective_s*1e3:.2f} ms → {rl.dominant}-bound, "
              f"MFU bound {rl.mfu_bound:.2%}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "" if variant == "base" else f"__{variant}"
        path = os.path.join(
            out_dir, f"{arch_id}__{shape_name}__{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    help="comma list: single,multi")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    failures = []
    for arch in archs:
        spec = get_arch(arch)
        shapes = (spec.shapes if args.shape == "all"
                  else [s for s in args.shape.split(",")
                        if s in spec.shapes])
        for shape in shapes:
            for mesh_name in args.mesh.split(","):
                suffix = "" if args.variant == "base" else \
                    f"__{args.variant}"
                path = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_name}{suffix}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"skip {arch} × {shape} × {mesh_name}")
                    continue
                try:
                    run_cell(arch, shape, mesh_name, out_dir=args.out,
                             variant=args.variant)
                except Exception as e:  # noqa: BLE001 - report and continue
                    traceback.print_exc()
                    failures.append((arch, shape, mesh_name, str(e)[:200]))
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall requested dry-run cells compiled successfully")


if __name__ == "__main__":
    main()
