"""Distributed training driver (deliverable b — the production launcher).

Runs any assigned architecture on an explicit (data, model) mesh with the
full substrate: sharded params/optimizer per ``repro.sharding.specs``,
synthetic data sharded per host, checkpoint auto-resume, preemption
handling, straggler monitoring, elastic re-meshing on restart.

Single host (CPU dev loop, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \\
        --smoke --steps 20 --mesh 1x1

Multi-device (e.g. 8 forced host devices):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
        python -m repro.launch.train --arch internlm2-1.8b --smoke \\
        --steps 10 --mesh 4x2

On a real pod the same entry point runs under ``jax.distributed`` with the
production mesh from ``repro.launch.mesh.make_production_mesh``.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.sharding import specs as sh
from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, SyntheticLM
from repro.train.fault_tolerance import (ElasticMesh, PreemptionHandler,
                                         StragglerMonitor, resume_or_init)
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step


def build(cfg, opt_cfg, mesh, key):
    """Initialize a sharded train state on the mesh."""
    params_a = registry.abstract_params(cfg)
    pspecs = sh.param_specs(cfg, mesh, params_a)
    opt_a = jax.eval_shape(lambda: init_opt_state(opt_cfg, params_a))
    ospecs = sh.opt_specs(cfg, mesh, opt_a, pspecs)
    state_shardings = {
        "params": jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s), pspecs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)),
        "opt": jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s), ospecs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)),
    }

    @jax.jit
    def _init(key):
        params = registry.init(cfg, key)
        return {"params": params, "opt": init_opt_state(opt_cfg, params)}

    with mesh:
        state = jax.jit(
            lambda k: _init(k), out_shardings=state_shardings)(key)
    return state, state_shardings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM, 'prod' (16x16) or 'prod2' (2x16x16)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.full
    if args.mesh == "prod":
        mesh = make_production_mesh()
    elif args.mesh == "prod2":
        mesh = make_production_mesh(multi_pod=True)
    else:
        d, m = map(int, args.mesh.split("x"))
        em = ElasticMesh(model_degree=m)
        mesh = em.build(jax.devices()[: d * m])
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name} "
          f"({registry.count_params(cfg) / 1e6:.1f}M params)")

    opt_cfg = OptConfig(peak_lr=1e-3, warmup_steps=10,
                        decay_steps=args.steps)
    state, shardings = build(cfg, opt_cfg, mesh, jax.random.PRNGKey(0))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    state, start = resume_or_init(mgr, state)
    if start:
        print(f"resumed from step {start}")

    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.global_batch)
    data = SyntheticLM(dc)
    bspec = sh.batch_specs(mesh, {
        "tokens": jax.ShapeDtypeStruct(
            (args.global_batch, args.seq), jnp.int32)})["tokens"]
    bsharding = jax.sharding.NamedSharding(mesh, bspec)

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, args.grad_accum),
                      donate_argnums=0)
    handler = PreemptionHandler()
    mon = StragglerMonitor()

    with mesh:
        for step in range(start, args.steps):
            mon.start()
            host = data.get_batch(step)
            batch = {
                "tokens": jax.device_put(host["tokens"], bsharding),
                "labels": jax.device_put(host["labels"], bsharding),
                "mask": jax.device_put(host["mask"], bsharding),
            }
            if cfg.family == "vlm":
                pos = np.broadcast_to(
                    np.arange(args.seq, dtype=np.int32)[None, None],
                    (3, args.global_batch, args.seq))
                batch["positions"] = jnp.asarray(pos)
            if cfg.family == "encdec":
                batch["embeds"] = jnp.zeros(
                    (args.global_batch, cfg.enc_seq, cfg.d_model),
                    cfg.jdtype)
            state, metrics = step_fn(state, batch)
            slow = mon.stop()
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.2f}"
                      + ("  [straggler]" if slow else ""), flush=True)
            if step and step % args.ckpt_every == 0:
                mgr.save(step, state, async_=True)
            if handler.should_stop:
                print("preempted — final checkpoint")
                mgr.save(step, state)
                return
    mgr.save(args.steps, state)
    print("done")


if __name__ == "__main__":
    main()
