"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never initializes jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices; real deployments get the same mesh
from actual TPU topology.
"""

from __future__ import annotations

import jax
import numpy as np

PODS = 2
POD_X = 16
POD_Y = 16


def make_production_mesh(*, multi_pod: bool = False):
    shape = (PODS, POD_X, POD_Y) if multi_pod else (POD_X, POD_Y)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == need:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    if len(devices) < need:
        raise RuntimeError(
            f"need {need} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    # dry-run environment exposes 512 placeholder devices; the single-pod
    # mesh uses the first 256 of them
    use = np.array(devices[:need]).reshape(shape)
    return jax.sharding.Mesh(use, axes)


def make_mesh_for_devices(data: int, model: int, devices=None):
    """Small-mesh helper for CPU tests (subprocess with N host devices)."""
    devices = devices if devices is not None else jax.devices()
    use = np.array(devices[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(use, ("data", "model"))


def ici_topology(mesh) -> "object":
    """The ICI torus graph underlying a mesh — Q-StaR's topology input.

    Single-pod (16×16) → 2D torus; multi-pod → per-pod torus + pod axis
    with reduced-bandwidth links (DCN), matching ``repro.core.multipod``.
    """
    from repro.core.topology import multipod, torus
    if "pod" in mesh.shape:
        return multipod(mesh.shape["pod"], mesh.shape["data"],
                        mesh.shape["model"])
    return torus(mesh.shape["data"], mesh.shape["model"])
