"""Whisper-style encoder–decoder backbone (arXiv:2212.04356).

The conv audio frontend is a STUB per the assignment: ``forward`` consumes
precomputed frame embeddings (B, S_audio, d) from ``input_specs()``.
Encoder: bidirectional self-attention + GELU MLP, sinusoidal positions.
Decoder: causal self-attention + cross-attention + GELU MLP, learned
positions; decode caches self-KV per layer plus precomputed cross-KV.
LayerNorm (not RMS) throughout, pre-norm, matching Whisper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig, dense_init, stack_layer_init
from repro.models.layers.basic import (
    embed, embedding_init, layer_norm, layer_norm_init, unembed)
from repro.models.layers.attention import (
    cross_apply, cross_init, cross_kv, gqa_apply, gqa_init)
from repro.models.layers.ffn import gelu_mlp, gelu_mlp_init
from repro.sharding.hints import hint_bsd


def _sinusoid(length: int, d: int):
    pos = np.arange(length)[:, None]
    dim = np.arange(0, d, 2)[None]
    ang = pos / np.power(10000.0, dim / d)
    out = np.zeros((length, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


# ------------------------------ encoder ------------------------------- #
def _enc_block_init(cfg, key):
    k1, k2 = jax.random.split(key)
    return {"ln1": layer_norm_init(cfg.d_model),
            "attn": gqa_init(cfg, k1),
            "ln2": layer_norm_init(cfg.d_model),
            "mlp": gelu_mlp_init(cfg, k2)}


def _enc_block_apply(cfg, p, x):
    x = hint_bsd(x)
    h = layer_norm(p["ln1"], x, cfg.norm_eps)
    attn, _ = gqa_apply(cfg, p["attn"], h, angles=None, causal=False)
    x = x + attn
    h = layer_norm(p["ln2"], x, cfg.norm_eps)
    return x + gelu_mlp(p["mlp"], h)


# ------------------------------ decoder ------------------------------- #
def _dec_block_init(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": layer_norm_init(cfg.d_model),
            "attn": gqa_init(cfg, k1),
            "ln_x": layer_norm_init(cfg.d_model),
            "xattn": cross_init(cfg, k2),
            "ln2": layer_norm_init(cfg.d_model),
            "mlp": gelu_mlp_init(cfg, k3)}


def _dec_block_apply(cfg, p, x, enc_kv, cache=None, cache_index=None):
    x = hint_bsd(x)
    h = layer_norm(p["ln1"], x, cfg.norm_eps)
    attn, new_cache = gqa_apply(cfg, p["attn"], h, angles=None, causal=True,
                                cache=cache, cache_index=cache_index)
    x = x + attn
    h = layer_norm(p["ln_x"], x, cfg.norm_eps)
    x = x + cross_apply(cfg, p["xattn"], h, enc_kv)
    h = layer_norm(p["ln2"], x, cfg.norm_eps)
    return x + gelu_mlp(p["mlp"], h), new_cache


# ------------------------------ model --------------------------------- #
MAX_DEC_POS = 32768  # learned decoder positions (whisper-base: 448; the
                     # assignment's prefill_32k/decode_32k shapes need 32k)


def init(cfg: ModelConfig, key):
    ks = jax.random.split(key, 6)
    return {
        "enc_blocks": stack_layer_init(
            lambda k: _enc_block_init(cfg, k), cfg.enc_layers, ks[0]),
        "enc_ln": layer_norm_init(cfg.d_model),
        "embed": embedding_init(ks[1], cfg.vocab, cfg.d_model, cfg.jdtype),
        "pos": dense_init(ks[2], (MAX_DEC_POS, cfg.d_model), cfg.jdtype,
                          scale=0.02),
        "dec_blocks": stack_layer_init(
            lambda k: _dec_block_init(cfg, k), cfg.n_layers, ks[3]),
        "dec_ln": layer_norm_init(cfg.d_model),
    }


def encode(cfg: ModelConfig, params, frames):
    """frames: (B, S_audio, d) stub frontend output."""
    x = frames + _sinusoid(frames.shape[1], cfg.d_model).astype(frames.dtype)
    block = functools.partial(_enc_block_apply, cfg)
    if cfg.remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)

    def body(x, p):
        return block(p, x), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layer_norm(params["enc_ln"], x, cfg.norm_eps)


def _dec_positions(params, s, start):
    return jax.lax.dynamic_slice_in_dim(params["pos"], start, s, axis=0)


def decode(cfg: ModelConfig, params, tokens, enc_out, caches=None,
           cache_index=None):
    b, s = tokens.shape
    start = cache_index if cache_index is not None else 0
    x = embed(params["embed"], tokens) + _dec_positions(params, s, start)
    block = functools.partial(_dec_block_apply, cfg)
    if cfg.remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)

    def body(x, layer_in):
        if caches is None:
            p = layer_in
            x, _ = block(p, x, cross_kv(cfg, p["xattn"], enc_out))
            return x, None
        p, c = layer_in
        x, nc = block(p, x, cross_kv(cfg, p["xattn"], enc_out),
                      cache=c, cache_index=cache_index)
        return x, nc

    xs = (params["dec_blocks"] if caches is None
          else (params["dec_blocks"], caches))
    x, new_caches = jax.lax.scan(body, x, xs)
    x = layer_norm(params["dec_ln"], x, cfg.norm_eps)
    logits = unembed(params["embed"], None, x, tie=True)  # whisper ties
    return logits, new_caches


def forward(cfg: ModelConfig, params, tokens, positions=None, embeds=None):
    """Training step input: ``embeds`` = audio frames, tokens = text."""
    assert embeds is not None, "enc-dec needs frame embeddings"
    enc = encode(cfg, params, embeds)
    logits, _ = decode(cfg, params, tokens, enc)
    return logits, jnp.float32(0)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dt = dtype or cfg.jdtype
    l, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((l, batch, max_len, kv, hd), dt),
            "v": jnp.zeros((l, batch, max_len, kv, hd), dt)}


def decode_step(cfg: ModelConfig, params, tokens, cache, index,
                enc_out=None, positions=None):
    """One decoder token against cached self-KV + encoder output."""
    assert enc_out is not None
    return decode(cfg, params, tokens, enc_out, caches=cache,
                  cache_index=index)


def prefill(cfg: ModelConfig, params, tokens, cache, enc_out=None,
            positions=None):
    return decode(cfg, params, tokens, enc_out, caches=cache, cache_index=0)
