"""Architecture zoo: 10 assigned architectures as pure-function pytrees."""

from repro.models.common import ModelConfig
from repro.models import registry

__all__ = ["ModelConfig", "registry"]
