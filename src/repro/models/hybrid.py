"""Hybrid Mamba+attention MoE stack (Jamba, arXiv:2403.19887).

Layer pattern: one attention layer per ``attn_period`` (Jamba: 1:7), FFN
after every mixer, MoE FFN every ``moe_period``-th layer (Jamba: 2).  The
stack is organized as ``n_layers / attn_period`` *super-blocks* — each
super-block is unrolled (1 attn + 7 mamba layers with alternating
dense/MoE FFNs) and the super-blocks are scanned, which divides compiled
HLO size by 9 for the 72-layer 398B config.

Decode state per super-block: 1 KV cache + 7 (conv, ssm) mamba states —
O(1) in sequence length for the mamba layers, which is what licenses the
``long_500k`` shape for this architecture.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, stack_layer_init
from repro.models.layers.basic import (
    embed, embedding_init, head_init, rms_norm, rms_norm_init, unembed)
from repro.models.layers.attention import gqa_apply, gqa_init
from repro.models.layers.ffn import moe_apply, moe_init, swiglu, swiglu_init
from repro.models.layers.recurrent import (
    mamba_apply, mamba_init, mamba_step)
from repro.models.layers.rope import rope_angles
from repro.sharding.hints import hint_bsd


def _superblock_layout(cfg: ModelConfig):
    """Within one super-block of ``attn_period`` layers: layer 0 is attn,
    the rest mamba; FFN j is MoE iff the global layer index is MoE —
    alignment requires attn_period % moe_period == 0."""
    ap = cfg.attn_period
    assert ap > 0 and cfg.n_layers % ap == 0
    moe_js = [j for j in range(ap)
              if cfg.is_moe and j % cfg.moe_period == cfg.moe_period - 1]
    dense_js = [j for j in range(ap) if j not in moe_js]
    return ap, moe_js, dense_js


def _superblock_init(cfg: ModelConfig, key):
    ap, moe_js, dense_js = _superblock_layout(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "attn": gqa_init(cfg, ks[0]),
        "attn_ln": rms_norm_init(cfg.d_model),
        "mamba": stack_layer_init(lambda k: mamba_init(cfg, k), ap - 1, ks[1]),
        "mamba_ln": stack_layer_init(
            lambda k: rms_norm_init(cfg.d_model), ap - 1, ks[1]),
        "ffn_ln": stack_layer_init(
            lambda k: rms_norm_init(cfg.d_model), ap, ks[2]),
    }
    if dense_js:
        p["ffn_dense"] = stack_layer_init(
            lambda k: swiglu_init(cfg, k), len(dense_js), ks[2])
    if moe_js:
        p["ffn_moe"] = stack_layer_init(
            lambda k: moe_init(cfg, k), len(moe_js), ks[3])
    return p


def _superblock_apply(cfg: ModelConfig, p, x, *, angles,
                      state=None, cache_index=None):
    """state: dict(kv=..., conv=(ap-1,...), ssm=(ap-1,...)) or None."""
    ap, moe_js, dense_js = _superblock_layout(cfg)
    x = hint_bsd(x)
    aux = jnp.float32(0)
    new_state = {} if state is not None else None
    di, mi = 0, 0
    for j in range(ap):
        # ---- mixer ---- #
        if j == 0:
            h = rms_norm(p["attn_ln"], x, cfg.norm_eps)
            cache = state["kv"] if state is not None else None
            attn, new_kv = gqa_apply(cfg, p["attn"], h, angles=angles,
                                     cache=cache, cache_index=cache_index)
            if state is not None:
                new_state["kv"] = new_kv
            x = x + attn
        else:
            mp = jax.tree.map(lambda a: a[j - 1], p["mamba"])
            ln = jax.tree.map(lambda a: a[j - 1], p["mamba_ln"])
            h = rms_norm(ln, x, cfg.norm_eps)
            if state is None:
                x = x + mamba_apply(cfg, mp, h)
            else:
                st = {"conv": state["conv"][j - 1], "ssm": state["ssm"][j - 1]}
                y, st2 = mamba_step(cfg, mp, h, st)
                new_state.setdefault("conv", []).append(st2["conv"])
                new_state.setdefault("ssm", []).append(st2["ssm"])
                x = x + y
        # ---- FFN ---- #
        ln = jax.tree.map(lambda a: a[j], p["ffn_ln"])
        h = rms_norm(ln, x, cfg.norm_eps)
        if j in moe_js:
            fp = jax.tree.map(lambda a: a[mi], p["ffn_moe"])
            y, a = moe_apply(cfg, fp, h)
            aux = aux + a
            mi += 1
        else:
            fp = jax.tree.map(lambda a: a[di], p["ffn_dense"])
            y = swiglu(fp, h)
            di += 1
        x = x + y
    if new_state is not None:
        new_state["conv"] = jnp.stack(new_state["conv"])
        new_state["ssm"] = jnp.stack(new_state["ssm"])
    return x, aux, new_state


def init(cfg: ModelConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    nsb = cfg.n_layers // cfg.attn_period
    p = {
        "embed": embedding_init(k1, cfg.vocab, cfg.d_model, cfg.jdtype),
        "blocks": stack_layer_init(
            lambda k: _superblock_init(cfg, k), nsb, k2),
        "ln_f": rms_norm_init(cfg.d_model),
        "head": head_init(k3, cfg.vocab, cfg.d_model, cfg.jdtype),
    }
    return p


def _run(cfg, params, x, angles, states=None, cache_index=None):
    block = functools.partial(_superblock_apply, cfg)
    if cfg.remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, layer_in):
        x, aux = carry
        if states is None:
            x, a, _ = block(layer_in, x, angles=angles)
            return (x, aux + a), None
        p, st = layer_in
        x, a, st2 = block(p, x, angles=angles, state=st,
                          cache_index=cache_index)
        return (x, aux + a), st2

    xs = params["blocks"] if states is None else (params["blocks"], states)
    (x, aux), new_states = jax.lax.scan(body, (x, jnp.float32(0)), xs)
    return x, aux, new_states


def forward(cfg: ModelConfig, params, tokens, positions=None, embeds=None):
    x = embeds if embeds is not None else embed(params["embed"], tokens)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    angles = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    x, aux, _ = _run(cfg, params, x, angles)
    x = rms_norm(params["ln_f"], x, cfg.norm_eps)
    return unembed(params["embed"], params.get("head"), x,
                   cfg.tie_embeddings), aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dt = dtype or cfg.jdtype
    nsb = cfg.n_layers // cfg.attn_period
    ap = cfg.attn_period
    from repro.models.layers.recurrent import _mamba_dims
    di, _, ds, dc = _mamba_dims(cfg)
    return {
        "kv": {"k": jnp.zeros((nsb, batch, max_len, cfg.n_kv_heads,
                               cfg.head_dim), dt),
               "v": jnp.zeros((nsb, batch, max_len, cfg.n_kv_heads,
                               cfg.head_dim), dt)},
        "conv": jnp.zeros((nsb, ap - 1, batch, dc - 1, di), dt),
        "ssm": jnp.zeros((nsb, ap - 1, batch, di, ds), jnp.float32),
    }


def decode_step(cfg: ModelConfig, params, tokens, cache, index,
                positions=None):
    x = embed(params["embed"], tokens)
    b, s = x.shape[:2]
    if positions is None:
        positions = index + jnp.arange(s, dtype=jnp.int32)[None]
        positions = jnp.broadcast_to(positions, (b, s))
    angles = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    x, _, new_states = _run(cfg, params, x, angles, states=cache,
                            cache_index=index)
    x = rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params["embed"], params.get("head"), x,
                     cfg.tie_embeddings)
    return logits, new_states


def prefill(cfg: ModelConfig, params, tokens, cache, positions=None):
    """Prefill is mamba-sequential; for simplicity we run the full forward
    while filling caches via decode-style chunking is left to serve_step
    (prefill uses the cached path with index 0)."""
    return decode_step(cfg, params, tokens, cache, jnp.int32(0), positions)
