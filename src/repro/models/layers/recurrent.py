"""Recurrent sequence mixers: Mamba (selective SSM) and xLSTM (mLSTM/sLSTM).

All three expose the same contract as attention layers:

* ``*_apply(cfg, p, x)``                 — full-sequence (train / prefill),
  chunked so compiled temp memory stays bounded at long context;
* ``*_step(cfg, p, x_t, state)``         — single-token decode with carried
  recurrent state (this is what makes the ``long_500k`` shape sub-quadratic
  and O(1)-state for the hybrid/ssm architectures);
* ``*_init_state(cfg, batch)``           — zero state.

Mamba follows arXiv:2312.00752 (conv → selective SSM → gate); the chunked
scan uses an associative scan within chunks and a carried (d_inner, d_state)
state across chunks — the same blocking the Pallas kernel
(``repro.kernels.mamba_scan``) implements in VMEM.

mLSTM/sLSTM follow arXiv:2405.04517: mLSTM in its chunkwise linear-attention
form with exponential gating (matrix memory C, normalizer n), sLSTM as a
true sequential scan with block-diagonal recurrent weights and the
stabilizer state m.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init
from repro.sharding.hints import hint, hint_bsf
from .basic import rms_norm, rms_norm_init


# ====================================================================== #
# Mamba
# ====================================================================== #
def _mamba_dims(cfg: ModelConfig):
    di = cfg.mamba_expand * cfg.d_model
    dt_rank = max(1, -(-cfg.d_model // 16))
    return di, dt_rank, cfg.mamba_d_state, cfg.mamba_d_conv


def mamba_init(cfg: ModelConfig, key):
    d = cfg.d_model
    di, dtr, ds, dc = _mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    dt = cfg.jdtype
    a = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dt),
        "conv_w": dense_init(ks[1], (dc, di), dt, scale=dc ** -0.5),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * ds), dt),
        "dt_proj": dense_init(ks[3], (dtr, di), dt),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.full((di,), 0.01, jnp.float32))),  # softplus⁻¹(dt_init)
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), dt),
    }


def _mamba_inner(cfg, p, xz, conv_state=None, ssm_state=None):
    """Shared core: xz = (B, S, 2·di) post in_proj.

    Returns (y, new_conv_state, new_ssm_state); states are None unless the
    corresponding input state was provided (decode mode).
    """
    di, dtr, ds, dc = _mamba_dims(cfg)
    xz = hint_bsf(xz)
    x, z = jnp.split(xz, 2, axis=-1)  # (B, S, di)
    b, s, _ = x.shape

    # depthwise causal conv along S
    if conv_state is not None:
        xin = jnp.concatenate([conv_state, x], axis=1)  # (B, dc-1+S, di)
        new_conv = xin[:, -(dc - 1):]
    else:
        xin = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
        new_conv = None
    wins = jnp.stack([xin[:, i:i + s] for i in range(dc)], axis=-1)
    xc = jnp.einsum("bsdc,cd->bsd", wins.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32))
    xc = jax.nn.silu(xc + p["conv_b"].astype(jnp.float32)).astype(x.dtype)

    proj = jnp.einsum("bsd,df->bsf", xc, p["x_proj"])
    dt_in, b_in, c_in = jnp.split(
        proj.astype(jnp.float32), [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_in, p["dt_proj"].astype(jnp.float32))
        + p["dt_bias"])                                  # (B, S, di)
    a = -jnp.exp(p["a_log"])                             # (di, ds)
    ad = jnp.exp(delta[..., None] * a)                   # (B, S, di, ds)
    bx = (delta * xc.astype(jnp.float32))[..., None] * b_in[:, :, None, :]

    chunk = max(1, min(cfg.mamba_chunk, s))
    npad = (-s) % chunk
    if npad:
        ad = jnp.pad(ad, ((0, 0), (0, npad), (0, 0), (0, 0)),
                     constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, npad), (0, 0), (0, 0)))
    nchunks = (s + npad) // chunk
    ad = ad.reshape(b, nchunks, chunk, di, ds)
    bx = bx.reshape(b, nchunks, chunk, di, ds)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, br + ar * bl

    def chunk_body(h, inp):
        ad_c, bx_c = inp  # (B, chunk, di, ds)
        cum_a, cum_b = jax.lax.associative_scan(combine, (ad_c, bx_c), axis=1)
        hs = cum_a * h[:, None] + cum_b                  # (B, chunk, di, ds)
        return hs[:, -1], hs

    h0 = (ssm_state if ssm_state is not None
          else hint(jnp.zeros((b, di, ds), jnp.float32),
                    ("pod", "data"), "model", None))
    h_last, hs = jax.lax.scan(chunk_body, h0,
                              (ad.swapaxes(0, 1), bx.swapaxes(0, 1)))
    hs = hs.swapaxes(0, 1).reshape(b, nchunks * chunk, di, ds)[:, :s]
    y = jnp.einsum("bsdn,bsn->bsd", hs, c_in)            # (B, S, di)
    y = y + p["d_skip"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    new_ssm = h_last if ssm_state is not None else None
    return y, new_conv, new_ssm


def mamba_apply(cfg: ModelConfig, p, x):
    xz = jnp.einsum("bsd,df->bsf", x, p["in_proj"])
    y, _, _ = _mamba_inner(cfg, p, xz)
    return jnp.einsum("bsf,fd->bsd", y, p["out_proj"]).astype(x.dtype)


def mamba_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, _, ds, dc = _mamba_dims(cfg)
    return {"conv": jnp.zeros((batch, dc - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, ds), jnp.float32)}


def mamba_step(cfg: ModelConfig, p, x, state):
    """x: (B, 1, d) single token; state: dict(conv, ssm)."""
    xz = jnp.einsum("bsd,df->bsf", x, p["in_proj"])
    y, new_conv, new_ssm = _mamba_inner(
        cfg, p, xz, conv_state=state["conv"].astype(x.dtype),
        ssm_state=state["ssm"])
    out = jnp.einsum("bsf,fd->bsd", y, p["out_proj"]).astype(x.dtype)
    return out, {"conv": new_conv.astype(state["conv"].dtype),
                 "ssm": new_ssm}


# ====================================================================== #
# mLSTM (chunkwise linear-attention form)
# ====================================================================== #
def _mlstm_dims(cfg: ModelConfig):
    dp = int(cfg.xlstm_proj_factor * cfg.d_model)
    h = cfg.n_heads
    return dp, h, dp // h


def mlstm_init(cfg: ModelConfig, key):
    d = cfg.d_model
    dp, h, dh = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    dt = cfg.jdtype
    return {
        "up": dense_init(ks[0], (d, 2 * dp), dt),
        "wq": dense_init(ks[1], (dp, dp), dt),
        "wk": dense_init(ks[2], (dp, dp), dt),
        "wv": dense_init(ks[3], (dp, dp), dt),
        "wi": dense_init(ks[4], (dp, h), jnp.float32),
        "bi": jnp.zeros((h,), jnp.float32),
        "wf": dense_init(ks[5], (dp, h), jnp.float32),
        "bf": jnp.full((h,), 3.0, jnp.float32),   # forget-gate bias > 0
        "norm": rms_norm_init(dp),
        "down": dense_init(ks[6], (dp, d), dt),
    }


def _mlstm_core(cfg, p, c_in, state):
    """c_in: (B, S, dp).  state: (C, n) or None.  Chunked linear attention
    with scalar-per-head exponential gates (unstabilized form, f32 inner).
    """
    dp, h, dh = _mlstm_dims(cfg)
    b, s, _ = c_in.shape
    q = jnp.einsum("bsd,df->bsf", c_in, p["wq"]).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,df->bsf", c_in, p["wk"]).reshape(b, s, h, dh)
    v = jnp.einsum("bsd,df->bsf", c_in, p["wv"]).reshape(b, s, h, dh)
    q = hint(q, ("pod", "data"), None, None, None)
    k = hint(k, ("pod", "data"), None, None, None)
    v = hint(v, ("pod", "data"), None, None, None)
    q = q.astype(jnp.float32) * dh ** -0.5
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", c_in.astype(jnp.float32), p["wf"])
        + p["bf"])                                     # (B, S, H) ≤ 0
    logi = jnp.minimum(
        jnp.einsum("bsd,dh->bsh", c_in.astype(jnp.float32), p["wi"])
        + p["bi"], 8.0)

    chunk = max(1, min(cfg.xlstm_chunk, s))
    npad = (-s) % chunk
    if npad:
        pad = ((0, 0), (0, npad), (0, 0))
        q = jnp.pad(q, pad + ((0, 0),))
        k = jnp.pad(k, pad + ((0, 0),))
        v = jnp.pad(v, pad + ((0, 0),))
        logf = jnp.pad(logf, pad)
        logi = jnp.pad(logi, pad, constant_values=-1e30)
    nch = (s + npad) // chunk
    shp = (b, nch, chunk, h)
    qc = q.reshape(*shp, dh)
    kc = k.reshape(*shp, dh)
    vc = v.reshape(*shp, dh)
    fc = logf.reshape(shp)
    ic = logi.reshape(shp)

    if state is None:
        c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
    else:
        c0, n0 = state["c"], state["n"]

    def body(carry, inp):
        cmat, nvec = carry
        qx, kx, vx, fx, ix = inp              # (B, chunk, H, ·)
        cf = jnp.cumsum(fx, axis=1)           # (B, chunk, H) inclusive
        # intra-chunk: decay(t, s) = exp(cf_t − cf_s + i_s) for s ≤ t
        dmat = cf[:, :, None, :] - cf[:, None, :, :] + ix[:, None, :, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, -1e30)
        w = jnp.exp(dmat)                     # (B, t, s, H)
        scores = jnp.einsum("bthd,bshd->btsh", qx, kx) * w
        y_intra = jnp.einsum("btsh,bshd->bthd", scores, vx)
        n_intra = jnp.einsum("btsh,bshd->bthd", w, kx)
        # inter-chunk contribution
        decay_t = jnp.exp(cf)                 # (B, chunk, H)
        y_inter = jnp.einsum("bthd,bhde->bthe", qx, cmat) \
            * decay_t[..., None]
        n_inter = jnp.einsum("bthd,bhd->bth", qx, nvec) * decay_t
        n_full = jnp.einsum("bthd,bthd->bth", qx, n_intra) + n_inter
        y = (y_intra + y_inter) / jnp.maximum(jnp.abs(n_full), 1.0)[..., None]
        # state update
        rem = cf[:, -1:, :] - cf + ix         # exp weight to end of chunk
        wk = jnp.exp(rem)[..., None] * kx     # (B, chunk, H, dh)
        cmat = cmat * jnp.exp(cf[:, -1])[..., None, None] \
            + jnp.einsum("bshd,bshe->bhde", wk, vx)
        nvec = nvec * jnp.exp(cf[:, -1])[..., None] + wk.sum(1)
        return (cmat, nvec), y

    (c_f, n_f), ys = jax.lax.scan(
        body, (c0, n0),
        (qc.swapaxes(0, 1), kc.swapaxes(0, 1), vc.swapaxes(0, 1),
         fc.swapaxes(0, 1), ic.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(b, nch * chunk, h, dh)[:, :s]
    return y.reshape(b, s, dp), {"c": c_f, "n": n_f}


def mlstm_apply(cfg: ModelConfig, p, x, state=None, return_state=False):
    dp, h, dh = _mlstm_dims(cfg)
    u = jnp.einsum("bsd,df->bsf", x, p["up"])
    c_in, gate = jnp.split(u, 2, axis=-1)
    y, new_state = _mlstm_core(cfg, p, c_in, state)
    y = rms_norm(p["norm"], y.astype(x.dtype), cfg.norm_eps)
    y = y * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsf,fd->bsd", y, p["down"]).astype(x.dtype)
    if return_state:
        return out, new_state
    return out


def mlstm_init_state(cfg: ModelConfig, batch: int):
    dp, h, dh = _mlstm_dims(cfg)
    return {"c": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32)}


def mlstm_step(cfg: ModelConfig, p, x, state):
    return mlstm_apply(cfg, p, x, state=state, return_state=True)


# ====================================================================== #
# sLSTM (sequential scan, block-diagonal recurrence, stabilized exp gates)
# ====================================================================== #
def slstm_init(cfg: ModelConfig, key):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 3)
    dt = cfg.jdtype
    return {
        "w": dense_init(ks[0], (d, 4 * d), dt),           # z i f o
        "r": dense_init(ks[1], (h, dh, 4 * dh), jnp.float32),
        "b": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                              jnp.full((d,), 3.0, jnp.float32),
                              jnp.zeros((d,), jnp.float32)]),
        "out": dense_init(ks[2], (d, d), dt),
    }


def slstm_init_state(cfg: ModelConfig, batch: int):
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z,
            "m": jnp.zeros((batch, h, dh), jnp.float32)}


def _slstm_cell(cfg, p, wx_t, st):
    """One recurrence step.  wx_t: (B, 4d) precomputed input projection."""
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    b = wx_t.shape[0]
    rh = jnp.einsum("bhd,hdf->bhf", st["h"], p["r"])      # (B, H, 4dh)
    # wx packs (z i f o) in four d-wide blocks; rebuild per head
    wx = wx_t.reshape(b, 4, d).transpose(0, 2, 1)          # (B, d, 4)
    wx = wx.reshape(b, h, dh, 4)
    rr = rh.reshape(b, h, dh, 4)
    pre = wx + rr + p["b"].reshape(4, d).T.reshape(h, dh, 4)
    z_t = jnp.tanh(pre[..., 0])
    i_t = pre[..., 1]
    f_t = pre[..., 2]
    o_t = jax.nn.sigmoid(pre[..., 3])
    logf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(logf + st["m"], i_t)
    i_s = jnp.exp(i_t - m_new)
    f_s = jnp.exp(logf + st["m"] - m_new)
    c_new = f_s * st["c"] + i_s * z_t
    n_new = f_s * st["n"] + i_s
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_apply(cfg: ModelConfig, p, x, state=None, return_state=False):
    b, s, d = x.shape
    wx = jnp.einsum("bsd,df->bsf", x, p["w"]).astype(jnp.float32)
    st = state if state is not None else slstm_init_state(cfg, b)

    def body(st, wx_t):
        st = _slstm_cell(cfg, p, wx_t, st)
        return st, st["h"]

    st, hs = jax.lax.scan(body, st, wx.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(b, s, d).astype(x.dtype)
    out = jnp.einsum("bsd,df->bsf", y, p["out"]).astype(x.dtype)
    if return_state:
        return out, st
    return out


def slstm_step(cfg: ModelConfig, p, x, state):
    return slstm_apply(cfg, p, x, state=state, return_state=True)
