"""Norms, embeddings, and dense projections (pure-function pytrees)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def rms_norm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p, x, eps: float = 1e-5):
    # stats in fp32, but the normalized activation never materializes in
    # fp32 — (B,S,D) stays in model dtype (§Perf iteration B2: cuts the
    # per-layer norm HBM round-trips roughly in half)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * p["scale"].astype(x.dtype)


def layer_norm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(dt)


def embedding_init(key, vocab: int, d: int, dtype):
    return {"table": dense_init(key, (vocab, d), dtype, scale=1.0)}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p_emb, p_head, x, tie: bool):
    """Project to vocabulary logits (optionally tied to the embedding)."""
    w = p_emb["table"] if tie else p_head["w"]
    return jnp.einsum("...d,vd->...v", x, w,
                      preferred_element_type=jnp.float32)


def head_init(key, vocab: int, d: int, dtype):
    return {"w": dense_init(key, (vocab, d), dtype)}


def linear_init(key, d_in: int, d_out: int, dtype, bias: bool = False):
    p = {"w": dense_init(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = jnp.einsum("...d,df->...f", x, p["w"]).astype(x.dtype)
    if "b" in p:
        y = y + p["b"]
    return y
