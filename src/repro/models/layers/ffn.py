"""FFN layers: SwiGLU, GELU MLP, and scatter-based top-k MoE.

The MoE dispatch avoids GShard's O(T·E·C) one-hot tensors: token→expert
assignment is materialized as (expert, position) indices and moved with
`.at[].add` scatters / `take` gathers, both of which XLA SPMD turns into the
expert-parallel all-to-all this paper's ICI scheduler targets.  Capacity is
``ceil(T/E · topk · capacity_factor)``; overflow tokens are dropped (their
combine weight is zero), standard for capacity-based MoE.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init
from repro.sharding.hints import hint_bsf, hint_expert


def swiglu_init(cfg: ModelConfig, key, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.jdtype
    return {
        "w_gate": dense_init(ks[0], (d, f), dt),
        "w_up": dense_init(ks[1], (d, f), dt),
        "w_down": dense_init(ks[2], (f, d), dt),
    }


def swiglu(p, x):
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
         ).astype(x.dtype)
    if h.ndim == 3:
        h = hint_bsf(h)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


def gelu_mlp_init(cfg: ModelConfig, key, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 2)
    dt = cfg.jdtype
    return {"w_in": dense_init(ks[0], (d, f), dt),
            "b_in": jnp.zeros((f,), dt),
            "w_out": dense_init(ks[1], (f, d), dt),
            "b_out": jnp.zeros((d,), dt)}


def gelu_mlp(p, x):
    h = jnp.einsum("...d,df->...f", x, p["w_in"]) + p["b_in"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    if h.ndim == 3:
        h = hint_bsf(h)
    return jnp.einsum("...f,fd->...d", h, p["w_out"]) + p["b_out"]


# ---------------------------------------------------------------------- #
# MoE
# ---------------------------------------------------------------------- #
def moe_init(cfg: ModelConfig, key):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    ep = max(cfg.moe_pad_to, e) if cfg.moe_pad_to else e
    ks = jax.random.split(key, 5)
    dt = cfg.jdtype
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": dense_init(ks[1], (ep, d, f), dt),
        "w_up": dense_init(ks[2], (ep, d, f), dt),
        "w_down": dense_init(ks[3], (ep, f, d), dt),
    }
    if cfg.moe_shared > 0:
        p["shared"] = swiglu_init(cfg, ks[4], d_ff=cfg.moe_shared * f)
    return p


def moe_apply(cfg: ModelConfig, p, x):
    """x: (B, S, d) → (y, aux_loss).

    ``moe_pad_to`` (§Perf iteration A2): dummy experts pad E up to an
    EP-divisible count — the router never selects them, but the expert
    buffers become evenly shardable over the model axis, turning the
    gather/all-reduce storm of ragged expert-TP into one clean all-to-all.
    """
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_topk
    e_buf = max(cfg.moe_pad_to, e) if cfg.moe_pad_to else e
    t = b * s
    xt = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, k)            # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch/GShard)
    me = probs.mean(0)                                       # (E,)
    one_hot = jax.nn.one_hot(experts, e, dtype=jnp.float32)  # (T, k, E)
    ce = one_hot.sum(1).mean(0)                              # fraction routed
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce)

    capacity = int(max(1, -(-t * k // e)) * cfg.capacity_factor)
    e = e_buf  # buffers/compute below use the (padded) expert count
    # position of each (token, slot) within its expert queue
    flat_exp = experts.reshape(-1)                           # (T*k,)
    eoh = jax.nn.one_hot(flat_exp, e, dtype=jnp.int32)       # (T*k, E)
    pos = (jnp.cumsum(eoh, axis=0) - 1)                      # (T*k, E)
    pos = jnp.take_along_axis(pos, flat_exp[:, None], 1)[:, 0]
    keep = pos < capacity
    slot = flat_exp * capacity + pos                         # (T*k,)
    slot = jnp.where(keep, slot, e * capacity)               # drop overflow

    buf = jnp.zeros((e * capacity, d), xt.dtype)
    src = jnp.repeat(xt, k, axis=0) if k > 1 else xt
    buf = buf.at[slot].add(src, mode="drop")
    buf = buf.reshape(e, capacity, d)
    buf = hint_expert(buf)

    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
         ).astype(xt.dtype)
    h = hint_expert(h)
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out = out.reshape(e * capacity, d)

    gathered = jnp.take(out, jnp.minimum(slot, e * capacity - 1), axis=0)
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = (gathered.reshape(t, k, d)
         * gate_vals[..., None].astype(xt.dtype)).sum(1)

    if cfg.moe_shared > 0:
        y = y + swiglu(p["shared"], xt)
    return y.reshape(b, s, d), aux
