"""Rotary position embeddings — standard RoPE and Qwen2-VL's M-RoPE.

M-RoPE (arXiv:2409.12191) splits the head-dim rotary pairs into sections
driven by (temporal, height, width) position ids; text tokens use identical
t/h/w ids, so M-RoPE degenerates to RoPE on pure text.
"""

from __future__ import annotations

import jax.numpy as jnp


def _freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def rope_angles(positions, head_dim: int, theta: float):
    """positions: (..., S) int → angles (..., S, head_dim/2)."""
    return positions[..., None].astype(jnp.float32) * _freqs(head_dim, theta)


def mrope_angles(positions_thw, head_dim: int, theta: float,
                 sections: tuple[int, ...]):
    """positions_thw: (3, B, S) → angles (B, S, head_dim/2).

    ``sections`` gives the number of rotary *pairs* driven by each of
    t/h/w (must sum to head_dim/2).
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    freqs = _freqs(head_dim, theta)  # (head_dim/2,)
    ang = positions_thw[..., None].astype(jnp.float32) * freqs  # (3,B,S,hd/2)
    parts = []
    off = 0
    for i, sec in enumerate(sections):
        parts.append(ang[i, ..., off:off + sec])
        off += sec
    return jnp.concatenate(parts, axis=-1)


def apply_rope(x, angles):
    """x: (B, S, H, D); angles: (B, S, D/2) or (S, D/2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if angles.ndim == 2:
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(dt)
