"""Attention: chunked flash-style reference + GQA / MLA / cross layers.

``flash_attention_ref`` is the memory-bounded pure-jnp implementation used
everywhere by default: it scans over (q-chunk, kv-chunk) block pairs with an
online softmax, materializing only chunk-sized score blocks.  For causal
attention the pair list is *triangular*, so the compiled HLO carries the
exact causal FLOP count (no rectangular-mask waste) — this keeps the
roofline's MODEL_FLOPS / HLO_FLOPS ratio honest and bounds compile-time temp
memory at 32k-token prefill.  It is also the oracle for the Pallas flash
kernel (``repro.kernels.flash_attention``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig, dense_init
from repro.sharding.hints import hint, hint_bshd, BATCH
from .basic import rms_norm, rms_norm_init
from .rope import apply_rope, rope_angles

NEG_INF = -1e30


def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention_ref(q, k, v, *, causal: bool,
                        q_chunk: int = 512, kv_chunk: int = 512,
                        bias_mask_len=None, scale: float | None = None,
                        return_lse: bool = False):
    """Chunked online-softmax attention.

    Args:
      q: (B, Sq, H, Dk).  k: (B, Skv, KV, Dk).  v: (B, Skv, KV, Dv).
        H must be a multiple of KV (GQA); H == KV is MHA.
      causal: lower-triangular masking (assumes Sq == Skv alignment at the
        *end*: query i attends keys ≤ i + (Skv − Sq)).
      bias_mask_len: optional valid-key lengths — (B,) per batch row, or
        (B, Sq) per query (used for causal prefill into a partially filled
        KV cache: query t sees keys < len[b, t]).
      scale: defaults to Dk^-1/2.

    Returns: (B, Sq, H, Dv) in q.dtype.
    """
    b, sq, h, dk = q.shape
    _, skv, kv, dv = v.shape
    g = h // kv
    scale = dk ** -0.5 if scale is None else scale
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    nq = -(-sq // qc)
    nk = -(-skv // kc)
    sq_p, skv_p = nq * qc, nk * kc
    offset = skv - sq  # causal diagonal offset
    qp = _pad_to(q, sq_p, 1).reshape(b, nq, qc, kv, g, dk)
    kp = _pad_to(k, skv_p, 1).reshape(b, nk, kc, kv, dk)
    vp = _pad_to(v, skv_p, 1).reshape(b, nk, kc, kv, dv)

    if causal:
        pairs = np.array([(i, j) for i in range(nq)
                          for j in range(nk)
                          if j * kc <= i * qc + offset + qc - 1],
                         np.int32)
    else:
        pairs = np.array([(i, j) for i in range(nq) for j in range(nk)],
                         np.int32)

    acc = hint(jnp.zeros((b, nq, qc, kv, g, dv), jnp.float32),
               BATCH, None, None, "model", None, None)
    m = hint(jnp.full((b, nq, qc, kv, g), NEG_INF, jnp.float32),
             BATCH, None, None, "model", None)
    l = hint(jnp.zeros((b, nq, qc, kv, g), jnp.float32),
             BATCH, None, None, "model", None)
    q_pos = jnp.arange(qc)
    k_pos = jnp.arange(kc)
    mask2d = None
    if bias_mask_len is not None and bias_mask_len.ndim == 2:
        mask2d = _pad_to(bias_mask_len, sq_p, 1).reshape(b, nq, qc)

    def body(carry, pair):
        acc, m, l = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qp, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kp, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vp, j, 1, keepdims=False)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qi.astype(jnp.float32),
                       kj.astype(jnp.float32)) * scale
        # masks: causal, key padding, cache length
        kabs = j * kc + k_pos  # (kc,)
        neg = jnp.float32(NEG_INF)
        if causal:
            qabs = i * qc + q_pos + offset
            s = jnp.where(kabs[None, None, None, None, :]
                          <= qabs[None, :, None, None, None], s, neg)
        s = jnp.where(kabs[None, None, None, None, :] < skv, s, neg)
        if bias_mask_len is not None:
            if mask2d is None:
                ml = bias_mask_len[:, None, None, None, None]
            else:
                ml = jax.lax.dynamic_index_in_dim(
                    mask2d, i, 1, keepdims=False)[:, :, None, None, None]
            s = jnp.where(kabs[None, None, None, None, :] < ml, s, neg)
        mi = jax.lax.dynamic_index_in_dim(m, i, 1, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 1, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(acc, i, 1, keepdims=False)
        m_new = jnp.maximum(mi, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + p.sum(-1)
        a_new = ai * corr[..., None] + jnp.einsum(
            "bqkgs,bskd->bqkgd", p, vj.astype(jnp.float32))
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 1)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(body, (acc, m, l), jnp.asarray(pairs))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.reshape(b, sq_p, h, dv)[:, :sq].astype(q.dtype)
    if return_lse:
        lse = (m + jnp.log(jnp.maximum(l, 1e-30)))
        lse = lse.reshape(b, sq_p, kv, g)[:, :sq]
        return out, lse
    return out


def _flash_fwd_lse(q, k, v, *, causal, q_chunk, kv_chunk, bias_mask_len):
    """Forward that also returns the log-sum-exp (flash backward residual).

    Mirrors :func:`flash_attention_ref` but keeps (m, l) to form
    ``lse = m + log l`` — the only O(S) residual the backward needs.
    """
    out = flash_attention_ref(q, k, v, causal=causal, q_chunk=q_chunk,
                              kv_chunk=kv_chunk,
                              bias_mask_len=bias_mask_len,
                              return_lse=True)
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_attention(q, k, v, causal, q_chunk, kv_chunk):
    return flash_attention_ref(q, k, v, causal=causal, q_chunk=q_chunk,
                               kv_chunk=kv_chunk)


def _flash_attn_fwd(q, k, v, causal, q_chunk, kv_chunk):
    out, lse = _flash_fwd_lse(q, k, v, causal=causal, q_chunk=q_chunk,
                              kv_chunk=kv_chunk, bias_mask_len=None)
    return out, (q, k, v, out, lse)


def _flash_attn_bwd(causal, q_chunk, kv_chunk, res, dout):
    """True flash backward: recompute score blocks per (q, kv) chunk pair;
    residual memory is O(B·S·H) for the lse instead of O(steps × acc)."""
    q, k, v, out, lse = res
    b, sq, h, dk = q.shape
    _, skv, kv, dv = v.shape
    g = h // kv
    scale = dk ** -0.5
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    nq = -(-sq // qc)
    nk = -(-skv // kc)
    sq_p, skv_p = nq * qc, nk * kc
    offset = skv - sq
    f32 = jnp.float32
    qp = _pad_to(q, sq_p, 1).reshape(b, nq, qc, kv, g, dk).astype(f32)
    kp = _pad_to(k, skv_p, 1).reshape(b, nk, kc, kv, dk).astype(f32)
    vp = _pad_to(v, skv_p, 1).reshape(b, nk, kc, kv, dv).astype(f32)
    dop = _pad_to(dout, sq_p, 1).reshape(b, nq, qc, kv, g, dv).astype(f32)
    op = _pad_to(out, sq_p, 1).reshape(b, nq, qc, kv, g, dv).astype(f32)
    lsep = _pad_to(lse, sq_p, 1).reshape(b, nq, qc, kv, g)
    # D = rowsum(dout ⊙ out)
    dmat = (dop * op).sum(-1)  # (b, nq, qc, kv, g)

    if causal:
        pairs = np.array([(i, j) for i in range(nq) for j in range(nk)
                          if j * kc <= i * qc + offset + qc - 1], np.int32)
    else:
        pairs = np.array([(i, j) for i in range(nq) for j in range(nk)],
                         np.int32)

    dq = hint(jnp.zeros_like(qp), BATCH, None, None, "model", None, None)
    dk_ = hint(jnp.zeros_like(kp), BATCH, None, None, "model", None)
    dv_ = hint(jnp.zeros_like(vp), BATCH, None, None, "model", None)
    q_pos = jnp.arange(qc)
    k_pos = jnp.arange(kc)

    def body(carry, pair):
        dq, dk_, dv_ = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qp, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kp, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vp, j, 1, keepdims=False)
        doi = jax.lax.dynamic_index_in_dim(dop, i, 1, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(lsep, i, 1, keepdims=False)
        di = jax.lax.dynamic_index_in_dim(dmat, i, 1, keepdims=False)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qi, kj) * scale
        kabs = j * kc + k_pos
        neg = jnp.float32(NEG_INF)
        if causal:
            qabs = i * qc + q_pos + offset
            s = jnp.where(kabs[None, None, None, None, :]
                          <= qabs[None, :, None, None, None], s, neg)
        s = jnp.where(kabs[None, None, None, None, :] < skv, s, neg)
        p = jnp.exp(s - li[..., None])                 # (b,q,k,g,s)
        dvj = jnp.einsum("bqkgs,bqkgd->bskd", p, doi)
        dp = jnp.einsum("bqkgd,bskd->bqkgs", doi, vj)
        ds = p * (dp - di[..., None]) * scale
        dqi = jnp.einsum("bqkgs,bskd->bqkgd", ds, kj)
        dkj = jnp.einsum("bqkgs,bqkgd->bskd", ds, qi)
        dq = dq.at[:, i].add(dqi)
        dk_ = dk_.at[:, j].add(dkj)
        dv_ = dv_.at[:, j].add(dvj)
        return (dq, dk_, dv_), None

    (dq, dk_, dv_), _ = jax.lax.scan(body, (dq, dk_, dv_),
                                     jnp.asarray(pairs))
    dq = dq.reshape(b, sq_p, h, dk)[:, :sq].astype(q.dtype)
    dk_ = dk_.reshape(b, skv_p, kv, dk)[:, :skv].astype(k.dtype)
    dv_ = dv_.reshape(b, skv_p, kv, dv)[:, :skv].astype(v.dtype)
    return dq, dk_, dv_


_flash_attention.defvjp(_flash_attn_fwd, _flash_attn_bwd)


def attention_op(cfg: ModelConfig, q, k, v, *, causal, mask_len=None):
    """Dispatch: Pallas flash kernel on TPU, chunked reference otherwise.

    The no-mask path (training) goes through the custom-VJP flash
    implementation — O(B·S·H) residuals; the masked paths (serving) never
    differentiate, so they use the plain reference.
    """
    if cfg.use_pallas:
        from repro.kernels.flash_attention import ops as _fops
        return _fops.flash_attention(q, k, v, causal=causal,
                                     mask_len=mask_len)
    if mask_len is None:
        return _flash_attention(q, k, v, causal, cfg.attn_q_chunk,
                                cfg.attn_kv_chunk)
    return flash_attention_ref(q, k, v, causal=causal,
                               q_chunk=cfg.attn_q_chunk,
                               kv_chunk=cfg.attn_kv_chunk,
                               bias_mask_len=mask_len)


# ---------------------------------------------------------------------- #
# GQA attention layer
# ---------------------------------------------------------------------- #
def gqa_init(cfg: ModelConfig, key):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = cfg.jdtype
    return {
        "wq": dense_init(ks[0], (d, h * hd), dt),
        "wk": dense_init(ks[1], (d, kv * hd), dt),
        "wv": dense_init(ks[2], (d, kv * hd), dt),
        "wo": dense_init(ks[3], (h * hd, d), dt),
    }


def gqa_apply(cfg: ModelConfig, p, x, *, angles, causal=True,
              cache=None, cache_index=None):
    """x: (B, S, d).  ``cache``: optional dict(k, v, len) for decoding —
    new K/V are written at ``cache_index`` and attention runs over the
    cache; returns (out, new_cache)."""
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,df->bsf", x, p["wq"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,df->bsf", x, p["wk"]).reshape(b, s, kv, hd)
    v = jnp.einsum("bsd,df->bsf", x, p["wv"]).reshape(b, s, kv, hd)
    q, k, v = hint_bshd(q), hint_bshd(k), hint_bshd(v)
    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    new_cache = None
    if cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1)
        k, v = ck, cv
        # query t may see cache prefix + in-chunk keys ≤ its own position
        mask_len = cache_index + jnp.arange(s, dtype=jnp.int32)[None] + 1
        mask_len = jnp.broadcast_to(mask_len, (b, s))
        out = attention_op(cfg, q, k.astype(q.dtype), v.astype(q.dtype),
                           causal=False, mask_len=mask_len)
        new_cache = {"k": ck, "v": cv}
    else:
        out = attention_op(cfg, q, k, v, causal=causal)
    out = jnp.einsum("bsf,fd->bsd", out.reshape(b, s, h * hd), p["wo"])
    return out.astype(x.dtype), new_cache


# ---------------------------------------------------------------------- #
# cross attention (enc-dec)
# ---------------------------------------------------------------------- #
def cross_init(cfg: ModelConfig, key):
    return gqa_init(cfg, key)


def cross_apply(cfg: ModelConfig, p, x, enc_kv):
    """enc_kv: dict(k, v) precomputed from the encoder output."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = jnp.einsum("bsd,df->bsf", x, p["wq"]).reshape(b, s, h, hd)
    out = attention_op(cfg, q, enc_kv["k"], enc_kv["v"], causal=False)
    out = jnp.einsum("bsf,fd->bsd", out.reshape(b, s, h * hd), p["wo"])
    return out.astype(x.dtype)


def cross_kv(cfg: ModelConfig, p, enc_out):
    b, se, d = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    k = jnp.einsum("bsd,df->bsf", enc_out, p["wk"]).reshape(b, se, kv, hd)
    v = jnp.einsum("bsd,df->bsf", enc_out, p["wv"]).reshape(b, se, kv, hd)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------- #
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------- #
def mla_init(cfg: ModelConfig, key):
    d, h = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dvh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    dt = cfg.jdtype
    return {
        "q_down": dense_init(ks[0], (d, qr), dt),
        "q_norm": rms_norm_init(qr),
        "q_up": dense_init(ks[1], (qr, h * (dn + dr)), dt),
        "kv_down": dense_init(ks[2], (d, kvr + dr), dt),
        "kv_norm": rms_norm_init(kvr),
        "kv_up": dense_init(ks[3], (kvr, h * (dn + dvh)), dt),
        "wo": dense_init(ks[4], (h * dvh, d), dt),
    }


def mla_apply(cfg: ModelConfig, p, x, *, positions, causal=True,
              cache=None, cache_index=None):
    """MLA with compressed-latent KV cache: the cache stores only
    (c_kv, k_rope) — ``kv_lora_rank + qk_rope_dim`` per token (§MiniCPM3).
    """
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dvh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    q = jnp.einsum("bsd,dr->bsr", x, p["q_down"])
    q = rms_norm(p["q_norm"], q, cfg.norm_eps)
    q = jnp.einsum("bsr,rf->bsf", q, p["q_up"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    ang = rope_angles(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, ang)

    ckv = jnp.einsum("bsd,dr->bsr", x, p["kv_down"])
    c_kv, k_rope = ckv[..., :kvr], ckv[..., kvr:]
    c_kv = rms_norm(p["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], ang)[:, :, 0]

    mask_len = None
    if cache is not None:
        c_kv = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), cache_index, 1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
            cache_index, 1)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        mask_len = cache_index + jnp.arange(s, dtype=jnp.int32)[None] + 1
        mask_len = jnp.broadcast_to(mask_len, (b, s))
        causal = False
    else:
        new_cache = None

    # expand latents → per-head keys/values (absorbed-matmul variant is the
    # documented §Perf optimization; this is the reference expansion)
    skv = c_kv.shape[1]
    kvu = jnp.einsum("bsr,rf->bsf", c_kv.astype(x.dtype),
                     p["kv_up"]).reshape(b, skv, h, dn + dvh)
    k_nope, v = kvu[..., :dn], kvu[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :].astype(x.dtype),
                                  (b, skv, h, dr))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = attention_op(cfg, q_full, k, v, causal=causal, mask_len=mask_len)
    out = jnp.einsum("bsf,fd->bsd", out.reshape(b, s, h * dvh), p["wo"])
    return out.astype(x.dtype), new_cache
