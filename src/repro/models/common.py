"""Model configuration and shared utilities for the architecture zoo.

One frozen dataclass covers all 10 assigned architectures; family-specific
fields are simply unused elsewhere.  Models are pure-function pytrees:
``init(cfg, key) -> params`` and ``forward(cfg, params, batch) -> logits``,
with repeated layers stacked on a leading axis and driven by ``lax.scan``
(keeps HLO size and 512-device compile times sane).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 ⇒ d_model // n_heads

    # --- MoE ----------------------------------------------------------- #
    moe_experts: int = 0
    moe_topk: int = 0
    moe_shared: int = 0            # always-on shared experts (qwen2-moe)
    moe_pad_to: int = 0            # pad expert dim (dummy experts) for EP
    moe_period: int = 1            # every k-th layer is MoE (jamba: 2)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- MLA (minicpm3) ------------------------------------------------- #
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- hybrid (jamba): 1 attention layer per ``attn_period`` ---------- #
    attn_period: int = 0           # 0 ⇒ pure attention stack
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # --- ssm (xlstm): 1 sLSTM block per ``slstm_period`` ---------------- #
    slstm_period: int = 0          # 0 ⇒ no sLSTM blocks
    xlstm_proj_factor: float = 2.0

    # --- enc-dec (whisper) ---------------------------------------------- #
    enc_layers: int = 0
    enc_seq: int = 1500            # encoder frames (stub frontend output)

    # --- vlm (qwen2-vl) -------------------------------------------------- #
    mrope_sections: tuple[int, ...] = ()

    # --- common ---------------------------------------------------------- #
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    use_pallas: bool = False       # Pallas TPU kernels (ref path if False)
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 512
    mamba_chunk: int = 64
    xlstm_chunk: int = 64

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------------ #
    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    def layer_is_moe(self, idx: int) -> bool:
        return self.is_moe and (idx % self.moe_period == self.moe_period - 1)

    def layer_is_attn(self, idx: int) -> bool:
        """Hybrid stacks: layer 0 of every ``attn_period`` group is attn."""
        if self.attn_period == 0:
            return True
        return idx % self.attn_period == 0

    def layer_is_slstm(self, idx: int) -> bool:
        return self.slstm_period > 0 and idx % self.slstm_period == 0

    # ------------------------------------------------------------------ #
    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline MODEL_FLOPS)."""
        from repro.models.registry import count_params  # lazy, avoids cycle
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params
        return count_params(self, active_only=True)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------- #
# init helpers
# ---------------------------------------------------------------------- #
def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * std).astype(dtype)


def stack_layer_init(init_fn, n: int, key):
    """vmap an ``init_fn(key) -> params`` across ``n`` stacked layers."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def take_layer(stacked: Params, idx):
    """Slice layer ``idx`` out of a stacked-params pytree (scan body)."""
    return jax.tree.map(lambda x: x[idx], stacked)


def param_bytes(params: Params) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree.leaves(params))


def param_count_tree(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
