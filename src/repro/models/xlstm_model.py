"""xLSTM language model (arXiv:2405.04517): mLSTM blocks with periodic
sLSTM blocks (xLSTM[7:1] → ``slstm_period = 8``).

``d_ff = 0`` in the assigned config: mLSTM blocks carry their own 2×
up/down projection; sLSTM blocks are followed by a GLU FFN with projection
factor 4/3 (paper's post-up structure).  Super-blocks of ``slstm_period``
layers (1 sLSTM + 7 mLSTM) are scanned.

Decode state: per mLSTM layer a (C: H×dh×dh, n: H×dh) matrix memory; per
sLSTM layer (c, n, h, m) — all O(1) in sequence length (licenses
``long_500k``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, stack_layer_init
from repro.models.layers.basic import (
    embed, embedding_init, head_init, rms_norm, rms_norm_init, unembed)
from repro.models.layers.ffn import swiglu, swiglu_init
from repro.sharding.hints import hint_bsd
from repro.models.layers.recurrent import (
    mlstm_apply, mlstm_init, mlstm_init_state, mlstm_step,
    slstm_apply, slstm_init, slstm_init_state, slstm_step)


def _layout(cfg: ModelConfig):
    sp = cfg.slstm_period if cfg.slstm_period > 0 else cfg.n_layers
    assert cfg.n_layers % sp == 0
    return sp


def _superblock_init(cfg: ModelConfig, key):
    sp = _layout(cfg)
    ks = jax.random.split(key, 4)
    return {
        "slstm": slstm_init(cfg, ks[0]),
        "slstm_ln": rms_norm_init(cfg.d_model),
        "slstm_ffn": swiglu_init(cfg, ks[1], d_ff=int(cfg.d_model * 4 / 3)),
        "slstm_ffn_ln": rms_norm_init(cfg.d_model),
        "mlstm": stack_layer_init(lambda k: mlstm_init(cfg, k), sp - 1, ks[2]),
        "mlstm_ln": stack_layer_init(
            lambda k: rms_norm_init(cfg.d_model), sp - 1, ks[3]),
    }


def _superblock_apply(cfg, p, x, state=None):
    sp = _layout(cfg)
    x = hint_bsd(x)
    new_state = {} if state is not None else None
    # sLSTM at position 0
    h = rms_norm(p["slstm_ln"], x, cfg.norm_eps)
    if state is None:
        x = x + slstm_apply(cfg, p["slstm"], h)
    else:
        y, st = slstm_step(cfg, p["slstm"], h, state["slstm"])
        new_state["slstm"] = st
        x = x + y
    h = rms_norm(p["slstm_ffn_ln"], x, cfg.norm_eps)
    x = x + swiglu(p["slstm_ffn"], h)
    # mLSTM blocks
    ms = []
    for j in range(sp - 1):
        mp = jax.tree.map(lambda a: a[j], p["mlstm"])
        ln = jax.tree.map(lambda a: a[j], p["mlstm_ln"])
        h = rms_norm(ln, x, cfg.norm_eps)
        if state is None:
            x = x + mlstm_apply(cfg, mp, h)
        else:
            st = jax.tree.map(lambda a: a[j], state["mlstm"])
            y, st2 = mlstm_step(cfg, mp, h, st)
            ms.append(st2)
            x = x + y
    if state is not None:
        new_state["mlstm"] = jax.tree.map(lambda *xs: jnp.stack(xs), *ms)
    return x, new_state


def init(cfg: ModelConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    nsb = cfg.n_layers // _layout(cfg)
    return {
        "embed": embedding_init(k1, cfg.vocab, cfg.d_model, cfg.jdtype),
        "blocks": stack_layer_init(
            lambda k: _superblock_init(cfg, k), nsb, k2),
        "ln_f": rms_norm_init(cfg.d_model),
        "head": head_init(k3, cfg.vocab, cfg.d_model, cfg.jdtype),
    }


def _run(cfg, params, x, states=None):
    block = functools.partial(_superblock_apply, cfg)
    if cfg.remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)

    def body(x, layer_in):
        if states is None:
            x, _ = block(layer_in, x)
            return x, None
        p, st = layer_in
        x, st2 = block(p, x, state=st)
        return x, st2

    xs = params["blocks"] if states is None else (params["blocks"], states)
    x, new_states = jax.lax.scan(body, x, xs)
    return x, new_states


def forward(cfg: ModelConfig, params, tokens, positions=None, embeds=None):
    x = embeds if embeds is not None else embed(params["embed"], tokens)
    x, _ = _run(cfg, params, x)
    x = rms_norm(params["ln_f"], x, cfg.norm_eps)
    return unembed(params["embed"], params.get("head"), x,
                   cfg.tie_embeddings), jnp.float32(0)


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0, dtype=None):
    """Recurrent state only — no sequence-length dimension at all."""
    sp = _layout(cfg)
    nsb = cfg.n_layers // sp
    sl = slstm_init_state(cfg, batch)
    ml = mlstm_init_state(cfg, batch)
    return {
        "slstm": jax.tree.map(lambda a: jnp.tile(a[None], (nsb,) + (1,) * a.ndim), sl),
        "mlstm": jax.tree.map(
            lambda a: jnp.tile(a[None, None], (nsb, sp - 1) + (1,) * a.ndim), ml),
    }


def decode_step(cfg: ModelConfig, params, tokens, cache, index,
                positions=None):
    x = embed(params["embed"], tokens)
    x, new_states = _run(cfg, params, x, states=cache)
    x = rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params["embed"], params.get("head"), x,
                     cfg.tie_embeddings)
    return logits, new_states


def prefill(cfg: ModelConfig, params, tokens, cache, positions=None):
    return decode_step(cfg, params, tokens, cache, jnp.int32(0), positions)
