"""Decoder-only LM covering the dense / MoE / MLA / VLM families.

Layers are homogeneous, stacked on a leading axis and driven by
``lax.scan`` (+ optional ``jax.checkpoint`` remat per block).  The VLM
(qwen2-vl) variant differs only in position handling (M-RoPE ids supplied by
the stub frontend) and is selected by ``cfg.mrope_sections``.

API:
  init(cfg, key) -> params
  forward(cfg, params, tokens, positions=None, embeds=None) -> (logits, aux)
  init_cache(cfg, batch, max_len) -> cache pytree
  prefill(cfg, params, tokens, cache, positions=None) -> (logits, cache)
  decode_step(cfg, params, tokens, cache, index, positions=None)
      -> (logits, cache)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, stack_layer_init
from repro.models.layers.basic import (
    embed, embedding_init, head_init, rms_norm, rms_norm_init, unembed)
from repro.models.layers.attention import gqa_apply, gqa_init, mla_apply, mla_init
from repro.models.layers.ffn import moe_apply, moe_init, swiglu, swiglu_init
from repro.models.layers.rope import mrope_angles, rope_angles
from repro.sharding.hints import hint_bsd


def _uses_moe(cfg: ModelConfig) -> bool:
    return cfg.is_moe and cfg.moe_period == 1


def _block_init(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    p = {"ln1": rms_norm_init(cfg.d_model), "ln2": rms_norm_init(cfg.d_model)}
    p["attn"] = mla_init(cfg, k1) if cfg.mla else gqa_init(cfg, k1)
    p["ffn"] = moe_init(cfg, k2) if _uses_moe(cfg) else swiglu_init(cfg, k2)
    return p


def _block_apply(cfg: ModelConfig, p, x, *, angles, positions,
                 cache=None, cache_index=None):
    x = hint_bsd(x)
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    if cfg.mla:
        attn, new_cache = mla_apply(cfg, p["attn"], h, positions=positions,
                                    cache=cache, cache_index=cache_index)
    else:
        attn, new_cache = gqa_apply(cfg, p["attn"], h, angles=angles,
                                    cache=cache, cache_index=cache_index)
    x = x + attn
    h = rms_norm(p["ln2"], x, cfg.norm_eps)
    if _uses_moe(cfg):
        y, aux = moe_apply(cfg, p["ffn"], h)
    else:
        y, aux = swiglu(p["ffn"], h), jnp.float32(0)
    return x + y, new_cache, aux


def init(cfg: ModelConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "embed": embedding_init(k1, cfg.vocab, cfg.d_model, cfg.jdtype),
        "blocks": stack_layer_init(
            lambda k: _block_init(cfg, k), cfg.n_layers, k2),
        "ln_f": rms_norm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = head_init(k3, cfg.vocab, cfg.d_model, cfg.jdtype)
    return p


def _angles_for(cfg: ModelConfig, positions):
    """positions: (B, S) int or (3, B, S) for M-RoPE."""
    if cfg.mla:
        return None  # MLA applies rope internally on its rope sub-dims
    if cfg.mrope_sections:
        assert positions.ndim == 3, "vlm needs (3, B, S) position ids"
        return mrope_angles(positions, cfg.head_dim, cfg.rope_theta,
                            cfg.mrope_sections)
    if positions.ndim == 3:
        positions = positions[0]
    return rope_angles(positions, cfg.head_dim, cfg.rope_theta)


def _default_positions(cfg: ModelConfig, b, s, start=0):
    pos = start + jnp.arange(s, dtype=jnp.int32)[None]
    pos = jnp.broadcast_to(pos, (b, s))
    if cfg.mrope_sections:
        return jnp.broadcast_to(pos[None], (3, b, s))
    return pos


def _run_blocks(cfg, params, x, angles, positions, caches=None,
                cache_index=None):
    block = functools.partial(_block_apply, cfg)
    if cfg.remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=())

    def body(carry, layer_in):
        x, aux = carry
        if caches is None:
            p = layer_in
            x, _, a = block(p, x, angles=angles, positions=positions)
            return (x, aux + a), None
        p, c = layer_in
        x, new_c, a = block(p, x, angles=angles, positions=positions,
                            cache=c, cache_index=cache_index)
        return (x, aux + a), new_c

    xs = params["blocks"] if caches is None else (params["blocks"], caches)
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.float32(0)), xs)
    return x, aux, new_caches


def forward(cfg: ModelConfig, params, tokens, positions=None, embeds=None):
    """tokens: (B, S) int32 — or ``embeds``: (B, S, d) from a stub modality
    frontend (vlm); returns (logits f32, aux_loss)."""
    x = embeds if embeds is not None else embed(params["embed"], tokens)
    b, s = x.shape[:2]
    if positions is None:
        positions = _default_positions(cfg, b, s)
    angles = _angles_for(cfg, positions)
    x, aux, _ = _run_blocks(cfg, params, x, angles, positions)
    x = rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params["embed"], params.get("head"), x,
                     cfg.tie_embeddings)
    return logits, aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dt = dtype or cfg.jdtype
    l = cfg.n_layers
    if cfg.mla:
        return {
            "c_kv": jnp.zeros((l, batch, max_len, cfg.kv_lora_rank), dt),
            "k_rope": jnp.zeros((l, batch, max_len, cfg.qk_rope_dim), dt),
        }
    kvd = cfg.n_kv_heads
    return {
        "k": jnp.zeros((l, batch, max_len, kvd, cfg.head_dim), dt),
        "v": jnp.zeros((l, batch, max_len, kvd, cfg.head_dim), dt),
    }


def _apply_with_cache(cfg, params, tokens, cache, index, positions, embeds):
    x = embeds if embeds is not None else embed(params["embed"], tokens)
    b, s = x.shape[:2]
    if positions is None:
        positions = _default_positions(cfg, b, s, start=index)
    angles = _angles_for(cfg, positions)
    x, aux, new_caches = _run_blocks(cfg, params, x, angles, positions,
                                     caches=cache, cache_index=index)
    x = rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params["embed"], params.get("head"), x,
                     cfg.tie_embeddings)
    return logits, new_caches


def prefill(cfg: ModelConfig, params, tokens, cache, positions=None,
            embeds=None):
    return _apply_with_cache(cfg, params, tokens, cache, 0, positions,
                             embeds)


def decode_step(cfg: ModelConfig, params, tokens, cache, index,
                positions=None):
    """tokens: (B, 1); index: traced int32 current length."""
    return _apply_with_cache(cfg, params, tokens, cache, index, positions,
                             None)
