"""Model registry: family dispatch + analytic parameter counting."""

from __future__ import annotations

from types import ModuleType

import math

import jax

from repro.models.common import ModelConfig
from repro.models import encdec, hybrid, lm, xlstm_model

_FAMILY_MODULE: dict[str, ModuleType] = {
    "dense": lm,
    "moe": lm,
    "vlm": lm,
    "hybrid": hybrid,
    "ssm": xlstm_model,
    "encdec": encdec,
}


def model_module(cfg: ModelConfig) -> ModuleType:
    return _FAMILY_MODULE[cfg.family]


def init(cfg: ModelConfig, key):
    return model_module(cfg).init(cfg, key)


def forward(cfg: ModelConfig, params, tokens, positions=None, embeds=None):
    return model_module(cfg).forward(cfg, params, tokens,
                                     positions=positions, embeds=embeds)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    return model_module(cfg).init_cache(cfg, batch, max_len, dtype=dtype)


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(
        lambda: init(cfg, jax.random.PRNGKey(0)))


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count from abstract shapes; ``active_only`` counts
    top-k routed + shared experts only (MoE MODEL_FLOPS)."""
    shapes = abstract_params(cfg)
    total = sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))
    if not active_only or not cfg.is_moe:
        return total
    # subtract the inactive routed experts' parameters
    d, f, e, k = cfg.d_model, cfg.d_ff, cfg.moe_experts, cfg.moe_topk
    per_expert = 3 * d * f
    n_moe_layers = sum(1 for i in range(cfg.n_layers)
                       if cfg.layer_is_moe(i))
    inactive = n_moe_layers * (e - k) * per_expert
    return total - inactive
