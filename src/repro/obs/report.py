"""Render a campaign job's observability artifacts into a report.

The flight recorder leaves three kinds of evidence under a job
directory (``repro.noc.service.CampaignJob``):

* ``cells/<slug>.telemetry.npz`` — in-sim probe rings per cell
  (:class:`repro.obs.probe.Telemetry`);
* ``trace.jsonl`` — Chrome-trace ctrl/planner events
  (:mod:`repro.obs.trace`);
* ``metrics.jsonl`` — streaming job progress records.

:func:`render_job` folds them into ``artifacts/obs/<job_id>/``:

* ``trajectories.csv`` — per (cell, lane, telemetry slot) the
  time-resolved bandwidth-normalized peak link load, delivered/shed
  counts and p99 latency — the "what did the fabric look like over
  time" view the scalar ``SimResult`` cannot give;
* ``replan_timeline.csv`` — ctrl-plane events (drift scores, replans
  with wall durations, hot swaps, environment events) in time order;
* ``report.md`` — a human summary: job progress, per-cell walls and
  peak-load trajectories, replan timings, plan-cache effectiveness.

Everything is stdlib + numpy; the renderer never imports the simulator,
so it can run on artifacts copied off the machine that produced them.
"""

from __future__ import annotations

import json
import os

import numpy as np

from .probe import Telemetry
from .trace import read_trace

__all__ = ["render_job", "load_metrics"]

TRAJ_HEADER = ["cell", "topo", "pattern", "algo", "scenario", "lane",
               "slot", "t_start", "cycles", "peak_link_load",
               "delivered", "shed", "p99_lat", "occ_mean"]

TIMELINE_HEADER = ["ts_us", "name", "ph", "dur_us", "cat", "args"]

# ctrl/planner/campaign event names worth a timeline row (host spans and
# instants; the per-epoch "epoch" spans are summarized, not listed)
_TIMELINE_NAMES = ("replan", "hot_swap", "drift_detected", "LinkFail",
                   "LinkRecover", "TrafficDrift", "build_plan_fast",
                   "build_plans_batched", "plan_cache_hit",
                   "plan_cache_miss", "cell")


def load_metrics(path: str) -> list[dict]:
    """Parse a ``metrics.jsonl`` stream (tolerates a torn last line)."""
    records = []
    if not os.path.exists(path):
        return records
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError:
                break   # killed mid-write: the stream ends here
    return records


def _write_csv(path: str, header: list[str], rows: list[list]) -> None:
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for row in rows:
            f.write(",".join(str(v) for v in row) + "\n")


def _traj_rows(cell: dict, tel: Telemetry) -> list[list]:
    rows = []
    peak = tel.peak_link_load()             # (lanes, slots)
    active = tel.active_slots()
    starts = tel.slot_starts()
    occ = tel.occupancy_mean()              # (lanes, slots)
    p99 = tel.latency_percentile(0.99)      # (lanes, slots)
    delivered = tel.count("delivered")
    shed = tel.count("shed")
    for lane in range(tel.num_lanes):
        for s in active:                    # active slot indices
            rows.append([
                cell["slug"], cell["topo"], cell["pattern"],
                cell["algo"], cell["scenario"], lane, int(s),
                int(starts[s]), int(tel.cycles[lane, s]),
                f"{peak[lane, s]:.4f}", int(delivered[lane, s]),
                int(shed[lane, s]), f"{p99[lane, s]:.1f}",
                f"{occ[lane, s]:.4f}"])
    return rows


def _timeline_rows(events: list[dict]) -> list[list]:
    rows = []
    for ev in events:
        if ev.get("name") not in _TIMELINE_NAMES:
            continue
        rows.append([f"{ev['ts']:.0f}", ev["name"], ev.get("ph", ""),
                     f"{ev.get('dur', 0):.0f}", ev.get("cat", ""),
                     json.dumps(ev.get("args", {}), sort_keys=True)
                     .replace(",", ";")])
    rows.sort(key=lambda r: float(r[0]))
    return rows


def _md_table(header: list[str], rows: list[list]) -> list[str]:
    lines = ["| " + " | ".join(header) + " |",
             "|" + "|".join("---" for _ in header) + "|"]
    lines += ["| " + " | ".join(str(v) for v in row) + " |"
              for row in rows]
    return lines


def render_job(job_dir: str, out_dir: str) -> dict:
    """Render one job's observability artifacts; returns a summary dict.

    ``job_dir`` is a ``CampaignJob`` directory (must hold
    ``manifest.json``); ``out_dir`` receives ``trajectories.csv``,
    ``replan_timeline.csv`` and ``report.md``.  Missing planes (no
    telemetry files, no trace, no metrics) degrade to empty sections —
    the report renders from whatever evidence exists.
    """
    with open(os.path.join(job_dir, "manifest.json")) as f:
        manifest = json.load(f)
    os.makedirs(out_dir, exist_ok=True)

    # ---- plane 1: telemetry trajectories ---- #
    traj_rows: list[list] = []
    cells_with_tel = []
    for cell in manifest["cells"]:
        path = os.path.join(job_dir, "cells",
                            f"{cell['slug']}.telemetry.npz")
        if not os.path.exists(path):
            continue
        tel = Telemetry.load(path)
        cells_with_tel.append((cell, tel))
        traj_rows.extend(_traj_rows(cell, tel))
    _write_csv(os.path.join(out_dir, "trajectories.csv"),
               TRAJ_HEADER, traj_rows)

    # ---- plane 2: ctrl/planner timeline ---- #
    trace_path = os.path.join(job_dir, "trace.jsonl")
    events = read_trace(trace_path) if os.path.exists(trace_path) else []
    timeline = _timeline_rows(events)
    _write_csv(os.path.join(out_dir, "replan_timeline.csv"),
               TIMELINE_HEADER, timeline)

    # ---- plane 3: job metrics ---- #
    metrics = load_metrics(os.path.join(job_dir, "metrics.jsonl"))
    cell_recs = [m for m in metrics if m.get("event") == "cell"]
    fresh = [m for m in cell_recs if not m.get("cached")]
    cache_stats = (cell_recs[-1].get("plan_cache") if cell_recs else None)

    # ---- report.md ---- #
    lines = [f"# Flight-recorder report: {manifest['job_id']}", ""]
    done = max((m.get("done", 0) for m in metrics), default=0)
    lines += [f"- cells: {done}/{manifest['num_cells']} done "
              f"({len(fresh)} executed this run, "
              f"{len(cell_recs) - len(fresh)} from checkpoints)"]
    if fresh:
        walls = [m["wall_s"] for m in fresh]
        lines += [f"- executed-cell wall: total {sum(walls):.2f}s, "
                  f"mean {np.mean(walls):.2f}s, max {max(walls):.2f}s"]
        rates = [m["lanes_per_s"] for m in fresh if "lanes_per_s" in m]
        if rates:
            lines += [f"- throughput: {np.mean(rates):.2f} lanes/s mean"]
    if cache_stats:
        lines += [f"- plan cache: {cache_stats['hits']} hits, "
                  f"{cache_stats['misses']} misses, "
                  f"{cache_stats['device_builds']} device builds"]
    lines += [""]

    if cells_with_tel:
        lines += ["## Telemetry trajectories", "",
                  "Per-cell lane-0 peak bandwidth-normalized link load "
                  "over telemetry slots (`trajectories.csv` has every "
                  "lane and field).", ""]
        rows = []
        for cell, tel in cells_with_tel:
            peak = tel.peak_link_load()[0]
            act = tel.active_slots()
            traj = " ".join(f"{v:.2f}" for v in peak[act])
            rows.append([cell["slug"], cell["scenario"],
                         f"{peak[act].max():.3f}" if act.size else "-",
                         traj])
        lines += _md_table(["cell", "scenario", "peak", "trajectory"],
                           rows) + [""]

    replans = [ev for ev in events if ev.get("name") == "replan"]
    if replans:
        lines += ["## Replans", ""]
        rows = [[f"{ev['ts']:.0f}", ev["args"].get("cycle"),
                 ev["args"].get("trigger"),
                 ev["args"].get("iterations"),
                 ev["args"].get("unroutable"),
                 f"{ev.get('dur', 0) / 1e3:.1f}"]
                for ev in replans]
        lines += _md_table(["ts_us", "cycle", "trigger", "iters",
                            "unroutable", "wall_ms"], rows) + [""]
    epochs = [ev for ev in events if ev.get("name") == "epoch"]
    if epochs:
        durs = np.asarray([ev.get("dur", 0) for ev in epochs]) / 1e3
        lines += ["## Sim epochs", "",
                  f"{len(epochs)} epoch spans, wall "
                  f"mean {durs.mean():.1f} ms / max {durs.max():.1f} ms.",
                  ""]
    with open(os.path.join(out_dir, "report.md"), "w") as f:
        f.write("\n".join(lines) + "\n")

    return {"job_id": manifest["job_id"], "cells_done": done,
            "cells_total": manifest["num_cells"],
            "telemetry_cells": len(cells_with_tel),
            "trace_events": len(events), "replans": len(replans),
            "traj_rows": len(traj_rows), "out_dir": out_dir}
