"""Structured event log: one emitter behind every ``verbose=`` flag.

:class:`EventLog` replaces the ad-hoc ``print()`` calls in the campaign
and control-plane loops.  Each call site names the event kind and its
structured fields once; the log then

* prints the human-readable line iff ``verbose`` (so quiet runs emit
  exactly nothing — byte-identical default output), and
* forwards the structured form to a :class:`repro.obs.trace.TraceWriter`
  as an instant event when one is attached (tracing is orthogonal to
  verbosity: a quiet service job still records its trace).
"""

from __future__ import annotations

import sys

from .trace import NULL_TRACER

__all__ = ["EventLog", "NULL_LOG"]


class EventLog:
    def __init__(self, verbose: bool = False, tracer=None, stream=None):
        self.verbose = bool(verbose)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.stream = stream

    def event(self, kind: str, msg: str | None = None, *,
              cat: str = "log", **fields) -> None:
        """Record one event.  ``msg`` is the human line (defaults to
        ``kind key=value ...``); ``fields`` are the structured args."""
        if self.verbose:
            if msg is None:
                msg = kind + "".join(f" {k}={v}" for k, v in fields.items())
            print(msg, file=self.stream or sys.stdout, flush=True)
        self.tracer.instant(kind, cat=cat, args=fields or None)


NULL_LOG = EventLog(verbose=False)
