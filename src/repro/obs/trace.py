"""Structured trace events: Chrome trace-event JSON, Perfetto-viewable.

The flight recorder's host-side plane.  :class:`TraceWriter` streams
events to disk in the Chrome trace-event **JSON Array Format**: a ``[``
followed by one ``{event},`` per line.  The format explicitly allows
the closing ``]`` to be absent, so a stream killed mid-write (the
campaign service's whole threat model) is still loadable by Perfetto /
``chrome://tracing`` — the writer therefore *never* terminates the
array, and resume simply appends.

Event vocabulary (the ``ph`` phases used here):

* ``X`` *complete* — a span with ``ts`` + ``dur`` (host wall time of a
  plan build, a control epoch, a campaign cell);
* ``i`` *instant* — a point event (drift detection, table hot-swap,
  link fail/recover, plan-cache hit/miss);
* ``C`` *counter* — a named value series (drift TV-distance per epoch,
  cells-done progress).

Timestamps are microseconds since the Unix epoch (Chrome only requires
a consistent µs clock), so spans from separate processes or resumed
jobs land on one coherent timeline.

:data:`NULL_TRACER` is the no-op sink: instrumented code paths take a
``tracer`` and default to it, so tracing off costs one attribute call
per event site and nothing else.
"""

from __future__ import annotations

import contextlib
import json
import os
import threading
import time

__all__ = ["TraceWriter", "NullTracer", "NULL_TRACER", "read_trace",
           "validate_events"]


class NullTracer:
    """No-op tracer with the :class:`TraceWriter` emit interface."""

    enabled = False

    def now_us(self) -> float:
        return 0.0

    def instant(self, name, **kw) -> None:
        pass

    def counter(self, name, values, **kw) -> None:
        pass

    def complete(self, name, ts_us, dur_us, **kw) -> None:
        pass

    @contextlib.contextmanager
    def span(self, name, **kw):
        yield {}

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


NULL_TRACER = NullTracer()


class TraceWriter:
    """Streaming Chrome trace-event writer (see module docstring).

    ``append=True`` (the default) continues an existing stream — the
    resume path: the array stays unterminated, so the concatenation of
    a job's runs is one valid trace.  Thread-safe: the campaign
    service emits from a daemon thread while ``status()`` pollers run
    on the caller's.
    """

    enabled = True

    def __init__(self, path: str, *, pid: str = "qstar",
                 append: bool = True):
        self.path = str(path)
        self.pid = str(pid)
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._lock = threading.Lock()
        self._f = open(self.path, "a" if append else "w")
        if self._f.tell() == 0:
            self._f.write("[\n")
            self._f.flush()

    def now_us(self) -> float:
        """Current timestamp on the trace clock (Unix epoch µs)."""
        return time.time() * 1e6

    # ------------------------------------------------------------- #
    def _emit(self, ev: dict) -> None:
        line = json.dumps(ev, sort_keys=True, default=str)
        with self._lock:
            self._f.write(line + ",\n")
            self._f.flush()

    def instant(self, name: str, *, cat: str = "ctrl",
                args: dict | None = None, tid: int = 0,
                ts_us: float | None = None) -> None:
        ev = {"name": name, "ph": "i", "cat": cat, "s": "t",
              "ts": self.now_us() if ts_us is None else ts_us,
              "pid": self.pid, "tid": tid}
        if args:
            ev["args"] = args
        self._emit(ev)

    def counter(self, name: str, values: dict, *, cat: str = "ctrl",
                tid: int = 0, ts_us: float | None = None) -> None:
        self._emit({"name": name, "ph": "C", "cat": cat,
                    "ts": self.now_us() if ts_us is None else ts_us,
                    "pid": self.pid, "tid": tid,
                    "args": {k: float(v) for k, v in values.items()}})

    def complete(self, name: str, ts_us: float, dur_us: float, *,
                 cat: str = "host", args: dict | None = None,
                 tid: int = 0) -> None:
        ev = {"name": name, "ph": "X", "cat": cat, "ts": ts_us,
              "dur": max(float(dur_us), 0.0), "pid": self.pid,
              "tid": tid}
        if args:
            ev["args"] = args
        self._emit(ev)

    @contextlib.contextmanager
    def span(self, name: str, *, cat: str = "host",
             args: dict | None = None, tid: int = 0):
        """``with tracer.span("replan") as a:`` — emits one complete
        event on exit (exceptions included, flagged in args).  The
        yielded dict collects extra args discovered inside the span."""
        extra: dict = {}
        t0 = self.now_us()
        try:
            yield extra
        except BaseException:
            extra["error"] = True
            raise
        finally:
            self.complete(name, t0, self.now_us() - t0, cat=cat,
                          args={**(args or {}), **extra} or None, tid=tid)

    def flush(self) -> None:
        with self._lock:
            self._f.flush()

    def close(self) -> None:
        """Close the file handle.  The array is deliberately left
        unterminated — valid per the trace-event spec, and the only
        representation that survives a kill at any byte."""
        with self._lock:
            if not self._f.closed:
                self._f.flush()
                self._f.close()


# ------------------------------------------------------------------- #
# readers (reports + tests)
# ------------------------------------------------------------------- #
def read_trace(path: str) -> list[dict]:
    """Parse a (possibly unterminated) JSON-array trace stream.

    Tolerates the trailing comma and missing ``]`` of a killed stream —
    the same leniency Perfetto's importer applies."""
    with open(path) as f:
        text = f.read()
    body = text.strip()
    if body.startswith("["):
        body = body[1:]
    body = body.rstrip().rstrip("]").rstrip().rstrip(",")
    if not body:
        return []
    return json.loads("[" + body + "]")


_PHASES = {"X", "i", "C"}


def validate_events(events: list[dict]) -> list[str]:
    """Schema check of the vocabulary this package emits; returns a
    list of problems (empty == valid)."""
    problems = []
    for i, ev in enumerate(events):
        for field in ("name", "ph", "ts", "pid"):
            if field not in ev:
                problems.append(f"event {i}: missing {field!r}")
        ph = ev.get("ph")
        if ph not in _PHASES:
            problems.append(f"event {i}: unknown phase {ph!r}")
        if ph == "X" and "dur" not in ev:
            problems.append(f"event {i}: complete event without dur")
        if ph == "C" and not isinstance(ev.get("args"), dict):
            problems.append(f"event {i}: counter without args dict")
        ts = ev.get("ts")
        if ts is not None and not isinstance(ts, (int, float)):
            problems.append(f"event {i}: non-numeric ts {ts!r}")
    return problems
