"""repro.obs — the flight recorder.

Three planes of observability over the NoC stack:

* :mod:`repro.obs.probe` — in-sim telemetry ring buffers collected
  inside the jitted chunk scan (off by default, bit-identical when
  off);
* :mod:`repro.obs.trace` — Chrome trace-event streaming for ctrl-plane
  events and host-side spans (Perfetto-viewable), plus
  :mod:`repro.obs.log`'s structured event log behind the ``verbose=``
  flags;
* :mod:`repro.obs.report` — per-job report rendering (trajectories,
  replan timeline) from a campaign job's persisted telemetry, trace,
  and metrics streams.
"""

from .log import EventLog, NULL_LOG
from .probe import (TEL_COUNT_FIELDS, TEL_KEYS, Telemetry,
                    resolved_epoch, telemetry_state)
from .trace import (NULL_TRACER, NullTracer, TraceWriter, read_trace,
                    validate_events)

__all__ = [
    "EventLog", "NULL_LOG",
    "TEL_COUNT_FIELDS", "TEL_KEYS", "Telemetry", "resolved_epoch",
    "telemetry_state",
    "NULL_TRACER", "NullTracer", "TraceWriter", "read_trace",
    "validate_events",
]
