"""In-sim telemetry probes: the flight recorder's traced plane.

Q-StaR's argument is about the *long-term trend* of load distribution,
but the simulator's scan only surfaces end-of-run aggregates.  This
module defines the optional time-resolved state the per-cycle
transition accumulates when ``SimConfig.telemetry`` is on:

* fixed-size **ring buffers** over ``tel_slots`` recording slots, each
  covering ``tel_epoch`` cycles (0 = auto: ``ceil(cycles/tel_slots)``,
  so one pass fills the ring exactly).  Runs longer than
  ``tel_slots × tel_epoch`` wrap and *accumulate* — old slots keep
  their counts and gain new ones (``tel_cycles`` normalizes);
* per-slot **per-channel forwarded flits** (``tel_chan`` — the
  time-resolved link-load trajectory, always-on like ``chan_seen``);
* per-slot **offered / accepted / shed / delivered** packet counters
  (``tel_counts``);
* per-slot **queue-occupancy histogram** (``tel_qocc``: each cycle
  drops one count into the bin of the total source-queue fill
  fraction);
* per-slot **latency histogram** (``tel_lat``: every tail eject,
  binned exactly like the aggregate ``lat_hist`` — per-slot
  percentile snapshots).

The arrays are ordinary state-pytree members, so they ride the same
``lax.scan`` / ``vmap`` / ``shard_map`` paths as the core state, work
identically under the fused Pallas simstep and the unfused oracle
(both update them with the same ops), and land in control-plane
snapshots for free.  With ``telemetry=False`` none of them exist and
the step functions emit zero extra ops — bit-identical to a build
without this module (the golden guarantee).

:class:`Telemetry` is the host-side view: lane-major numpy arrays
pulled from a fetched state dict, with trajectory accessors and npz
persistence (the service's per-cell ``telemetry.npz``).
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

__all__ = ["TEL_KEYS", "TEL_COUNT_FIELDS", "resolved_epoch",
           "telemetry_state", "Telemetry"]

# Telemetry state keys, in the order fresh_state creates them.
TEL_KEYS = ("tel_chan", "tel_counts", "tel_cycles", "tel_lat", "tel_qocc")
# Columns of tel_counts.
TEL_COUNT_FIELDS = ("offered", "accepted", "shed", "delivered")


def resolved_epoch(cfg) -> int:
    """Recording-slot length in cycles (0 when telemetry is off).

    Pure function of the config, so the fused and unfused step builders
    — and any chunked/resumed execution of the same config — agree on
    slot boundaries."""
    if not cfg.telemetry:
        return 0
    if int(cfg.tel_epoch) > 0:
        return int(cfg.tel_epoch)
    return max(1, -(-int(cfg.cycles) // int(cfg.tel_slots)))


def telemetry_state(meta: dict, cfg) -> dict:
    """Fresh per-lane telemetry ring buffers ({} when telemetry is off).

    Kept beside the other state builders rather than in ``fresh_state``
    itself so the kernel package can size-budget the same arrays
    (``repro.kernels.simstep.ops.state_footprint_bytes``) without
    duplicating the layout."""
    if not cfg.telemetry:
        return {}
    import jax.numpy as jnp
    s = int(cfg.tel_slots)
    z = lambda shape: jnp.zeros(shape, jnp.int32)  # noqa: E731
    return dict(
        tel_chan=z((s, meta["C"])),
        tel_counts=z((s, len(TEL_COUNT_FIELDS))),
        tel_cycles=z((s,)),
        tel_lat=z((s, cfg.lat_bins)),
        tel_qocc=z((s, cfg.tel_occ_bins)),
    )


@dataclasses.dataclass
class Telemetry:
    """Host-side telemetry bundle for one cell (all lanes).

    Arrays are lane-major: ``chan`` is (lanes, slots, C), ``counts``
    (lanes, slots, 4) in :data:`TEL_COUNT_FIELDS` order, ``cycles``
    (lanes, slots), ``lat`` (lanes, slots, lat_bins), ``qocc`` (lanes,
    slots, occ_bins).  ``bw`` is the per-slot per-channel bandwidth in
    effect at each slot's end (slots, C) — attached by the caller, who
    knows the fault timeline; None means the static topology bandwidth
    was never known.
    """

    epoch_len: int
    lat_bin_width: int
    chan: np.ndarray
    counts: np.ndarray
    cycles: np.ndarray
    lat: np.ndarray
    qocc: np.ndarray
    bw: np.ndarray | None = None

    # ------------------------------------------------------------- #
    @classmethod
    def from_state(cls, host_state: dict, cfg) -> "Telemetry | None":
        """Build from a fetched (device_get) state dict with a leading
        lane axis; None when the state carries no telemetry."""
        if "tel_chan" not in host_state:
            return None
        a = {k: np.asarray(host_state[k]) for k in TEL_KEYS}
        if a["tel_chan"].ndim == 2:        # single lane: add the axis
            a = {k: v[None] for k, v in a.items()}
        return cls(epoch_len=resolved_epoch(cfg),
                   lat_bin_width=int(cfg.lat_bin_width),
                   chan=a["tel_chan"].astype(np.int64),
                   counts=a["tel_counts"].astype(np.int64),
                   cycles=a["tel_cycles"].astype(np.int64),
                   lat=a["tel_lat"].astype(np.int64),
                   qocc=a["tel_qocc"].astype(np.int64))

    def with_bw(self, bw_slots: np.ndarray) -> "Telemetry":
        return dataclasses.replace(
            self, bw=np.asarray(bw_slots, np.float64))

    # ------------------------------------------------------------- #
    @property
    def num_lanes(self) -> int:
        return int(self.chan.shape[0])

    @property
    def num_slots(self) -> int:
        return int(self.chan.shape[1])

    def active_slots(self) -> np.ndarray:
        """Indices of slots that recorded at least one cycle.  The
        per-slot cycle count is lane-independent (every lane steps
        every cycle), so lane 0 speaks for all."""
        return np.nonzero(self.cycles[0] > 0)[0]

    def slot_starts(self) -> np.ndarray:
        """First absolute cycle of each slot (ring wrap ignored)."""
        return np.arange(self.num_slots, dtype=np.int64) * self.epoch_len

    # ------------------------------------------------------------- #
    def link_load(self) -> np.ndarray:
        """(lanes, slots, C) per-channel flits/cycle, normalized by the
        per-slot bandwidth when attached (dead links → 0 by the same
        convention as ``postprocess``)."""
        cyc = np.maximum(self.cycles, 1)[:, :, None].astype(np.float64)
        load = self.chan.astype(np.float64) / cyc
        if self.bw is not None:
            bw = self.bw[None]
            load = np.where(bw > 0, load / np.where(bw > 0, bw, 1.0), 0.0)
        return load

    def peak_link_load(self) -> np.ndarray:
        """(lanes, slots) max normalized channel load per slot — the
        time-resolved version of ``SimResult.link_load_max``."""
        load = self.link_load()
        return load.max(axis=2) if load.shape[2] else np.zeros(
            load.shape[:2])

    def latency_percentile(self, q: float) -> np.ndarray:
        """(lanes, slots) latency q-quantile snapshot per slot, from
        the per-slot histograms (same estimator as the aggregate
        percentiles; empty slots → 0)."""
        from repro.noc.sim import hist_percentile
        out = np.zeros((self.num_lanes, self.num_slots))
        for i in range(self.num_lanes):
            for s in range(self.num_slots):
                out[i, s] = hist_percentile(
                    self.lat[i, s], self.lat_bin_width, q)
        return out

    def occupancy_mean(self) -> np.ndarray:
        """(lanes, slots) mean source-queue fill fraction, from the
        per-slot occupancy histograms (bin centers)."""
        nb = self.qocc.shape[2]
        centers = (np.arange(nb) + 0.5) / nb
        tot = np.maximum(self.qocc.sum(axis=2), 1).astype(np.float64)
        return (self.qocc @ centers) / tot

    def count(self, field: str) -> np.ndarray:
        """(lanes, slots) one :data:`TEL_COUNT_FIELDS` counter."""
        return self.counts[:, :, TEL_COUNT_FIELDS.index(field)]

    # ------------------------------------------------------------- #
    def save(self, path: str) -> None:
        """Persist as npz (meta as a JSON bytes array, the
        CellCheckpoint idiom)."""
        meta = {"epoch_len": int(self.epoch_len),
                "lat_bin_width": int(self.lat_bin_width)}
        payload = dict(chan=self.chan, counts=self.counts,
                       cycles=self.cycles, lat=self.lat, qocc=self.qocc)
        if self.bw is not None:
            payload["bw"] = self.bw
        payload["__meta__"] = np.frombuffer(
            json.dumps(meta).encode(), np.uint8)
        np.savez_compressed(path, **payload)

    @classmethod
    def load(cls, path: str) -> "Telemetry":
        with np.load(path, allow_pickle=False) as z:
            d = {k: z[k] for k in z.files}
        meta = json.loads(bytes(d.pop("__meta__")).decode())
        return cls(epoch_len=int(meta["epoch_len"]),
                   lat_bin_width=int(meta["lat_bin_width"]),
                   chan=d["chan"], counts=d["counts"],
                   cycles=d["cycles"], lat=d["lat"], qocc=d["qocc"],
                   bw=d.get("bw"))
