"""Simulator configuration (paper §4.1 defaults)."""

from __future__ import annotations

import dataclasses
import enum

import numpy as np



class Algo(enum.IntEnum):
    """Routing algorithms evaluated in the paper (§2.1 / §4.1)."""

    XY = 0        # deterministic DOR
    YX = 1        # deterministic DOR, reverse order
    O1TURN = 2    # oblivious: random XY/YX per packet [17]
    VALIANT = 3   # oblivious: random intermediate anywhere [20]
    ROMM = 4      # oblivious: random intermediate in MinRect [15]
    ODDEVEN = 5   # adaptive: odd-even turn model [1]
    BIDOR = 6     # Q-StaR: N-Rank-guided XY/YX choice (this paper)


# Packed flit-record layout: one (NIN, BUF, NF) int32 array instead of ten
# (NIN, BUF) arrays — FIFO pushes/pops become a single scatter/gather with
# a contiguous NF-word payload (the dominant per-cycle cost on CPU/TPU).
# Shared by the unfused step (repro.noc.sim) and the fused kernel
# (repro.kernels.simstep), which both operate on the same state pytree.
NF = 10
(F_SRC, F_DST, F_INTER, F_SEQ, F_TIME,
 F_HOPS, F_ORDER, F_HEAD, F_TAIL, F_PHASE) = range(NF)
# Packed source-queue packet records: (N, Q, NQ) int32.
NQ = 5
(Q_DST, Q_INTER, Q_ORDER, Q_TIME, Q_SEQ) = range(NQ)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Cycle-level simulation parameters.

    Defaults mirror the paper's setup (§4.1): input-queued routers, wormhole
    flits, credit-based flow control, 2 VCs sharing a 64-flit input buffer,
    and a 2-cycle base hop latency (realized as 1 movement/cycle + 1 extra
    cycle per hop charged in latency accounting — identical across all
    algorithms, preserving every relative comparison).
    """

    algo: Algo = Algo.XY
    num_vcs: int = 2
    buf_per_vc: int = 32          # 64-flit input buffer shared by 2 VCs
    packet_len: int = 4           # flits per packet
    src_queue_pkts: int = 64      # per-node source queue (open loop)
    cycles: int = 12_000
    warmup: int = 4_000
    drain: int = 0                # trailing cycles with injection halted
    injection_rate: float = 0.1   # flits / cycle / I/O port
    seed: int = 0
    reorder_window: int = 32      # per-flow sequence tracking window
    lat_bins: int = 96            # latency histogram bins (percentiles)
    lat_bin_width: int = 8        # cycles per histogram bin; last = overflow
    # Per-cycle hot path: True runs the fused flit-step kernel
    # (repro.kernels.simstep — Pallas on TPU/GPU, the fused dense jnp
    # fallback on CPU); False runs the legacy unfused jnp step, kept as
    # the differential-testing oracle (tests/test_simstep_kernel.py) and
    # the simstep_scale benchmark baseline.  Both are bit-identical.
    use_kernel: bool = True
    # Blocked simstep kernel (repro.kernels.simstep): tile the per-cycle
    # body over node ranges of this size so only one tile's flit/queue
    # records are resident on chip at a time (double-buffered HBM→VMEM
    # streaming on TPU/GPU; a vmapped-tiles XLA flavor on CPU).  Must
    # divide the node count.  0 = auto: the dispatcher
    # (repro.kernels.simstep.ops.make_step) picks whole-array when the
    # state fits the VMEM budget, else the largest fitting tile, else
    # the fused dense body.  Every path is bit-identical
    # (tests/test_simstep_kernel.py), so — like telemetry — this knob
    # is excluded from the service's spec fingerprint.
    sim_tile_nodes: int = 0
    # In-sim telemetry probes (repro.obs.probe): when on, the per-cycle
    # transition additionally accumulates fixed-size ring buffers of
    # time-resolved statistics (per-channel load, offered/accepted/shed/
    # delivered, queue-occupancy and latency histograms) over tel_slots
    # recording slots of tel_epoch cycles each (0 = auto:
    # ceil(cycles / tel_slots)).  Off by default; when off, zero extra
    # state and zero extra ops — results are bit-identical with or
    # without this feature (tests/test_obs.py).  The probes never
    # change simulation results either way, so the service's spec
    # fingerprint deliberately excludes these fields.
    telemetry: bool = False
    tel_epoch: int = 0
    tel_slots: int = 64
    tel_occ_bins: int = 16
    # Runtime stall watchdog (repro.noc.watchdog): when on, per-input
    # stall-age counters classify wedged heads as deadlocked past
    # wd_stall_cycles (recovery: one escape hop via the DOR escape
    # table) and runaway packets as livelocked past wd_hop_limit hops
    # (recovery: mask the source's generation for wd_throttle_cycles).
    # Off by default; when off, zero extra state and zero extra ops —
    # results are bit-identical with or without this feature
    # (tests/test_watchdog.py).  Unlike telemetry, the watchdog CHANGES
    # results when on (escapes misroute, throttles shed), so these
    # fields DO enter the service's spec fingerprint.
    watchdog: bool = False
    wd_stall_cycles: int = 64
    wd_hop_limit: int = 64
    wd_throttle_cycles: int = 32

    def __post_init__(self):
        if self.warmup + self.drain >= self.cycles:
            raise ValueError(
                f"warmup ({self.warmup}) + drain ({self.drain}) leaves no "
                f"measurement window inside cycles ({self.cycles})")
        if self.sim_tile_nodes < 0:
            raise ValueError(
                f"sim_tile_nodes ({self.sim_tile_nodes}) must be >= 0")

    @property
    def measure(self) -> int:
        """Length of the measurement window (cycles)."""
        return self.cycles - self.warmup - self.drain

    def replace(self, **kw) -> "SimConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Post-processed simulation statistics."""

    algo: Algo
    injection_rate: float
    throughput: float           # accepted flits / cycle / I/O port
    offered: float              # offered flits / cycle / I/O port
    avg_latency: float
    max_latency: float
    node_load: np.ndarray       # (N,) forwarding rate per node
    lcv: float                  # coefficient of variation of node loads
    reorder_value: int          # max reorder-buffer occupancy (flits)
    ejected_flits: int
    injected_flits: int
    in_flight_flits: int        # conservation check: injected = ejected + in flight
    seed: int = 0
    meas_cycles: int = 0        # cycles actually measured (early exit aware)
    saturated: bool = False     # campaign saturation detector verdict
    p50_latency: float = 0.0    # histogram-derived percentiles
    p90_latency: float = 0.0
    p99_latency: float = 0.0
    link_load_max: float = 0.0  # max per-channel load / bandwidth

    def summary(self) -> str:
        sat = " SAT" if self.saturated else ""
        return (f"{self.algo.name:8s} rate={self.injection_rate:.3f} "
                f"thr={self.throughput:.4f} lat={self.avg_latency:.1f} "
                f"p99={self.p99_latency:.0f} maxlat={self.max_latency:.0f} "
                f"lcv={self.lcv:.3f} reorder={self.reorder_value}{sat}")
