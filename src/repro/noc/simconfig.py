"""Simulator configuration (paper §4.1 defaults)."""

from __future__ import annotations

import dataclasses
import enum

import numpy as np

from repro.core.topology import Topology


class Algo(enum.IntEnum):
    """Routing algorithms evaluated in the paper (§2.1 / §4.1)."""

    XY = 0        # deterministic DOR
    YX = 1        # deterministic DOR, reverse order
    O1TURN = 2    # oblivious: random XY/YX per packet [17]
    VALIANT = 3   # oblivious: random intermediate anywhere [20]
    ROMM = 4      # oblivious: random intermediate in MinRect [15]
    ODDEVEN = 5   # adaptive: odd-even turn model [1]
    BIDOR = 6     # Q-StaR: N-Rank-guided XY/YX choice (this paper)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Cycle-level simulation parameters.

    Defaults mirror the paper's setup (§4.1): input-queued routers, wormhole
    flits, credit-based flow control, 2 VCs sharing a 64-flit input buffer,
    and a 2-cycle base hop latency (realized as 1 movement/cycle + 1 extra
    cycle per hop charged in latency accounting — identical across all
    algorithms, preserving every relative comparison).
    """

    algo: Algo = Algo.XY
    num_vcs: int = 2
    buf_per_vc: int = 32          # 64-flit input buffer shared by 2 VCs
    packet_len: int = 4           # flits per packet
    src_queue_pkts: int = 64      # per-node source queue (open loop)
    cycles: int = 12_000
    warmup: int = 4_000
    injection_rate: float = 0.1   # flits / cycle / I/O port
    seed: int = 0
    reorder_window: int = 32      # per-flow sequence tracking window

    def replace(self, **kw) -> "SimConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Post-processed simulation statistics."""

    algo: Algo
    injection_rate: float
    throughput: float           # accepted flits / cycle / I/O port
    offered: float              # offered flits / cycle / I/O port
    avg_latency: float
    max_latency: float
    node_load: np.ndarray       # (N,) forwarding rate per node
    lcv: float                  # coefficient of variation of node loads
    reorder_value: int          # max reorder-buffer occupancy (flits)
    ejected_flits: int
    injected_flits: int
    in_flight_flits: int        # conservation check: injected = ejected + in flight

    def summary(self) -> str:
        return (f"{self.algo.name:8s} rate={self.injection_rate:.3f} "
                f"thr={self.throughput:.4f} lat={self.avg_latency:.1f} "
                f"maxlat={self.max_latency:.0f} lcv={self.lcv:.3f} "
                f"reorder={self.reorder_value}")
