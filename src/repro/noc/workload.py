"""Realistic-workload synthesis for the paper's §4.3 evaluation.

The paper traces port-pair traffic of a leaf switch inside an ns-3 Clos
network running the HPCC workload [12], and observes (Fig. 2a) a *sparse,
highly skewed, bursty* port-pair matrix.  ns-3 is out of scope here; this
module synthesizes traffic with matched statistics:

* a small set of hot flows with Zipf-distributed intensity (rack-to-rack
  elephants) over the edge-I/O nodes,
* a light uniform background (mice),
* epoch-level burstiness: each epoch re-samples which hot flows are active
  (on/off flows), while the *aggregate* matrix — what Q-StaR's offline
  statistics would see — stays fixed.

``clos_leaf_trace`` returns (segments, aggregate_matrix) for
:func:`repro.noc.sim.run_trace`.
"""

from __future__ import annotations

import numpy as np

from repro.core.topology import Topology

__all__ = ["clos_leaf_trace"]


def clos_leaf_trace(
    topo: Topology,
    num_epochs: int = 8,
    num_hot_flows: int = 12,
    active_frac: float = 0.5,
    zipf_a: float = 1.2,
    background: float = 0.15,
    base_rate: float = 0.25,
    seed: int = 7,
) -> tuple[list[tuple[np.ndarray, float]], np.ndarray]:
    """Synthesize an epoch trace of a Clos leaf switch.

    Args:
      topo: NoC topology (I/O-weighted nodes are the switch ports).
      num_epochs: number of piecewise-constant traffic epochs.
      num_hot_flows: total distinct elephant flows across the trace.
      active_frac: fraction of hot flows active in any given epoch.
      zipf_a: Zipf exponent of flow intensities.
      background: fraction of traffic that is uniform background.
      base_rate: mean injection rate (flits/cycle/port); epochs are scaled
        by their relative activity, giving burstiness.
      seed: RNG seed.

    Returns:
      (segments, aggregate): segments = [(traffic_matrix, rate), ...];
      aggregate is the statistics matrix Q-StaR builds its plan from.
    """
    rng = np.random.default_rng(seed)
    n = topo.num_nodes
    io = np.nonzero(topo.io_weights > 0)[0]
    # sample hot flows (distinct ordered port pairs)
    flows = set()
    while len(flows) < num_hot_flows:
        s, d = rng.choice(io, 2, replace=False)
        flows.add((int(s), int(d)))
    flows = sorted(flows)
    intensity = (1.0 / np.arange(1, num_hot_flows + 1) ** zipf_a)
    intensity /= intensity.sum()
    rng.shuffle(intensity)

    bg = np.outer(topo.io_weights, topo.io_weights).astype(np.float64)
    np.fill_diagonal(bg, 0)
    bg /= bg.sum()

    segments: list[tuple[np.ndarray, float]] = []
    agg = np.zeros((n, n), np.float64)
    for _ in range(num_epochs):
        active = rng.random(num_hot_flows) < active_frac
        if not active.any():
            active[rng.integers(num_hot_flows)] = True
        hot = np.zeros((n, n), np.float64)
        for (s, d), w, a in zip(flows, intensity, active):
            if a:
                hot[s, d] += w
        hot /= hot.sum()
        t = background * bg + (1 - background) * hot
        t /= t.sum()
        # epoch rate scales with how much of the flow mass is active
        rate = base_rate * (0.5 + intensity[active].sum())
        segments.append((t, float(rate)))
        agg += t * rate
    agg /= agg.sum()
    return segments, agg
