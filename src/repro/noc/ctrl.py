"""Quasi-static control plane: fault injection, drift detection, and
online N-Rank re-planning.

Q-StaR's premise (paper §3.1) is *quasi-static* routing: plans are cheap
enough to recompute at a coarse timescale as topology and traffic change.
The simulator alone only replays one offline plan; this module closes the
loop:

* an **event schedule** (:class:`LinkFail` / :class:`LinkRecover` /
  :class:`TrafficDrift`) perturbs a running simulation — link bandwidth
  changes flow through the per-channel gating in :mod:`repro.noc.sim`,
  traffic epochs swap the generation tables;
* an **online estimator** (:class:`TrafficEstimator`) accumulates an
  observed traffic matrix from the per-flow injection counters the
  simulator already tracks, and a **drift detector**
  (:class:`DriftDetector`) watches the always-on per-channel forwarding
  profile for distribution shift;
* a **re-planner** re-runs N-Rank *warm-started from the previous fixed
  point* (``w0`` carry), rebuilds BiDOR against the degraded topology
  (infeasible dimension orders leave the minimization, so every route
  stays a pure DOR route inside its VC class — deadlock-free by
  construction), optionally refines with BiDOR-G against the degraded
  bandwidths, and shedding unroutable pairs at the source (admission
  control);
* the new tables **hot-swap** into the running simulation between chunks
  (:func:`repro.noc.sim.retarget_tables`) without touching in-flight
  state.

Three policies bracket the design space (the ``dynamics`` benchmark):
``"oracle"`` replans instantly from ground truth at every event,
``"stale"`` never replans (the seed repo's behaviour), and ``"online"``
replans from its own estimates when a fault is signalled or drift is
detected.  Adaptive routing (odd-even) runs through the same event
machinery as the per-cycle-reactive contrast.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bidor import BiDORTable, bidor, greedy_refine
from repro.core.certify import (CertificationError, apply_repair,
                                certify_table)
from repro.core.nrank import NRankResult, initial_weights, nrank_channel
from repro.core.plan_fast import build_plan_fast
from repro.core.topology import Topology
from repro.obs.log import EventLog
from repro.obs.probe import Telemetry, resolved_epoch
from repro.obs.trace import NULL_TRACER
from .watchdog import WatchdogReport
from .sim import (build_tables, get_runner, make_states,
                  maybe_shard_states, postprocess, queue_occupancy,
                  retarget_tables, source_queue_meta)
from .simconfig import Algo, SimConfig, SimResult

__all__ = [
    "LinkFail", "LinkRecover", "TrafficDrift", "Scenario",
    "TrafficEstimator", "DriftDetector", "ReplanConfig", "Replan",
    "ControlledResult", "run_controlled",
]


# ---------------------------------------------------------------------- #
# events & scenarios
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class LinkFail:
    """Fail (bw_scale = 0) or degrade (0 < bw_scale < 1) directed channels
    at an absolute cycle.  ``links`` holds (u, n) node pairs; a full
    bidirectional link is two entries."""

    cycle: int
    links: tuple
    bw_scale: float = 0.0


@dataclasses.dataclass(frozen=True)
class LinkRecover:
    """Restore the listed channels to their original bandwidth."""

    cycle: int
    links: tuple


@dataclasses.dataclass(frozen=True)
class TrafficDrift:
    """Swap the generation traffic matrix (a new epoch) and optionally
    scale every lane's injection rate."""

    cycle: int
    traffic: np.ndarray
    rate_scale: float = 1.0


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named event schedule plus the control policy that faces it.

    ``policy``: "stale" (never replan), "oracle" (replan from ground truth
    at every event), or "online" (replan from observed estimates on fault
    signals and detected drift).  Non-BiDOR algorithms ignore the policy —
    events still apply (they are the environment, not the plan).
    """

    name: str
    events: tuple = ()
    policy: str = "stale"
    replan: "ReplanConfig | None" = None

    def __post_init__(self):
        cycles = [e.cycle for e in self.events]
        if cycles != sorted(cycles):
            raise ValueError("scenario events must be sorted by cycle")
        if any(c <= 0 for c in cycles):
            raise ValueError(
                "event cycles must be >= 1 (events apply at chunk "
                "boundaries after the cycle; bake cycle-0 conditions "
                "into the topology/traffic instead)")
        if self.policy not in ("stale", "oracle", "online"):
            raise ValueError(f"unknown policy {self.policy!r}")


# ---------------------------------------------------------------------- #
# online estimation & drift detection
# ---------------------------------------------------------------------- #
class TrafficEstimator:
    """Observed traffic matrix from the simulator's per-flow counters.

    The simulator stamps every generated packet with a per-(source,
    destination) sequence number (``next_seq``); its per-epoch delta *is*
    the observed pair-count matrix.  An exponential moving average over
    epochs keeps the estimate current under drift while smoothing
    sampling noise — exactly the "statistical information" path of paper
    §4.1, but gathered online.

    ``prior`` is the offline matrix the initial plan was built from: it
    backs :attr:`matrix` until the first packets are observed, so a
    cold-start replan (a fault signalled before any delivery) plans
    from the best statistics available instead of requiring every
    caller to carry its own fallback.  The prior never mixes into the
    EMA — the first observed epoch replaces it outright, exactly as
    before — and an all-zero observation window simply keeps the
    current estimate (the empty-window divide is guarded here, in both
    :meth:`update` and :attr:`matrix`, not at call sites).
    """

    def __init__(self, num_nodes: int, ema: float = 0.5,
                 prior: np.ndarray | None = None):
        self.ema = float(ema)
        self._m: np.ndarray | None = None
        self._n = int(num_nodes)
        self._prior = (np.asarray(prior, np.float64).copy()
                       if prior is not None else None)

    def update(self, pair_counts: np.ndarray) -> None:
        """Fold one epoch's (N, N) pair-count delta into the estimate."""
        c = np.asarray(pair_counts, np.float64)
        if c.shape != (self._n, self._n):
            raise ValueError(f"pair_counts shape {c.shape}")
        tot = c.sum()
        if tot <= 0:
            return
        obs = c / tot
        if self._m is None:
            self._m = obs
        else:
            self._m = (1.0 - self.ema) * self._m + self.ema * obs

    @property
    def matrix(self) -> np.ndarray | None:
        """Current normalized estimate — the observed EMA once any
        packets have been seen, else the offline prior; None only when
        neither carries any demand."""
        m = self._m if self._m is not None else self._prior
        if m is None:
            return None
        m = m.copy()
        np.fill_diagonal(m, 0.0)
        s = m.sum()
        return m / s if s > 0 else None


class DriftDetector:
    """Distribution-shift detector over the per-channel forwarding profile.

    The reference profile is pinned at plan time; each epoch's observed
    profile (always-on ``chan_seen`` deltas, normalized to unit sum) is
    compared by total-variation distance.  Distance above ``threshold``
    flags drift — the re-planner then resets the reference.
    """

    def __init__(self, threshold: float = 0.25):
        self.threshold = float(threshold)
        self._ref: np.ndarray | None = None
        self.last_distance = 0.0

    def reset(self) -> None:
        """Forget the reference (called after a replan)."""
        self._ref = None
        self.last_distance = 0.0

    def update(self, chan_counts: np.ndarray) -> bool:
        """Feed one epoch's per-channel counts; True ⇔ drift detected."""
        c = np.asarray(chan_counts, np.float64)
        tot = c.sum()
        if tot <= 0:
            return False
        prof = c / tot
        if self._ref is None:
            self._ref = prof
            return False
        self.last_distance = 0.5 * float(np.abs(prof - self._ref).sum())
        return self.last_distance > self.threshold


# ---------------------------------------------------------------------- #
# re-planning
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ReplanConfig:
    """Knobs of the online re-planner."""

    epoch: int = 500            # control period (cycles) between checks
    drift_threshold: float = 0.25
    ema: float = 0.5            # estimator smoothing
    warm: bool = True           # carry the previous N-Rank fixed point
    greedy_sweeps: int = 2      # BiDOR-G refinement against degraded bw
    sat_occupancy: float = 0.9  # source-queue fraction flagging saturation
    # hot-swap guard: reject a replan whose shed fraction (unroutable
    # pairs among the pairs with demand) exceeds this, keeping the
    # previous table instead of silently wedging most of the traffic
    max_shed: float = 0.5


@dataclasses.dataclass(frozen=True)
class Replan:
    """One re-planning action (for logs/plots/tests)."""

    cycle: int
    trigger: str                # "fault" | "drift" | "event"
    iterations: int             # N-Rank evolution iterations
    unroutable_pairs: int
    drift_distance: float = 0.0


def replan(topo: Topology, traffic: np.ndarray, channel_bw: np.ndarray,
           prev: "object | None" = None, *,
           warm: bool = True, greedy_sweeps: int = 2,
           use_fast: bool = True, tracer=None,
           ) -> tuple[BiDORTable, "object"]:
    """One quasi-static re-planning step against a degraded fabric.

    Args:
      topo: the intact topology (full channel indexing).
      traffic: the (estimated or true) traffic matrix to plan for.
      channel_bw: current per-channel bandwidth; 0 marks hard-failed
        channels.
      prev: previous :class:`repro.core.nrank.NRankResult` for the
        warm-start carry (its residual fixed point seeds the new
        evolution on top of the fresh eq. (1) weights).
      use_fast: run N-Rank + BiDOR as the single jitted device pipeline
        (:func:`repro.core.plan_fast.build_plan_fast`; hard-failed
        channels are masked, so every fault pattern reuses one
        compilation) instead of the stage-by-stage host oracle.  Both
        produce the same choice tables; the fast path is what makes
        online replanning latency proportional to the device, not the
        host loops.

    Returns (table, nrank_result).  ``table.unroutable`` flags pairs no
    dimension order can serve; shed their generation upstream.
    """
    bw = np.asarray(channel_bw, np.float64)
    down = np.nonzero(bw <= 0)[0]
    plan_topo = dataclasses.replace(topo, channel_bw=bw)
    w0 = None
    if warm and prev is not None:
        w0 = initial_weights(traffic) + np.asarray(prev.w_final, np.float64)
    if use_fast:
        plan = build_plan_fast(plan_topo, traffic, w0=w0,
                               down_channels=down if down.size else None,
                               tracer=tracer)
        table, nr = plan.table, plan.nrank
    else:
        # N-Rank sees the degraded connectivity (hard-failed channels
        # leave the possibility sets); BiDOR masks them from the choice.
        nr_topo = (plan_topo.degrade(down, drop=True) if down.size
                   else plan_topo)
        nr = nrank_channel(nr_topo, traffic, w0=w0)
        table = bidor(plan_topo, nr.w_nr,
                      down_channels=down if down.size else None)
    if greedy_sweeps > 0:
        table = greedy_refine(plan_topo, traffic, table,
                              sweeps=greedy_sweeps)
    # deadlock gate on the hot-swap artifact: build_plan_fast certifies
    # its own output, but greedy refinement (and the host-oracle path)
    # re-shape the choice table afterwards — certify what actually ships
    cert = certify_table(plan_topo, table, traffic=traffic, w_nr=nr.w_nr,
                         tracer=tracer, label="replan")
    if not cert.ok:
        raise CertificationError(
            f"replan for {topo.name} failed deadlock certification "
            f"({cert.cyclic_nodes} cyclic CDG nodes survive repair)")
    if cert.verdict == "repaired":
        table = apply_repair(table, cert)
    return table, nr


# ---------------------------------------------------------------------- #
# the controlled run
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class ControlledResult:
    """Output of one controlled (event-driven) run."""

    scenario: str
    policy: str
    points: list                 # [(rate, seed), ...] lane order
    results: list                # [SimResult, ...] per lane
    replans: list                # [Replan, ...]
    # time-resolved load: per lane, the peak over control epochs of the
    # max bandwidth-normalized link load (the completion-time bottleneck
    # metric; a saturated degraded link pins it at ≈ 1)
    link_peak: np.ndarray
    epoch_bounds: list           # [(t0, t1), ...] control epochs
    # in-sim probe rings (cfg.telemetry on), bw-normalized against the
    # bandwidth in effect per telemetry slot (faults tracked)
    telemetry: "Telemetry | None" = None
    # stall-watchdog summary over all lanes (cfg.watchdog on)
    watchdog: "object | None" = None

    def result_with_peak(self, i: int) -> SimResult:
        """Lane i's SimResult with the time-resolved link peak in
        ``link_load_max`` (the static field would normalize by the intact
        bandwidths)."""
        return dataclasses.replace(self.results[i],
                                   link_load_max=float(self.link_peak[i]))


def _apply_events(events, bw, topo, base_bw):
    """Fold one boundary's events into the environment; returns the new
    (bw, traffic, rate_scale, kinds) with traffic/rate None if unchanged."""
    traffic = None
    rate_scale = None
    kinds = set()
    for ev in events:
        if isinstance(ev, LinkFail):
            ids = [topo.channel_index(*l) for l in ev.links]
            bw = bw.copy()
            bw[ids] = base_bw[ids] * ev.bw_scale
            kinds.add("fault")
        elif isinstance(ev, LinkRecover):
            ids = [topo.channel_index(*l) for l in ev.links]
            bw = bw.copy()
            bw[ids] = base_bw[ids]
            kinds.add("fault")
        elif isinstance(ev, TrafficDrift):
            traffic = np.asarray(ev.traffic, np.float64)
            rate_scale = float(ev.rate_scale)
            kinds.add("drift")
        else:
            raise TypeError(f"unknown event {ev!r}")
    return bw, traffic, rate_scale, kinds


def _bw_slots(bw_hist, epoch: int, slots: int, total: int) -> np.ndarray:
    """Per-slot channel bandwidth for telemetry load normalization.

    ``bw_hist`` is [(cycle, bw), ...] — the bandwidth vector in effect
    from each cycle on (faults and recoveries append entries).  A slot is
    normalized by the bw in effect at the END of its last accumulation
    window; when the ring wraps, the later window wins, consistent with
    its counts dominating the accumulated slot.
    """
    out = np.zeros((slots, bw_hist[0][1].shape[0]))
    for j in range(slots):
        last = min(j * epoch + epoch, total) - 1   # slot's last cycle
        t = j * epoch + epoch * slots
        while t < total:                            # ring wraps
            last = min(t + epoch, total) - 1
            t += epoch * slots
        bw = bw_hist[0][1]
        for cyc, b in bw_hist:
            if cyc <= last:
                bw = b
        out[j] = bw
    return out


_NR_FIELDS = ("w_nr", "w0", "w_final", "p", "p_drn", "w_possibility")


def _ctrl_snapshot(batched, *, bound_i, sat, link_peak, bw, cur_traffic,
                   cur_gen, cur_unroutable, fault_pending, estimator,
                   detector, replans, table, nr_prev, bw_hist=None):
    """Serializable (arrays, meta) state of a controlled run at the TOP
    of boundary iteration ``bound_i``: everything up to
    ``bounds[bound_i - 1]`` (events, replans, counters) applied, the next
    epoch not yet run.  ``_ctrl_restore`` inverts it bit-identically."""
    arrays = {f"s_{k}": np.asarray(v)
              for k, v in jax.device_get(batched).items()}
    arrays.update(sat=sat, link_peak=link_peak, bw=bw,
                  cur_traffic=cur_traffic, cur_gen=cur_gen)
    if cur_unroutable is not None:
        arrays["cur_unroutable"] = np.asarray(cur_unroutable, bool)
    if estimator._m is not None:
        arrays["est_m"] = estimator._m
    if detector._ref is not None:
        arrays["det_ref"] = detector._ref
    if table is not None:
        arrays["tab_choice"] = np.asarray(table.choice, np.int8)
    if bw_hist:
        arrays["bwh"] = np.stack([b for _, b in bw_hist])
    if nr_prev is not None:
        for f in _NR_FIELDS:
            arrays[f"nr_{f}"] = np.asarray(getattr(nr_prev, f),
                                           np.float64)
    meta = dict(bound_i=int(bound_i),
                bwh_cycles=[int(c) for c, _ in (bw_hist or [])],
                fault_pending=bool(fault_pending),
                last_distance=float(detector.last_distance),
                has_nr=nr_prev is not None,
                nr_iterations=(int(nr_prev.iterations)
                               if nr_prev is not None else 0),
                replans=[dataclasses.asdict(r) for r in replans])
    return arrays, meta


def run_controlled(topo: Topology, traffic: np.ndarray, cfg: SimConfig,
                   scenario: Scenario | None = None, *,
                   rates: list[float] | None = None,
                   seeds: list[int] | None = None,
                   bidor_table: BiDORTable | None = None,
                   nrank0: NRankResult | None = None,
                   sat_occupancy: float | None = None,
                   multi_device: bool | None = None,
                   checkpoint=None,
                   verbose: bool = False,
                   tracer=None) -> ControlledResult:
    """Run a simulation under an event schedule with a control policy.

    Lanes are the (rate, seed) grid, batched exactly as
    :func:`repro.noc.sim.run_sweep` (same per-point PRNG streams): with an
    empty scenario the chunked, hot-swapping loop is bit-identical to the
    single-call sweep (asserted by ``tests/test_ctrl.py``).
    ``multi_device`` selects the ``shard_map`` lane-parallel runner for
    every control epoch (semantics as in
    :func:`repro.noc.sim.get_runner`); the per-cycle transition itself —
    fused kernel vs. unfused jnp — follows ``cfg.use_kernel``, and both
    knobs leave every statistic bit-identical.

    The run advances in control epochs (``scenario.replan.epoch`` cycles,
    event cycles added as extra boundaries).  At each boundary the
    environment applies due events, the controller reads the on-device
    counters, and — policy permitting — re-plans and hot-swaps tables.

    ``checkpoint`` — optional epoch-boundary checkpointer (duck-typed:
    ``save(arrays, meta)`` persists a flat ``dict[str, np.ndarray]`` plus
    a JSON-able meta dict; ``load()`` returns the latest such pair or
    None).  At the top of every boundary the full run state (sim pytree,
    environment, estimator/detector, warm-start fixed point, replan log)
    is saved; on entry a stored snapshot is restored and the completed
    epochs skipped.  The boundary grid is deterministic, so the resumed
    run replays the identical chunk lengths (same cached compilations)
    and its results are bit-identical to the uninterrupted run
    (``tests/test_service.py``).

    ``tracer`` — optional :class:`repro.obs.trace.TraceWriter`; when
    present the loop emits ctrl-plane events (epoch spans, drift scores,
    detection firings, environment events, replan spans, table
    hot-swaps).  Epoch spans block on device completion to time real
    work, so tracing perturbs wall time but never results.
    """
    tracer = tracer if tracer is not None else NULL_TRACER
    log = EventLog(verbose=verbose)
    scenario = scenario or Scenario("static")
    rc = scenario.replan or ReplanConfig()
    policy = scenario.policy
    rates = [float(r) for r in (rates or [cfg.injection_rate])]
    seeds = [int(s) for s in (seeds or [cfg.seed])]
    points = [(r, s) for r in rates for s in seeds]

    table = bidor_table
    nr_prev = nrank0   # seed plan's fixed point: first replan warm-starts
    if cfg.algo == Algo.BIDOR:
        if table is None:
            plan0 = build_plan_fast(topo, traffic)
            table, nr_prev = plan0.table, plan0.nrank
    tables, meta = build_tables(
        topo, traffic, table if cfg.algo == Algo.BIDOR else None,
        cfg.num_vcs)
    batched = make_states(meta, cfg, points)
    q_meta = source_queue_meta(tables, cfg)   # refresh on gen retargets

    # environment state
    base_bw = np.asarray(topo.channel_bw, np.float64)
    bw = base_bw.copy()
    bw_hist = [(0, bw.copy())]   # (cycle, bw) — telemetry normalization
    cur_traffic = np.asarray(traffic, np.float64)
    cur_gen = cur_traffic    # what the sim currently *generates* from
    fault_pending = False
    cur_unroutable = None    # active admission-control mask (shed pairs)

    # the offline matrix rides along as the estimator's cold-start
    # prior (never the ground-truth *current* matrix — that would be
    # the oracle): a fault before any delivery still gets a plan
    estimator = TrafficEstimator(topo.num_nodes, ema=rc.ema,
                                 prior=traffic)
    detector = DriftDetector(threshold=rc.drift_threshold)
    replans: list[Replan] = []

    # boundary grid: control epochs ∪ event cycles ∪ end of run
    total = int(cfg.cycles)
    bounds = set(range(rc.epoch, total, rc.epoch)) | {total}
    bounds |= {int(e.cycle) for e in scenario.events if 0 < e.cycle < total}
    bounds = sorted(bounds)

    nlanes = len(points)
    prev_seq = np.zeros((nlanes,) + (meta["N"],) * 2, np.int64)
    prev_seen = np.zeros((nlanes, meta["C"]), np.int64)
    prev_fwd = np.zeros((nlanes, meta["C"]), np.int64)
    prev_meas = np.zeros(nlanes, np.int64)
    link_peak = np.zeros(nlanes)
    epoch_bounds = []
    sat_th = rc.sat_occupancy if sat_occupancy is None else sat_occupancy
    sat = np.zeros(nlanes, bool)

    # ---- resume from an epoch-boundary snapshot, if one exists ---- #
    resume_i = 0
    snap = checkpoint.load() if checkpoint is not None else None
    if snap is not None:
        arrays, cmeta = snap
        resume_i = int(cmeta["bound_i"])
        batched = maybe_shard_states(
            {k[2:]: jnp.asarray(v) for k, v in arrays.items()
             if k.startswith("s_")})
        sat = np.asarray(arrays["sat"], bool).copy()
        link_peak = np.asarray(arrays["link_peak"], np.float64).copy()
        bw = np.asarray(arrays["bw"], np.float64)
        if "bwh" in arrays and cmeta.get("bwh_cycles"):
            bwh = np.asarray(arrays["bwh"], np.float64)
            bw_hist = [(int(c), bwh[k].copy())
                       for k, c in enumerate(cmeta["bwh_cycles"])]
        else:   # pre-telemetry snapshot: current bw stands in for history
            bw_hist = [(0, bw.copy())]
        cur_traffic = np.asarray(arrays["cur_traffic"], np.float64)
        cur_gen = np.asarray(arrays["cur_gen"], np.float64)
        cur_unroutable = (np.asarray(arrays["cur_unroutable"], bool)
                          if "cur_unroutable" in arrays else None)
        fault_pending = bool(cmeta["fault_pending"])
        estimator._m = (np.asarray(arrays["est_m"], np.float64)
                        if "est_m" in arrays else None)
        detector._ref = (np.asarray(arrays["det_ref"], np.float64)
                         if "det_ref" in arrays else None)
        detector.last_distance = float(cmeta["last_distance"])
        replans = [Replan(**r) for r in cmeta["replans"]]
        if cmeta["has_nr"]:
            nr_prev = NRankResult(
                iterations=int(cmeta["nr_iterations"]),
                **{f: arrays[f"nr_{f}"] for f in _NR_FIELDS})
        # re-point the sim tables at the checkpointed environment (a
        # value-identical hot-swap: retarget is deterministic in its
        # inputs, so unchanged fields rebuild to the same values)
        choice = arrays.get("tab_choice")
        if choice is not None and table is not None:
            # keep the live table in sync so a LATER snapshot (second
            # interruption) records the replanned choice, not the seed's
            table = dataclasses.replace(table, choice=choice)
        tables = retarget_tables(
            tables, topo, traffic=cur_gen,
            choice=(choice if cfg.algo == Algo.BIDOR
                    and choice is not None else None),
            channel_bw=bw)
        q_meta = source_queue_meta(tables, cfg)
        prev_seq = np.asarray(arrays["s_next_seq"], np.int64)
        prev_seen = np.asarray(arrays["s_chan_seen"], np.int64)
        prev_fwd = np.asarray(arrays["s_chan_fwd"], np.int64)
        prev_meas = np.asarray(arrays["s_meas_cnt"], np.int64)
        t_prev = 0
        for j in range(resume_i):
            epoch_bounds.append((t_prev, bounds[j]))
            t_prev = bounds[j]

    t0 = bounds[resume_i - 1] if resume_i else 0
    for bound_i in range(resume_i, len(bounds)):
        t1 = bounds[bound_i]
        if checkpoint is not None and bound_i > resume_i:
            checkpoint.save(*_ctrl_snapshot(
                batched, bound_i=bound_i, sat=sat, link_peak=link_peak,
                bw=bw, cur_traffic=cur_traffic, cur_gen=cur_gen,
                cur_unroutable=cur_unroutable,
                fault_pending=fault_pending, estimator=estimator,
                detector=detector, replans=replans, table=table,
                nr_prev=nr_prev, bw_hist=bw_hist))
        runner = get_runner(meta, cfg, t1 - t0, num_lanes=nlanes,
                            multi_device=multi_device)
        te0 = tracer.now_us() if tracer.enabled else 0.0
        batched = runner(tables, batched)
        if tracer.enabled:
            # block so the span times the device work, not the dispatch
            jax.block_until_ready(batched)
            tracer.complete(
                "epoch", te0, tracer.now_us() - te0, cat="sim",
                args={"t0": t0, "t1": t1, "scenario": scenario.name,
                      "policy": policy})
        epoch_bounds.append((t0, t1))
        t0 = t1

        # ---- read counters (one small host transfer) ---- #
        seq = np.asarray(jax.device_get(batched["next_seq"]), np.int64)
        seen = np.asarray(jax.device_get(batched["chan_seen"]), np.int64)
        fwd = np.asarray(jax.device_get(batched["chan_fwd"]), np.int64)
        meas = np.asarray(jax.device_get(batched["meas_cnt"]), np.int64)
        d_seq, d_seen = seq - prev_seq, seen - prev_seen
        d_fwd, d_meas = fwd - prev_fwd, meas - prev_meas
        prev_seq, prev_seen, prev_fwd, prev_meas = seq, seen, fwd, meas

        # time-resolved max normalized link load (this epoch's bw)
        live = bw > 0
        for i in range(nlanes):
            if d_meas[i] > 0 and live.any():
                loads = d_fwd[i, live] / float(d_meas[i]) / bw[live]
                link_peak[i] = max(link_peak[i], float(loads.max()))

        if t1 > cfg.warmup:
            # saturation accumulates from post-warmup reads only — a
            # transient warmup spike must not permanently latch a lane
            sat |= queue_occupancy(tables, cfg, batched["q_size"],
                                   q_meta) >= sat_th

        estimator.update(d_seq.sum(axis=0))
        drifted = detector.update(d_seen.sum(axis=0))
        if tracer.enabled:
            tracer.counter("drift_tv", {"tv": detector.last_distance},
                           cat="ctrl")
            if drifted:
                tracer.instant(
                    "drift_detected", cat="ctrl",
                    args={"cycle": t1, "tv": detector.last_distance})

        if t1 >= total:
            break

        # ---- apply due events (the environment) ---- #
        due = [e for e in scenario.events if e.cycle == t1]
        event_kinds: set = set()
        if due:
            bw, new_traffic, rate_scale, event_kinds = _apply_events(
                due, bw, topo, base_bw)
            if tracer.enabled:
                for ev in due:
                    a = {"cycle": t1}
                    if isinstance(ev, LinkFail):
                        a["bw_scale"] = ev.bw_scale
                    tracer.instant(type(ev).__name__, cat="env", args=a)
            if "fault" in event_kinds:
                bw_hist.append((t1, bw.copy()))
            gen_traffic = new_traffic
            if new_traffic is not None and cur_unroutable is not None:
                # an active shed outlives a traffic epoch: the dead link
                # is still dead, so the new matrix generates under the
                # same admission-control mask until the next replan
                gen_traffic = np.where(cur_unroutable, 0.0, new_traffic)
            tables = retarget_tables(
                tables, topo,
                traffic=gen_traffic,
                channel_bw=bw if "fault" in event_kinds else None)
            if gen_traffic is not None:
                cur_gen = gen_traffic
                q_meta = source_queue_meta(tables, cfg)
            if new_traffic is not None:
                cur_traffic = new_traffic
            if rate_scale is not None:
                # absolute vs base: rate_scale=1.0 restores the original
                # injection rates after a previously scaled epoch
                batched["rate"] = jnp.asarray(
                    [r * rate_scale for r, _ in points], jnp.float32)
            fault_pending |= "fault" in event_kinds

        # ---- control decision ---- #
        if cfg.algo != Algo.BIDOR or policy == "stale":
            continue
        if policy == "oracle":
            do, trigger, m = bool(due), "event", cur_traffic
        else:  # online
            # faults are signalled out of band (hardware link state, as in
            # real fabrics); traffic drift must be *detected*
            trigger = "fault" if fault_pending else "drift"
            do = fault_pending or drifted
            # estimator.matrix backs off to the offline prior until the
            # first packets arrive, so a cold-start fault replans from
            # the plan-time statistics; None only when there is no
            # demand to plan for at all
            m = estimator.matrix
            if m is None:
                do = False
        if not do:
            continue
        drift_dist = detector.last_distance
        tr0 = tracer.now_us() if tracer.enabled else 0.0
        table, nr_prev = replan(
            topo, m, bw, nr_prev,
            warm=rc.warm, greedy_sweeps=rc.greedy_sweeps, tracer=tracer)
        # hot-swap guard: a replan that sheds most of the demanded pairs
        # would silently wedge the run behind a near-empty table — keep
        # the previous (still-certified) table and record the rejection
        if table.unroutable is not None:
            demanded = np.asarray(cur_traffic) > 0
            n_dem = int(demanded.sum())
            shed_frac = (int((table.unroutable & demanded).sum()) / n_dem
                         if n_dem else 0.0)
            if shed_frac > rc.max_shed:
                if tracer.enabled:
                    tracer.instant(
                        "hot_swap_rejected", cat="ctrl",
                        args={"cycle": t1, "trigger": trigger,
                              "shed_frac": round(shed_frac, 4),
                              "max_shed": rc.max_shed})
                log.event("replan_rejected",
                          f"ctrl[{scenario.name}/{policy}] hot-swap "
                          f"rejected @ {t1}: shed {shed_frac:.0%} > "
                          f"max {rc.max_shed:.0%}", cycle=t1,
                          trigger=trigger)
                detector.reset()
                fault_pending = False
                continue
        # admission control: shed unroutable pairs from generation; when
        # the new plan can serve everything (e.g. after LinkRecover),
        # restore the full current matrix — a previous shed must not
        # outlive the fault that caused it
        gen = cur_traffic
        cur_unroutable = None
        if table.unroutable is not None and table.unroutable.any():
            cur_unroutable = table.unroutable
            gen = np.where(cur_unroutable, 0.0, cur_traffic)
        tables = retarget_tables(tables, topo, choice=table.choice,
                                 traffic=gen)
        cur_gen = gen
        q_meta = source_queue_meta(tables, cfg)
        detector.reset()
        fault_pending = False
        replans.append(Replan(
            cycle=t1, trigger=trigger, iterations=nr_prev.iterations,
            unroutable_pairs=int(table.unroutable.sum())
            if table.unroutable is not None else 0,
            drift_distance=drift_dist))
        if tracer.enabled:
            tracer.complete(
                "replan", tr0, tracer.now_us() - tr0, cat="ctrl",
                args={"cycle": t1, "trigger": trigger,
                      "warm": rc.warm and nr_prev is not None,
                      "iterations": int(nr_prev.iterations),
                      "unroutable": replans[-1].unroutable_pairs,
                      "drift_tv": drift_dist})
            tracer.instant("hot_swap", cat="ctrl", args={"cycle": t1})
        log.event("replan",
                  f"ctrl[{scenario.name}/{policy}] replan @ {t1} "
                  f"({trigger}), {nr_prev.iterations} iters",
                  cycle=t1, trigger=trigger)

    results = []
    host = jax.device_get(batched)
    for i, (rate, seed) in enumerate(points):
        o = jax.tree.map(lambda x: x[i], host)
        results.append(postprocess(o, cfg, topo, rate=rate, seed=seed,
                                   saturated=bool(sat[i])))
    telemetry = Telemetry.from_state(host, cfg)
    if telemetry is not None:
        telemetry = telemetry.with_bw(_bw_slots(
            bw_hist, resolved_epoch(cfg), cfg.tel_slots, total))
    watchdog = WatchdogReport.from_state(host, cfg)
    if watchdog is not None and watchdog.tripped and tracer.enabled:
        tracer.instant("watchdog_tripped", cat="ctrl",
                       args=watchdog.trace_args())
    return ControlledResult(
        scenario=scenario.name, policy=policy, points=points,
        results=results, replans=replans, link_peak=link_peak,
        epoch_bounds=epoch_bounds, telemetry=telemetry,
        watchdog=watchdog)
