"""Flit-level NoC simulation substrate (the paper's BookSim2 role)."""

from .simconfig import Algo, SimConfig, SimResult
from .sim import run_sim

__all__ = ["Algo", "SimConfig", "SimResult", "run_sim"]
