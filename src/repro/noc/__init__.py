"""Flit-level NoC simulation substrate (the paper's BookSim2 role)."""

from .simconfig import Algo, SimConfig, SimResult
from .sim import run_sim, run_sweep, run_trace, run_trace_sweep
from .campaign import (CampaignExecutor, CampaignPoint, CampaignResult,
                       CampaignSpec, CellKey, CellOutcome, campaign_cells,
                       run_campaign)
from .ctrl import (ControlledResult, DriftDetector, LinkFail, LinkRecover,
                   Replan, ReplanConfig, Scenario, TrafficDrift,
                   TrafficEstimator, run_controlled)
from .service import (CampaignJob, CellCheckpoint, JobStatus,
                      run_campaign_service, spec_fingerprint)
from .chaos import (ChaosConfig, chaos_scenarios, chaos_schedule,
                    hotspot_traffic, region_links)
from .mltraffic import MLWorkload, WorkloadSpec, derive_workload
from .watchdog import WatchdogReport

__all__ = ["Algo", "SimConfig", "SimResult", "run_sim", "run_sweep",
           "run_trace", "run_trace_sweep", "CampaignSpec", "CampaignPoint",
           "CampaignResult", "run_campaign", "CampaignExecutor", "CellKey",
           "CellOutcome", "campaign_cells",
           "ControlledResult", "DriftDetector", "LinkFail", "LinkRecover",
           "Replan", "ReplanConfig", "Scenario", "TrafficDrift",
           "TrafficEstimator", "run_controlled",
           "CampaignJob", "CellCheckpoint", "JobStatus",
           "run_campaign_service", "spec_fingerprint",
           "ChaosConfig", "chaos_schedule", "chaos_scenarios",
           "hotspot_traffic", "region_links", "WatchdogReport",
           "MLWorkload", "WorkloadSpec", "derive_workload"]
