"""Chaos scenario generator: seeded compound fault/drift schedules.

The control-plane tests exercise *single* events (one link failure, one
drift).  Real networks fail in bursts: links flap repeatedly, whole
regions die while a replan is still settling, and traffic shifts land
back-to-back with faults.  This module composes the existing event
vocabulary (:class:`repro.noc.ctrl.LinkFail` / ``LinkRecover`` /
``TrafficDrift``) into deterministic *storms* from a single seed, so a
chaos campaign is exactly as replayable as any other scenario — the
same seed always produces the same schedule, which is what lets the
chaos benchmark assert kill-and-resume byte-identity mid-storm.

Three compound patterns, freely mixed by :func:`chaos_schedule`:

* **link-flap storm** — a cluster of bidirectional links fails and
  recovers on a short period, several times in a row (the classic
  flapping-transceiver signature).  Replanning against a flap is a
  trap: the online policy sees a fault, replans, and the link is back
  before the new table settles.
* **region failure** — every link incident to a contiguous node region
  dies at once (power-domain loss).  Scheduled one control epoch after
  a drift event, so the replan triggered by the drift is still in
  flight when the region disappears — the hot-swap guard
  (:class:`repro.noc.ctrl.ReplanConfig` ``max_shed``) is what keeps a
  mostly-shed emergency table from being installed.
* **traffic drift** — the generation matrix swaps to a seeded hotspot
  pattern (optionally rate-scaled), back-to-back with the faults.

Everything returns plain :class:`repro.noc.ctrl.Scenario` objects, so
chaos schedules run through the unmodified control loop, the campaign
service, and the flight recorder.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.topology import Topology
from .ctrl import LinkFail, LinkRecover, Scenario, TrafficDrift

__all__ = ["ChaosConfig", "hotspot_traffic", "region_links",
           "chaos_schedule", "chaos_scenarios"]


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Shape of one seeded chaos schedule (cycles are absolute)."""

    seed: int = 0
    start: int = 1_000          # first event lands here
    horizon: int = 10_000       # last event strictly before this cycle
    flap_storms: int = 2        # link-flap storm count
    flap_links: int = 3         # bidirectional links per storm
    flap_bursts: int = 3        # fail->recover rounds per storm
    flap_period: int = 300      # cycles between a fail and its recover
    region_failures: int = 1    # region-loss events
    region_radius: int = 1      # Chebyshev radius of the lost region
    drift_events: int = 2       # traffic-swap events
    drift_hotspots: int = 4     # hot destinations per drifted matrix
    drift_rate_scale: float = 1.0
    bw_scale: float = 0.0       # 0 = hard failure, (0, 1) = degrade


def hotspot_traffic(num_nodes: int, rng: np.random.Generator,
                    hotspots: int = 4, weight: float = 8.0) -> np.ndarray:
    """Uniform background + ``hotspots`` hot destination columns."""
    m = np.ones((num_nodes, num_nodes), np.float64)
    hot = rng.choice(num_nodes, size=min(hotspots, num_nodes),
                     replace=False)
    m[:, hot] *= weight
    np.fill_diagonal(m, 0.0)
    return m / m.sum()


def region_links(topo: Topology, center: int,
                 radius: int = 1) -> tuple[tuple[int, int], ...]:
    """All directed channels incident to the node region within
    Chebyshev ``radius`` of ``center`` (both directions — the region
    goes fully dark, like a power-domain loss)."""
    coords = np.asarray(topo.coords)
    cheb = np.abs(coords - coords[center]).max(axis=1)
    region = set(np.flatnonzero(cheb <= radius).tolist())
    return tuple((u, v) for (u, v) in topo.chan_id
                 if u in region or v in region)


def _undirected_links(topo: Topology) -> list[tuple[int, int]]:
    """Deduplicated undirected link list (u < v), deterministic order."""
    seen = set()
    out = []
    for (u, v) in topo.chan_id:
        key = (min(u, v), max(u, v))
        if key not in seen:
            seen.add(key)
            out.append(key)
    return out


def chaos_schedule(topo: Topology, cc: ChaosConfig = ChaosConfig(),
                   *, policy: str = "online",
                   replan=None) -> Scenario:
    """Compose one seeded compound schedule into a :class:`Scenario`.

    Event cycles are spread deterministically over
    ``[cc.start, cc.horizon)``; ties are resolved by stable sort, so the
    schedule satisfies the Scenario ordering contract for any config."""
    rng = np.random.default_rng(cc.seed)
    n = topo.num_nodes
    links = _undirected_links(topo)
    events: list = []

    # window per compound pattern, so storms don't all pile on cc.start
    total = cc.flap_storms + cc.region_failures + cc.drift_events
    span = max(cc.horizon - cc.start, 1)
    slots = iter(np.linspace(cc.start, cc.start + span,
                             num=max(total, 1), endpoint=False))

    for _ in range(cc.flap_storms):
        t0 = int(next(slots))
        pick = rng.choice(len(links), size=min(cc.flap_links, len(links)),
                          replace=False)
        flap = tuple(pair for i in pick
                     for pair in ((links[i][0], links[i][1]),
                                  (links[i][1], links[i][0])))
        for b in range(cc.flap_bursts):
            t_fail = t0 + 2 * b * cc.flap_period
            t_rec = t_fail + cc.flap_period
            if t_rec >= cc.horizon:
                break
            events.append(LinkFail(cycle=max(t_fail, 1), links=flap,
                                   bw_scale=cc.bw_scale))
            events.append(LinkRecover(cycle=t_rec, links=flap))

    for _ in range(cc.drift_events):
        t0 = int(next(slots))
        events.append(TrafficDrift(
            cycle=max(t0, 1),
            traffic=hotspot_traffic(n, rng, cc.drift_hotspots),
            rate_scale=cc.drift_rate_scale))

    epoch = getattr(replan, "epoch", 500) if replan is not None else 500
    for _ in range(cc.region_failures):
        t0 = int(next(slots))
        center = int(rng.integers(n))
        # one control epoch after the slot start: when the slot carries
        # a drift (above), the replan it triggers is still settling
        t_fail = min(max(t0 + epoch, 1), cc.horizon - 1)
        events.append(LinkFail(cycle=t_fail,
                               links=region_links(topo, center,
                                                  cc.region_radius),
                               bw_scale=cc.bw_scale))

    events.sort(key=lambda e: e.cycle)
    return Scenario(name=f"chaos-s{cc.seed}", events=tuple(events),
                    policy=policy, replan=replan)


def chaos_scenarios(topo: Topology, seeds, *, policy: str = "online",
                    replan=None,
                    base: ChaosConfig = ChaosConfig()) -> list[Scenario]:
    """One :func:`chaos_schedule` per seed (same shape, different draws)."""
    return [chaos_schedule(topo, dataclasses.replace(base, seed=int(s)),
                           policy=policy, replan=replan)
            for s in seeds]
