"""Campaign-as-a-service: resumable, cached, streaming mega-sweeps.

``run_campaign`` is one blocking call — fine for a minute-long grid,
useless for the hours-long sweeps behind the paper's headline numbers,
which must survive preemption and stream partial results.  This module
makes a campaign a *job*:

* **Per-cell checkpointing.**  A cell — one (topo, pattern item, algo,
  scenario) batch — is the unit of work (``repro.noc.campaign``'s
  resumable cell machinery).  As each completes, its per-lane
  ``SimResult``s, saturation flags and wall-clock land under
  ``artifacts/campaigns/<job_id>/cells/`` as one atomic npz (the
  ``repro.train.checkpoint`` write-then-rename idiom), and its CSV rows
  are appended to the job's ``results.csv``.
* **Mid-cell checkpointing.**  Scenario cells additionally snapshot the
  full control-loop state at every epoch boundary
  (``run_controlled(checkpoint=...)``), so even a single hours-long
  dynamic cell resumes from its last boundary instead of cycle 0.
* **Resume is bit-identical.**  The job manifest is keyed on a content
  hash of the ``CampaignSpec`` (:func:`spec_fingerprint`); re-running the
  same spec against the same directory skips completed cells, re-emits
  their stored results, and continues.  Cells are deterministic given the
  spec (per-point PRNG streams, deterministic boundary grids), so the
  final ``CampaignResult`` — and the final ``results.csv``, byte for
  byte — is identical however many times the job was interrupted
  (``tests/test_service.py``).
* **Plan caching.**  Jobs share a persistent
  :class:`repro.core.plan_cache.PlanCache` (default
  ``<root>/plan-cache``), keyed on (topology fingerprint, traffic matrix
  bytes, fault mask, hyper-parameters): a warm re-run rebuilds zero
  plans — ``build_plans_batched`` is not called at all.
* **Streaming.**  ``results.csv`` grows append-only while the job runs;
  a resume rewrites it from the completed cells' checkpoints (identical
  bytes — the stream is derived state, the npz cells are truth) before
  appending fresh cells.  Partial results are usable mid-flight.

The driver is :class:`CampaignJob`: synchronous ``run()`` (optionally
budgeted via ``max_cells`` — the interruption knob CI's
resume-equivalence check uses), async ``start()``/``wait()`` on a
daemon thread, ``status()``/``result()`` accessors.
:func:`run_campaign_service` wraps the common run-to-completion case.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
import time

import numpy as np

from repro.core.plan_cache import PlanCache, topology_fingerprint
from repro.obs.probe import Telemetry
from repro.obs.trace import NULL_TRACER, TraceWriter
from .campaign import (CampaignExecutor, CampaignPoint, CampaignResult,
                       CampaignSpec, CellKey, CellOutcome, campaign_cells,
                       csv_rows)
from .simconfig import Algo, SimConfig, SimResult

__all__ = ["CampaignJob", "JobStatus", "CellCheckpoint",
           "run_campaign_service", "spec_fingerprint"]

DEFAULT_ROOT = os.path.join("artifacts", "campaigns")


# --------------------------------------------------------------------- #
# spec fingerprinting (the manifest key)
# --------------------------------------------------------------------- #
def _traffic_hash(tm) -> str:
    import hashlib
    a = np.ascontiguousarray(np.asarray(tm, np.float64))
    return hashlib.sha256(a.tobytes()).hexdigest()


def _event_desc(ev) -> dict:
    d = {"kind": type(ev).__name__, "cycle": int(ev.cycle)}
    if hasattr(ev, "links"):
        d["links"] = [[int(u), int(n)] for u, n in ev.links]
    if hasattr(ev, "bw_scale"):
        d["bw_scale"] = float(ev.bw_scale)
    if hasattr(ev, "traffic"):
        d["traffic"] = _traffic_hash(ev.traffic)
    if hasattr(ev, "rate_scale"):
        d["rate_scale"] = float(ev.rate_scale)
    return d


# SimConfig fields that never change results — observability probes are
# bit-identity-neutral (tests/test_obs.py), so toggling telemetry on a
# spec must resume the SAME job, exactly like multi_device below.
# sim_tile_nodes only picks the kernel schedule (whole/blocked/dense are
# bit-identical, tests/test_simstep_kernel.py), so it rides along too.
_OBS_FIELDS = frozenset({"telemetry", "tel_epoch", "tel_slots",
                         "tel_occ_bins", "sim_tile_nodes"})


def spec_fingerprint(spec: CampaignSpec) -> str:
    """Content hash of everything that determines a campaign's results.

    Topologies hash by full content (:func:`topology_fingerprint`),
    explicit traffic matrices by bytes, scenarios by their event
    schedules (drift matrices hashed) and replan knobs.  ``multi_device``
    and the telemetry knobs (``_OBS_FIELDS``) are deliberately EXCLUDED:
    lane sharding and probe collection are bit-identical by construction,
    so a job may resume on a different device count or with telemetry
    newly enabled.
    """
    import hashlib
    desc = {
        "topos": [topology_fingerprint(t) for t in spec.topo_axis],
        "algos": [a.name for a in spec.algos],
        "patterns": [p if isinstance(p, str)
                     else [str(p[0]), _traffic_hash(p[1])]
                     for p in spec.patterns],
        # ML workloads hash by name + derived rank-flow bytes (topology
        # independent; the per-topology embedding is deterministic)
        "workloads": [[str(w.name), _traffic_hash(w.campaign_flows())]
                      if hasattr(w, "matrix_for")
                      else [str(w[0]), _traffic_hash(w[1])]
                      for w in spec.workloads],
        "rates": [float(r) for r in spec.rates],
        "seeds": [int(s) for s in spec.seeds],
        "base": {f.name: (int(v) if isinstance(v, (bool, int, Algo))
                          else float(v))
                 for f in dataclasses.fields(SimConfig)
                 if f.name not in _OBS_FIELDS
                 for v in [getattr(spec.base, f.name)]},
        "chunk": int(spec.chunk),
        "sat_occupancy": float(spec.sat_occupancy),
        "scenarios": [{
            "name": s.name, "policy": s.policy,
            "events": [_event_desc(e) for e in s.events],
            "replan": (dataclasses.asdict(s.replan)
                       if s.replan is not None else None),
        } for s in spec.scenarios],
    }
    blob = json.dumps(desc, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


# --------------------------------------------------------------------- #
# atomic file helpers (the repro.train.checkpoint idiom)
# --------------------------------------------------------------------- #
def _sha256_file(path: str) -> str:
    import hashlib
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _write_sidecar(path: str) -> None:
    """Record ``path``'s content hash next to it (integrity sidecar)."""
    _atomic_write_text(path + ".sha256", _sha256_file(path) + "\n")


def _verify_sidecar(path: str) -> bool:
    """True iff ``path`` matches its sidecar.  A file without a sidecar
    (pre-hardening layout) passes — corruption there still surfaces as a
    load failure, which callers also treat as corrupt."""
    side = path + ".sha256"
    if not os.path.exists(side):
        return True
    with open(side) as f:
        return f.read().strip() == _sha256_file(path)


def _atomic_savez(path: str, payload: dict) -> None:
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _atomic_write_text(path: str, text: str) -> None:
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


class CellCheckpoint:
    """Single-file atomic (arrays, meta) checkpoint — the duck-typed
    epoch-boundary checkpointer ``run_controlled`` consumes.  Meta rides
    inside the npz as a JSON bytes array, so save/replace is one atomic
    rename and a partial write can never be observed.

    Every save records a sha256 sidecar; ``load`` verifies it (and the
    npz parse itself) and treats any mismatch as *no checkpoint*: the
    corrupt file is set aside as ``<path>.corrupt`` and the cell restarts
    from cycle 0 — a slower resume, never a wrong one."""

    def __init__(self, path: str):
        self.path = str(path)

    def save(self, arrays: dict, meta: dict) -> None:
        payload = dict(arrays)
        payload["__meta__"] = np.frombuffer(
            json.dumps(meta).encode(), np.uint8)
        _atomic_savez(self.path, payload)
        _write_sidecar(self.path)

    def load(self):
        if not os.path.exists(self.path):
            return None
        try:
            if not _verify_sidecar(self.path):
                raise ValueError("checkpoint sha256 mismatch")
            with np.load(self.path, allow_pickle=False) as z:
                d = {k: z[k] for k in z.files}
            meta = json.loads(bytes(d.pop("__meta__")).decode())
            return d, meta
        except Exception:
            os.replace(self.path, self.path + ".corrupt")
            side = self.path + ".sha256"
            if os.path.exists(side):
                os.unlink(side)
            return None

    def clear(self) -> None:
        for p in (self.path, self.path + ".sha256"):
            if os.path.exists(p):
                os.unlink(p)


# --------------------------------------------------------------------- #
# cell outcome (de)serialization
# --------------------------------------------------------------------- #
_RESULT_FIELDS = [f.name for f in dataclasses.fields(SimResult)]


def _save_outcome(path: str, outcome: CellOutcome) -> None:
    payload = {"wall_s": np.float64(outcome.wall_s)}
    for name in _RESULT_FIELDS:
        vals = [getattr(r, name) for r in outcome.results]
        if name == "node_load":
            payload[name] = np.stack([np.asarray(v, np.float64)
                                      for v in vals])
        elif name == "algo":
            payload[name] = np.asarray([int(v) for v in vals], np.int64)
        else:
            payload[name] = np.asarray(vals)
    _atomic_savez(path, payload)
    _write_sidecar(path)


def _load_outcome(path: str, key: CellKey) -> CellOutcome:
    with np.load(path, allow_pickle=False) as z:
        d = {k: z[k] for k in z.files}
    n = d["algo"].shape[0]
    results = []
    for i in range(n):
        kw = {}
        for name in _RESULT_FIELDS:
            v = d[name][i]
            if name == "node_load":
                kw[name] = np.asarray(v, np.float64)
            elif name == "algo":
                kw[name] = Algo(int(v))
            elif v.dtype == np.bool_:
                kw[name] = bool(v)
            elif np.issubdtype(v.dtype, np.integer):
                kw[name] = int(v)
            else:
                kw[name] = float(v)
        results.append(SimResult(**kw))
    return CellOutcome(key=key, results=results,
                       wall_s=float(d["wall_s"]))


# --------------------------------------------------------------------- #
# the job
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class JobStatus:
    job_id: str
    total_cells: int
    done_cells: int
    running: bool
    complete: bool
    # live-progress fields (readable while the background thread runs)
    in_flight: str | None = None     # slug of the executing cell
    error: str | None = None         # repr of a failed run's exception
    eta_s: float | None = None       # remaining-cell estimate from
    #                                  this process's mean cell wall


class CampaignJob:
    """A campaign as a resumable on-disk job (see module docstring).

    ``root/<job_id>/`` layout::

        manifest.json    spec fingerprint + cell table (written once)
        cells/<slug>.npz completed-cell results (atomic, one per cell)
        ckpt/<slug>.npz  epoch-boundary snapshot of the in-flight
                         scenario cell (deleted when the cell completes)
        results.csv      streaming CSV, appended as cells complete

    ``job_id`` defaults to a prefix of the spec fingerprint, so the same
    spec always maps to the same directory and ``resume=True`` (the
    default) picks up exactly where a previous process stopped.  A
    directory whose manifest hashes a *different* spec is refused.

    ``plan_cache``: a :class:`PlanCache`, a directory path, ``"shared"``
    (default — ``<root>/plan-cache``, shared by every job under the
    root), or None to disable plan caching.

    **Chaos hardening.**  Every stored cell npz carries a sha256
    sidecar; a cached cell that fails verification (or fails to parse)
    is moved to ``cells/quarantine/`` and recomputed — corruption costs
    a re-run, never a wrong result.  Executing a cell retries up to
    ``max_retries`` times with exponential backoff; a cell that still
    fails is recorded as a ``cell_error`` event in ``metrics.jsonl`` and
    the job *continues* — one poisoned cell cannot take down an
    hours-long campaign (``run()`` then returns False so callers re-run
    or investigate).
    """

    def __init__(self, spec: CampaignSpec, *, root: str = DEFAULT_ROOT,
                 job_id: str | None = None,
                 bidor_tables: dict[str, np.ndarray] | None = None,
                 plan_cache="shared",
                 resume: bool = True,
                 verbose: bool = False,
                 trace: bool = False,
                 max_retries: int = 2,
                 retry_backoff_s: float = 0.5):
        self.spec = spec
        self.fingerprint = spec_fingerprint(spec)
        self.job_id = job_id or f"job-{self.fingerprint[:12]}"
        self.dir = os.path.join(root, self.job_id)
        self.cells_dir = os.path.join(self.dir, "cells")
        self.quarantine_dir = os.path.join(self.cells_dir, "quarantine")
        self.ckpt_dir = os.path.join(self.dir, "ckpt")
        self.csv_path = os.path.join(self.dir, "results.csv")
        self.metrics_path = os.path.join(self.dir, "metrics.jsonl")
        self.trace_path = os.path.join(self.dir, "trace.jsonl")
        self.verbose = verbose
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        if plan_cache == "shared":
            plan_cache = PlanCache(os.path.join(root, "plan-cache"))
        elif isinstance(plan_cache, str):
            plan_cache = PlanCache(plan_cache)
        self.plan_cache = plan_cache
        self.cells = campaign_cells(spec)
        # progress shared with status(): guarded so a concurrent reader
        # never sees a torn (done, in_flight, walls) triple
        self._lock = threading.Lock()
        self._in_flight: str | None = None
        self._done: int | None = None    # None ⇔ no run() in this process
        self._walls: list[float] = []    # executed-cell walls (ETA basis)
        os.makedirs(self.cells_dir, exist_ok=True)
        os.makedirs(self.quarantine_dir, exist_ok=True)
        os.makedirs(self.ckpt_dir, exist_ok=True)
        self._init_manifest(resume)
        # after _init_manifest: a resume=False wipe must not unlink the
        # trace file out from under an already-open writer
        self.tracer = (TraceWriter(self.trace_path) if trace
                       else NULL_TRACER)
        self.executor = CampaignExecutor(
            spec, bidor_tables=bidor_tables, plan_cache=plan_cache,
            verbose=verbose, tracer=self.tracer)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # ------------------------------------------------------------- #
    def _init_manifest(self, resume: bool) -> None:
        path = os.path.join(self.dir, "manifest.json")
        if os.path.exists(path):
            with open(path) as f:
                manifest = json.load(f)
            if manifest["spec_fingerprint"] != self.fingerprint:
                raise ValueError(
                    f"job dir {self.dir} holds a different campaign "
                    f"(manifest fingerprint "
                    f"{manifest['spec_fingerprint'][:12]}..., this spec "
                    f"{self.fingerprint[:12]}...); pick another job_id")
            if not resume:
                for k in self.cells:
                    cp = self._cell_path(k)
                    for p in (cp, cp + ".sha256", self._tel_path(k)):
                        if os.path.exists(p):
                            os.unlink(p)
                    CellCheckpoint(self._ckpt_path(k)).clear()
                for p in (self.csv_path, self.metrics_path,
                          self.trace_path):
                    if os.path.exists(p):
                        os.unlink(p)
                if os.path.isdir(self.quarantine_dir):
                    for name in os.listdir(self.quarantine_dir):
                        os.unlink(os.path.join(self.quarantine_dir, name))
            return
        manifest = {
            "job_id": self.job_id,
            "spec_fingerprint": self.fingerprint,
            "created_unix": time.time(),
            "num_points": self.spec.num_points,
            "num_cells": len(self.cells),
            "csv_header": CampaignResult.CSV_HEADER,
            "cells": [{
                "index": k.index, "slug": k.slug, "topo": k.topo,
                "pattern": k.pattern, "algo": k.algo.name,
                "scenario": k.scenario, "workload": k.workload,
            } for k in self.cells],
        }
        _atomic_write_text(path, json.dumps(manifest, indent=1))

    def _cell_path(self, key: CellKey) -> str:
        return os.path.join(self.cells_dir, f"{key.slug}.npz")

    def _quarantine_cell(self, key: CellKey) -> str:
        """Move a corrupt cell npz (and sidecar) out of the cache so the
        run loop recomputes it; returns the quarantine path."""
        path = self._cell_path(key)
        dest = os.path.join(self.quarantine_dir, os.path.basename(path))
        os.replace(path, dest)
        side = path + ".sha256"
        if os.path.exists(side):
            os.replace(side, dest + ".sha256")
        return dest

    def _load_cell(self, key: CellKey) -> "CellOutcome | None":
        """Verified load of a completed cell: sha256 sidecar first, then
        the npz parse itself.  Any failure quarantines the file and
        returns None — the caller recomputes the cell."""
        path = self._cell_path(key)
        try:
            if not _verify_sidecar(path):
                raise ValueError("cell sha256 mismatch")
            return _load_outcome(path, key)
        except Exception:
            self._quarantine_cell(key)
            return None

    def _tel_path(self, key: CellKey) -> str:
        return os.path.join(self.cells_dir, f"{key.slug}.telemetry.npz")

    def _ckpt_path(self, key: CellKey) -> str:
        return os.path.join(self.ckpt_dir, f"{key.slug}.npz")

    def cell_telemetry(self, key: CellKey) -> "Telemetry | None":
        """A completed cell's saved probe rings (None when the cell ran
        with telemetry off or has not completed)."""
        path = self._tel_path(key)
        return Telemetry.load(path) if os.path.exists(path) else None

    # ------------------------------------------------------------- #
    def completed_cells(self) -> list[CellKey]:
        return [k for k in self.cells
                if os.path.exists(self._cell_path(k))]

    def status(self) -> JobStatus:
        """Live job progress; safe to call concurrently with ``start()``.

        While a run is active in this process the counters come from the
        run loop's lock-guarded progress state — not a directory rescan,
        which could tear against a half-written cell and is stale for the
        in-flight cell anyway.  With no run in this process it falls back
        to counting cell checkpoints on disk.
        """
        with self._lock:
            done, in_flight = self._done, self._in_flight
            walls = list(self._walls)
            err = self._error
        if done is None:                  # no run() in this process yet
            done = len(self.completed_cells())
        eta = None
        if walls and done < len(self.cells):
            eta = (len(self.cells) - done) * (sum(walls) / len(walls))
        return JobStatus(
            job_id=self.job_id, total_cells=len(self.cells),
            done_cells=done,
            running=self._thread is not None and self._thread.is_alive(),
            complete=done == len(self.cells),
            in_flight=in_flight,
            error=repr(err) if err is not None else None,
            eta_s=eta)

    # ------------------------------------------------------------- #
    def _append_csv(self, f, outcome: CellOutcome) -> None:
        for row in csv_rows(self.executor.cell_points(outcome)):
            f.write(",".join(str(v) for v in row) + "\n")
        f.flush()

    def _emit_metric(self, f, record: dict) -> None:
        record = dict(record, t_unix=round(time.time(), 3))
        f.write(json.dumps(record, sort_keys=True) + "\n")
        f.flush()

    def _cell_metric(self, key: CellKey, *, done: int, cached: bool,
                     wall_s: float) -> dict:
        rec = {"event": "cell", "cell": key.slug, "index": key.index,
               "cached": cached, "done": done, "total": len(self.cells),
               "wall_s": round(wall_s, 4)}
        if key.workload:
            rec["workload"] = key.workload
        if not cached and wall_s > 0:
            rec["lanes_per_s"] = round(
                len(self.executor.points) / wall_s, 3)
        with self._lock:
            walls = list(self._walls)
        if walls and done < len(self.cells):
            rec["eta_s"] = round(
                (len(self.cells) - done) * sum(walls) / len(walls), 2)
        if self.plan_cache is not None:
            rec["plan_cache"] = self.plan_cache.stats.as_dict()
        return rec

    def _run_cell_with_retry(self, key: CellKey, ckpt, mf):
        """Bounded retry-with-backoff around one cell execution; returns
        the outcome, or None after ``max_retries + 1`` failed attempts
        (the terminal error is recorded as a ``cell_error`` metric)."""
        err = None
        for attempt in range(self.max_retries + 1):
            try:
                return self.executor.run_cell(
                    key, checkpoint=ckpt if key.scen_i >= 0 else None)
            except Exception as e:      # noqa: BLE001 — isolate the cell
                err = e
                self._emit_metric(mf, {
                    "event": "cell_retry", "cell": key.slug,
                    "attempt": attempt + 1,
                    "max_attempts": self.max_retries + 1,
                    "error": repr(e)})
                if attempt < self.max_retries:
                    time.sleep(self.retry_backoff_s * (2 ** attempt))
        self._emit_metric(mf, {
            "event": "cell_error", "cell": key.slug, "index": key.index,
            "attempts": self.max_retries + 1, "error": repr(err)})
        return None

    def run(self, max_cells: int | None = None) -> bool:
        """Execute remaining cells in order; True when the job is done.

        Completed cells are loaded (after sha256 verification — a
        corrupt npz is quarantined and recomputed), not re-run; the
        streaming CSV and ``metrics.jsonl`` are rewritten from their
        stored results (byte-identical CSV — the cell npz files are the
        source of truth) and then appended per fresh cell.  A cell whose
        execution keeps failing is skipped after the retry budget (see
        class docstring) — the job completes every other cell and
        returns False.  ``max_cells`` budgets the number of *executed*
        cells before returning — the controlled-interruption knob used
        by the resume tests and CI.
        """
        executed = 0
        failed = 0
        with self._lock:
            self._done, self._in_flight, self._walls = 0, None, []
        with open(self.csv_path, "w") as f, \
                open(self.metrics_path, "w") as mf:
            self._emit_metric(mf, {
                "event": "job_start", "job_id": self.job_id,
                "total": len(self.cells),
                "lanes_per_cell": len(self.executor.points)})
            f.write(",".join(CampaignResult.CSV_HEADER) + "\n")
            for key in self.cells:
                path = self._cell_path(key)
                if os.path.exists(path):
                    cached = self._load_cell(key)
                    if cached is not None:
                        self._append_csv(f, cached)
                        with self._lock:
                            self._done += 1
                            done = self._done
                        self._emit_metric(mf, self._cell_metric(
                            key, done=done, cached=True, wall_s=0.0))
                        continue
                    # corrupt: quarantined by _load_cell, recompute below
                    self._emit_metric(mf, {
                        "event": "cell_quarantined", "cell": key.slug,
                        "index": key.index,
                        "quarantine": os.path.join(
                            "cells", "quarantine", f"{key.slug}.npz")})
                if max_cells is not None and executed >= max_cells:
                    with self._lock:
                        done = self._done
                    self._emit_metric(mf, {
                        "event": "job_pause", "done": done,
                        "total": len(self.cells), "executed": executed})
                    return False
                with self._lock:
                    self._in_flight = key.slug
                ckpt = CellCheckpoint(self._ckpt_path(key))
                outcome = self._run_cell_with_retry(key, ckpt, mf)
                if outcome is None:     # poisoned: job completes the rest
                    failed += 1
                    with self._lock:
                        self._in_flight = None
                    continue
                _save_outcome(path, outcome)
                if outcome.telemetry is not None:
                    outcome.telemetry.save(self._tel_path(key))
                ckpt.clear()
                executed += 1
                with self._lock:
                    self._in_flight = None
                    self._done += 1
                    self._walls.append(outcome.wall_s)
                    done = self._done
                self._emit_metric(mf, self._cell_metric(
                    key, done=done, cached=False,
                    wall_s=outcome.wall_s))
                self._append_csv(f, outcome)
            self._emit_metric(mf, {
                "event": "job_done", "done": len(self.cells) - failed,
                "total": len(self.cells), "executed": executed,
                "failed": failed})
        self.tracer.flush()
        return failed == 0

    # ------------------------------------------------------------- #
    def start(self, max_cells: int | None = None) -> "CampaignJob":
        """Run the job on a daemon thread (async dispatch)."""
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError(f"job {self.job_id} is already running")
        with self._lock:
            self._error = None

        def _target():
            try:
                self.run(max_cells)
            except BaseException as e:   # surfaced by wait()/status()
                with self._lock:
                    self._error = e

        self._thread = threading.Thread(
            target=_target, name=f"campaign-{self.job_id}", daemon=True)
        self._thread.start()
        return self

    def wait(self, timeout: float | None = None) -> JobStatus:
        """Join the background run; re-raises its error, if any."""
        if self._thread is not None:
            self._thread.join(timeout)
        if self._error is not None:
            raise self._error
        return self.status()

    # ------------------------------------------------------------- #
    def result(self) -> CampaignResult:
        """Assemble the CampaignResult from the per-cell checkpoints.

        Requires a complete job; points come back in canonical order, so
        the result is interchangeable with a ``run_campaign`` return.
        """
        points: list[CampaignPoint] = []
        wall: dict[tuple, float] = {}
        total = 0.0
        for key in self.cells:
            path = self._cell_path(key)
            if not os.path.exists(path):
                raise RuntimeError(
                    f"job {self.job_id} incomplete: cell {key.slug} has "
                    f"no checkpoint (run() or resume first)")
            outcome = _load_outcome(path, key)
            points.extend(self.executor.cell_points(outcome))
            wall[key.wall_key(self.spec)] = outcome.wall_s
            total += outcome.wall_s
        return CampaignResult(spec=self.spec, points=points,
                              wall_clock_s=wall, total_wall_clock_s=total)


def run_campaign_service(spec: CampaignSpec, *, root: str = DEFAULT_ROOT,
                         job_id: str | None = None,
                         bidor_tables=None, plan_cache="shared",
                         resume: bool = True,
                         max_cells: int | None = None,
                         verbose: bool = False,
                         trace: bool = False):
    """Run (or resume) a campaign job to completion and return its
    :class:`CampaignResult`; with ``max_cells`` set the job may stop
    early, returning ``(None, job)`` — callers re-invoke to continue.

    Returns ``(result | None, job)``.
    """
    job = CampaignJob(spec, root=root, job_id=job_id,
                      bidor_tables=bidor_tables, plan_cache=plan_cache,
                      resume=resume, verbose=verbose, trace=trace)
    complete = job.run(max_cells)
    return (job.result() if complete else None), job
