"""Vectorized flit-level NoC simulator (replaces BookSim2 for §4).

Model (paper §4.1): input-queued wormhole routers, ``num_vcs`` virtual
channels per input port with per-VC FIFOs, credit-based flow control
(zero-delay credits — the synchronous global update reads receiver occupancy
directly), one flit per channel per cycle, round-robin switch allocation,
single-cycle routing.  The paper's 2-cycle base hop latency is realized as
1 movement/cycle plus 1 extra cycle per hop charged in latency accounting —
identical for every algorithm, so all relative comparisons are preserved.

The whole per-cycle pipeline is pure jnp and runs under ``lax.scan``; one
jit-compilation per (topology, algorithm, packet-length) triple.

Routing algorithms (``Algo``): XY, YX, O1Turn, Valiant, ROMM (oblivious,
two-phase XY with per-phase VCs), Odd-Even (minimal adaptive, turn model of
Chiu [1]), and BiDOR (this paper: quasi-static XY/YX choice from N-Rank,
VC0 = XY / VC1 = YX as in §3.3.2).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bidor import BiDORTable
from repro.core.routes import dimension_orders, next_port_table
from repro.core.topology import Topology
from .simconfig import Algo, SimConfig, SimResult

_BIG = jnp.int32(1 << 30)


class _Tables(NamedTuple):
    """Static (trace-time constant) lookup tables."""

    port: jnp.ndarray      # (2, N, N) int32: DOR out-port (order, cur, target)
    choice: jnp.ndarray    # (N, N) int32: BiDOR order per (s, d)
    neighbor: jnp.ndarray  # (N, P) int32
    recv_port: jnp.ndarray  # (N, P) int32: input port at the neighbor
    cdf: jnp.ndarray       # (N, N) float32 destination CDF per source
    p_gen: jnp.ndarray     # (N,) float32 packet-generation probability @rate 1
    coords: jnp.ndarray    # (N, 2) int32
    n_of: jnp.ndarray      # (NIN,) node of each input
    p_of: jnp.ndarray      # (NIN,) port of each input
    v_of: jnp.ndarray      # (NIN,) vc of each input


def _build_tables(topo: Topology, traffic: np.ndarray,
                  bidor_choice: np.ndarray | None,
                  num_vcs: int) -> tuple[_Tables, dict]:
    if topo.ndim != 2:
        raise ValueError("the flit simulator supports 2D topologies")
    n, p, v = topo.num_nodes, topo.num_ports, num_vcs
    orders = dimension_orders(2)
    port = np.stack([next_port_table(topo, o) for o in orders]).astype(np.int32)
    choice = (np.zeros((n, n), np.int32) if bidor_choice is None
              else bidor_choice.astype(np.int32))
    neighbor = topo.neighbor_table.astype(np.int32)
    recv_port = np.full((n, p), 0, np.int32)
    for c in range(topo.num_channels):
        u = int(topo.channels[c, 0])
        recv_port[u, topo.channel_port[c]] = topo.port_of_channel_at_receiver[c]
    t = np.asarray(traffic, np.float64)
    row = t.sum(1)
    with np.errstate(invalid="ignore"):
        cdf = np.cumsum(np.where(row[:, None] > 0, t / np.maximum(row, 1e-300)[:, None], 0), 1)
    # p_gen (at rate=1 flit/cycle/port): node share ∝ its traffic row sum
    total_ports = topo.io_weights.sum()
    p_gen = row * total_ports  # × rate / packet_len at runtime
    nin = n * p * v
    idx = np.arange(nin)
    tables = _Tables(
        port=jnp.asarray(port), choice=jnp.asarray(choice),
        neighbor=jnp.asarray(neighbor), recv_port=jnp.asarray(recv_port),
        cdf=jnp.asarray(cdf, jnp.float32),
        p_gen=jnp.asarray(p_gen, jnp.float32),
        coords=jnp.asarray(topo.coords.astype(np.int32)),
        n_of=jnp.asarray(idx // (p * v)),
        p_of=jnp.asarray((idx // v) % p),
        v_of=jnp.asarray(idx % v),
    )
    meta = dict(N=n, P=p, V=v, NIN=nin, P_LOCAL=topo.port_local,
                W=int(topo.dims[0]))
    return tables, meta


def _fresh_state(meta: dict, cfg: SimConfig):
    n, nin = meta["N"], meta["NIN"]
    b, q = cfg.buf_per_vc, cfg.src_queue_pkts
    i32 = jnp.int32
    z = functools.partial(jnp.zeros, dtype=i32)
    return dict(
        # per-input-VC FIFOs (struct of arrays)
        f_src=z((nin, b)), f_dst=z((nin, b)), f_inter=z((nin, b)),
        f_seq=z((nin, b)), f_time=z((nin, b)), f_hops=z((nin, b)),
        f_order=z((nin, b)),
        f_head=jnp.zeros((nin, b), bool), f_tail=jnp.zeros((nin, b), bool),
        f_phase=jnp.zeros((nin, b), bool),
        fifo_start=z((nin,)), fifo_size=z((nin,)),
        # wormhole locks
        lock_op=jnp.full((nin,), -1, i32), lock_ov=jnp.full((nin,), -1, i32),
        out_held=jnp.full((n, meta["P"], meta["V"]), -1, i32),
        rr=z((n, meta["P"])),
        # source queues (packets)
        q_dst=z((n, q)), q_inter=z((n, q)), q_order=z((n, q)),
        q_time=z((n, q)), q_seq=z((n, q)),
        q_start=z((n,)), q_size=z((n,)), prog=z((n,)),
        next_seq=z((n, n)),
        # destination-side reorder tracking (paper §4.1 'Reorder Value')
        exp_seq=z((n, n)), rbits=jnp.zeros((n, n), jnp.uint32),
        # statistics
        node_fwd=z((n,)), eject_flits=z((n,)),
        lat_sum=z(()), lat_cnt=z(()), lat_max=z(()),
        reorder_max=z(()), injected=z(()), offered=z(()), dropped=z(()),
        eject_total=z(()),
        rate=jnp.float32(0.0),
        cycle0=jnp.int32(0),   # absolute-cycle offset (trace segments)
        key=jax.random.PRNGKey(cfg.seed),
    )


def _popcount(x):
    return jax.lax.population_count(x)


def _make_step(meta: dict, cfg: SimConfig):
    """Build the per-cycle transition function (tables traced, so all
    traffic patterns and injection rates share one compilation per algo)."""
    algo = Algo(cfg.algo)
    n, p, v, nin = meta["N"], meta["P"], meta["V"], meta["NIN"]
    p_local = meta["P_LOCAL"]
    b, q, l = cfg.buf_per_vc, cfg.src_queue_pkts, cfg.packet_len
    pv = p * v
    n_arange = jnp.arange(n)
    nin_arange = jnp.arange(nin)
    two_phase = algo in (Algo.VALIANT, Algo.ROMM)

    def fifo_push(state, idx, ok, fields):
        """Append one flit to FIFO ``idx`` where ``ok`` (vector batch)."""
        slot = (state["fifo_start"][idx] + state["fifo_size"][idx]) % b
        safe_idx = jnp.where(ok, idx, nin)  # out of range ⇒ dropped
        for name, val in fields.items():
            state[f"f_{name}"] = state[f"f_{name}"].at[safe_idx, slot].set(
                val, mode="drop")
        state["fifo_size"] = state["fifo_size"].at[safe_idx].add(
            1, mode="drop")
        return state

    def gen_metadata(t, key, src, dst):
        """Per-algo packet metadata: (order, inter)."""
        k1, k2, k3 = jax.random.split(key, 3)
        if algo == Algo.XY:
            order = jnp.zeros(n, jnp.int32)
        elif algo == Algo.YX:
            order = jnp.ones(n, jnp.int32)
        elif algo == Algo.O1TURN:
            order = jax.random.bernoulli(k1, 0.5, (n,)).astype(jnp.int32)
        elif algo == Algo.BIDOR:
            order = t.choice[src, dst]
        else:
            order = jnp.zeros(n, jnp.int32)
        if algo == Algo.VALIANT:
            inter = jax.random.randint(k2, (n,), 0, n)
        elif algo == Algo.ROMM:
            cs, cd = t.coords[src], t.coords[dst]
            lo = jnp.minimum(cs, cd)
            hi = jnp.maximum(cs, cd)
            u = jax.random.uniform(k3, (n, 2))
            ic = lo + (u * (hi - lo + 1)).astype(jnp.int32)
            ic = jnp.clip(ic, lo, hi)
            inter = ic[:, 1] * jnp.int32(meta["W"]) + ic[:, 0]
        else:
            inter = jnp.full((n,), -1, jnp.int32)
        return order, inter

    def oddeven_route(t, cur, src, target, free_by_port):
        """Chiu's minimal adaptive odd-even ROUTE + credit-based selection.

        Ports: 0=+x(E) 1=−x(W) 2=+y 3=−y.  Returns the chosen port.
        """
        cx = t.coords[cur, 0]
        sx = t.coords[src, 0]
        dx = t.coords[target, 0] - cx
        dy = t.coords[target, 1] - t.coords[cur, 1]
        y_port = jnp.where(dy > 0, 2, 3)
        east_ok = (dx > 0) & ((dy == 0)
                              | (t.coords[target, 0] % 2 == 1) | (dx != 1))
        y_ok_east = (dx > 0) & (dy != 0) & ((cx % 2 == 1) | (cx == sx))
        west_ok = dx < 0
        y_ok_west = (dx < 0) & (dy != 0) & (cx % 2 == 0)
        y_ok_straight = (dx == 0) & (dy != 0)
        x_port = jnp.where(dx > 0, 0, 1)
        x_ok = east_ok | west_ok
        y_ok = y_ok_east | y_ok_west | y_ok_straight
        fx = jnp.take_along_axis(free_by_port, x_port[:, None], 1)[:, 0]
        fy = jnp.take_along_axis(free_by_port, y_port[:, None], 1)[:, 0]
        prefer_y = y_ok & ((~x_ok) | (fy > fx))
        return jnp.where(prefer_y, y_port, x_port), x_ok, y_ok

    def step(t, state, cycle):
        cycle = state["cycle0"] + cycle    # absolute cycle across segments
        key, kg, kd, km, kv = jax.random.split(state["key"], 5)
        state["key"] = key
        measuring = cycle >= cfg.warmup

        # ---------------- 1. packet generation (open loop) -------------- #
        u = jax.random.uniform(kg, (n,))
        gen = u < (t.p_gen * (state["rate"] / l))
        ud = jax.random.uniform(kd, (n,))
        dst = jnp.clip((t.cdf <= ud[:, None]).sum(1), 0, n - 1).astype(jnp.int32)
        order, inter = gen_metadata(t, km, n_arange, dst)
        space = state["q_size"] < q
        push = gen & space
        seq = state["next_seq"][n_arange, dst]
        state["next_seq"] = state["next_seq"].at[n_arange, dst].add(
            push.astype(jnp.int32))
        slot = (state["q_start"] + state["q_size"]) % q
        row = jnp.where(push, n_arange, n)  # drop when not pushing
        for name, val in (("q_dst", dst), ("q_inter", inter),
                          ("q_order", order), ("q_seq", seq),
                          ("q_time", cycle * jnp.ones(n, jnp.int32))):
            state[name] = state[name].at[row, slot].set(val, mode="drop")
        state["q_size"] = state["q_size"] + push
        state["offered"] += jnp.where(measuring, gen.sum(), 0)
        state["dropped"] += jnp.where(measuring, (gen & ~space).sum(), 0)

        # ---------------- 2. flit injection (1/cycle/node) -------------- #
        hs = state["q_start"]
        h_dst = state["q_dst"][n_arange, hs]
        h_inter = state["q_inter"][n_arange, hs]
        h_order = state["q_order"][n_arange, hs]
        h_seq = state["q_seq"][n_arange, hs]
        h_time = state["q_time"][n_arange, hs]
        fl_head = state["prog"] == 0
        fl_tail = state["prog"] == l - 1
        phase0 = (h_inter < 0) | (h_inter == n_arange)
        if algo in (Algo.XY, Algo.YX):
            vc_in = (n_arange + h_dst) % v
        elif algo in (Algo.O1TURN, Algo.BIDOR):
            vc_in = h_order % v
        elif two_phase:
            vc_in = phase0.astype(jnp.int32) % v
        else:  # ODDEVEN: local VC with more space
            base = (n_arange * p + p_local) * v
            sizes = jnp.stack([state["fifo_size"][base + k]
                               for k in range(v)], 1)
            vc_in = jnp.argmin(sizes, 1).astype(jnp.int32)
        lf_idx = (n_arange * p + p_local) * v + vc_in
        can = (state["q_size"] > 0) & (state["fifo_size"][lf_idx] < b)
        state = fifo_push(state, lf_idx, can, dict(
            src=n_arange, dst=h_dst, inter=h_inter, seq=h_seq, time=h_time,
            hops=jnp.zeros(n, jnp.int32), order=h_order,
            head=fl_head, tail=fl_tail, phase=phase0))
        state["prog"] = jnp.where(can, state["prog"] + 1, state["prog"])
        done = can & (state["prog"] >= l)
        state["prog"] = jnp.where(done, 0, state["prog"])
        state["q_start"] = jnp.where(done, (hs + 1) % q, hs)
        state["q_size"] = state["q_size"] - done
        state["injected"] += can.sum()

        # ---------------- 3. head-of-line + routing --------------------- #
        st_ = state["fifo_start"]
        g = {name: state[f"f_{name}"][nin_arange, st_]
             for name in ("src", "dst", "inter", "seq", "time", "hops",
                          "order", "head", "tail", "phase")}
        valid = state["fifo_size"] > 0
        route_phase = g["phase"] | (g["inter"] < 0) | (g["inter"] == t.n_of)
        target = jnp.where(route_phase, g["dst"], g["inter"])
        target = jnp.clip(target, 0, n - 1)
        at_dest = target == t.n_of
        locked = state["lock_op"] >= 0

        # receiver free space per (input, port): for adaptive selection
        if algo == Algo.ODDEVEN:
            recv_base = (t.neighbor * p + t.recv_port) * v  # (N, P)
            free_pv = jnp.stack(
                [b - state["fifo_size"][recv_base + k] for k in range(v)],
                -1)  # (N, P, V)
            free_port_total = free_pv.sum(-1)  # (N, P)
            op_ad, _, _ = oddeven_route(
                t, t.n_of, g["src"], target, free_port_total[t.n_of])
            # VC choice: freer VC at the chosen port, must be un-held
            held = state["out_held"][t.n_of, op_ad] >= 0  # (NIN, V)
            f = free_pv[t.n_of, op_ad]  # (NIN, V)
            f = jnp.where(held, -1, f)
            ov_route = jnp.argmax(f, -1).astype(jnp.int32)
            op_route = op_ad
        else:
            if algo == Algo.XY:
                eff_order = jnp.zeros(nin, jnp.int32)
            elif algo == Algo.YX:
                eff_order = jnp.ones(nin, jnp.int32)
            elif two_phase:
                eff_order = jnp.zeros(nin, jnp.int32)
            else:
                eff_order = g["order"]
            op_route = t.port[eff_order, t.n_of, target]
            if algo in (Algo.XY, Algo.YX):
                ov_route = t.v_of
            elif two_phase:
                ov_route = route_phase.astype(jnp.int32) % v
            else:
                ov_route = g["order"] % v
        op = jnp.where(at_dest, p_local, op_route)
        ov = jnp.where(at_dest, 0, ov_route)
        op = jnp.where(locked, state["lock_op"], op)
        ov = jnp.where(locked, state["lock_ov"], ov)

        # ---------------- 4. eligibility -------------------------------- #
        is_eject = op == p_local
        nei = t.neighbor[t.n_of, jnp.clip(op, 0, p - 1)]
        rp = t.recv_port[t.n_of, jnp.clip(op, 0, p - 1)]
        recv_idx = (nei * p + rp) * v + ov
        has_credit = is_eject | (state["fifo_size"][
            jnp.clip(recv_idx, 0, nin - 1)] < b)
        vc_free = state["out_held"][t.n_of, jnp.clip(op, 0, p - 1), ov] == -1
        needs_alloc = g["head"] & ~locked & ~is_eject
        elig = valid & has_credit & (vc_free | ~needs_alloc)

        # ---------------- 5. switch allocation (round-robin) ------------ #
        in_local = nin_arange % pv  # input index within its node
        elig2 = elig.reshape(n, pv)
        op2 = op.reshape(n, pv)
        grants = jnp.full((n, p), -1, jnp.int32)
        for po in range(p):
            mask = elig2 & (op2 == po)
            score = (jnp.arange(pv)[None, :] - state["rr"][:, po:po + 1]) % pv
            score = jnp.where(mask, score, _BIG)
            win = jnp.argmin(score, 1).astype(jnp.int32)
            ok = jnp.take_along_axis(score, win[:, None], 1)[:, 0] < _BIG
            grants = grants.at[:, po].set(jnp.where(ok, win, -1))
            state["rr"] = state["rr"].at[:, po].set(
                jnp.where(ok, (win + 1) % pv, state["rr"][:, po]))

        # ---------------- 6. move granted flits ------------------------- #
        granted = grants >= 0  # (N, P)
        win_nin = jnp.where(granted,
                            n_arange[:, None] * pv + grants, nin)  # drop idx
        win_flat = jnp.clip(win_nin, 0, nin - 1)
        w = {k: val[win_flat.reshape(-1)].reshape(n, p) for k, val in g.items()}
        w_op = op[win_flat.reshape(-1)].reshape(n, p)
        w_ov = ov[win_flat.reshape(-1)].reshape(n, p)
        w_phase = route_phase[win_flat.reshape(-1)].reshape(n, p)
        # pops
        state["fifo_start"] = state["fifo_start"].at[
            win_nin.reshape(-1)].add(1, mode="drop")
        state["fifo_start"] = state["fifo_start"] % b
        state["fifo_size"] = state["fifo_size"].at[
            win_nin.reshape(-1)].add(-1, mode="drop")
        # pushes (network ports only)
        net = granted & (w_op != p_local)
        dest_nei = t.neighbor[n_arange[:, None], jnp.clip(w_op, 0, p - 1)]
        dest_rp = t.recv_port[n_arange[:, None], jnp.clip(w_op, 0, p - 1)]
        dest_idx = (dest_nei * p + dest_rp) * v + w_ov
        state = fifo_push(
            state, dest_idx.reshape(-1), net.reshape(-1), dict(
                src=w["src"].reshape(-1), dst=w["dst"].reshape(-1),
                inter=w["inter"].reshape(-1), seq=w["seq"].reshape(-1),
                time=w["time"].reshape(-1),
                hops=(w["hops"] + 1).reshape(-1),
                order=w["order"].reshape(-1),
                head=w["head"].reshape(-1), tail=w["tail"].reshape(-1),
                phase=w_phase.reshape(-1)))
        # locks: set on head (non-tail), clear on tail
        set_lock = granted & w["head"] & ~w["tail"]
        clr_lock = granted & w["tail"]
        li = jnp.where(set_lock | clr_lock, win_nin, nin).reshape(-1)
        new_op = jnp.where(set_lock, w_op, -1).reshape(-1)
        new_ov = jnp.where(set_lock, w_ov, -1).reshape(-1)
        state["lock_op"] = state["lock_op"].at[li].set(new_op, mode="drop")
        state["lock_ov"] = state["lock_ov"].at[li].set(new_ov, mode="drop")
        # out_held bookkeeping (network ports only)
        hold_set = set_lock & net
        hold_clr = clr_lock & net
        hn = jnp.where(hold_set | hold_clr, n_arange[:, None], n).reshape(-1)
        hp = jnp.clip(w_op, 0, p - 1).reshape(-1)
        hv = jnp.clip(w_ov, 0, v - 1).reshape(-1)
        holder = jnp.where(hold_set, grants, -1).reshape(-1)
        state["out_held"] = state["out_held"].at[hn, hp, hv].set(
            holder, mode="drop")

        # ---------------- 7. statistics --------------------------------- #
        moved = granted.sum()
        state["node_fwd"] = state["node_fwd"] + jnp.where(
            measuring, granted.sum(1), 0)
        ej = granted & (w_op == p_local)
        state["eject_total"] += ej.sum()
        state["eject_flits"] = state["eject_flits"] + jnp.where(
            measuring, ej.sum(1), 0)
        # latency at tail ejects, for packets generated after warmup
        tail_ej = ej & w["tail"]
        lat = (cycle - w["time"]) + w["hops"] + 1  # +1: eject traversal
        lat_ok = tail_ej & (w["time"] >= cfg.warmup)
        state["lat_sum"] += jnp.where(lat_ok, lat, 0).sum()
        state["lat_cnt"] += lat_ok.sum()
        state["lat_max"] = jnp.maximum(
            state["lat_max"], jnp.where(lat_ok, lat, 0).max())
        # reorder tracking (≤ 1 tail eject per node per cycle: the local port)
        te = tail_ej.any(1)
        col = jnp.argmax(tail_ej, 1)
        src_v = w["src"][n_arange, col]
        seq_v = w["seq"][n_arange, col]
        src_safe = jnp.where(te, src_v, 0)
        exp = state["exp_seq"][n_arange, src_safe]
        bits = state["rbits"][n_arange, src_safe]
        off = seq_v - exp
        in_win = (off >= 0) & (off < 32)
        off_c = jnp.clip(off, 0, 31).astype(jnp.uint32)
        bits2 = jnp.where(te & in_win,
                          bits | (jnp.uint32(1) << off_c),
                          bits)
        lowmask = (bits2 & ~(bits2 + 1))  # trailing ones
        run = _popcount(lowmask)
        advance = te & ((bits2 & 1) == 1)
        exp2 = jnp.where(advance, exp + run, exp)
        run_c = jnp.minimum(run, 31).astype(jnp.uint32)
        bits3 = jnp.where(advance,
                          jnp.where(run >= 32, jnp.uint32(0), bits2 >> run_c),
                          bits2)
        state["exp_seq"] = state["exp_seq"].at[n_arange, src_safe].set(
            jnp.where(te, exp2, exp))
        state["rbits"] = state["rbits"].at[n_arange, src_safe].set(
            jnp.where(te, bits3, bits))
        occ = _popcount(state["rbits"]).sum(1) * l
        state["reorder_max"] = jnp.maximum(
            state["reorder_max"],
            jnp.where(measuring, occ.max(), 0).astype(jnp.int32))
        return state, None

    return step


@functools.lru_cache(maxsize=None)
def _get_runner(meta_key: tuple, cfg_key: tuple):
    """One jit compilation per (mesh size, algo, flow-control params);
    vmapped over injection rates, shared across traffic patterns."""
    meta = dict(meta_key)
    cfg = SimConfig(**dict(cfg_key))
    step = _make_step(meta, cfg)

    def run(tables, state):
        state, _ = jax.lax.scan(
            lambda s, c: step(tables, s, c), state, jnp.arange(cfg.cycles))
        return state

    return jax.jit(jax.vmap(run, in_axes=(None, 0)))


def _cfg_key(cfg: SimConfig) -> tuple:
    return tuple(sorted(dict(
        algo=int(cfg.algo), num_vcs=cfg.num_vcs, buf_per_vc=cfg.buf_per_vc,
        packet_len=cfg.packet_len, src_queue_pkts=cfg.src_queue_pkts,
        cycles=cfg.cycles, warmup=cfg.warmup, seed=cfg.seed).items()))


def run_sweep(topo: Topology, traffic: np.ndarray, cfg: SimConfig,
              rates: list[float],
              bidor_table: BiDORTable | None = None) -> list[SimResult]:
    """Run a batch of simulations over injection rates (vmapped)."""
    choice = None
    if cfg.algo == Algo.BIDOR:
        if bidor_table is None:
            raise ValueError("BIDOR needs a BiDORTable")
        choice = bidor_table.choice
    tables, meta = _build_tables(topo, traffic, choice, cfg.num_vcs)
    runner = _get_runner(tuple(sorted(meta.items())), _cfg_key(cfg))
    states = []
    for i, rate in enumerate(rates):
        st = _fresh_state(meta, cfg)
        st["rate"] = jnp.float32(rate)
        st["key"] = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), i)
        states.append(st)
    batched = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    out = jax.device_get(runner(tables, batched))
    n = meta["N"]
    meas_cycles = cfg.cycles - cfg.warmup
    ports = float(topo.io_weights.sum())
    results = []
    for i, rate in enumerate(rates):
        o = jax.tree.map(lambda x: x[i], out)
        ejected = int(o["eject_flits"].sum())
        load = o["node_fwd"].astype(np.float64) / meas_cycles
        active = load[load > 1e-9]
        lcv = float(active.std() / active.mean()) if active.size else 0.0
        lat_cnt = max(int(o["lat_cnt"]), 1)
        results.append(SimResult(
            algo=Algo(cfg.algo), injection_rate=float(rate),
            throughput=ejected / meas_cycles / ports,
            offered=float(o["offered"]) / meas_cycles / ports,
            avg_latency=float(o["lat_sum"]) / lat_cnt,
            max_latency=float(o["lat_max"]),
            node_load=load, lcv=lcv,
            reorder_value=int(o["reorder_max"]),
            ejected_flits=int(o["eject_total"]),
            injected_flits=int(o["injected"]),
            in_flight_flits=int(o["fifo_size"].sum()),
        ))
    return results


def run_sim(topo: Topology, traffic: np.ndarray, cfg: SimConfig,
            bidor_table: BiDORTable | None = None) -> SimResult:
    """Run one simulation and post-process statistics."""
    return run_sweep(topo, traffic, cfg, [cfg.injection_rate],
                     bidor_table)[0]


def run_trace(topo: Topology, segments: list[tuple[np.ndarray, float]],
              cfg: SimConfig,
              bidor_table: BiDORTable | None = None):
    """Trace-driven simulation: piecewise-constant traffic epochs.

    Each segment is (traffic_matrix, injection_rate); the network state
    (buffers, in-flight packets, reorder bookkeeping) carries across
    segments.  Used for the paper's realistic-workload evaluation (§4.3),
    where a leaf-switch port-pair trace is replayed as epochs.  BiDOR's
    routing table stays fixed (built offline from the aggregate statistics),
    while adaptive routing reacts per cycle — exactly the paper's contrast.

    Returns (final SimResult over all measured cycles, per-segment LCVs).
    """
    choice = None
    if cfg.algo == Algo.BIDOR:
        if bidor_table is None:
            raise ValueError("BIDOR needs a BiDORTable")
        choice = bidor_table.choice
    meta = None
    state = None
    lcvs = []
    prev_fwd = None
    agg = dict(eject=0, lat_sum=0, lat_cnt=0, lat_max=0, reorder=0,
               injected=0, offered=0)
    for si, (tm, rate) in enumerate(segments):
        tables, meta = _build_tables(topo, tm, choice, cfg.num_vcs)
        runner = _get_runner(tuple(sorted(meta.items())), _cfg_key(cfg))
        if state is None:
            state = _fresh_state(meta, cfg)
            state["key"] = jax.random.fold_in(
                jax.random.PRNGKey(cfg.seed), si)
            prev_fwd = np.zeros(meta["N"], np.int64)
        else:
            state["cycle0"] = jnp.int32(si * cfg.cycles)
        state["rate"] = jnp.float32(rate)
        batched = jax.tree.map(lambda x: jnp.asarray(x)[None], state)
        out = runner(tables, batched)
        state = jax.tree.map(lambda x: x[0], out)
        host = jax.device_get(state)
        fwd = host["node_fwd"].astype(np.int64)
        seg = fwd - prev_fwd
        prev_fwd = fwd
        active = seg[seg > 0]
        if active.size:
            lcvs.append(float(active.std() / active.mean()))
    meas_cycles = (cfg.cycles - cfg.warmup) + cfg.cycles * (len(segments) - 1)
    ports = float(topo.io_weights.sum())
    o = jax.device_get(state)
    lat_cnt = max(int(o["lat_cnt"]), 1)
    load = o["node_fwd"].astype(np.float64) / meas_cycles
    active = load[load > 1e-9]
    res = SimResult(
        algo=Algo(cfg.algo), injection_rate=float(np.mean(
            [r for _, r in segments])),
        throughput=int(o["eject_flits"].sum()) / meas_cycles / ports,
        offered=float(o["offered"]) / meas_cycles / ports,
        avg_latency=float(o["lat_sum"]) / lat_cnt,
        max_latency=float(o["lat_max"]),
        node_load=load,
        lcv=float(active.std() / active.mean()) if active.size else 0.0,
        reorder_value=int(o["reorder_max"]),
        ejected_flits=int(o["eject_total"]),
        injected_flits=int(o["injected"]),
        in_flight_flits=int(o["fifo_size"].sum()),
    )
    return res, lcvs
