"""Vectorized flit-level NoC simulator (replaces BookSim2 for §4).

Model (paper §4.1): input-queued wormhole routers, ``num_vcs`` virtual
channels per input port with per-VC FIFOs, credit-based flow control
(zero-delay credits — the synchronous global update reads receiver occupancy
directly), one flit per channel per cycle, round-robin switch allocation,
single-cycle routing.  The paper's 2-cycle base hop latency is realized as
1 movement/cycle plus 1 extra cycle per hop charged in latency accounting —
identical for every algorithm, so all relative comparisons are preserved.

The whole per-cycle pipeline is pure jnp and runs under ``lax.scan``; one
jit-compilation per (topology, algorithm, packet-length) triple.  By
default (``SimConfig.use_kernel``) the per-cycle transition is the fused
flit-step kernel of :mod:`repro.kernels.simstep` — one on-chip pass over
the packed flit records (Pallas on TPU/GPU, fused dense jnp on CPU),
bit-identical to the unfused chain in :func:`_make_step`, which stays as
the differential-testing oracle.  Campaign lane batches can additionally
run under an explicit ``shard_map`` over all local devices with donated
carry buffers (:func:`get_runner` ``multi_device``).

**Routing is plan-table-driven.**  The simulator never recomputes a
dimension-order decision: every per-cycle routing step is a gather over a
:class:`repro.core.bidor.BiDORTable` artifact — ``port_tables[order, cur,
target]`` with the packet's order stamped at injection (for BiDOR, from the
plan's ``choice[s, d]``; for the DOR baselines, a constant or random order
over :func:`repro.core.bidor.dor_table`'s trivial artifact).  Tables are
traced runner arguments, so the same compiled pipeline serves ANY topology
the planning stack can produce tables for — 2D/3D meshes and tori,
concentrated and express meshes, irregular fault-region graphs
(:mod:`repro.core.topology`'s zoo) — and plan hot-swaps are plain array
replacements (:func:`retarget_tables`).

Routing algorithms (``Algo``): XY, YX, O1Turn, Valiant, ROMM (oblivious,
two-phase XY with per-phase VCs), Odd-Even (minimal adaptive, turn model of
Chiu [1]; inherently 2D), and BiDOR (this paper: quasi-static XY/YX choice
from N-Rank, VC0 = XY / VC1 = YX as in §3.3.2).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bidor import BiDORTable, dor_table
from repro.core.routes import dimension_orders, next_port_table
from repro.core.topology import Topology
from repro.obs.probe import Telemetry, resolved_epoch, telemetry_state
from .watchdog import watchdog_state
# Packed record layouts live in simconfig so the fused kernel package
# (repro.kernels.simstep) can share them without importing this module.
from .simconfig import (Algo, SimConfig, SimResult, NF, F_SRC, F_DST,
                        F_INTER, F_SEQ, F_TIME, F_HOPS, F_ORDER, F_HEAD,
                        F_TAIL, F_PHASE, NQ, Q_DST, Q_INTER, Q_ORDER,
                        Q_TIME, Q_SEQ)

_BIG = jnp.int32(1 << 30)


class _Tables(NamedTuple):
    """Static (trace-time constant) lookup tables."""

    port: jnp.ndarray      # (O, N, N) int32: plan out-port (order, cur, target)
    choice: jnp.ndarray    # (N, N) int32: plan order per (s, d)
    neighbor: jnp.ndarray  # (N, P) int32
    recv_port: jnp.ndarray  # (N, P) int32: input port at the neighbor
    cdf: jnp.ndarray       # (N, N) float32 destination CDF per source
    p_gen: jnp.ndarray     # (N,) float32 packet-generation probability @rate 1
    coords: jnp.ndarray    # (N, ndim) int32
    strides: jnp.ndarray   # (ndim,) int32: coord → node-id strides
    n_of: jnp.ndarray      # (NIN,) node of each input
    p_of: jnp.ndarray      # (NIN,) port of each input
    v_of: jnp.ndarray      # (NIN,) vc of each input
    chan_src_n: jnp.ndarray  # (C,) source node of each channel
    chan_src_p: jnp.ndarray  # (C,) output port of each channel at its source
    chan_of: jnp.ndarray   # (N, P) int32: channel at (node, out-port); C if none
    chan_bw: jnp.ndarray   # (C,) float32 relative bandwidth (0 = link down)
    esc_port: jnp.ndarray  # (N, N) int32: DOR escape table (watchdog recovery)


def _gen_tables(topo: Topology, traffic) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Packet-generation tables from a traffic matrix: per-source
    destination CDF and per-node generation probability at rate 1
    (× rate / packet_len at runtime).  Single source of truth for
    ``build_tables`` and the ``retarget_tables`` hot-swap path."""
    t = np.asarray(traffic, np.float64)
    row = t.sum(1)
    with np.errstate(invalid="ignore"):
        cdf = np.cumsum(
            np.where(row[:, None] > 0,
                     t / np.maximum(row, 1e-300)[:, None], 0), 1)
    # node share ∝ its traffic row sum; total I/O ports normalize
    p_gen = row * topo.io_weights.sum()
    return jnp.asarray(cdf, jnp.float32), jnp.asarray(p_gen, jnp.float32)


def build_tables(topo: Topology, traffic: np.ndarray,
                 table: BiDORTable | None,
                 num_vcs: int) -> tuple[_Tables, dict]:
    """Device tables for one simulation cell.

    ``table`` is the routing artifact the simulator consumes — a
    :class:`BiDORTable` with per-(order, node, destination) next-port
    tables plus the per-⟨s, d⟩ order choice.  Pass the plan's table for
    BiDOR; ``None`` routes over the trivial DOR artifact
    (:func:`repro.core.bidor.dor_table`), which the oblivious baselines
    index by constant/random order.
    """
    if table is None:
        table = dor_table(topo)
    n, p, v = topo.num_nodes, topo.num_ports, num_vcs
    port = np.asarray(table.port_tables, np.int32)
    if port.shape[1:] != (n, n):
        raise ValueError(f"port tables {port.shape} do not match {n} nodes")
    choice = np.asarray(table.choice, np.int32)
    neighbor = topo.neighbor_table.astype(np.int32)
    recv_port = np.full((n, p), 0, np.int32)
    for c in range(topo.num_channels):
        u = int(topo.channels[c, 0])
        recv_port[u, topo.channel_port[c]] = topo.port_of_channel_at_receiver[c]
    cdf, p_gen = _gen_tables(topo, traffic)
    nin = n * p * v
    idx = np.arange(nin)
    chan_of = np.full((n, p), topo.num_channels, np.int32)
    chan_of[topo.channels[:, 0], topo.channel_port] = np.arange(
        topo.num_channels, dtype=np.int32)
    tables = _Tables(
        port=jnp.asarray(port), choice=jnp.asarray(choice),
        neighbor=jnp.asarray(neighbor), recv_port=jnp.asarray(recv_port),
        cdf=cdf, p_gen=p_gen,
        coords=jnp.asarray(topo.coords.astype(np.int32)),
        strides=jnp.asarray(topo.coord_strides.astype(np.int32)),
        n_of=jnp.asarray(idx // (p * v)),
        p_of=jnp.asarray((idx // v) % p),
        v_of=jnp.asarray(idx % v),
        chan_src_n=jnp.asarray(topo.channels[:, 0].astype(np.int32)),
        chan_src_p=jnp.asarray(topo.channel_port.astype(np.int32)),
        chan_of=jnp.asarray(chan_of),
        chan_bw=jnp.asarray(topo.channel_bw, jnp.float32),
        # watchdog escape table: plain first-dimension-order DOR, built
        # from the topology alone (never from the possibly-broken plan
        # table) so it exists — and is acyclic — whatever was deployed
        esc_port=jnp.asarray(next_port_table(
            topo, dimension_orders(topo.ndim)[0]).astype(np.int32)),
    )
    meta = dict(N=n, P=p, V=v, NIN=nin, P_LOCAL=topo.port_local,
                NDIM=topo.ndim, O=port.shape[0], C=topo.num_channels)
    return tables, meta


def abstract_tables(meta: dict) -> _Tables:
    """The :class:`_Tables` a cell traces, as shapes only — one
    :class:`jax.ShapeDtypeStruct` per field, derived from ``meta``
    without building a topology or plan.

    Single source of truth for the kernel package's capacity math
    (``repro.kernels.simstep.ops.state_footprint_bytes`` and the blocked
    tile chooser): the VMEM gate sizes the *actual* traced operands
    instead of a hand-maintained byte formula.  A drift test
    (``tests/test_simstep_kernel.py``) pins every field's shape and
    dtype against real :func:`build_tables` output across the topology
    zoo, so this mirror cannot silently disagree with reality."""
    n, p, nin, c = meta["N"], meta["P"], meta["NIN"], meta["C"]
    nd, o = meta["NDIM"], meta["O"]

    def s(shape, dtype=jnp.int32):
        return jax.ShapeDtypeStruct(shape, dtype)

    return _Tables(
        port=s((o, n, n)), choice=s((n, n)), neighbor=s((n, p)),
        recv_port=s((n, p)), cdf=s((n, n), jnp.float32),
        p_gen=s((n,), jnp.float32), coords=s((n, nd)), strides=s((nd,)),
        n_of=s((nin,)), p_of=s((nin,)), v_of=s((nin,)),
        chan_src_n=s((c,)), chan_src_p=s((c,)), chan_of=s((n, p)),
        chan_bw=s((c,), jnp.float32), esc_port=s((n, n)))


def source_queue_meta(tables: _Tables,
                      cfg: SimConfig) -> tuple[np.ndarray, float]:
    """(io_mask, qcap) for :func:`queue_occupancy` — one ``p_gen`` device
    read.  Compute once per cell (or after a traffic retarget) and pass
    through; deriving it inside every chunk of an early-exit loop costs a
    host transfer per chunk for a value that only changes when the
    generation tables do."""
    io_mask = np.asarray(jax.device_get(tables.p_gen)) > 0
    qcap = float(io_mask.sum() * cfg.src_queue_pkts)
    return io_mask, qcap


def queue_occupancy(tables: _Tables, cfg: SimConfig,
                    q_size, meta: tuple[np.ndarray, float] | None = None,
                    ) -> np.ndarray:
    """Per-lane source-queue occupancy fraction over the I/O-capable
    nodes — the lane-saturation criterion shared by the campaign
    early-exit and the control plane's saturation flag.  ``meta`` is the
    precomputed :func:`source_queue_meta`; omitting it re-derives the
    mask from the device tables on every call.

    A pattern with no I/O-capable sources (all-zero generation rows,
    e.g. a fully-shed fault-region matrix) has ``qcap == 0``; its lanes
    can never queue a packet, so their occupancy is 0.0 by definition —
    NOT NaN, which would poison the ``>=`` saturation comparison and the
    early-exit downstream."""
    io_mask, qcap = source_queue_meta(tables, cfg) if meta is None else meta
    q = np.asarray(jax.device_get(q_size))
    if qcap <= 0:
        return np.zeros(q.shape[0])
    return q[:, io_mask].sum(1) / qcap


def retarget_tables(tables: _Tables, topo: Topology, *,
                    traffic: np.ndarray | None = None,
                    choice: np.ndarray | None = None,
                    channel_bw: np.ndarray | None = None) -> _Tables:
    """Plan hot-swap path: a new `_Tables` with only the requested fields
    replaced.

    Tables are *traced* runner arguments, so swapping them between chunks
    re-uses the cached jit compilation and leaves all in-flight state
    (buffers, locks, source queues, statistics) untouched — the mechanism
    behind the quasi-static control plane (:mod:`repro.noc.ctrl`):

    * ``traffic`` — new generation matrix (destination CDF + per-node
      injection probability are rebuilt; drift epochs).
    * ``choice`` — new BiDOR plan; only packets generated after the swap
      follow it, in-flight packets keep the order stamped at injection.
    * ``channel_bw`` — link fail/recover/degrade events.

    Passing nothing returns an identical table set (the empty-schedule
    identity asserted by ``tests/test_ctrl.py``).
    """
    kw = {}
    if traffic is not None:
        kw["cdf"], kw["p_gen"] = _gen_tables(topo, traffic)
    if choice is not None:
        kw["choice"] = jnp.asarray(np.asarray(choice, np.int32))
    if channel_bw is not None:
        kw["chan_bw"] = jnp.asarray(np.asarray(channel_bw), jnp.float32)
    return tables._replace(**kw) if kw else tables


def fresh_state(meta: dict, cfg: SimConfig):
    """Per-run dynamic state — a flat dict of arrays, hence a pytree that
    can be stacked/vmapped over a leading batch axis (one lane per
    (rate, seed) campaign point)."""
    n, nin = meta["N"], meta["NIN"]
    b, q = cfg.buf_per_vc, cfg.src_queue_pkts
    i32 = jnp.int32
    z = functools.partial(jnp.zeros, dtype=i32)
    # optional time-resolved probes (repro.obs.probe) and stall watchdog
    # (repro.noc.watchdog); {} when off, so a probe-free state pytree is
    # unchanged key for key
    tel = telemetry_state(meta, cfg)
    wd = watchdog_state(meta, cfg)
    return dict(
        **tel,
        **wd,
        # per-input-VC FIFOs: packed flit records (see NF layout above)
        flits=z((nin, b, NF)),
        fifo_start=z((nin,)), fifo_size=z((nin,)),
        # wormhole locks
        lock_op=jnp.full((nin,), -1, i32), lock_ov=jnp.full((nin,), -1, i32),
        out_held=jnp.full((n, meta["P"], meta["V"]), -1, i32),
        rr=z((n, meta["P"])),
        # source queues: packed packet records (see NQ layout above)
        qpkts=z((n, q, NQ)),
        q_start=z((n,)), q_size=z((n,)), prog=z((n,)),
        next_seq=z((n, n)),
        # destination-side reorder tracking (paper §4.1 'Reorder Value')
        exp_seq=z((n, n)), rbits=jnp.zeros((n, n), jnp.uint32),
        # statistics
        node_fwd=z((n,)), eject_flits=z((n,)), chan_fwd=z((meta["C"],)),
        chan_seen=z((meta["C"],)),
        lat_sum=z(()), lat_cnt=z(()), lat_max=z(()),
        lat_hist=z((cfg.lat_bins,)),
        reorder_max=z(()), injected=z(()), offered=z(()), dropped=z(()),
        eject_total=z(()), meas_cnt=z(()),
        rate=jnp.float32(0.0),
        cycle0=jnp.int32(0),   # absolute-cycle offset (chunks / segments)
        # phase boundaries (dynamic, per run): injection and measurement
        # stop at these absolute cycles; the tail is the drain phase.
        inject_until=jnp.int32(cfg.cycles - cfg.drain),
        measure_until=jnp.int32(cfg.cycles - cfg.drain),
        key=jax.random.PRNGKey(cfg.seed),
    )


def _popcount(x):
    return jax.lax.population_count(x)


def _make_step(meta: dict, cfg: SimConfig):
    """Build the per-cycle transition function (tables traced, so all
    traffic patterns and injection rates share one compilation per algo).

    With ``cfg.use_kernel`` (the default) the transition is the fused
    flit-step kernel (:mod:`repro.kernels.simstep`: one Pallas pass on
    TPU/GPU, the fused dense jnp body on CPU) — bit-identical to the
    unfused chain below, which remains the differential-testing oracle
    and the ``simstep_scale`` benchmark baseline."""
    if cfg.use_kernel:
        from repro.kernels import simstep  # deferred: avoids an import
        return simstep.make_step(meta, cfg)  # cycle with repro.noc
    algo = Algo(cfg.algo)
    n, p, v, nin = meta["N"], meta["P"], meta["V"], meta["NIN"]
    p_local = meta["P_LOCAL"]
    num_orders = meta["O"]
    if algo == Algo.ODDEVEN and meta["NDIM"] != 2:
        raise ValueError("odd-even routing is a 2D turn model; "
                         f"topology has ndim={meta['NDIM']}")
    b, q, l = cfg.buf_per_vc, cfg.src_queue_pkts, cfg.packet_len
    pv = p * v
    n_arange = jnp.arange(n)
    nin_arange = jnp.arange(nin)
    two_phase = algo in (Algo.VALIANT, Algo.ROMM)
    tel_epoch = resolved_epoch(cfg)  # 0 ⇔ telemetry off
    watchdog = bool(cfg.watchdog)

    def fifo_push(state, idx, ok, records):
        """Append packed flit ``records`` (K, NF) to FIFOs ``idx`` where
        ``ok`` — ONE scatter with a contiguous NF-word payload."""
        slot = (state["fifo_start"][idx] + state["fifo_size"][idx]) % b
        safe_idx = jnp.where(ok, idx, nin)  # out of range ⇒ dropped
        state["flits"] = state["flits"].at[safe_idx, slot].set(
            records, mode="drop")
        state["fifo_size"] = state["fifo_size"].at[safe_idx].add(
            1, mode="drop")
        return state

    def gen_metadata(t, key, src, dst):
        """Per-algo packet metadata: (order, inter)."""
        k1, k2, k3 = jax.random.split(key, 3)
        if algo == Algo.XY:
            order = jnp.zeros(n, jnp.int32)
        elif algo == Algo.YX:
            # last order is the descending one ("YX" on 2D, and its k-dim
            # generalization when a k-orders plan table is in play)
            order = jnp.full((n,), num_orders - 1, jnp.int32)
        elif algo == Algo.O1TURN:
            order = jnp.where(jax.random.bernoulli(k1, 0.5, (n,)),
                              num_orders - 1, 0).astype(jnp.int32)
        elif algo == Algo.BIDOR:
            order = t.choice[src, dst]
        else:
            order = jnp.zeros(n, jnp.int32)
        if algo == Algo.VALIANT:
            inter = jax.random.randint(k2, (n,), 0, n)
        elif algo == Algo.ROMM:
            cs, cd = t.coords[src], t.coords[dst]
            lo = jnp.minimum(cs, cd)
            hi = jnp.maximum(cs, cd)
            u = jax.random.uniform(k3, (n, lo.shape[-1]))
            ic = lo + (u * (hi - lo + 1)).astype(jnp.int32)
            ic = jnp.clip(ic, lo, hi)
            inter = (ic * t.strides).sum(-1)
        else:
            inter = jnp.full((n,), -1, jnp.int32)
        return order, inter

    def oddeven_route(t, cur, src, target, free_by_port):
        """Chiu's minimal adaptive odd-even ROUTE + credit-based selection.

        Ports: 0=+x(E) 1=−x(W) 2=+y 3=−y.  Returns the chosen port.
        """
        cx = t.coords[cur, 0]
        sx = t.coords[src, 0]
        dx = t.coords[target, 0] - cx
        dy = t.coords[target, 1] - t.coords[cur, 1]
        y_port = jnp.where(dy > 0, 2, 3)
        east_ok = (dx > 0) & ((dy == 0)
                              | (t.coords[target, 0] % 2 == 1) | (dx != 1))
        y_ok_east = (dx > 0) & (dy != 0) & ((cx % 2 == 1) | (cx == sx))
        west_ok = dx < 0
        y_ok_west = (dx < 0) & (dy != 0) & (cx % 2 == 0)
        y_ok_straight = (dx == 0) & (dy != 0)
        x_port = jnp.where(dx > 0, 0, 1)
        x_ok = east_ok | west_ok
        y_ok = y_ok_east | y_ok_west | y_ok_straight
        fx = jnp.take_along_axis(free_by_port, x_port[:, None], 1)[:, 0]
        fy = jnp.take_along_axis(free_by_port, y_port[:, None], 1)[:, 0]
        prefer_y = y_ok & ((~x_ok) | (fy > fx))
        return jnp.where(prefer_y, y_port, x_port), x_ok, y_ok

    def step(t, state, cycle):
        cycle = state["cycle0"] + cycle    # absolute cycle across segments
        key, kg, kd, km, kv = jax.random.split(state["key"], 5)
        state["key"] = key
        # warmup → measure → drain phasing: statistics only inside the
        # measurement window, no new packets once the drain phase starts.
        measuring = (cycle >= cfg.warmup) & (cycle < state["measure_until"])
        state["meas_cnt"] += measuring.astype(jnp.int32)

        # ---------------- 1. packet generation (open loop) -------------- #
        u = jax.random.uniform(kg, (n,))
        gen = (u < (t.p_gen * (state["rate"] / l))) \
            & (cycle < state["inject_until"])
        if watchdog:
            # livelock throttle: mask generation at throttled sources —
            # mask only, the RNG stream above is drawn unconditionally,
            # so throttling never perturbs other sources' randomness
            gen = gen & (state["wd_throttle"] <= 0)
            state["wd_throttle"] = jnp.maximum(state["wd_throttle"] - 1, 0)
        ud = jax.random.uniform(kd, (n,))
        dst = jnp.clip((t.cdf <= ud[:, None]).sum(1), 0, n - 1).astype(jnp.int32)
        order, inter = gen_metadata(t, km, n_arange, dst)
        space = state["q_size"] < q
        push = gen & space
        seq = state["next_seq"][n_arange, dst]
        # dense one-hot update: row s bumps column dst[s] (rows distinct)
        state["next_seq"] = state["next_seq"] + (
            push[:, None] & (n_arange[None, :] == dst[:, None]))
        slot = (state["q_start"] + state["q_size"]) % q
        row = jnp.where(push, n_arange, n)  # drop when not pushing
        qrec = jnp.stack(
            [dst, inter, order, jnp.full((n,), cycle, jnp.int32), seq], -1)
        state["qpkts"] = state["qpkts"].at[row, slot].set(qrec, mode="drop")
        state["q_size"] = state["q_size"] + push
        state["offered"] += jnp.where(measuring, gen.sum(), 0)
        state["dropped"] += jnp.where(measuring, (gen & ~space).sum(), 0)

        # ---------------- 2. flit injection (1/cycle/node) -------------- #
        hs = state["q_start"]
        hpkt = state["qpkts"][n_arange, hs]  # (N, NQ)
        h_dst = hpkt[:, Q_DST]
        h_inter = hpkt[:, Q_INTER]
        h_order = hpkt[:, Q_ORDER]
        h_seq = hpkt[:, Q_SEQ]
        h_time = hpkt[:, Q_TIME]
        fl_head = state["prog"] == 0
        fl_tail = state["prog"] == l - 1
        phase0 = (h_inter < 0) | (h_inter == n_arange)
        if algo in (Algo.XY, Algo.YX):
            vc_in = (n_arange + h_dst) % v
        elif algo in (Algo.O1TURN, Algo.BIDOR):
            vc_in = h_order % v
        elif two_phase:
            vc_in = phase0.astype(jnp.int32) % v
        else:  # ODDEVEN: local VC with more space
            base = (n_arange * p + p_local) * v
            sizes = jnp.stack([state["fifo_size"][base + k]
                               for k in range(v)], 1)
            vc_in = jnp.argmin(sizes, 1).astype(jnp.int32)
        lf_idx = (n_arange * p + p_local) * v + vc_in
        can = (state["q_size"] > 0) & (state["fifo_size"][lf_idx] < b)
        inj_rec = jnp.stack(
            [n_arange, h_dst, h_inter, h_seq, h_time,
             jnp.zeros(n, jnp.int32), h_order, fl_head.astype(jnp.int32),
             fl_tail.astype(jnp.int32), phase0.astype(jnp.int32)], -1)
        state = fifo_push(state, lf_idx, can, inj_rec)
        state["prog"] = jnp.where(can, state["prog"] + 1, state["prog"])
        done = can & (state["prog"] >= l)
        state["prog"] = jnp.where(done, 0, state["prog"])
        state["q_start"] = jnp.where(done, (hs + 1) % q, hs)
        state["q_size"] = state["q_size"] - done
        state["injected"] += can.sum()

        # ---------------- 3. head-of-line + routing --------------------- #
        st_ = state["fifo_start"]
        g_all = state["flits"][nin_arange, st_]  # (NIN, NF) one gather
        g = dict(src=g_all[:, F_SRC], dst=g_all[:, F_DST],
                 inter=g_all[:, F_INTER], seq=g_all[:, F_SEQ],
                 time=g_all[:, F_TIME], hops=g_all[:, F_HOPS],
                 order=g_all[:, F_ORDER], head=g_all[:, F_HEAD] != 0,
                 tail=g_all[:, F_TAIL] != 0, phase=g_all[:, F_PHASE] != 0)
        valid = state["fifo_size"] > 0
        route_phase = g["phase"] | (g["inter"] < 0) | (g["inter"] == t.n_of)
        target = jnp.where(route_phase, g["dst"], g["inter"])
        target = jnp.clip(target, 0, n - 1)
        at_dest = target == t.n_of
        locked = state["lock_op"] >= 0

        # receiver free space per (input, port): for adaptive selection
        if algo == Algo.ODDEVEN:
            recv_base = (t.neighbor * p + t.recv_port) * v  # (N, P)
            free_pv = jnp.stack(
                [b - state["fifo_size"][recv_base + k] for k in range(v)],
                -1)  # (N, P, V)
            free_port_total = free_pv.sum(-1)  # (N, P)
            op_ad, _, _ = oddeven_route(
                t, t.n_of, g["src"], target, free_port_total[t.n_of])
            # VC choice: freer VC at the chosen port, must be un-held
            held = state["out_held"][t.n_of, op_ad] >= 0  # (NIN, V)
            f = free_pv[t.n_of, op_ad]  # (NIN, V)
            f = jnp.where(held, -1, f)
            ov_route = jnp.argmax(f, -1).astype(jnp.int32)
            op_route = op_ad
        else:
            if algo == Algo.XY:
                eff_order = jnp.zeros(nin, jnp.int32)
            elif algo == Algo.YX:
                eff_order = jnp.full((nin,), num_orders - 1, jnp.int32)
            elif two_phase:
                eff_order = jnp.zeros(nin, jnp.int32)
            else:
                eff_order = g["order"]
            op_route = t.port[eff_order, t.n_of, target]
            if algo in (Algo.XY, Algo.YX):
                ov_route = t.v_of
            elif two_phase:
                ov_route = route_phase.astype(jnp.int32) % v
            else:
                ov_route = g["order"] % v
        op = jnp.where(at_dest, p_local, op_route)
        ov = jnp.where(at_dest, 0, ov_route)
        op = jnp.where(locked, state["lock_op"], op)
        ov = jnp.where(locked, state["lock_ov"], ov)
        if watchdog:
            # deadlock escape: a head stalled past the threshold misroutes
            # one hop via the acyclic DOR escape table ON THE HIGHEST VC
            # (Duato-style escape lane — the wedged cycle holds the lower
            # classes, so the escape hop has somewhere to drain to), then
            # routes normally (body flits follow the head's locked
            # port/VC; the escape still goes through eligibility + credit
            # + allocation — a misroute, never a teleport)
            esc = (state["wd_stall"] >= cfg.wd_stall_cycles) \
                & valid & g["head"] & ~locked & ~at_dest
            op = jnp.where(esc, t.esc_port[t.n_of, target], op)
            ov = jnp.where(esc, v - 1, ov)

        # ---------------- 4. eligibility -------------------------------- #
        is_eject = op == p_local
        nei = t.neighbor[t.n_of, jnp.clip(op, 0, p - 1)]
        rp = t.recv_port[t.n_of, jnp.clip(op, 0, p - 1)]
        recv_idx = (nei * p + rp) * v + ov
        has_credit = is_eject | (state["fifo_size"][
            jnp.clip(recv_idx, 0, nin - 1)] < b)
        vc_free = state["out_held"][t.n_of, jnp.clip(op, 0, p - 1), ov] == -1
        needs_alloc = g["head"] & ~locked & ~is_eject
        # fractional channel bandwidth: channel c may transmit this cycle
        # iff the fixed-rate service schedule ⌊(cyc+1)·bw⌋ − ⌊cyc·bw⌋ fires
        # (bw = 1 ⇒ every cycle, bit-identical to the ungated simulator;
        # bw = 0 ⇒ never — a dead link).  Degraded links come from the
        # control plane's fault events (repro.noc.ctrl).
        cycf = cycle.astype(jnp.float32)
        chan_live = (jnp.floor((cycf + 1.0) * t.chan_bw)
                     - jnp.floor(cycf * t.chan_bw)) >= 1.0
        chan_live = jnp.concatenate(
            [chan_live, jnp.zeros((1,), bool)])  # sentinel: no channel
        chan_ok = is_eject | chan_live[
            t.chan_of[t.n_of, jnp.clip(op, 0, p - 1)]]
        elig = valid & has_credit & chan_ok & (vc_free | ~needs_alloc)

        # ---------------- 5. switch allocation (round-robin) ------------ #
        # all output ports allocated at once: score (N, PV, P), winner per
        # (node, port) column — ports are independent, so this is exactly
        # the per-port round-robin pick
        in_local = nin_arange % pv  # input index within its node
        elig2 = elig.reshape(n, pv)
        op2 = op.reshape(n, pv)
        mask_po = elig2[:, :, None] & (op2[:, :, None]
                                       == jnp.arange(p)[None, None, :])
        score = (jnp.arange(pv)[None, :, None]
                 - state["rr"][:, None, :]) % pv
        score = jnp.where(mask_po, score, _BIG)
        win = jnp.argmin(score, 1).astype(jnp.int32)      # (N, P)
        ok = score.min(1) < _BIG
        grants = jnp.where(ok, win, -1)
        state["rr"] = jnp.where(ok, (win + 1) % pv, state["rr"])

        # ---------------- 6. move granted flits ------------------------- #
        granted = grants >= 0  # (N, P)
        # input-centric pop flag: input i moved iff it won its output port
        popped = elig & (grants[t.n_of, jnp.clip(op, 0, p - 1)] == in_local)
        win_nin = jnp.where(granted,
                            n_arange[:, None] * pv + grants, nin)  # drop idx
        win_flat = jnp.clip(win_nin, 0, nin - 1).reshape(-1)
        # winner records + routing decision, ONE gather of NF+3 words
        g_ext = jnp.concatenate(
            [g_all, op[:, None], ov[:, None],
             route_phase.astype(jnp.int32)[:, None]], -1)
        w_ext = g_ext[win_flat].reshape(n, p, NF + 3)
        w_all = w_ext[..., :NF]
        w_op = w_ext[..., NF]
        w_ov = w_ext[..., NF + 1]
        w_phase = w_ext[..., NF + 2]
        w = dict(head=w_all[..., F_HEAD] != 0, tail=w_all[..., F_TAIL] != 0)
        # pops (elementwise — ``popped`` marks at most one flit per input)
        state["fifo_start"] = jnp.where(popped, (st_ + 1) % b, st_)
        state["fifo_size"] = state["fifo_size"] - popped
        # pushes (network ports only): one packed scatter
        net = granted & (w_op != p_local)
        dest_nei = t.neighbor[n_arange[:, None], jnp.clip(w_op, 0, p - 1)]
        dest_rp = t.recv_port[n_arange[:, None], jnp.clip(w_op, 0, p - 1)]
        dest_idx = (dest_nei * p + dest_rp) * v + w_ov
        push_rec = w_all.at[..., F_HOPS].add(1)
        push_rec = push_rec.at[..., F_PHASE].set(w_phase.astype(jnp.int32))
        state = fifo_push(state, dest_idx.reshape(-1), net.reshape(-1),
                          push_rec.reshape(-1, NF))
        # wormhole locks (elementwise): set on head (non-tail), clear on tail
        set_lock_i = popped & g["head"] & ~g["tail"]
        clr_lock_i = popped & g["tail"]
        state["lock_op"] = jnp.where(
            set_lock_i, op, jnp.where(clr_lock_i, -1, state["lock_op"]))
        state["lock_ov"] = jnp.where(
            set_lock_i, ov, jnp.where(clr_lock_i, -1, state["lock_ov"]))
        # out_held bookkeeping (elementwise over (N, P, V); net ports only)
        hold_set = granted & w["head"] & ~w["tail"] & net
        hold_clr = granted & w["tail"] & net
        vmask = ((hold_set | hold_clr)[..., None]
                 & (jnp.arange(v)[None, None, :] == w_ov[..., None]))
        hold_val = jnp.where(hold_set, grants, -1)
        state["out_held"] = jnp.where(vmask, hold_val[..., None],
                                      state["out_held"])
        if watchdog:
            # stall age: +1 per cycle an occupied input fails to move,
            # reset on movement; deadlock trip counted exactly at the
            # threshold crossing (once per stall episode)
            new_stall = jnp.where(valid & ~popped, state["wd_stall"] + 1, 0)
            state["wd_trips"] = state["wd_trips"].at[0].add(
                (new_stall == cfg.wd_stall_cycles).sum())
            state["wd_stall"] = new_stall
            # livelock: a moved flit whose hop count passes the limit
            # throttles its source (set, not add: re-trips re-arm it);
            # trip counted once per flit at the exact crossing
            hops_now = push_rec[..., F_HOPS]
            lv = net & (hops_now > cfg.wd_hop_limit)
            lv_src = jnp.where(lv, w_all[..., F_SRC], n)
            state["wd_throttle"] = state["wd_throttle"].at[
                lv_src.reshape(-1)].set(cfg.wd_throttle_cycles, mode="drop")
            state["wd_trips"] = state["wd_trips"].at[1].add(
                (net & (hops_now == cfg.wd_hop_limit + 1)).sum())

        # ---------------- 7. statistics --------------------------------- #
        state["node_fwd"] = state["node_fwd"] + jnp.where(
            measuring, granted.sum(1), 0)
        # per-channel forwarded flits (link loads / max-link-load roofline):
        # channel c moved a flit iff its source (node, port) granted a
        # network move — a gather at compile-time-constant indices
        state["chan_fwd"] = state["chan_fwd"] + (
            net & measuring)[t.chan_src_n, t.chan_src_p]
        # always-on per-channel counter (control plane's drift detector
        # needs link profiles during warmup and drain too)
        state["chan_seen"] = state["chan_seen"] + (
            net[t.chan_src_n, t.chan_src_p])
        # ejects only ever leave through the local output port, so all
        # eject/latency/reorder statistics live on its (N,) column
        ej_n = granted[:, p_local]
        wl = w_ext[:, p_local, :]  # (N, NF+3) local-port winner records
        state["eject_total"] += ej_n.sum()
        state["eject_flits"] = state["eject_flits"] + jnp.where(
            measuring, ej_n, 0)
        # latency at tail ejects, for packets generated in the measurement
        # window (drain-phase landings of measured packets still count)
        tail_ej = ej_n & (wl[:, F_TAIL] != 0)
        lat = (cycle - wl[:, F_TIME]) + wl[:, F_HOPS] + 1  # +1: eject hop
        lat_ok = tail_ej & (wl[:, F_TIME] >= cfg.warmup)
        state["lat_sum"] += jnp.where(lat_ok, lat, 0).sum()
        state["lat_cnt"] += lat_ok.sum()
        state["lat_max"] = jnp.maximum(
            state["lat_max"], jnp.where(lat_ok, lat, 0).max())
        # latency histogram (percentiles); last bin is the overflow bucket
        hbin = jnp.minimum(lat // cfg.lat_bin_width, cfg.lat_bins - 1)
        state["lat_hist"] = state["lat_hist"].at[
            jnp.where(lat_ok, hbin, cfg.lat_bins)].add(1, mode="drop")
        # reorder tracking (≤ 1 tail eject per node per cycle: the local port)
        te = tail_ej
        src_v = wl[:, F_SRC]
        seq_v = wl[:, F_SEQ]
        src_safe = jnp.where(te, src_v, 0)
        exp = state["exp_seq"][n_arange, src_safe]
        bits = state["rbits"][n_arange, src_safe]
        off = seq_v - exp
        in_win = (off >= 0) & (off < 32)
        off_c = jnp.clip(off, 0, 31).astype(jnp.uint32)
        bits2 = jnp.where(te & in_win,
                          bits | (jnp.uint32(1) << off_c),
                          bits)
        lowmask = (bits2 & ~(bits2 + 1))  # trailing ones
        run = _popcount(lowmask)
        advance = te & ((bits2 & 1) == 1)
        exp2 = jnp.where(advance, exp + run, exp)
        run_c = jnp.minimum(run, 31).astype(jnp.uint32)
        bits3 = jnp.where(advance,
                          jnp.where(run >= 32, jnp.uint32(0), bits2 >> run_c),
                          bits2)
        src_oh = te[:, None] & (n_arange[None, :] == src_safe[:, None])
        state["exp_seq"] = jnp.where(src_oh, exp2[:, None],
                                     state["exp_seq"])
        state["rbits"] = jnp.where(src_oh, bits3[:, None], state["rbits"])
        occ = _popcount(state["rbits"]).sum(1) * l
        state["reorder_max"] = jnp.maximum(
            state["reorder_max"],
            jnp.where(measuring, occ.max(), 0).astype(jnp.int32))

        # ------------- 8. telemetry probes (optional) ------------------- #
        # Time-resolved ring buffers (repro.obs.probe): reads existing
        # cycle values, writes only tel_* arrays, consumes no RNG — so
        # every core statistic is bit-identical with telemetry on or off,
        # and absent entirely when off.  Slot index wraps (accumulating);
        # tel_cycles normalizes.  Mirrored op for op in the fused body
        # (repro.kernels.simstep.ref).
        if tel_epoch:
            slot = (cycle // tel_epoch) % cfg.tel_slots
            state["tel_cycles"] = state["tel_cycles"].at[slot].add(1)
            state["tel_chan"] = state["tel_chan"].at[slot].add(
                net[t.chan_src_n, t.chan_src_p].astype(jnp.int32))
            state["tel_counts"] = state["tel_counts"].at[slot].add(
                jnp.stack([gen.sum(), push.sum(), (gen & ~space).sum(),
                           tail_ej.sum()]).astype(jnp.int32))
            nb = cfg.tel_occ_bins
            obin = jnp.minimum(state["q_size"].sum() * nb // (n * q),
                               nb - 1)
            state["tel_qocc"] = state["tel_qocc"].at[slot, obin].add(1)
            state["tel_lat"] = state["tel_lat"].at[
                slot, jnp.where(tail_ej, hbin, cfg.lat_bins)].add(
                1, mode="drop")
        return state, None

    return step


@functools.lru_cache(maxsize=None)
def _get_runner(meta_key: tuple, cfg_key: tuple, num_cycles: int):
    """One jit compilation per (mesh size, algo, flow-control params,
    cycle-chunk length); vmapped over batched per-run states — the batch
    axis carries (injection-rate, seed) campaign points — and shared
    across traffic patterns (tables are traced arguments)."""
    meta = dict(meta_key)
    cfg = SimConfig(**dict(cfg_key))
    step = _make_step(meta, cfg)

    def run(tables, state):
        state, _ = jax.lax.scan(
            lambda s, c: step(tables, s, c), state, jnp.arange(num_cycles))
        state["cycle0"] = state["cycle0"] + num_cycles
        return state

    return jax.jit(jax.vmap(run, in_axes=(None, 0)))


@functools.lru_cache(maxsize=None)
def _get_sharded_runner(meta_key: tuple, cfg_key: tuple, num_cycles: int,
                        ndev: int):
    """shard_map lane-parallel variant of :func:`_get_runner`.

    Lanes are fully independent, so splitting the batch axis over an
    explicit ("lane",) device mesh is exact — every lane runs the same
    per-cycle ops on the same bits, each device just owns its slice.
    The carry state is donated: chunked campaigns and the control
    plane's epoch loop update multi-MB flit buffers in place instead of
    reallocating them per call.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec

    meta = dict(meta_key)
    cfg = SimConfig(**dict(cfg_key))
    step = _make_step(meta, cfg)

    def run(tables, state):
        state, _ = jax.lax.scan(
            lambda s, c: step(tables, s, c), state, jnp.arange(num_cycles))
        state["cycle0"] = state["cycle0"] + num_cycles
        return state

    mesh = Mesh(np.array(jax.devices()[:ndev]), ("lane",))
    fn = shard_map(jax.vmap(run, in_axes=(None, 0)), mesh=mesh,
                   in_specs=(PartitionSpec(), PartitionSpec("lane")),
                   out_specs=PartitionSpec("lane"), check_rep=False)
    return jax.jit(fn, donate_argnums=(1,))


def _cfg_key(cfg: SimConfig) -> tuple:
    """Compile-relevant SimConfig fields (rate and seed are dynamic)."""
    return tuple(sorted(dict(
        algo=int(cfg.algo), num_vcs=cfg.num_vcs, buf_per_vc=cfg.buf_per_vc,
        packet_len=cfg.packet_len, src_queue_pkts=cfg.src_queue_pkts,
        cycles=cfg.cycles, warmup=cfg.warmup, drain=cfg.drain,
        lat_bins=cfg.lat_bins, lat_bin_width=cfg.lat_bin_width,
        use_kernel=bool(cfg.use_kernel),
        sim_tile_nodes=int(cfg.sim_tile_nodes),
        telemetry=bool(cfg.telemetry),
        tel_epoch=cfg.tel_epoch, tel_slots=cfg.tel_slots,
        tel_occ_bins=cfg.tel_occ_bins, watchdog=bool(cfg.watchdog),
        wd_stall_cycles=cfg.wd_stall_cycles,
        wd_hop_limit=cfg.wd_hop_limit,
        wd_throttle_cycles=cfg.wd_throttle_cycles).items()))


def get_runner(meta: dict, cfg: SimConfig, num_cycles: int, *,
               num_lanes: int | None = None,
               multi_device: bool | None = None):
    """Public cached-runner accessor (used by :mod:`repro.noc.campaign`
    and :mod:`repro.noc.ctrl`).

    ``multi_device`` selects the ``shard_map`` lane-parallel runner:
    ``True`` forces it (raises if the ``num_lanes`` batch does not
    divide over the local devices), ``False`` pins the single-device
    runner, and ``None`` — the default — auto-enables it whenever more
    than one local device is visible and ``num_lanes`` divides evenly.
    Both runners produce bit-identical states (asserted by
    ``tests/test_multidevice.py``)."""
    ndev = jax.device_count()
    want = (multi_device if multi_device is not None
            else ndev > 1 and num_lanes is not None
            and num_lanes % ndev == 0)
    if want:
        if ndev <= 1:
            raise ValueError("multi_device=True with a single device; "
                             "on CPU expose cores via XLA_FLAGS="
                             "--xla_force_host_platform_device_count=N")
        if num_lanes is None or num_lanes % ndev:
            raise ValueError(
                f"multi_device=True needs the lane count to divide over "
                f"the devices ({num_lanes} lanes, {ndev} devices)")
        return _get_sharded_runner(tuple(sorted(meta.items())),
                                   _cfg_key(cfg), int(num_cycles), ndev)
    return _get_runner(tuple(sorted(meta.items())), _cfg_key(cfg),
                       int(num_cycles))


def hist_percentile(hist: np.ndarray, bin_width: int, q: float) -> float:
    """q-quantile (0 < q < 1) from a fixed-width latency histogram, with
    linear interpolation inside the bin.  The last bin is an overflow
    bucket, so quantiles landing there are lower bounds."""
    hist = np.asarray(hist, dtype=np.float64)
    total = hist.sum()
    if total <= 0:
        return 0.0
    target = q * total
    cum = np.cumsum(hist)
    b = int(np.searchsorted(cum, target))
    before = cum[b - 1] if b > 0 else 0.0
    frac = (target - before) / max(hist[b], 1.0)
    return float((b + frac) * bin_width)


def postprocess(o: dict, cfg: SimConfig, topo: Topology, *,
                rate: float, seed: int, saturated: bool = False,
                meas_cycles: int | None = None) -> SimResult:
    """Turn one run's device state (already on host) into a SimResult."""
    meas = int(o["meas_cnt"]) if meas_cycles is None else int(meas_cycles)
    meas = max(meas, 1)
    ports = float(topo.io_weights.sum())
    load = o["node_fwd"].astype(np.float64) / meas
    active = load[load > 1e-9]
    lat_cnt = max(int(o["lat_cnt"]), 1)
    bw = np.asarray(topo.channel_bw, np.float64)
    flits = o["chan_fwd"].astype(np.float64) / meas
    # dead (bw = 0) channels never forward, so 0/0 → 0 by convention
    link = flits / np.where(bw > 0, bw, 1.0)
    hist = o["lat_hist"]
    return SimResult(
        algo=Algo(cfg.algo), injection_rate=float(rate),
        throughput=int(o["eject_flits"].sum()) / meas / ports,
        offered=float(o["offered"]) / meas / ports,
        avg_latency=float(o["lat_sum"]) / lat_cnt,
        max_latency=float(o["lat_max"]),
        node_load=load,
        lcv=float(active.std() / active.mean()) if active.size else 0.0,
        reorder_value=int(o["reorder_max"]),
        ejected_flits=int(o["eject_total"]),
        injected_flits=int(o["injected"]),
        in_flight_flits=int(o["fifo_size"].sum()),
        seed=int(seed),
        meas_cycles=meas,
        saturated=bool(saturated),
        p50_latency=hist_percentile(hist, cfg.lat_bin_width, 0.50),
        p90_latency=hist_percentile(hist, cfg.lat_bin_width, 0.90),
        p99_latency=hist_percentile(hist, cfg.lat_bin_width, 0.99),
        link_load_max=float(link.max()) if link.size else 0.0,
    )


def point_key(seed: int, rate: float) -> jnp.ndarray:
    """PRNG stream of a (rate, seed) campaign point: a pure function of
    the point itself (the float32 bit pattern of the rate is folded in),
    so a point gets the identical stream whether it runs alone, inside a
    sweep, or as any lane of a batched campaign."""
    rate_bits = int(np.float32(rate).view(np.uint32))
    return jax.random.fold_in(jax.random.PRNGKey(seed), rate_bits)


def make_states(meta: dict, cfg: SimConfig,
                points: list[tuple[float, int]]):
    """Batched fresh state for a list of (rate, seed) points."""
    states = []
    for rate, seed in points:
        st = fresh_state(meta, cfg)
        st["rate"] = jnp.float32(rate)
        st["key"] = point_key(seed, rate)
        states.append(st)
    return maybe_shard_states(jax.tree.map(lambda *xs: jnp.stack(xs),
                                           *states))


def maybe_shard_states(batched):
    """Shard the lane (batch) axis across local devices when possible.

    Lanes are fully independent, so SPMD partitioning of the leading axis
    is exact: results are bit-identical to the unsharded run, each device
    just executes its slice of lanes in parallel.  No-op on a single
    device or when the batch does not divide evenly.  On CPU, expose
    cores as devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before
    the first jax import), as ``benchmarks/run.py`` does.
    """
    ndev = jax.device_count()
    nb = jax.tree.leaves(batched)[0].shape[0]
    if ndev <= 1 or nb % ndev:
        return batched
    from jax.sharding import Mesh, NamedSharding, PartitionSpec
    mesh = Mesh(np.array(jax.devices()), ("lane",))
    spec = NamedSharding(mesh, PartitionSpec("lane"))
    return jax.tree.map(lambda x: jax.device_put(x, spec), batched)


def static_bw_slots(topo: Topology, cfg: SimConfig) -> np.ndarray:
    """(tel_slots, C) per-slot bandwidth for a run with no fault events:
    every slot sees the topology's static channel bandwidths."""
    return np.broadcast_to(
        np.asarray(topo.channel_bw, np.float64),
        (int(cfg.tel_slots), topo.num_channels)).copy()


def run_sweep(topo: Topology, traffic: np.ndarray, cfg: SimConfig,
              rates: list[float],
              bidor_table: BiDORTable | None = None,
              seeds: list[int] | None = None, *,
              return_telemetry: bool = False,
              return_watchdog: bool = False):
    """Run a batch of simulations over (rate, seed) points in ONE jitted,
    vmapped call.  Results are ordered rate-major: ``[(r, s) for r in
    rates for s in seeds]``; with ``seeds=None`` (default ``[cfg.seed]``)
    this is the legacy one-result-per-rate list.

    ``return_telemetry=True`` returns ``(results, telemetry)`` instead —
    the lane-major :class:`repro.obs.probe.Telemetry` bundle (None when
    ``cfg.telemetry`` is off).  ``return_watchdog=True`` appends the
    all-lane :class:`repro.noc.watchdog.WatchdogReport` (None when
    ``cfg.watchdog`` is off) as the trailing element."""
    table = None
    if cfg.algo == Algo.BIDOR:
        if bidor_table is None:
            raise ValueError("BIDOR needs a BiDORTable")
        table = bidor_table
    tables, meta = build_tables(topo, traffic, table, cfg.num_vcs)
    runner = get_runner(meta, cfg, cfg.cycles)
    points = [(r, s) for r in rates for s in (seeds or [cfg.seed])]
    batched = make_states(meta, cfg, points)
    out = jax.device_get(runner(tables, batched))
    results = [postprocess(jax.tree.map(lambda x: x[i], out), cfg, topo,
                           rate=r, seed=s)
               for i, (r, s) in enumerate(points)]
    extras: list = []
    if return_telemetry:
        tel = Telemetry.from_state(out, cfg)
        if tel is not None:
            tel = tel.with_bw(static_bw_slots(topo, cfg))
        extras.append(tel)
    if return_watchdog:
        from .watchdog import WatchdogReport
        extras.append(WatchdogReport.from_state(out, cfg))
    if not extras:
        return results
    return (results, *extras)


def run_sim(topo: Topology, traffic: np.ndarray, cfg: SimConfig,
            bidor_table: BiDORTable | None = None, *,
            return_telemetry: bool = False,
            return_watchdog: bool = False):
    """Run one simulation and post-process statistics.  With
    ``return_telemetry=True``, returns ``(SimResult, Telemetry | None)``;
    with ``return_watchdog=True``, the
    :class:`repro.noc.watchdog.WatchdogReport` (or None) is appended."""
    out = run_sweep(topo, traffic, cfg, [cfg.injection_rate],
                    bidor_table, return_telemetry=return_telemetry,
                    return_watchdog=return_watchdog)
    if return_telemetry or return_watchdog:
        results, *extras = out
        return (results[0], *extras)
    return out[0]


def run_trace_sweep(topo: Topology,
                    segments: list[tuple[np.ndarray, float]],
                    cfg: SimConfig,
                    bidor_table: BiDORTable | None = None,
                    seeds: list[int] | None = None):
    """Trace-driven simulation: piecewise-constant traffic epochs, batched
    (vmapped) over seeds.

    Each segment is (traffic_matrix, injection_rate); the network state
    (buffers, in-flight packets, reorder bookkeeping) carries across
    segments.  Used for the paper's realistic-workload evaluation (§4.3),
    where a leaf-switch port-pair trace is replayed as epochs.  BiDOR's
    routing table stays fixed (built offline from the aggregate statistics),
    while adaptive routing reacts per cycle — exactly the paper's contrast.

    Returns a list over seeds of (SimResult over all measured cycles,
    per-segment LCVs).
    """
    table = None
    if cfg.algo == Algo.BIDOR:
        if bidor_table is None:
            raise ValueError("BIDOR needs a BiDORTable")
        table = bidor_table
    seeds = list(seeds or [cfg.seed])
    nb = len(seeds)
    batched = None
    lcvs: list[list[float]] = [[] for _ in seeds]
    prev_fwd = None
    for si, (tm, rate) in enumerate(segments):
        tables, meta = build_tables(topo, tm, table, cfg.num_vcs)
        runner = get_runner(meta, cfg, cfg.cycles)
        if batched is None:
            states = []
            for seed in seeds:
                st = fresh_state(meta, cfg)
                st["key"] = jax.random.fold_in(
                    jax.random.PRNGKey(seed), si)
                # traces run open-ended: every segment injects and
                # measures for its full cfg.cycles window
                st["inject_until"] = _BIG
                st["measure_until"] = _BIG
                states.append(st)
            batched = maybe_shard_states(
                jax.tree.map(lambda *xs: jnp.stack(xs), *states))
            prev_fwd = np.zeros((nb, meta["N"]), np.int64)
        batched["rate"] = jnp.full((nb,), rate, jnp.float32)
        batched = runner(tables, batched)
        fwd = np.asarray(jax.device_get(batched["node_fwd"]), np.int64)
        seg = fwd - prev_fwd
        prev_fwd = fwd
        for bi in range(nb):
            active = seg[bi][seg[bi] > 0]
            if active.size:
                lcvs[bi].append(float(active.std() / active.mean()))
    out = jax.device_get(batched)
    mean_rate = float(np.mean([r for _, r in segments]))
    return [(postprocess(jax.tree.map(lambda x: x[bi], out), cfg, topo,
                         rate=mean_rate, seed=seeds[bi]), lcvs[bi])
            for bi in range(nb)]


def run_trace(topo: Topology, segments: list[tuple[np.ndarray, float]],
              cfg: SimConfig,
              bidor_table: BiDORTable | None = None):
    """Single-seed :func:`run_trace_sweep` — returns (SimResult, lcvs)."""
    return run_trace_sweep(topo, segments, cfg, bidor_table)[0]
