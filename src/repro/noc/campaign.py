"""Batched simulation-campaign engine.

Every headline number in the paper (42.9% throughput, 86.4%/95.3% latency)
comes from sweeping (algorithm × traffic pattern × injection rate × seed)
through the flit simulator.  This module turns that sweep into a first-class
subsystem:

* A declarative :class:`CampaignSpec` names the grid once.
* All (rate, seed) points of a cell — one (algorithm, pattern) pair — run
  inside a SINGLE jitted, vmapped call: per-run state is a pytree batched
  over a leading axis (``repro.noc.sim.make_states``), static lookup tables
  are traced arguments shared by every lane.  One XLA compilation per
  (mesh, algorithm, flow-control, chunk-length) tuple covers the whole
  campaign.
* Explicit warmup → measure → drain phasing (``SimConfig.warmup`` /
  ``.drain``): statistics only inside the measurement window, injection
  halted for the trailing drain cycles so in-flight packets land and
  latency tails are complete.
* Saturation early-exit: the cell advances in ``chunk``-cycle slices; after
  each slice a cheap host-side detector reads source-queue occupancy, and
  once EVERY lane is saturated (queues ≥ ``sat_occupancy`` of capacity) the
  remaining cycles are skipped — per-lane ``meas_cnt`` keeps the statistics
  exactly normalized.  ``chunk=0`` disables chunking (one call per cell).

:class:`CampaignResult` returns per-point latency percentiles (p50/p90/p99
from in-simulator histograms), throughput, max link load, and per-cell
wall-clock, with grid accessors for plotting/tables.
"""

from __future__ import annotations

import dataclasses
import re
import time
from typing import Sequence

import jax
import numpy as np

from repro.core import traffic as traffic_mod
from repro.core.plan_fast import build_plans_batched
from repro.core.topology import Topology
from repro.obs.log import EventLog
from repro.obs.probe import Telemetry
from repro.obs.trace import NULL_TRACER
from .sim import (build_tables, get_runner, make_states, postprocess,
                  queue_occupancy, source_queue_meta, static_bw_slots)
from .simconfig import Algo, SimConfig, SimResult

__all__ = ["CampaignSpec", "CampaignPoint", "CampaignResult",
           "run_campaign", "CellKey", "CellOutcome", "campaign_cells",
           "CampaignExecutor", "csv_rows"]


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """Declarative grid of simulations.

    Attributes:
      topo: the network under test.
      topos: optional *topology axis* — when non-empty, the whole grid runs
        once per listed topology (``topo`` is ignored); string patterns are
        re-resolved per topology, and BiDOR plans (including fault masking
        for topologies with dead channels) are rebuilt per topology.
      algos: routing algorithms to sweep.
      patterns: traffic patterns — names resolved through
        ``repro.core.traffic.PATTERNS`` or explicit ``(name, matrix)``
        pairs.
      rates: injection rates (flits/cycle/I/O-port).
      seeds: RNG seeds; each (rate, seed) is an independent lane of the
        vmapped batch.
      base: simulation parameters shared by every point (``algo``,
        ``injection_rate`` and ``seed`` fields are overridden per point).
      chunk: host-loop granularity in cycles for the saturation early-exit;
        0 runs each cell as one jitted call of ``base.cycles`` cycles.
      sat_occupancy: source-queue occupancy fraction above which a lane is
        declared saturated.
      scenarios: optional fault/drift dynamics axis —
        :class:`repro.noc.ctrl.Scenario` entries.  Empty () keeps the
        classic static grid; with scenarios, every (algo, pattern,
        scenario) cell runs through the control plane's event-driven loop
        (:func:`repro.noc.ctrl.run_controlled`), (rate, seed) points still
        batched as lanes of one vmapped state.
      multi_device: ``shard_map`` lane parallelism — ``True`` forces the
        explicit multi-device runner (lanes split over all local devices,
        carry buffers donated), ``False`` pins single-device execution,
        ``None`` (default) auto-enables whenever >1 device is visible and
        the (rate, seed) lane count divides evenly.  Results are
        bit-identical either way (``tests/test_multidevice.py``); on CPU
        expose cores with
        ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
      workloads: ML-workload axis — entries are
        :class:`repro.noc.mltraffic.MLWorkload` instances (anything with
        ``.name`` and ``.matrix_for(topo)``) or explicit ``(name,
        matrix)`` pairs.  Workloads join the pattern axis as extra items
        (same plan building, plan cache, certifier gate, and cell
        enumeration), tagged with their name in the ``workload`` CSV /
        telemetry column so derived and synthetic rows stay separable.
    """

    topo: Topology | None
    algos: tuple[Algo, ...]
    patterns: tuple
    rates: tuple[float, ...]
    seeds: tuple[int, ...] = (0,)
    base: SimConfig = SimConfig()
    chunk: int = 0
    sat_occupancy: float = 0.9
    scenarios: tuple = ()
    topos: tuple[Topology, ...] = ()
    multi_device: bool | None = None
    workloads: tuple = ()

    def __post_init__(self):
        if not (self.algos and (self.patterns or self.workloads)
                and self.rates and self.seeds):
            raise ValueError("campaign grid must be non-empty on all axes")
        if self.topo is None and not self.topos:
            raise ValueError("provide topo or a non-empty topos axis")

    @property
    def topo_axis(self) -> tuple[Topology, ...]:
        return self.topos or (self.topo,)

    @property
    def num_points(self) -> int:
        return (len(self.algos)
                * (len(self.patterns) + len(self.workloads))
                * len(self.rates)
                * len(self.seeds) * max(len(self.scenarios), 1)
                * len(self.topo_axis))

    def pattern_items(self, topo: Topology | None = None,
                      ) -> list[tuple[str, np.ndarray]]:
        """Resolve the combined pattern ⊕ workload axis to (name,
        traffic matrix) pairs — workload items come last, in axis
        order (``campaign_cells`` relies on this item indexing)."""
        topo = self.topo if topo is None else topo
        items = []
        for p in self.patterns:
            if isinstance(p, str):
                if p not in traffic_mod.PATTERNS:
                    raise KeyError(
                        f"unknown traffic pattern {p!r}; available: "
                        f"{sorted(traffic_mod.PATTERNS)}")
                items.append((p, traffic_mod.PATTERNS[p](topo)))
            else:
                name, tm = p
                items.append((str(name), np.asarray(tm, np.float64)))
        for w in self.workloads:
            if hasattr(w, "matrix_for"):
                items.append((str(w.name), w.matrix_for(topo)))
            else:
                name, tm = w
                items.append((str(name), traffic_mod.from_pair_counts(
                    topo, np.asarray(tm, np.float64))))
        return items


@dataclasses.dataclass(frozen=True)
class CampaignPoint:
    """One grid point: the cell coordinates plus its SimResult."""

    algo: Algo
    pattern: str
    rate: float
    seed: int
    result: SimResult
    scenario: str = "static"
    topo: str = ""
    # name of the originating CampaignSpec.workloads entry; "" for
    # synthetic patterns (the workload's name doubles as its pattern)
    workload: str = ""


@dataclasses.dataclass
class CampaignResult:
    """Structured campaign output.

    ``points`` is ordered (topo, pattern, algo, scenario, rate, seed)
    nested-loop major.  ``wall_clock_s`` maps one key per cell to the
    wall-clock of its single batched call chain (compile time included on
    first use).  The key shape follows the active axes:

    * ``(algo name, pattern)`` — classic single-topology static grid;
    * ``(algo name, pattern, scenario)`` — with a ``scenarios`` axis;
    * ``(topo, algo name, pattern)`` /
      ``(topo, algo name, pattern, scenario)`` — with a ``topos`` axis
      (the topology name is *prepended*).

    :meth:`summary` labels each part explicitly, so logs stay readable
    whatever the key arity.
    """

    spec: CampaignSpec
    points: list[CampaignPoint]
    wall_clock_s: dict[tuple[str, ...], float]
    total_wall_clock_s: float

    def select(self, algo: Algo | None = None, pattern: str | None = None,
               rate: float | None = None,
               seed: int | None = None,
               scenario: str | None = None,
               topo: str | None = None,
               workload: str | None = None) -> list[CampaignPoint]:
        out = []
        for p in self.points:
            if algo is not None and p.algo != algo:
                continue
            if pattern is not None and p.pattern != pattern:
                continue
            if rate is not None and p.rate != rate:
                continue
            if seed is not None and p.seed != seed:
                continue
            if scenario is not None and p.scenario != scenario:
                continue
            if topo is not None and p.topo != topo:
                continue
            if workload is not None and p.workload != workload:
                continue
            out.append(p)
        return out

    def _resolve_axis(self, name: str, value: str | None,
                      options: tuple[str, ...]) -> str:
        """Default a cell axis for single-valued campaigns; on a
        multi-valued axis an explicit value is REQUIRED — silently
        pooling points across scenarios/topologies is exactly the
        last-write-wins corruption this guard exists to prevent."""
        if value is not None:
            if value not in options:
                raise KeyError(f"unknown {name} {value!r}; campaign has "
                               f"{list(options)}")
            return value
        if len(options) == 1:
            return options[0]
        raise ValueError(
            f"ambiguous {name} axis: this campaign has "
            f"{list(options)}; pass {name}=... to the accessor")

    @property
    def scenario_names(self) -> tuple[str, ...]:
        return tuple(s.name for s in self.spec.scenarios) or ("static",)

    @property
    def topo_names(self) -> tuple[str, ...]:
        return tuple(t.name for t in self.spec.topo_axis)

    def grid(self, field: str, algo: Algo, pattern: str,
             scenario: str | None = None,
             topo: str | None = None) -> np.ndarray:
        """(num_rates, num_seeds) array of a SimResult field for ONE cell.

        ``scenario`` / ``topo`` select along the scenario and topology
        axes; they default only when the campaign has a single value on
        that axis, and raise otherwise (an ambiguous selection would
        overlay every scenario/topology into one grid, last write wins).
        """
        scenario = self._resolve_axis("scenario", scenario,
                                      self.scenario_names)
        topo = self._resolve_axis("topo", topo, self.topo_names)
        rates, seeds = list(self.spec.rates), list(self.spec.seeds)
        g = np.zeros((len(rates), len(seeds)))
        filled = np.zeros((len(rates), len(seeds)), bool)
        for p in self.select(algo=algo, pattern=pattern,
                             scenario=scenario, topo=topo):
            ij = rates.index(p.rate), seeds.index(p.seed)
            if filled[ij]:
                raise ValueError(
                    f"duplicate point for (rate={p.rate}, seed={p.seed}) "
                    f"in cell ({algo.name}, {pattern!r}, {scenario!r}, "
                    f"{topo!r}) — pattern names are not unique in this "
                    f"campaign; use explicit (name, matrix) labels")
            filled[ij] = True
            g[ij] = getattr(p.result, field)
        if not filled.all():
            raise ValueError(
                f"cell ({algo.name}, {pattern!r}, {scenario!r}, {topo!r}) "
                f"is missing {int((~filled).sum())} of the "
                f"{filled.size} (rate, seed) points")
        return g

    def mean_over_seeds(self, field: str, algo: Algo, pattern: str,
                        scenario: str | None = None,
                        topo: str | None = None) -> np.ndarray:
        return self.grid(field, algo, pattern, scenario=scenario,
                         topo=topo).mean(axis=1)

    def saturation_throughput(self, algo: Algo, pattern: str,
                              scenario: str | None = None,
                              topo: str | None = None) -> float:
        """Max seed-averaged accepted throughput across the rate sweep."""
        return float(self.mean_over_seeds(
            "throughput", algo, pattern, scenario=scenario,
            topo=topo).max())

    CSV_HEADER = ["topo", "scenario", "pattern", "workload", "algo",
                  "rate", "seed",
                  "throughput",
                  "offered", "avg_lat", "p50_lat", "p90_lat", "p99_lat",
                  "max_lat", "lcv", "link_load_max", "reorder",
                  "saturated", "meas_cycles"]

    def to_rows(self) -> list[list]:
        return csv_rows(self.points)

    def _wall_key_labels(self, key: tuple[str, ...]) -> list[str]:
        """Name the parts of one ``wall_clock_s`` key (see the class
        docstring for the shape rules)."""
        parts = list(key)
        labels = []
        if len(self.spec.topo_axis) > 1:
            labels.append("topo")
        labels += ["algo", "pattern"]
        if self.spec.scenarios:
            labels.append("scenario")
        if len(labels) != len(parts):   # foreign/legacy key: best effort
            return [str(p) for p in parts]
        return [f"{l}={p}" for l, p in zip(labels, parts)]

    def summary(self) -> str:
        lines = [f"campaign: {self.spec.num_points} points in "
                 f"{self.total_wall_clock_s:.1f}s wall-clock"]
        for key, dt in self.wall_clock_s.items():
            cell = " ".join(f"{part:14s}"
                            for part in self._wall_key_labels(key))
            lines.append(f"  cell {cell} {dt:6.2f}s")
        return "\n".join(lines)


def csv_rows(points: Sequence[CampaignPoint]) -> list[list]:
    """CSV rows (matching ``CampaignResult.CSV_HEADER``) for a point list.

    Module-level so the campaign service can stream a cell's rows the
    moment the cell completes, with byte-identical formatting to a full
    ``CampaignResult.to_rows`` dump.
    """
    rows = []
    for p in points:
        r = p.result
        rows.append([p.topo, p.scenario, p.pattern, p.workload,
                     p.algo.name,
                     p.rate, p.seed,
                     f"{r.throughput:.4f}", f"{r.offered:.4f}",
                     f"{r.avg_latency:.1f}", f"{r.p50_latency:.1f}",
                     f"{r.p90_latency:.1f}", f"{r.p99_latency:.1f}",
                     f"{r.max_latency:.0f}", f"{r.lcv:.3f}",
                     f"{r.link_load_max:.4f}", r.reorder_value,
                     int(r.saturated), r.meas_cycles])
    return rows


def _run_cell(spec: CampaignSpec, cfg: SimConfig, tables, meta,
              points: list[tuple[float, int]]):
    """Advance one (algo, pattern) cell; returns (host state, sat flags).

    The cell is one vmapped batch over ``points``.  With ``spec.chunk``
    set, execution proceeds in chunk-cycle slices so the host can stop the
    whole batch as soon as every lane is saturated.
    """
    batched = make_states(meta, cfg, points)
    total = int(cfg.cycles)
    chunk = int(spec.chunk) or total
    sat = np.zeros(len(points), bool)
    q_meta = source_queue_meta(tables, cfg)   # static for the whole cell
    done = 0
    while done < total:
        step_cycles = min(chunk, total - done)
        runner = get_runner(meta, cfg, step_cycles,
                            num_lanes=len(points),
                            multi_device=spec.multi_device)
        batched = runner(tables, batched)
        done += step_cycles
        if done > cfg.warmup:
            # saturation accumulates from post-warmup reads only — a
            # transient warmup spike must not permanently latch a lane
            occ = queue_occupancy(tables, cfg, batched["q_size"], q_meta)
            sat |= occ >= spec.sat_occupancy
            if done < total and sat.all():
                break  # every lane saturated: verdict reached
    return jax.device_get(batched), sat


# --------------------------------------------------------------------- #
# resumable cell machinery (the campaign service's unit of work)
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class CellKey:
    """Coordinates of one campaign cell in the spec's enumeration order.

    ``index`` is the cell's position in :func:`campaign_cells` order —
    the canonical topo → pattern item → algo → scenario nesting — which
    is also the order of ``CampaignResult.points`` (lane-major within a
    cell).  ``item_i`` carries the *pattern item index*, not just the
    name: explicit ``(name, matrix)`` patterns may repeat a name with
    different matrices.  ``scen_i`` is -1 for the static (no-scenario)
    cell.
    """

    index: int
    topo_i: int
    topo: str
    item_i: int
    pattern: str
    algo: Algo
    scen_i: int
    scenario: str
    # the workload-axis name when this cell's item is a workload
    # (item_i >= len(spec.patterns)); "" for synthetic pattern cells
    workload: str = ""

    @property
    def slug(self) -> str:
        """Filesystem-safe unique cell name (checkpoint file stem)."""
        parts = (self.topo, f"i{self.item_i}", self.pattern,
                 self.algo.name, self.scenario)
        clean = "_".join(re.sub(r"[^A-Za-z0-9.+-]+", "-", p)
                         for p in parts)
        return f"cell{self.index:04d}_{clean}"

    def wall_key(self, spec: CampaignSpec) -> tuple[str, ...]:
        """The cell's ``CampaignResult.wall_clock_s`` key."""
        key: tuple[str, ...] = (self.algo.name, self.pattern)
        if self.scen_i >= 0:
            key = key + (self.scenario,)
        if len(spec.topo_axis) > 1:
            key = (self.topo,) + key
        return key


@dataclasses.dataclass
class CellOutcome:
    """One executed cell: its per-lane results plus wall-clock."""

    key: CellKey
    results: list[SimResult]    # one per (rate, seed) lane, rate-major
    wall_s: float
    # per-lane probe rings when cfg.telemetry is on (None otherwise);
    # bw-normalized — static cells against the topology's bandwidths,
    # scenario cells against the per-slot fault-tracking timeline
    telemetry: "Telemetry | None" = None


def _pattern_names(spec: CampaignSpec) -> list[str]:
    """Combined pattern ⊕ workload axis names without resolving matrices
    (cheap enumeration; workload names come last, matching
    ``CampaignSpec.pattern_items`` item order)."""
    names = [p if isinstance(p, str) else str(p[0]) for p in spec.patterns]
    names += [str(w.name) if hasattr(w, "matrix_for") else str(w[0])
              for w in spec.workloads]
    return names


def campaign_cells(spec: CampaignSpec) -> list[CellKey]:
    """Enumerate the spec's cells in canonical execution order.

    The nesting (topo → pattern item → algo → scenario) matches the
    historical ``run_campaign`` loop exactly, so ``CampaignResult.points``
    built from this order is identical to a pre-service campaign's.
    """
    names = _pattern_names(spec)
    n_pat = len(spec.patterns)
    cells: list[CellKey] = []
    index = 0
    for topo_i, topo in enumerate(spec.topo_axis):
        for item_i, pat_name in enumerate(names):
            for algo in spec.algos:
                for scen_i, scen in enumerate(spec.scenarios or (None,)):
                    cells.append(CellKey(
                        index=index, topo_i=topo_i, topo=topo.name,
                        item_i=item_i, pattern=pat_name, algo=algo,
                        scen_i=-1 if scen is None else scen_i,
                        scenario="static" if scen is None else scen.name,
                        workload=pat_name if item_i >= n_pat else ""))
                    index += 1
    return cells


@dataclasses.dataclass
class _ItemPrep:
    """Per-(topology, pattern item) execution inputs."""

    name: str
    tm: np.ndarray
    table: object | None       # BiDORTable (None when BiDOR absent)
    nrank: object | None       # warm-start fixed point for replans
    bidor_tm: np.ndarray       # admission-controlled generation matrix


class CampaignExecutor:
    """Executes campaign cells one at a time, in any order.

    Holds everything a cell run needs — the resolved pattern matrices,
    BiDOR plans (admission-controlled for degraded topologies), and the
    lane list — prepared lazily per topology so resuming a job at cell k
    does not re-plan topologies whose cells are all complete.

    ``plan_cache`` (a :class:`repro.core.plan_cache.PlanCache`) serves
    plan builds by content key; when every pattern of a topology hits,
    ``build_plans_batched`` is not called at all for that topology.
    """

    def __init__(self, spec: CampaignSpec, *,
                 bidor_tables: dict[str, np.ndarray] | None = None,
                 plan_cache=None, verbose: bool = False, tracer=None):
        self.spec = spec
        self.bidor_tables = bidor_tables
        self.plan_cache = plan_cache
        self.verbose = verbose
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.log = EventLog(verbose=verbose)
        self.points = [(float(r), int(s))
                       for r in spec.rates for s in spec.seeds]
        self._prepped: dict[int, list[_ItemPrep]] = {}

    # ------------------------------------------------------------- #
    def _build_plans(self, topo: Topology, items, need: list[int]):
        """Plans for the needed pattern items, through the cache when
        one is configured (misses batched into one device call)."""
        plans: dict[int, object] = {}
        if not need:
            return plans
        down = topo.down_channels
        dc = down if down.size else None
        cache = self.plan_cache
        if cache is None:
            built = build_plans_batched(topo, [items[i][1] for i in need],
                                        down_channels=dc,
                                        tracer=self.tracer)
            return dict(zip(need, built))
        from repro.core.plan_fast import gate_plan, plan_cache_key
        miss: list[tuple[int, str]] = []
        for i in need:
            key = plan_cache_key(topo, items[i][1], down_channels=dc)
            hit = cache.get(key, topo)
            if hit is not None:
                # cache admission: a stored clean certificate satisfies
                # the deadlock gate; anything else re-certifies
                cert = cache.get_cert(key)
                if cert is not None and cert.verdict == "clean":
                    hit = dataclasses.replace(hit, cert=cert)
                else:
                    hit = gate_plan(topo, hit, tracer=self.tracer,
                                    label=f"cache_admission:{topo.name}")
                plans[i] = hit
                if self.tracer.enabled:
                    self.tracer.instant(
                        "plan_cache_hit", cat="plan",
                        args={"item": i, "topo": topo.name, "store": True})
            else:
                miss.append((i, key))
        if miss:
            built = build_plans_batched(
                topo, [items[i][1] for i, _ in miss], down_channels=dc,
                tracer=self.tracer)
            for (i, key), plan in zip(miss, built):
                plans[i] = plan
                cache.put(key, plan)
            cache.stats.device_builds += 1
        return plans

    def _prep_topo(self, topo_i: int) -> list[_ItemPrep]:
        if topo_i in self._prepped:
            return self._prepped[topo_i]
        spec = self.spec
        bidor_tables = self.bidor_tables
        topo = spec.topo_axis[topo_i]
        items = spec.pattern_items(topo)
        # one vmapped device call plans every pattern that needs one (the
        # campaign's pattern axis; scenario replans reuse these as their
        # warm-start seeds).  Keyed by item index: explicit (name, matrix)
        # patterns may repeat a name with different matrices.
        plans: dict[int, object] = {}
        if Algo.BIDOR in spec.algos:
            need = [i for i, (name, _) in enumerate(items)
                    if not (bidor_tables and name in bidor_tables)
                    or spec.scenarios]
            plans = self._build_plans(topo, items, need)
        prepped: list[_ItemPrep] = []
        for item_i, (pat_name, tm) in enumerate(items):
            pat_table = None
            pat_nrank = None  # seed fixed point: scenario replans warm-start
            if Algo.BIDOR in spec.algos:
                if bidor_tables and pat_name in bidor_tables:
                    choice = np.asarray(bidor_tables[pat_name], np.int8)
                    if spec.scenarios:  # scenario cells need the full plan
                        pat_table = dataclasses.replace(
                            plans[item_i].table, choice=choice)
                        pat_nrank = plans[item_i].nrank
                    else:
                        from repro.core.bidor import dor_table
                        pat_table = dataclasses.replace(
                            dor_table(topo), choice=choice)
                else:
                    pat_table = plans[item_i].table
                    pat_nrank = plans[item_i].nrank
            # admission control: pairs no dimension order can serve on a
            # degraded topology are shed from BiDOR's generation matrix
            # (the control plane does the same after a replan)
            bidor_tm = tm
            if (pat_table is not None and pat_table.unroutable is not None
                    and pat_table.unroutable.any()):
                bidor_tm = np.where(pat_table.unroutable, 0.0, tm)
            prepped.append(_ItemPrep(name=pat_name, tm=tm, table=pat_table,
                                     nrank=pat_nrank, bidor_tm=bidor_tm))
        self._prepped[topo_i] = prepped
        return prepped

    # ------------------------------------------------------------- #
    def run_cell(self, key: CellKey, *, checkpoint=None) -> CellOutcome:
        """Execute one cell (all its (rate, seed) lanes, one batch).

        ``checkpoint`` — optional epoch-boundary checkpointer handed to
        the control plane for scenario cells (see
        ``repro.noc.ctrl.run_controlled``); static cells run in one
        chunked call and checkpoint only at completion.
        """
        spec = self.spec
        topo = spec.topo_axis[key.topo_i]
        prep = self._prep_topo(key.topo_i)[key.item_i]
        algo = key.algo
        cfg = spec.base.replace(algo=algo)
        scen = spec.scenarios[key.scen_i] if key.scen_i >= 0 else None
        t0 = time.perf_counter()
        tc0 = self.tracer.now_us() if self.tracer.enabled else 0.0
        cell_tm = prep.bidor_tm if algo == Algo.BIDOR else prep.tm
        telemetry = None
        if scen is None:
            tables, meta = build_tables(
                topo, cell_tm,
                prep.table if algo == Algo.BIDOR else None, cfg.num_vcs)
            host, sat = _run_cell(spec, cfg, tables, meta, self.points)
            results = []
            for i, (rate, seed) in enumerate(self.points):
                o = jax.tree.map(lambda x: x[i], host)
                results.append(postprocess(
                    o, cfg, topo, rate=rate, seed=seed,
                    saturated=bool(sat[i])))
            telemetry = Telemetry.from_state(host, cfg)
            if telemetry is not None:
                telemetry = telemetry.with_bw(static_bw_slots(topo, cfg))
        else:
            from .ctrl import run_controlled
            ctrl_res = run_controlled(
                topo, cell_tm, cfg, scen,
                rates=[float(r) for r in spec.rates],
                seeds=list(spec.seeds),
                bidor_table=prep.table if algo == Algo.BIDOR else None,
                nrank0=prep.nrank if algo == Algo.BIDOR else None,
                sat_occupancy=spec.sat_occupancy,
                multi_device=spec.multi_device,
                checkpoint=checkpoint,
                verbose=self.verbose,
                tracer=self.tracer)
            results = [ctrl_res.result_with_peak(i)
                       for i in range(len(self.points))]
            telemetry = ctrl_res.telemetry
        dt = time.perf_counter() - t0
        if self.tracer.enabled:
            self.tracer.complete(
                "cell", tc0, self.tracer.now_us() - tc0, cat="campaign",
                args={"slug": key.slug, "topo": key.topo,
                      "pattern": key.pattern, "algo": algo.name,
                      "scenario": key.scenario,
                      "lanes": len(self.points)})
        self.log.event("cell_done",
                       f"campaign cell {key.topo:16s} {key.pattern:12s} "
                       f"{algo.name:8s} {key.scenario:12s} "
                       f"{len(self.points)} pts in {dt:.2f}s",
                       cell=key.slug, wall_s=round(dt, 3))
        return CellOutcome(key=key, results=results, wall_s=dt,
                           telemetry=telemetry)

    def cell_points(self, outcome: CellOutcome) -> list[CampaignPoint]:
        """The cell's CampaignPoints, in canonical lane order."""
        k = outcome.key
        return [CampaignPoint(algo=k.algo, pattern=k.pattern, rate=rate,
                              seed=seed, result=res, scenario=k.scenario,
                              topo=k.topo, workload=k.workload)
                for (rate, seed), res in zip(self.points, outcome.results)]


def run_campaign(spec: CampaignSpec, *,
                 bidor_tables: dict[str, np.ndarray] | None = None,
                 plan_cache=None,
                 verbose: bool = False,
                 tracer=None) -> CampaignResult:
    """Execute the full campaign grid.

    BiDOR plans are built per pattern from that pattern's own matrix (the
    paper's offline-statistics assumption); pass ``bidor_tables`` (pattern
    name → (N, N) choice table) to override, e.g. with aggregate-trace
    plans.  ``plan_cache`` serves/stores those builds by content key (see
    :class:`repro.core.plan_cache.PlanCache`).

    With ``spec.scenarios`` set, each (algo, pattern, scenario) cell runs
    the control plane's event-driven loop instead of the static cell —
    the scenario's events (link failures, drift epochs) apply mid-run and
    its policy decides when plans hot-swap.  ``SimResult.link_load_max``
    then reports the *time-resolved* peak (max over control epochs of the
    max bandwidth-normalized link load), since a mid-run failure changes
    the normalization.

    This is the blocking, in-memory driver over the resumable cell
    machinery; ``repro.noc.service`` runs the same cells as a
    checkpointed job.
    """
    t_start = time.perf_counter()
    executor = CampaignExecutor(spec, bidor_tables=bidor_tables,
                                plan_cache=plan_cache, verbose=verbose,
                                tracer=tracer)
    out_points: list[CampaignPoint] = []
    wall: dict[tuple, float] = {}
    for key in campaign_cells(spec):
        outcome = executor.run_cell(key)
        wall[key.wall_key(spec)] = outcome.wall_s
        out_points.extend(executor.cell_points(outcome))
    return CampaignResult(spec=spec, points=out_points, wall_clock_s=wall,
                          total_wall_clock_s=time.perf_counter() - t_start)

