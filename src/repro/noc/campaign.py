"""Batched simulation-campaign engine.

Every headline number in the paper (42.9% throughput, 86.4%/95.3% latency)
comes from sweeping (algorithm × traffic pattern × injection rate × seed)
through the flit simulator.  This module turns that sweep into a first-class
subsystem:

* A declarative :class:`CampaignSpec` names the grid once.
* All (rate, seed) points of a cell — one (algorithm, pattern) pair — run
  inside a SINGLE jitted, vmapped call: per-run state is a pytree batched
  over a leading axis (``repro.noc.sim.make_states``), static lookup tables
  are traced arguments shared by every lane.  One XLA compilation per
  (mesh, algorithm, flow-control, chunk-length) tuple covers the whole
  campaign.
* Explicit warmup → measure → drain phasing (``SimConfig.warmup`` /
  ``.drain``): statistics only inside the measurement window, injection
  halted for the trailing drain cycles so in-flight packets land and
  latency tails are complete.
* Saturation early-exit: the cell advances in ``chunk``-cycle slices; after
  each slice a cheap host-side detector reads source-queue occupancy, and
  once EVERY lane is saturated (queues ≥ ``sat_occupancy`` of capacity) the
  remaining cycles are skipped — per-lane ``meas_cnt`` keeps the statistics
  exactly normalized.  ``chunk=0`` disables chunking (one call per cell).

:class:`CampaignResult` returns per-point latency percentiles (p50/p90/p99
from in-simulator histograms), throughput, max link load, and per-cell
wall-clock, with grid accessors for plotting/tables.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import numpy as np

from repro.core import traffic as traffic_mod
from repro.core.plan_fast import build_plans_batched
from repro.core.topology import Topology
from .sim import (build_tables, get_runner, make_states, postprocess,
                  queue_occupancy, source_queue_meta)
from .simconfig import Algo, SimConfig, SimResult

__all__ = ["CampaignSpec", "CampaignPoint", "CampaignResult",
           "run_campaign"]


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """Declarative grid of simulations.

    Attributes:
      topo: the network under test.
      topos: optional *topology axis* — when non-empty, the whole grid runs
        once per listed topology (``topo`` is ignored); string patterns are
        re-resolved per topology, and BiDOR plans (including fault masking
        for topologies with dead channels) are rebuilt per topology.
      algos: routing algorithms to sweep.
      patterns: traffic patterns — names resolved through
        ``repro.core.traffic.PATTERNS`` or explicit ``(name, matrix)``
        pairs.
      rates: injection rates (flits/cycle/I/O-port).
      seeds: RNG seeds; each (rate, seed) is an independent lane of the
        vmapped batch.
      base: simulation parameters shared by every point (``algo``,
        ``injection_rate`` and ``seed`` fields are overridden per point).
      chunk: host-loop granularity in cycles for the saturation early-exit;
        0 runs each cell as one jitted call of ``base.cycles`` cycles.
      sat_occupancy: source-queue occupancy fraction above which a lane is
        declared saturated.
      scenarios: optional fault/drift dynamics axis —
        :class:`repro.noc.ctrl.Scenario` entries.  Empty () keeps the
        classic static grid; with scenarios, every (algo, pattern,
        scenario) cell runs through the control plane's event-driven loop
        (:func:`repro.noc.ctrl.run_controlled`), (rate, seed) points still
        batched as lanes of one vmapped state.
      multi_device: ``shard_map`` lane parallelism — ``True`` forces the
        explicit multi-device runner (lanes split over all local devices,
        carry buffers donated), ``False`` pins single-device execution,
        ``None`` (default) auto-enables whenever >1 device is visible and
        the (rate, seed) lane count divides evenly.  Results are
        bit-identical either way (``tests/test_multidevice.py``); on CPU
        expose cores with
        ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """

    topo: Topology | None
    algos: tuple[Algo, ...]
    patterns: tuple
    rates: tuple[float, ...]
    seeds: tuple[int, ...] = (0,)
    base: SimConfig = SimConfig()
    chunk: int = 0
    sat_occupancy: float = 0.9
    scenarios: tuple = ()
    topos: tuple[Topology, ...] = ()
    multi_device: bool | None = None

    def __post_init__(self):
        if not (self.algos and self.patterns and self.rates and self.seeds):
            raise ValueError("campaign grid must be non-empty on all axes")
        if self.topo is None and not self.topos:
            raise ValueError("provide topo or a non-empty topos axis")

    @property
    def topo_axis(self) -> tuple[Topology, ...]:
        return self.topos or (self.topo,)

    @property
    def num_points(self) -> int:
        return (len(self.algos) * len(self.patterns) * len(self.rates)
                * len(self.seeds) * max(len(self.scenarios), 1)
                * len(self.topo_axis))

    def pattern_items(self, topo: Topology | None = None,
                      ) -> list[tuple[str, np.ndarray]]:
        """Resolve the pattern axis to (name, traffic matrix) pairs."""
        topo = self.topo if topo is None else topo
        items = []
        for p in self.patterns:
            if isinstance(p, str):
                if p not in traffic_mod.PATTERNS:
                    raise KeyError(
                        f"unknown traffic pattern {p!r}; available: "
                        f"{sorted(traffic_mod.PATTERNS)}")
                items.append((p, traffic_mod.PATTERNS[p](topo)))
            else:
                name, tm = p
                items.append((str(name), np.asarray(tm, np.float64)))
        return items


@dataclasses.dataclass(frozen=True)
class CampaignPoint:
    """One grid point: the cell coordinates plus its SimResult."""

    algo: Algo
    pattern: str
    rate: float
    seed: int
    result: SimResult
    scenario: str = "static"
    topo: str = ""


@dataclasses.dataclass
class CampaignResult:
    """Structured campaign output.

    ``points`` is ordered (pattern, algo, rate, seed) nested-loop major.
    ``wall_clock_s`` maps (algo name, pattern) cells to the wall-clock of
    their single batched call chain (compile time included on first use).
    """

    spec: CampaignSpec
    points: list[CampaignPoint]
    wall_clock_s: dict[tuple[str, str], float]
    total_wall_clock_s: float

    def select(self, algo: Algo | None = None, pattern: str | None = None,
               rate: float | None = None,
               seed: int | None = None,
               scenario: str | None = None,
               topo: str | None = None) -> list[CampaignPoint]:
        out = []
        for p in self.points:
            if algo is not None and p.algo != algo:
                continue
            if pattern is not None and p.pattern != pattern:
                continue
            if rate is not None and p.rate != rate:
                continue
            if seed is not None and p.seed != seed:
                continue
            if scenario is not None and p.scenario != scenario:
                continue
            if topo is not None and p.topo != topo:
                continue
            out.append(p)
        return out

    def grid(self, field: str, algo: Algo, pattern: str) -> np.ndarray:
        """(num_rates, num_seeds) array of a SimResult field for a cell."""
        rates, seeds = list(self.spec.rates), list(self.spec.seeds)
        g = np.zeros((len(rates), len(seeds)))
        for p in self.select(algo=algo, pattern=pattern):
            g[rates.index(p.rate), seeds.index(p.seed)] = getattr(
                p.result, field)
        return g

    def mean_over_seeds(self, field: str, algo: Algo,
                        pattern: str) -> np.ndarray:
        return self.grid(field, algo, pattern).mean(axis=1)

    def saturation_throughput(self, algo: Algo, pattern: str) -> float:
        """Max seed-averaged accepted throughput across the rate sweep."""
        return float(self.mean_over_seeds("throughput", algo,
                                          pattern).max())

    CSV_HEADER = ["topo", "scenario", "pattern", "algo", "rate", "seed",
                  "throughput",
                  "offered", "avg_lat", "p50_lat", "p90_lat", "p99_lat",
                  "max_lat", "lcv", "link_load_max", "reorder",
                  "saturated", "meas_cycles"]

    def to_rows(self) -> list[list]:
        rows = []
        for p in self.points:
            r = p.result
            rows.append([p.topo, p.scenario, p.pattern, p.algo.name,
                         p.rate, p.seed,
                         f"{r.throughput:.4f}", f"{r.offered:.4f}",
                         f"{r.avg_latency:.1f}", f"{r.p50_latency:.1f}",
                         f"{r.p90_latency:.1f}", f"{r.p99_latency:.1f}",
                         f"{r.max_latency:.0f}", f"{r.lcv:.3f}",
                         f"{r.link_load_max:.4f}", r.reorder_value,
                         int(r.saturated), r.meas_cycles])
        return rows

    def summary(self) -> str:
        lines = [f"campaign: {self.spec.num_points} points in "
                 f"{self.total_wall_clock_s:.1f}s wall-clock"]
        for key, dt in self.wall_clock_s.items():
            cell = " ".join(f"{part:12s}" for part in key)
            lines.append(f"  cell {cell} {dt:6.2f}s")
        return "\n".join(lines)


def _run_cell(spec: CampaignSpec, cfg: SimConfig, tables, meta,
              points: list[tuple[float, int]]):
    """Advance one (algo, pattern) cell; returns (host state, sat flags).

    The cell is one vmapped batch over ``points``.  With ``spec.chunk``
    set, execution proceeds in chunk-cycle slices so the host can stop the
    whole batch as soon as every lane is saturated.
    """
    batched = make_states(meta, cfg, points)
    total = int(cfg.cycles)
    chunk = int(spec.chunk) or total
    sat = np.zeros(len(points), bool)
    q_meta = source_queue_meta(tables, cfg)   # static for the whole cell
    done = 0
    while done < total:
        step_cycles = min(chunk, total - done)
        runner = get_runner(meta, cfg, step_cycles,
                            num_lanes=len(points),
                            multi_device=spec.multi_device)
        batched = runner(tables, batched)
        done += step_cycles
        occ = queue_occupancy(tables, cfg, batched["q_size"], q_meta)
        sat |= occ >= spec.sat_occupancy
        if done < total and sat.all() and done > cfg.warmup:
            break  # every lane saturated: steady-state verdict reached
    return jax.device_get(batched), sat


def run_campaign(spec: CampaignSpec, *,
                 bidor_tables: dict[str, np.ndarray] | None = None,
                 verbose: bool = False) -> CampaignResult:
    """Execute the full campaign grid.

    BiDOR plans are built per pattern from that pattern's own matrix (the
    paper's offline-statistics assumption); pass ``bidor_tables`` (pattern
    name → (N, N) choice table) to override, e.g. with aggregate-trace
    plans.

    With ``spec.scenarios`` set, each (algo, pattern, scenario) cell runs
    the control plane's event-driven loop instead of the static cell —
    the scenario's events (link failures, drift epochs) apply mid-run and
    its policy decides when plans hot-swap.  ``SimResult.link_load_max``
    then reports the *time-resolved* peak (max over control epochs of the
    max bandwidth-normalized link load), since a mid-run failure changes
    the normalization.
    """
    t_start = time.perf_counter()
    cfg0 = spec.base
    points = [(float(r), int(s)) for r in spec.rates for s in spec.seeds]
    out_points: list[CampaignPoint] = []
    wall: dict[tuple, float] = {}
    topo_axis = spec.topo_axis
    multi_topo = len(topo_axis) > 1
    for topo in topo_axis:
        items = spec.pattern_items(topo)
        # dead channels (e.g. a fault-region mesh) mask the plan build
        down = topo.down_channels
        # one vmapped device call plans every pattern that needs one (the
        # campaign's pattern axis; scenario replans reuse these as their
        # warm-start seeds).  Keyed by item index: explicit (name, matrix)
        # patterns may repeat a name with different matrices.
        plans: dict[int, object] = {}
        if Algo.BIDOR in spec.algos:
            need = [i for i, (name, _) in enumerate(items)
                    if not (bidor_tables and name in bidor_tables)
                    or spec.scenarios]
            if need:
                built = build_plans_batched(
                    topo, [items[i][1] for i in need],
                    down_channels=down if down.size else None)
                plans = dict(zip(need, built))
        for item_i, (pat_name, tm) in enumerate(items):
            pat_table = None
            pat_nrank = None  # seed fixed point: scenario replans warm-start
            if Algo.BIDOR in spec.algos:
                if bidor_tables and pat_name in bidor_tables:
                    choice = np.asarray(bidor_tables[pat_name], np.int8)
                    if spec.scenarios:  # scenario cells need the full plan
                        pat_table = dataclasses.replace(
                            plans[item_i].table, choice=choice)
                        pat_nrank = plans[item_i].nrank
                    else:
                        from repro.core.bidor import dor_table
                        pat_table = dataclasses.replace(
                            dor_table(topo), choice=choice)
                else:
                    pat_table = plans[item_i].table
                    pat_nrank = plans[item_i].nrank
            # admission control: pairs no dimension order can serve on a
            # degraded topology are shed from BiDOR's generation matrix
            # (the control plane does the same after a replan)
            bidor_tm = tm
            if (pat_table is not None and pat_table.unroutable is not None
                    and pat_table.unroutable.any()):
                bidor_tm = np.where(pat_table.unroutable, 0.0, tm)
            for algo in spec.algos:
                cfg = cfg0.replace(algo=algo)
                for scen in (spec.scenarios or (None,)):
                    t0 = time.perf_counter()
                    cell_tm = bidor_tm if algo == Algo.BIDOR else tm
                    if scen is None:
                        tables, meta = build_tables(
                            topo, cell_tm,
                            pat_table if algo == Algo.BIDOR else None,
                            cfg.num_vcs)
                        host, sat = _run_cell(spec, cfg, tables, meta,
                                              points)
                        results = []
                        for i, (rate, seed) in enumerate(points):
                            o = jax.tree.map(lambda x: x[i], host)
                            results.append(postprocess(
                                o, cfg, topo, rate=rate, seed=seed,
                                saturated=bool(sat[i])))
                        scen_name = "static"
                        key = (algo.name, pat_name)
                    else:
                        from .ctrl import run_controlled
                        ctrl_res = run_controlled(
                            topo, cell_tm, cfg, scen,
                            rates=[float(r) for r in spec.rates],
                            seeds=list(spec.seeds),
                            bidor_table=pat_table if algo == Algo.BIDOR
                            else None,
                            nrank0=pat_nrank if algo == Algo.BIDOR
                            else None,
                            sat_occupancy=spec.sat_occupancy,
                            multi_device=spec.multi_device,
                            verbose=verbose)
                        results = [ctrl_res.result_with_peak(i)
                                   for i in range(len(points))]
                        scen_name = scen.name
                        key = (algo.name, pat_name, scen.name)
                    if multi_topo:
                        key = (topo.name,) + key
                    dt = time.perf_counter() - t0
                    wall[key] = dt
                    for (rate, seed), res in zip(points, results):
                        out_points.append(CampaignPoint(
                            algo=algo, pattern=pat_name, rate=rate,
                            seed=seed, result=res, scenario=scen_name,
                            topo=topo.name))
                    if verbose:
                        print(f"campaign cell {topo.name:16s} "
                              f"{pat_name:12s} {algo.name:8s} "
                              f"{scen_name:12s} {len(points)} pts "
                              f"in {dt:.2f}s", flush=True)
    return CampaignResult(spec=spec, points=out_points, wall_clock_s=wall,
                          total_wall_clock_s=time.perf_counter() - t_start)
