"""HLO-derived ML collective traffic → NoC traffic matrices.

The paper's headline results are on "realistic workloads"; this module
closes the loop between the repo's model substrate and the NoC campaign
engine.  For one sharded model config it:

  1. lowers the phase programs (train step / fwd loss / decode step) under
     the mesh + sharding specs (``repro.sharding.specs``), exactly like
     ``repro.launch.dryrun`` but on the smoke config at a campaign-sized
     mesh;
  2. extracts every collective of the post-SPMD HLO — bytes, replica
     groups, ``source_target_pairs``, while-loop execution counts — via
     :func:`repro.analysis.hlo.collective_ops`;
  3. maps each collective onto logical-device (rank, rank) flows under the
     ring collective model (all-reduce rings, all-gather/reduce-scatter
     rings, all-to-all full exchange, collective-permute explicit pairs);
  4. embeds ranks onto a physical :class:`~repro.core.topology.Topology`
     (mesh axis k → torus dim k when the shapes line up, flat rank → node
     otherwise) and normalizes into a campaign traffic matrix.

The resulting :class:`MLWorkload` is a first-class ``CampaignSpec``
``workloads`` axis entry: it exposes ``.name`` and ``.matrix_for(topo)``
and flows through plan building, the plan cache, the certifier gate, and
the CSV/telemetry columns like any synthetic pattern.

Byte conservation is a tested invariant: per phase and per collective
kind, the (rank, rank) flow matrix sums exactly to the fabric wire bytes
reported by :func:`repro.analysis.hlo.collective_flow_totals`
(``tests/test_mltraffic.py``).

Deriving a workload needs ``jax.device_count() >= data*model``.  When the
current process was initialized with fewer host devices,
:func:`derive_workload` transparently re-derives in a subprocess with
``--xla_force_host_platform_device_count`` forced (the flag only takes
effect before jax's first init, and ``repro.noc`` imports jax at package
import — hence the child must receive it via the environment, not set it
itself).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import subprocess
import sys
import tempfile

import numpy as np

__all__ = ["WorkloadSpec", "MLWorkload", "collective_flows", "embed_ranks",
           "derive", "derive_workload", "DIRECT_PHASES"]

# phases lowered as real programs; "bwd" is derived as train − fwd
DIRECT_PHASES = ("fwd", "train", "decode")


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One model workload to derive traffic for.

    ``data``/``model`` are the logical mesh shape
    (``repro.launch.mesh.make_mesh_for_devices``); ``axes`` is pure
    metadata naming those two mesh axes — the derivation never keys on
    the names, which is what makes the matrices invariant under mesh-axis
    relabeling (tested).  ``moe_pad_to`` pads the expert count so expert
    parallelism divides the model axis (e.g. qwen2-moe's 6 smoke experts
    → 8).  ``phases`` lists the programs to lower (subset of
    ``DIRECT_PHASES``).
    """

    arch: str
    data: int = 1
    model: int = 8
    batch: int = 4
    seq: int = 32
    decode_len: int = 32
    moe_pad_to: int = 0
    phases: tuple[str, ...] = ("train", "decode")
    axes: tuple[str, str] = ("data", "model")
    label: str = ""

    def __post_init__(self):
        bad = [p for p in self.phases if p not in DIRECT_PHASES]
        if bad:
            raise ValueError(f"unknown phases {bad}; derivable phases are "
                             f"{DIRECT_PHASES} ('bwd' is computed from "
                             f"train − fwd)")

    @property
    def num_devices(self) -> int:
        return self.data * self.model

    @property
    def name(self) -> str:
        return self.label or f"{self.arch}@{self.data}x{self.model}"

    def fingerprint(self) -> str:
        return hashlib.sha256(json.dumps(
            dataclasses.asdict(self), sort_keys=True,
            default=str).encode()).hexdigest()


def collective_flows(ops, num_devices: int) -> dict[str, np.ndarray]:
    """Per-kind (rank, rank) wire-byte matrices under the ring model.

    * all-reduce / all-gather / reduce-scatter: each group is a logical
      ring over its ranks in group order; every ring edge (i → next)
      carries the per-participant wire bytes (``2(g-1)/g·size`` for
      all-reduce, ``(g-1)/g·size`` otherwise).
    * all-to-all: every ordered pair within a group exchanges ``size/g``.
    * collective-permute: each ``source_target_pairs`` entry carries the
      full payload.

    Summing a kind's matrix reproduces that kind's
    :func:`repro.analysis.hlo.collective_flow_totals` entry exactly —
    the conservation invariant.
    """
    mats: dict[str, np.ndarray] = {}
    for op in ops:
        m = mats.setdefault(
            op.kind, np.zeros((num_devices, num_devices), np.float64))
        if op.kind == "collective-permute":
            for s, t in op.pairs:
                m[s, t] += op.count * op.size_bytes
            continue
        for grp in op.groups:
            g = len(grp)
            if g <= 1:
                continue
            if op.kind == "all-to-all":
                per = op.size_bytes / g
                for i in grp:
                    for j in grp:
                        if i != j:
                            m[i, j] += op.count * per
            else:
                factor = 2.0 if op.kind == "all-reduce" else 1.0
                per = factor * (g - 1) / g * op.size_bytes
                for a, b in zip(grp, grp[1:] + (grp[0],)):
                    m[a, b] += op.count * per
    return mats


def embed_ranks(topo, mesh_shape: tuple[int, ...]) -> np.ndarray:
    """Map logical mesh ranks onto physical topology node ids.

    Mesh rank r has mesh coordinates ``np.unravel_index(r, mesh_shape)``
    (last axis fastest — jax's device-array reshape order).  When the
    topology dims equal the mesh shape axis-for-axis (the
    ``repro.launch.mesh.ici_topology`` bridge), mesh axis k lands on
    torus dim k; ``Topology.node_id`` is dim-0-fastest, so this is NOT
    the identity for ``data > 1``.  Otherwise, if the node count covers
    the rank count, ranks map flat (rank r → node r) — e.g. an ``(1, 8)``
    mesh folded onto a 4×2 torus, where the model ring snakes across
    both physical dimensions.
    """
    d = int(np.prod(mesh_shape))
    if tuple(topo.dims) == tuple(mesh_shape):
        emb = np.empty(d, np.int64)
        for r in range(d):
            emb[r] = topo.node_id(np.unravel_index(r, mesh_shape))
        return emb
    if topo.num_nodes >= d:
        return np.arange(d, dtype=np.int64)
    raise ValueError(
        f"cannot embed {d} mesh ranks ({mesh_shape}) onto "
        f"{topo.name} ({topo.num_nodes} nodes)")


@dataclasses.dataclass
class MLWorkload:
    """Derived per-phase collective flows for one :class:`WorkloadSpec`.

    ``flows[phase][kind]`` is a (D, D) rank-pair wire-byte matrix;
    ``totals[phase][kind]`` is the HLO-side fabric byte total the matrix
    must sum to.  Phases present are exactly ``spec.phases``.
    """

    spec: WorkloadSpec
    flows: dict[str, dict[str, np.ndarray]]
    totals: dict[str, dict[str, float]]
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def name(self) -> str:
        return self.spec.name

    def phase_flows(self, phase: str) -> np.ndarray:
        """(D, D) byte matrix of one phase, summed over collective kinds.

        ``"bwd"`` is the derived backward residual ``max(train − fwd, 0)``
        (requires both in ``spec.phases``); ``"step"`` aliases ``"train"``.
        """
        if phase == "step":
            phase = "train"
        if phase == "bwd":
            return np.maximum(
                self.phase_flows("train") - self.phase_flows("fwd"), 0.0)
        if phase not in self.flows:
            raise KeyError(f"phase {phase!r} not derived for {self.name}; "
                           f"have {sorted(self.flows)}")
        d = self.spec.num_devices
        out = np.zeros((d, d), np.float64)
        for m in self.flows[phase].values():
            out += m
        return out

    def campaign_flows(self) -> np.ndarray:
        """The workload's campaign-axis byte matrix: all derived phases
        summed, except ``fwd`` whenever ``train`` is present (a train
        step re-runs the forward collectives — summing both would double
        count them)."""
        phases = [p for p in self.flows
                  if not (p == "fwd" and "train" in self.flows)]
        d = self.spec.num_devices
        out = np.zeros((d, d), np.float64)
        for p in phases:
            out += self.phase_flows(p)
        return out

    def matrix_for(self, topo) -> np.ndarray:
        """Campaign traffic matrix on ``topo``: rank flows embedded onto
        physical nodes, then normalized like every synthetic pattern
        (zero diagonal, Σ = 1) via ``traffic.from_pair_counts``."""
        from repro.core import traffic as traffic_mod
        flows = self.campaign_flows()
        if flows.sum() <= 0:
            raise ValueError(
                f"workload {self.name} derived zero collective bytes "
                f"(mesh {self.spec.data}x{self.spec.model}) — nothing to "
                f"route; use a sharded mesh (model > 1)")
        emb = embed_ranks(topo, (self.spec.data, self.spec.model))
        counts = np.zeros((topo.num_nodes, topo.num_nodes), np.float64)
        counts[np.ix_(emb, emb)] = flows
        return traffic_mod.from_pair_counts(topo, counts)

    # ----------------------------------------------------------------- #
    def save(self, path: str) -> None:
        arrs = {f"flow__{ph}__{k}": m
                for ph, kinds in self.flows.items()
                for k, m in kinds.items()}
        header = json.dumps({
            "spec": dataclasses.asdict(self.spec),
            "totals": self.totals,
            "meta": self.meta,
        })
        np.savez(path, __meta__=np.array(header), **arrs)

    @classmethod
    def load(cls, path: str) -> "MLWorkload":
        with np.load(path) as z:
            header = json.loads(str(z["__meta__"]))
            flows: dict[str, dict[str, np.ndarray]] = {}
            for key in z.files:
                if not key.startswith("flow__"):
                    continue
                _, ph, kind = key.split("__", 2)
                flows.setdefault(ph, {})[kind] = np.asarray(
                    z[key], np.float64)
        sd = header["spec"]
        for k in ("phases", "axes"):
            sd[k] = tuple(sd[k])
        return cls(spec=WorkloadSpec(**sd), flows=flows,
                   totals=header["totals"], meta=header.get("meta", {}))


# --------------------------------------------------------------------- #
# derivation: lower → extract → map
# --------------------------------------------------------------------- #
def _smoke_config(spec: WorkloadSpec):
    from repro.configs.base import get_arch
    cfg = get_arch(spec.arch).smoke
    if spec.moe_pad_to:
        cfg = cfg.replace(moe_pad_to=spec.moe_pad_to)
    return cfg


def _lower_phase(spec: WorkloadSpec, phase: str) -> str:
    """Compile one phase program under the spec's mesh + shardings and
    return its post-SPMD HLO text."""
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import make_mesh_for_devices
    from repro.models import registry
    from repro.sharding import specs as sh

    cfg = _smoke_config(spec)
    mesh = make_mesh_for_devices(spec.data, spec.model)

    def sds(tree, spec_tree):
        return jax.tree.map(
            lambda s, p: jax.ShapeDtypeStruct(
                s.shape, s.dtype,
                sharding=jax.sharding.NamedSharding(mesh, p)),
            tree, spec_tree,
            is_leaf=lambda x: isinstance(
                x, (jax.ShapeDtypeStruct, jax.sharding.PartitionSpec)))

    params_a = registry.abstract_params(cfg)
    pspecs = sh.param_specs(cfg, mesh, params_a)
    params_sds = sds(params_a, pspecs)
    b, s = spec.batch, spec.seq

    if phase in ("fwd", "train"):
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
        }
        if cfg.family == "vlm":
            batch["positions"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
        if cfg.family == "encdec":
            batch["embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), cfg.jdtype)
        batch_sds = sds(batch, sh.batch_specs(mesh, batch))
        if phase == "fwd":
            from repro.train.train_step import loss_fn
            fn = lambda p, bt: loss_fn(cfg, p, bt)        # noqa: E731
            args = (params_sds, batch_sds)
        else:
            from repro.train.optimizer import OptConfig, init_opt_state
            from repro.train.train_step import make_train_step
            opt_cfg = OptConfig()
            opt_a = jax.eval_shape(lambda: init_opt_state(opt_cfg,
                                                          params_a))
            ospecs = sh.opt_specs(cfg, mesh, opt_a, pspecs)
            state_sds = {"params": params_sds, "opt": sds(opt_a, ospecs)}
            fn = make_train_step(cfg, opt_cfg, grad_accum=1)
            args = (state_sds, batch_sds)
    elif phase == "decode":
        mod = registry.model_module(cfg)
        cache_a = jax.eval_shape(
            lambda: registry.init_cache(cfg, b, spec.decode_len))
        cspecs = sh.cache_specs(cfg, mesh, cache_a, seq_parallel=False)
        cache_sds = sds(cache_a, cspecs)
        tokens = jax.ShapeDtypeStruct(
            (b, 1), jnp.int32,
            sharding=jax.sharding.NamedSharding(
                mesh, sh.fit_spec(mesh, (b, 1), (sh.DATA, None))))
        index = jax.ShapeDtypeStruct(
            (), jnp.int32,
            sharding=jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()))

        def fn(params, tokens, cache, index):
            logits, cache = mod.decode_step(cfg, params, tokens, cache,
                                            index)
            return (jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32),
                    cache)

        args = (params_sds, tokens, cache_sds, index)
    else:
        raise ValueError(f"unknown phase {phase!r}")

    with mesh:
        compiled = jax.jit(fn).lower(*args).compile()
    return compiled.as_text()


def derive(spec: WorkloadSpec) -> MLWorkload:
    """Derive a workload in-process (needs ``jax.device_count() >=
    spec.num_devices``; see :func:`derive_workload` for the transparent
    subprocess fallback)."""
    import jax

    from repro.analysis.hlo import collective_flow_totals, collective_ops

    if jax.device_count() < spec.num_devices:
        raise RuntimeError(
            f"workload {spec.name} needs {spec.num_devices} devices, "
            f"process has {jax.device_count()} (set "
            f"--xla_force_host_platform_device_count before jax's first "
            f"init, or go through derive_workload)")
    flows: dict[str, dict[str, np.ndarray]] = {}
    totals: dict[str, dict[str, float]] = {}
    counts: dict[str, int] = {}
    for phase in spec.phases:
        text = _lower_phase(spec, phase)
        ops = collective_ops(text, spec.num_devices)
        flows[phase] = collective_flows(ops, spec.num_devices)
        totals[phase] = collective_flow_totals(ops)
        counts[phase] = len(ops)
    return MLWorkload(spec=spec, flows=flows, totals=totals,
                      meta={"collective_op_counts": counts})


def _derive_subprocess(spec: WorkloadSpec, timeout_s: float) -> MLWorkload:
    """Re-derive in a child interpreter with the host device count forced.

    The child's environment carries the XLA flag because ``repro.noc``
    (and thus this module's package) initializes jax at import — by the
    time a ``main()`` could set it, the device count is pinned.
    """
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={spec.num_devices}")
    env["PYTHONPATH"] = os.pathsep.join(
        [src_root] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    with tempfile.TemporaryDirectory(prefix="mltraffic_") as tmp:
        out = os.path.join(tmp, "workload.npz")
        cmd = [sys.executable, "-m", "repro.noc.mltraffic",
               "--arch", spec.arch,
               "--data", str(spec.data), "--model", str(spec.model),
               "--batch", str(spec.batch), "--seq", str(spec.seq),
               "--decode-len", str(spec.decode_len),
               "--moe-pad-to", str(spec.moe_pad_to),
               "--phases", ",".join(spec.phases),
               "--label", spec.label,
               "--out", out]
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                              timeout=timeout_s)
        if proc.returncode != 0:
            raise RuntimeError(
                f"subprocess derivation of {spec.name} failed "
                f"(rc={proc.returncode}):\n{proc.stderr[-2000:]}")
        return MLWorkload.load(out)


def derive_workload(spec: WorkloadSpec, *, cache_dir: str | None = None,
                    timeout_s: float = 600.0) -> MLWorkload:
    """Derive a workload, in-process when the device count allows and via
    a subprocess otherwise; with ``cache_dir``, serve/store the derived
    npz by spec fingerprint (the bench stage points this at
    ``artifacts/bench/mltraffic`` so CI uploads the matrices)."""
    path = None
    if cache_dir:
        os.makedirs(cache_dir, exist_ok=True)
        stem = spec.name.replace("@", "_").replace("/", "-")
        path = os.path.join(
            cache_dir, f"{stem}__{spec.fingerprint()[:10]}.npz")
        if os.path.exists(path):
            return MLWorkload.load(path)
    import jax
    if jax.device_count() >= spec.num_devices:
        wl = derive(spec)
    else:
        wl = _derive_subprocess(spec, timeout_s)
    if path:
        wl.save(path)
    return wl


def main(argv=None) -> int:
    """Subprocess entry point: derive one workload, write it as npz."""
    import argparse

    ap = argparse.ArgumentParser(
        description="Derive HLO collective traffic for one model workload")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--decode-len", type=int, default=32)
    ap.add_argument("--moe-pad-to", type=int, default=0)
    ap.add_argument("--phases", default="train,decode")
    ap.add_argument("--label", default="")
    ap.add_argument("--out", required=True)
    args = ap.parse_args(argv)
    spec = WorkloadSpec(
        arch=args.arch, data=args.data, model=args.model, batch=args.batch,
        seq=args.seq, decode_len=args.decode_len,
        moe_pad_to=args.moe_pad_to,
        phases=tuple(p for p in args.phases.split(",") if p),
        label=args.label)
    wl = derive(spec)
    wl.save(args.out)
    print(json.dumps({"workload": wl.name,
                      "phases": {p: sorted(t) for p, t in
                                 wl.totals.items()},
                      "total_bytes": float(wl.campaign_flows().sum())}))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
