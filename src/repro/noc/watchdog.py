"""In-sim stall watchdog: deadlock/livelock detection + escape recovery.

The static certifier (:mod:`repro.core.certify`) proves the *tables*
deadlock-free, but the simulator also accepts hand-built tables, and a
certifier bug — or a genuinely cyclic table pushed past the gate with
``repair=False`` — would wedge a multi-million-cycle campaign silently.
This module defines the optional runtime sentinel the per-cycle
transition carries when ``SimConfig.watchdog`` is on:

* ``wd_stall`` (NIN,) — per-input-VC stall age: +1 every cycle the
  FIFO's head flit fails to move, reset on movement.  A head stalled
  past ``wd_stall_cycles`` is classified **deadlocked** and recovers by
  *escaping*: its next hop is routed via the always-built DOR escape
  table (``_Tables.esc_port`` — plain first-dimension-order routing,
  acyclic by the certifier's own argument), after which it routes
  normally again (and re-escapes if it wedges again).  The escape hop
  flows through the ordinary eligibility / credit / allocation pipeline,
  so it is a *misroute*, never a teleport.
* ``wd_throttle`` (N,) — per-source throttle: a moving flit whose hop
  count exceeds ``wd_hop_limit`` is classified **livelocked** (it keeps
  moving without arriving — the escape path can cause this by design),
  and its source's packet generation is masked for
  ``wd_throttle_cycles`` cycles.  Only the generation *mask* changes;
  the RNG stream is untouched, so throttling never perturbs the random
  sequence of other sources.
* ``wd_trips`` (2,) — [deadlock trips, livelock trips]: exact
  threshold-crossing counters (a stall episode or a runaway packet
  counts once), the host-visible "the watchdog fired" signal.

All of it is python-level gated on ``cfg.watchdog`` exactly like the
telemetry probes (:mod:`repro.obs.probe`): when off, the state carries
no ``wd_*`` keys and the step functions emit zero extra ops — results
are bit-identical to a build without this module, on the unfused,
fused-dense and Pallas-interpret paths (``tests/test_watchdog.py``).
The fused kernel wrapper is generic over table fields and state keys,
so there are zero Pallas-kernel changes.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["WD_KEYS", "watchdog_state", "WatchdogReport"]

# Watchdog state keys, in the order fresh_state creates them.
WD_KEYS = ("wd_stall", "wd_throttle", "wd_trips")


def watchdog_state(meta: dict, cfg) -> dict:
    """Fresh per-lane watchdog state ({} when the watchdog is off).

    Mirrors :func:`repro.obs.probe.telemetry_state` so the kernel
    package can size-budget the same arrays
    (``repro.kernels.simstep.ops.state_footprint_bytes``)."""
    if not getattr(cfg, "watchdog", False):
        return {}
    import jax.numpy as jnp
    z = lambda shape: jnp.zeros(shape, jnp.int32)  # noqa: E731
    return dict(
        wd_stall=z((meta["NIN"],)),
        wd_throttle=z((meta["N"],)),
        wd_trips=z((2,)),
    )


@dataclasses.dataclass(frozen=True)
class WatchdogReport:
    """Host-side watchdog summary for one cell (summed over lanes)."""

    deadlock_trips: int
    livelock_trips: int
    stalled_inputs: int        # inputs at/over the stall threshold now
    max_stall: int             # worst current stall age (cycles)
    throttled_sources: int     # sources currently under throttle

    @property
    def tripped(self) -> bool:
        return self.deadlock_trips > 0 or self.livelock_trips > 0

    @classmethod
    def from_state(cls, host_state: dict, cfg) -> "WatchdogReport | None":
        """Build from a fetched state dict (with or without a leading
        lane axis); None when the state carries no watchdog."""
        if "wd_trips" not in host_state:
            return None
        trips = np.asarray(host_state["wd_trips"], np.int64).reshape(-1, 2)
        stall = np.asarray(host_state["wd_stall"], np.int64)
        throttle = np.asarray(host_state["wd_throttle"], np.int64)
        return cls(
            deadlock_trips=int(trips[:, 0].sum()),
            livelock_trips=int(trips[:, 1].sum()),
            stalled_inputs=int((stall >= int(cfg.wd_stall_cycles)).sum()),
            max_stall=int(stall.max()) if stall.size else 0,
            throttled_sources=int((throttle > 0).sum()))

    def trace_args(self) -> dict:
        """JSON-able summary for trace instants / metrics records."""
        return {"deadlock_trips": self.deadlock_trips,
                "livelock_trips": self.livelock_trips,
                "stalled_inputs": self.stalled_inputs,
                "max_stall": self.max_stall,
                "throttled_sources": self.throttled_sources}
