"""NoC / ICI topology graphs.

A :class:`Topology` is the first of the two inputs of N-Rank (paper §3.2):
it provides the *connection relationships* (each node's upstream set ``U^n``
and downstream set ``D^n``) and, implicitly, the *spatial attributes* used by
the possibility sets of eq. (4).

The same abstraction covers

* the paper's evaluation topologies — ``mesh2d`` (5×5 2DMesh, Fig. 1b) and
  ``mesh2d_edge_io`` (2DMesh with I/O only at edge nodes, Fig. 1c/1d),
* the TPU-adaptation topologies — ``torus`` for a single-pod ICI fabric
  (16×16, or 3D: ``torus(4, 4, 4)``) and ``multipod`` for the 2×16×16
  production mesh, where the inter-pod dimension has distinct (DCN)
  bandwidth, and
* the topology zoo beyond the paper's two graphs: ``cmesh`` (concentrated
  mesh — several cores share one router), ``express_mesh`` (2D mesh with
  express channels skipping intermediate routers), and
  ``fault_region_mesh`` (a mesh with a dead rectangular region — the
  irregular-graph stress case for plan-table routing).

All construction is offline (numpy); the arrays are consumed by the jnp
evolution loop in :mod:`repro.core.nrank` and by the simulator.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

__all__ = [
    "Topology",
    "mesh2d",
    "mesh2d_edge_io",
    "torus",
    "multipod",
    "cmesh",
    "express_mesh",
    "fault_region_mesh",
    "PORT_LOCAL",
]

# Port encoding used by the routers/simulator: for dimension k, port 2k is the
# +k direction and port 2k+1 the −k direction.  Express channels (axis-aligned
# hops of magnitude > 1) get dedicated port pairs after the 2·ndim base ports,
# one (+, −) pair per distinct (dimension, magnitude) class, so the even/odd
# port pairing (+dir ⇄ −dir) holds for every network port.  The final port is
# local inject/eject.  (5-port router for a plain 2D mesh, as in paper §4.1.)
PORT_LOCAL = -1  # resolved per-topology as ``num_ports - 1``


@dataclasses.dataclass(frozen=True)
class Topology:
    """A directed channel graph with spatial coordinates.

    Attributes:
      name: human-readable identifier.
      dims: per-dimension extents, e.g. ``(5, 5)`` for the paper's mesh
        (dimension 0 is "x", the first dimension traversed by XY routing).
      wrap: per-dimension wrap-around flags (True ⇒ torus links).
      coords: ``(N, ndim)`` integer coordinates of each node.
      channels: ``(C, 2)`` directed channels ``(u, n)`` — "u has a channel
        towards n", so ``n ∈ D^u`` and ``u ∈ U^n``.
      io_weights: ``(N,)`` traffic-endpoint weight of each node.  1 for every
        node in a plain mesh; in the edge-I/O variant interior nodes get 0 and
        corner nodes 2 (20 I/O ports over 16 edge nodes, paper §4.1).
      channel_bw: ``(C,)`` relative bandwidth of each channel (1.0 = one flit
        per cycle; inter-pod DCN links get < 1).
    """

    name: str
    dims: tuple[int, ...]
    wrap: tuple[bool, ...]
    coords: np.ndarray
    channels: np.ndarray
    io_weights: np.ndarray
    channel_bw: np.ndarray

    # ------------------------------------------------------------------ #
    # basic derived quantities
    # ------------------------------------------------------------------ #
    @property
    def num_nodes(self) -> int:
        return self.coords.shape[0]

    @property
    def num_channels(self) -> int:
        return self.channels.shape[0]

    @property
    def ndim(self) -> int:
        return len(self.dims)

    @property
    def num_ports(self) -> int:
        """Router ports: 2 per dimension + express port pairs + 1 local."""
        return self.port_local + 1

    @property
    def port_local(self) -> int:
        return 2 * self.ndim + 2 * len(self._express_classes)

    def node_id(self, coord: Sequence[int]) -> int:
        """Row-major in reversed-dim order: id = Σ coord[k] * stride[k], with
        dimension 0 the fastest-varying (so a 5×5 mesh numbers nodes row by
        row, matching Fig. 1/7 of the paper)."""
        nid = 0
        for k in reversed(range(self.ndim)):
            nid = nid * self.dims[k] + int(coord[k])
        return nid

    @functools.cached_property
    def chan_id(self) -> dict[tuple[int, int], int]:
        """(u, n) → channel index."""
        return {(int(u), int(n)): c for c, (u, n) in enumerate(self.channels)}

    @functools.cached_property
    def downstream(self) -> list[np.ndarray]:
        """D^n for every node (paper §3.2)."""
        out: list[list[int]] = [[] for _ in range(self.num_nodes)]
        for u, n in self.channels:
            out[int(u)].append(int(n))
        return [np.array(sorted(v), dtype=np.int32) for v in out]

    @functools.cached_property
    def upstream(self) -> list[np.ndarray]:
        """U^n for every node (paper §3.2)."""
        out: list[list[int]] = [[] for _ in range(self.num_nodes)]
        for u, n in self.channels:
            out[int(n)].append(int(u))
        return [np.array(sorted(v), dtype=np.int32) for v in out]

    @functools.cached_property
    def adjacency(self) -> np.ndarray:
        """(N, N) boolean adjacency (directed)."""
        a = np.zeros((self.num_nodes, self.num_nodes), dtype=bool)
        a[self.channels[:, 0], self.channels[:, 1]] = True
        return a

    @functools.cached_property
    def distances(self) -> np.ndarray:
        """(N, N) hop distances via BFS (int32; unreachable ⇒ large)."""
        n = self.num_nodes
        dist = np.full((n, n), np.iinfo(np.int32).max // 4, dtype=np.int32)
        np.fill_diagonal(dist, 0)
        reach = np.eye(n, dtype=bool)
        frontier = np.eye(n, dtype=bool)
        adj = self.adjacency
        d = 0
        while frontier.any():
            d += 1
            nxt = (frontier @ adj) & ~reach
            if not nxt.any():
                break
            dist[nxt] = d
            reach |= nxt
            frontier = nxt
        return dist

    def _channel_step(self, u: int, n: int) -> tuple[int, int]:
        """(dimension, signed step) of channel (u, n); wrap-corrected."""
        cu, cn = self.coords[int(u)], self.coords[int(n)]
        delta = cn - cu
        nz = np.nonzero(delta)[0]
        if len(nz) != 1:  # pragma: no cover - malformed channel
            raise ValueError(f"channel {u}->{n} is not axis-aligned")
        k = int(nz[0])
        step = int(delta[k])
        if self.wrap[k] and abs(step) == self.dims[k] - 1:
            step = int(-np.sign(step))  # wrap link: +dim edge goes size-1 → 0
        return k, step

    @functools.cached_property
    def _express_classes(self) -> tuple[tuple[int, int], ...]:
        """Distinct (dimension, magnitude) classes of express channels
        (axis-aligned steps with magnitude > 1), sorted.  Each class owns a
        (+, −) port pair after the 2·ndim unit-step base ports."""
        classes = set()
        for u, n in self.channels:
            k, step = self._channel_step(int(u), int(n))
            if abs(step) > 1:
                classes.add((k, abs(step)))
        return tuple(sorted(classes))

    @functools.cached_property
    def coord_strides(self) -> np.ndarray:
        """(ndim,) int64 strides mapping coordinates to node ids
        (dimension 0 fastest-varying): ``node_id = coords @ coord_strides``.
        Single source of truth for the numbering convention."""
        strides = np.ones(self.ndim, dtype=np.int64)
        for k in range(1, self.ndim):
            strides[k] = strides[k - 1] * self.dims[k - 1]
        return strides

    @property
    def route_horizon(self) -> int:
        """Upper bound on DOR route length (hops), per-dimension monotone:
        every hop makes ≥ 1 coordinate progress, so a route takes at most
        the unit-step diameter even when express channels shorten the BFS
        distances below route lengths.  Equals the BFS diameter on plain
        meshes/tori — the route walkers use this as their scan length."""
        return sum(d // 2 if w else d - 1
                   for d, w in zip(self.dims, self.wrap))

    @functools.cached_property
    def channel_port(self) -> np.ndarray:
        """(C,) output-port index at ``u`` of each channel (u, n).

        Unit steps use the base ports 2k (+) / 2k+1 (−); express classes
        use port pairs ``2·ndim + 2j`` (+) / ``2·ndim + 2j + 1`` (−) in
        ``_express_classes`` order.  The +/− pairing is even/odd for every
        class, which ``port_of_channel_at_receiver`` relies on.
        """
        express = {cls: 2 * self.ndim + 2 * j
                   for j, cls in enumerate(self._express_classes)}
        ports = np.zeros(self.num_channels, dtype=np.int32)
        for c, (u, n) in enumerate(self.channels):
            k, step = self._channel_step(int(u), int(n))
            base = 2 * k if abs(step) == 1 else express[(k, abs(step))]
            ports[c] = base if step > 0 else base + 1
        return ports

    @functools.cached_property
    def neighbor_table(self) -> np.ndarray:
        """(N, num_ports) neighbor node per output port; −1 if absent.

        The local port maps to the node itself.
        """
        table = np.full((self.num_nodes, self.num_ports), -1, dtype=np.int32)
        for c, (u, n) in enumerate(self.channels):
            table[int(u), self.channel_port[c]] = int(n)
        table[:, self.port_local] = np.arange(self.num_nodes)
        return table

    @functools.cached_property
    def port_of_channel_at_receiver(self) -> np.ndarray:
        """(C,) input-port index at ``n`` where channel (u, n) arrives.

        A +k channel arrives at the receiver's −k port and vice versa.
        """
        p = self.channel_port
        return np.where(p % 2 == 0, p + 1, p - 1).astype(np.int32)

    # ------------------------------------------------------------------ #
    # fault modelling (control plane)
    # ------------------------------------------------------------------ #
    @property
    def down_channels(self) -> np.ndarray:
        """Indices of channels with no usable bandwidth (hard-failed)."""
        return np.nonzero(self.channel_bw <= 0)[0]

    def channel_index(self, u: int, n: int) -> int:
        """Channel id of the directed link (u, n); raises if absent."""
        key = (int(u), int(n))
        if key not in self.chan_id:
            raise KeyError(f"no channel {u}->{n} in {self.name}")
        return self.chan_id[key]

    def degrade(self, failed: Sequence, bw_scale: float = 0.0,
                drop: bool = False) -> "Topology":
        """Topology with the listed channels failed or degraded.

        Args:
          failed: channel ids, or (u, n) node pairs, identifying directed
            channels.  A physical link is two directed channels; pass both
            if the whole link is down.
          bw_scale: multiplier applied to the failed channels' bandwidth.
            0 models a hard failure; fractions model a link retrained at
            reduced width (lane failure).
          drop: remove the failed channels from the graph entirely instead
            of keeping them at scaled bandwidth.  The planner view: hop
            distances, possibility sets and adjacency then reflect the
            degraded connectivity.  The simulator keeps the full channel
            set (same indexing) and models the failure through
            ``channel_bw`` instead, so only use ``drop`` for offline
            planning artifacts.

        Returns a new :class:`Topology`; ``self`` is unchanged.
        """
        ids = []
        for f in failed:
            if isinstance(f, (tuple, list, np.ndarray)):
                ids.append(self.channel_index(f[0], f[1]))
            else:
                ids.append(int(f))
        mask = np.zeros(self.num_channels, dtype=bool)
        mask[ids] = True
        if drop:
            return dataclasses.replace(
                self, name=self.name + "_degraded",
                channels=self.channels[~mask],
                channel_bw=self.channel_bw[~mask])
        bw = self.channel_bw.copy()
        bw[mask] = bw[mask] * float(bw_scale)
        return dataclasses.replace(self, name=self.name + "_degraded",
                                   channel_bw=bw)


# ---------------------------------------------------------------------- #
# constructors
# ---------------------------------------------------------------------- #
def _grid(dims: Sequence[int], wrap: Sequence[bool], name: str,
          io_weights: np.ndarray | None = None,
          inter_dim_bw: dict[int, float] | None = None) -> Topology:
    dims = tuple(int(d) for d in dims)
    wrap = tuple(bool(w) for w in wrap)
    ndim = len(dims)
    n = int(np.prod(dims))
    # coords with dimension 0 fastest-varying
    grids = np.meshgrid(*[np.arange(d) for d in dims], indexing="ij")
    coords = np.stack([g.reshape(-1) for g in grids], axis=-1)
    # reorder so node_id = y*W + x for 2D (dim 0 fastest)
    order = np.lexsort(tuple(coords[:, k] for k in range(ndim)))
    coords = coords[order]

    strides = np.ones(ndim, dtype=np.int64)
    for k in range(1, ndim):
        strides[k] = strides[k - 1] * dims[k - 1]

    def nid(c):
        return int((c * strides).sum())

    chans: list[tuple[int, int]] = []
    bws: list[float] = []
    for i in range(n):
        c = coords[i]
        for k in range(ndim):
            for step in (+1, -1):
                cc = c.copy()
                cc[k] += step
                if 0 <= cc[k] < dims[k]:
                    pass
                elif wrap[k] and dims[k] > 2:
                    cc[k] %= dims[k]
                else:
                    continue
                chans.append((i, nid(cc)))
                bw = 1.0
                if inter_dim_bw and k in inter_dim_bw:
                    bw = inter_dim_bw[k]
                bws.append(bw)
    channels = np.array(sorted(set(chans)), dtype=np.int32)
    # re-derive bw aligned with the sorted/unique channel list
    bw_map = {}
    for ch, bw in zip(chans, bws):
        bw_map[ch] = bw
    channel_bw = np.array([bw_map[(int(u), int(v))] for u, v in channels])

    if io_weights is None:
        io_weights = np.ones(n, dtype=np.float64)
    return Topology(name=name, dims=dims, wrap=wrap, coords=coords,
                    channels=channels, io_weights=io_weights,
                    channel_bw=channel_bw)


def mesh2d(width: int, height: int) -> Topology:
    """Plain 2D mesh; every node has one I/O port (Fig. 1b setting)."""
    return _grid((width, height), (False, False), f"mesh2d_{width}x{height}")


def mesh2d_edge_io(width: int, height: int) -> Topology:
    """2D mesh where only edge nodes carry I/O ports (paper §4.1, Fig. 1c/d).

    The paper's 5×5 NoC exposes 20 I/O ports, 5 per edge, over 16 distinct
    edge nodes — corners therefore carry two ports and get weight 2.
    """
    topo = _grid((width, height), (False, False),
                 f"mesh2d_edge_io_{width}x{height}")
    x, y = topo.coords[:, 0], topo.coords[:, 1]
    on_x_edge = (x == 0) | (x == width - 1)
    on_y_edge = (y == 0) | (y == height - 1)
    w = on_x_edge.astype(np.float64) + on_y_edge.astype(np.float64)
    return dataclasses.replace(topo, io_weights=w)


def torus(*dims: int, name: str | None = None) -> Topology:
    """k-ary n-dimensional torus — the single-pod TPU ICI fabric."""
    return _grid(dims, (True,) * len(dims),
                 name or "torus_" + "x".join(map(str, dims)))


def multipod(num_pods: int, pod_x: int, pod_y: int,
             interpod_bw: float = 0.5) -> Topology:
    """Multi-pod fabric: per-pod 2D ICI torus + a (non-wrapping) pod axis.

    The pod axis models DCN/OCI connectivity between corresponding chips of
    adjacent pods with reduced relative bandwidth ``interpod_bw``.
    Dimension layout: (x, y, pod) so DOR orders generalize naturally.
    """
    return _grid(
        (pod_x, pod_y, num_pods),
        (True, True, False),
        f"multipod_{num_pods}x{pod_x}x{pod_y}",
        inter_dim_bw={2: interpod_bw},
    )


# ---------------------------------------------------------------------- #
# topology zoo (beyond the paper's mesh/torus pair)
# ---------------------------------------------------------------------- #
def cmesh(width: int, height: int, concentration: int = 4) -> Topology:
    """Concentrated mesh: a ``width×height`` router mesh where every router
    serves ``concentration`` cores (CMesh of Balfour & Dally).

    The router graph is a plain 2D mesh; concentration shows up as the
    per-router traffic-endpoint weight, so every traffic builder and the
    injection model scale naturally (``concentration`` I/O ports per node).
    """
    topo = _grid((width, height), (False, False),
                 f"cmesh_{width}x{height}c{concentration}")
    return dataclasses.replace(
        topo, io_weights=np.full(topo.num_nodes, float(concentration)))


def express_mesh(width: int, height: int, interval: int = 2,
                 express_bw: float = 1.0) -> Topology:
    """2D mesh with express channels (Dally's express cubes): every node at
    a coordinate multiple of ``interval`` gets a bidirectional channel
    skipping ``interval − 1`` routers along each dimension.

    Express channels are extra directed channels with |step| = interval;
    they carry their own router-port pair (see ``channel_port``) and appear
    in hop distances, possibility sets, and DOR next-hop tables (the route
    walker takes the longest non-overshooting hop), so the whole
    N-Rank → BiDOR → plan-table pipeline sees them as plain graph edges.
    """
    if interval < 2:
        raise ValueError("express interval must be >= 2")
    base = _grid((width, height), (False, False),
                 f"express_{width}x{height}i{interval}")
    chans = [(int(u), int(v)) for u, v in base.channels]
    extra: list[tuple[int, int]] = []
    for i in range(base.num_nodes):
        c = base.coords[i]
        for k in range(2):
            if c[k] % interval:
                continue
            cc = c.copy()
            cc[k] += interval
            if cc[k] < base.dims[k]:
                j = base.node_id(cc)
                extra.extend([(i, j), (j, i)])
    bw = {ch: 1.0 for ch in chans}
    bw.update({ch: float(express_bw) for ch in extra})
    channels = np.array(sorted(bw), dtype=np.int32)
    channel_bw = np.array([bw[(int(u), int(v))] for u, v in channels])
    return dataclasses.replace(base, channels=channels,
                               channel_bw=channel_bw)


def fault_region_mesh(width: int, height: int,
                      region: tuple[int, int, int, int],
                      bw_scale: float = 0.0) -> Topology:
    """Irregular mesh: a rectangular region of routers is failed.

    ``region`` is the inclusive rectangle (x0, y0, x1, y1).  Channels
    touching a region node keep their indices but lose their bandwidth
    (scaled by ``bw_scale``; 0 = hard fault) — the simulator models the
    fault through ``channel_bw``, while planners mask the down channels
    (``down_channels``) so hop distances and possibility sets see the
    irregular graph.  Region nodes also lose their I/O weight: dead
    routers neither source nor sink traffic.
    """
    x0, y0, x1, y1 = region
    # the region is part of the identity: two different fault regions on
    # the same grid must not collide in campaign CSVs / select() keys
    name = (f"fault_region_{width}x{height}_"
            f"r{x0}.{y0}.{x1}.{y1}"
            + (f"b{bw_scale:g}" if bw_scale else ""))
    topo = _grid((width, height), (False, False), name)
    x, y = topo.coords[:, 0], topo.coords[:, 1]
    dead = (x >= x0) & (x <= x1) & (y >= y0) & (y <= y1)
    if dead.all():
        raise ValueError("fault region covers the whole mesh")
    failed = np.nonzero(dead[topo.channels[:, 0]]
                        | dead[topo.channels[:, 1]])[0]
    out = topo.degrade(failed, bw_scale=bw_scale)
    return dataclasses.replace(
        out, name=name, io_weights=np.where(dead, 0.0, topo.io_weights))
