"""Q-StaR facade: N-Rank + BiDOR (paper Fig. 3 workflow).

``build_plan`` is the complete offline pipeline:

    (topology, traffic distribution) ──N-Rank──▶ w_NR ──BiDOR──▶ bitmaps

The returned :class:`QStarPlan` is everything a deployment needs: the
NR-weights (diagnostics / Fig. 1 overlay), the per-source routing bitmaps,
and the per-order next-port tables consumed by the simulator or by the
ICI collective scheduler (:mod:`repro.dist.qstar_collectives`).

Analysis helpers (``predicted_node_load``, ``link_load``) evaluate a routing
choice against a traffic matrix without running the simulator — these drive
the ICI link-load roofline work in §Perf.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .bidor import BiDORTable, bidor, bidor_k
from .nrank import NRankResult, nrank, nrank_channel
from .routes import walk_routes
from .topology import Topology

__all__ = ["QStarPlan", "build_plan", "predicted_node_load", "link_load",
           "link_load_stats"]


@dataclasses.dataclass(frozen=True)
class QStarPlan:
    topology: Topology
    traffic: np.ndarray
    nrank: NRankResult
    table: BiDORTable
    # deadlock-freedom certificate (repro.core.certify) attached by the
    # build gates; None for plans assembled outside the gated paths
    cert: object = None

    @property
    def w_nr(self) -> np.ndarray:
        return self.nrank.w_nr

    @property
    def choice(self) -> np.ndarray:
        return self.table.choice


def build_plan(topo: Topology, traffic: np.ndarray, *,
               k_orders: bool = False,
               mode: str = "channel",
               w_th: float = 0.01, iter_th: int = 100,
               use_kernel: bool = False,
               w0: np.ndarray | None = None,
               down_channels: np.ndarray | None = None) -> QStarPlan:
    """Offline Q-StaR pipeline.

    Args:
      k_orders: False → paper-faithful binary BiDOR (XY/YX); True → the
        BiDOR-k generalization over all dimension orders (beyond-paper).
      mode: "channel" (default) — channel-level evolution, the reading of
        §3.2.2's no-detour assumption that reproduces the paper's reported
        results; "node" — the literal node-level eq. (2)–(3) evolution
        (kept as the paper-faithful baseline; see EXPERIMENTS.md §Fidelity).
      use_kernel: compute the possibility stages on the compiled device
        kernels instead of the host numpy loops (both modes).  This keeps
        the stage-by-stage host pipeline; the end-to-end device-resident
        build is :func:`repro.core.plan_fast.build_plan_fast`, which the
        campaign engine and the online re-planner use.
      w0: warm-start carry for the N-Rank evolution (node-level initial
        weights) — the online re-planner passes the previous plan's
        residual added to the fresh eq. (1) weights.
      down_channels: hard-failed channel mask/ids over ``topo.channels``;
        dimension orders whose route crosses a down channel leave the
        BiDOR minimization (see :func:`repro.core.bidor.bidor_k`).
    """
    if mode == "channel":
        nr = nrank_channel(topo, traffic, w_th=w_th, iter_th=iter_th, w0=w0,
                           use_kernel=use_kernel)
    else:
        nr = nrank(topo, traffic, w_th=w_th, iter_th=iter_th,
                   use_kernel=use_kernel, w0=w0)
    if k_orders:
        table = bidor_k(topo, nr.w_nr, down_channels=down_channels)
    else:
        table = bidor(topo, nr.w_nr, down_channels=down_channels)
    return QStarPlan(topology=topo, traffic=np.asarray(traffic), nrank=nr,
                     table=table)


def _route_seqs(topo: Topology,
                orders: tuple[tuple[int, ...], ...]) -> list[np.ndarray]:
    """Node sequences of every DOR route, one ``(N, N, L+1)`` array per
    order (L = diameter; routes are padded by repeating the destination).
    Per-pair order selection is applied by the callers via the BiDOR
    ``choice`` table."""
    return [walk_routes(topo, o) for o in orders]


def predicted_node_load(topo: Topology, traffic: np.ndarray,
                        table: BiDORTable) -> np.ndarray:
    """Per-node forwarding load implied by a routing table: the static
    analogue of the 'data forwarding rate' of Fig. 1.

    load[n] = Σ_{s,d} T[s,d] · [n on route(s,d)]  (endpoints included).
    """
    n = topo.num_nodes
    load = np.zeros(n, dtype=np.float64)
    seqs = _route_seqs(topo, table.orders)
    t = np.asarray(traffic, dtype=np.float64)
    if table.unroutable is not None:
        t = np.where(table.unroutable, 0.0, t)
    for oi, seq in enumerate(seqs):
        sel = table.choice == oi  # (N, N)
        w = np.where(sel, t, 0.0)
        hops = seq.shape[-1]
        prev = None
        for h in range(hops):
            nodes = seq[..., h]  # (N, N)
            if prev is not None:
                w_step = np.where(nodes != prev, w, 0.0)  # only while moving
            else:
                w_step = w
            np.add.at(load, nodes.reshape(-1), w_step.reshape(-1))
            prev = nodes
    return load


def link_load(topo: Topology, traffic: np.ndarray,
              table: BiDORTable) -> np.ndarray:
    """Per-channel load (bandwidth-normalized) implied by a routing table.

    Used to score ICI collective schedules: completion time of a decomposed
    collective ∝ max link load.
    """
    load = np.zeros(topo.num_channels, dtype=np.float64)
    seqs = _route_seqs(topo, table.orders)
    t = np.asarray(traffic, dtype=np.float64)
    if table.unroutable is not None:
        t = np.where(table.unroutable, 0.0, t)  # shed traffic contributes 0
    n = topo.num_nodes
    chan_lut = np.full((n, n), -1, dtype=np.int64)
    chan_lut[topo.channels[:, 0], topo.channels[:, 1]] = np.arange(
        topo.num_channels)
    for oi, seq in enumerate(seqs):
        sel = table.choice == oi
        w = np.where(sel, t, 0.0)
        hops = seq.shape[-1]
        for h in range(hops - 1):
            a, b = seq[..., h], seq[..., h + 1]
            moving = (a != b) & (chan_lut[a, b] >= 0)
            if not (a != b).any():
                break
            ids = chan_lut[a[moving], b[moving]]
            np.add.at(load, ids, w[moving])
    # a hard-failed (bw == 0) channel carrying planned load is an
    # infinite bottleneck, not a division error
    bw = topo.channel_bw
    with np.errstate(divide="ignore", invalid="ignore"):
        out = np.where(bw > 0, load / np.where(bw > 0, bw, 1.0),
                       np.where(load > 0, np.inf, 0.0))
    return out


def link_load_stats(topo: Topology, traffic: np.ndarray,
                    table: BiDORTable) -> dict:
    """Max and CV of the finite bandwidth-normalized link loads — the
    collective completion-time bound and its dispersion (infinite
    entries, i.e. planned load over a dead link, are excluded; detect
    them via :func:`link_load` directly)."""
    ll = link_load(topo, traffic, table)
    live = ll[np.isfinite(ll)]
    mean = float(live.mean()) if live.size else 0.0
    return {"max": float(live.max()) if live.size else 0.0,
            "cv": float(live.std() / mean) if mean else 0.0}
