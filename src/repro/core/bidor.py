"""BiDOR — bi-modal dimension-order routing guided by N-Rank (paper §3.3).

For every ⟨s, d⟩, compare the cumulative ``w_NR`` along the XY and YX routes
(eq. 10) and pick the cheaper one; the choice is stored one bit per
destination in a per-source bitmap (eq. 11) for O(1) runtime lookup.

``bidor_k`` generalizes the binary choice to all k! dimension orders on
k-dimensional topologies (used for the multi-pod ICI fabric); with
``orders=dimension_orders(2)`` it reduces exactly to the paper's scheme.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .topology import Topology
from .routes import dimension_orders, route_costs, next_port_table

__all__ = ["BiDORTable", "bidor", "bidor_k", "dor_table", "TIE_TOL"]

# Relative tolerance of the eq. 10 minimization's tie detection.  Shared
# with the device-resident pipeline (repro.core.plan_fast), whose choice
# tables must be identical to this oracle's.
TIE_TOL = 1e-5


@dataclasses.dataclass(frozen=True)
class BiDORTable:
    """Offline routing artifact deployed to the routers.

    Attributes:
      choice: (N, N) int8 — DOR-order index for every ⟨s, d⟩ (0 = XY).
        For the binary paper scheme this *is* the bitmap of eq. (11):
        ``bitmap[s] = choice[s, :]``.
      orders: the dimension orders the indices refer to.
      costs: (len(orders), N, N) cumulative w_NR per route (diagnostics).
      port_tables: (len(orders), N, N) int8 — next output port for
        (current-node, destination) under each order; routers follow
        ``port_tables[choice[s, d], cur, d]``.
    """

    choice: np.ndarray
    orders: tuple[tuple[int, ...], ...]
    costs: np.ndarray
    port_tables: np.ndarray
    # (N, N) bool — pairs for which NO dimension order avoids the down
    # channels (set by fault-aware planning; None on intact topologies).
    # Their traffic must be shed (admission control) — the stored choice
    # would cross a dead link.
    unroutable: np.ndarray | None = None

    @property
    def bitmaps(self) -> np.ndarray:
        """Per-source |N|-bitmaps (eq. 11); valid for the binary scheme."""
        if len(self.orders) > 2:
            raise ValueError("bitmaps are defined for the binary (XY/YX) scheme")
        return self.choice.astype(np.uint8)

    def packed_bitmaps(self) -> np.ndarray:
        """(N, ceil(N/8)) uint8 — the hardware bitmap layout."""
        return np.packbits(self.bitmaps, axis=1)


def dor_table(topo: Topology,
              orders: list[tuple[int, ...]] | None = None) -> BiDORTable:
    """Plan-table artifact for plain dimension-order routing.

    The table-routed simulator consumes (``port_tables``, ``choice``) for
    EVERY algorithm; the DOR baselines (XY, YX, O1Turn, Valiant, ROMM)
    route over this trivial artifact — binary orders, all-XY choice, no
    costs — so the simulator needs no routing logic of its own beyond the
    table gather.
    """
    if orders is None:
        orders = dimension_orders(topo.ndim, binary_only=True)
    n = topo.num_nodes
    ports = np.stack([next_port_table(topo, o) for o in orders])
    return BiDORTable(choice=np.zeros((n, n), np.int8),
                      orders=tuple(map(tuple, orders)),
                      costs=np.zeros((len(orders), n, n)),
                      port_tables=ports)


def route_feasibility(topo: Topology,
                      orders: list[tuple[int, ...]],
                      down: np.ndarray) -> np.ndarray:
    """(O, N, N) bool — order o's DOR route s→d avoids every down channel.

    ``down`` is a boolean per-channel mask (or an index array) over
    ``topo.channels``.  Works on the *intact* channel indexing: DOR routes
    are functions of coordinates alone, so feasibility is just a walk of
    each route against the down set.
    """
    from .routes import walk_routes

    down = np.asarray(down)
    if down.dtype != bool:
        m = np.zeros(topo.num_channels, dtype=bool)
        m[down] = True
        down = m
    n = topo.num_nodes
    down_pair = np.zeros((n, n), dtype=bool)
    down_pair[topo.channels[down, 0], topo.channels[down, 1]] = True
    feas = np.ones((len(orders), n, n), dtype=bool)
    for oi, order in enumerate(orders):
        seq = walk_routes(topo, order)               # (N, N, L+1)
        for h in range(seq.shape[-1] - 1):
            a, b = seq[..., h], seq[..., h + 1]
            hit = (a != b) & down_pair[a, b]
            feas[oi] &= ~hit
    return feas


def bidor_k(topo: Topology, w_nr: np.ndarray,
            orders: list[tuple[int, ...]] | None = None,
            tie_break: str = "xy",
            down_channels: np.ndarray | None = None) -> BiDORTable:
    """Choose, per ⟨s, d⟩, the DOR order with minimal Σ w_NR (eq. 10).

    ``tie_break``: "xy" (paper default — lowest order index) or "hash"
    (deterministic per-pair split across tied orders).  Flip-symmetric
    patterns (Overturn) tie on EVERY pair; measurements (EXPERIMENTS.md
    §Fidelity) show tie→XY dominates, so it stays the default.

    ``down_channels`` (fault-aware planning): boolean mask or index array
    over ``topo.channels`` of hard-failed channels.  Orders whose route
    crosses a down channel are masked out of the eq. (10) minimization, so
    every selected route stays a pure DOR route inside its own VC class —
    the fallback keeps the quasi-static scheme deadlock-free by
    construction.  Pairs no order can serve are flagged in
    ``BiDORTable.unroutable`` (their traffic must be shed upstream).
    """
    if orders is None:
        orders = dimension_orders(topo.ndim)
    costs = route_costs(topo, w_nr, orders)          # (O, N, N)
    unroutable = None
    if down_channels is not None and np.asarray(down_channels).size:
        feas = route_feasibility(topo, orders, down_channels)
        unroutable = ~feas.any(axis=0)
        np.fill_diagonal(unroutable, False)
        # infeasible orders leave the minimization; unroutable pairs keep
        # their unmasked costs so `choice` stays well-defined (and shed).
        big = np.where(unroutable[None], costs, np.inf)
        costs = np.where(feas, costs, big)
    # Ties are resolved with a tolerance (w_NR is float32; ties on
    # symmetric topologies are symmetry-exact) and broken by a
    # deterministic per-pair hash across the tied orders.  Flip-symmetric
    # patterns (e.g. Overturn) tie on EVERY pair — always defaulting to XY
    # would degenerate BiDOR to pure XY there, contradicting the paper's
    # own Table 1; the hash splits tied pairs evenly while staying fully
    # deterministic/offline (same bitmap artifact, same in-order property).
    n = topo.num_nodes
    best = costs.min(axis=0)
    tol = TIE_TOL * (1.0 + np.abs(best))
    is_min = costs <= best + tol                      # (O, N, N)
    if tie_break == "hash":
        num_min = is_min.sum(axis=0)                  # (N, N)
        sid = np.arange(n, dtype=np.uint64)
        mix = (sid[:, None] * np.uint64(2654435761)
               ^ (sid[None, :] * np.uint64(40503) + np.uint64(0x9E3779B9)))
        rank = ((mix >> np.uint64(13)).astype(np.int64)
                % np.maximum(num_min, 1))
        cum = np.cumsum(is_min, axis=0) - 1           # rank of tied order
        pick = is_min & (cum == rank[None])
        choice = np.argmax(pick, axis=0).astype(np.int8)
    else:
        choice = np.argmax(is_min, axis=0).astype(np.int8)  # first minimal
    np.fill_diagonal(choice, 0)
    ports = np.stack([next_port_table(topo, o) for o in orders])
    return BiDORTable(choice=choice, orders=tuple(map(tuple, orders)),
                      costs=costs, port_tables=ports,
                      unroutable=unroutable)


def bidor(topo: Topology, w_nr: np.ndarray,
          down_channels: np.ndarray | None = None) -> BiDORTable:
    """Paper-faithful binary BiDOR: XY vs YX only."""
    return bidor_k(topo, w_nr, dimension_orders(topo.ndim, binary_only=True),
                   down_channels=down_channels)


def greedy_refine(topo: Topology, traffic, table: BiDORTable,
                  sweeps: int = 4) -> BiDORTable:
    """BiDOR-G (beyond paper): greedy max-link-load refinement.

    BiDOR minimizes each pair's *own* path cost against the static w_NR
    field; it never sees the load its choice induces on others.  BiDOR-G
    post-processes the table: sweep pairs in decreasing traffic order and
    flip a pair's dimension order whenever that lowers the current maximum
    link load (recomputed incrementally).  Still fully offline/quasi-static
    — the output is the same bitmap artifact.
    """
    import numpy as _np
    from .routes import walk_routes
    from .qstar import link_load as _link_load

    t = _np.asarray(traffic, dtype=_np.float64)
    n = topo.num_nodes
    orders = table.orders
    seqs = [walk_routes(topo, o) for o in orders]
    chan_lut = _np.full((n, n), -1, _np.int64)
    chan_lut[topo.channels[:, 0], topo.channels[:, 1]] = _np.arange(
        topo.num_channels)

    def pair_links(oi, s, d):
        """Channel ids of route (s, d) under order oi; None if the route
        crosses a channel absent from the (possibly degraded) graph."""
        seq = seqs[oi][s, d]
        ids = []
        for h in range(len(seq) - 1):
            a, b = int(seq[h]), int(seq[h + 1])
            if a == b:
                break
            c = int(chan_lut[a, b])
            if c < 0:
                return None
            ids.append(c)
        return ids

    choice = table.choice.copy()
    load = _link_load(topo, t,
                      BiDORTable(choice=choice, orders=orders,
                                 costs=table.costs,
                                 port_tables=table.port_tables,
                                 unroutable=table.unroutable))
    bw = _np.where(topo.channel_bw > 0, topo.channel_bw, 1e-12)
    unroutable = table.unroutable
    pairs = [(s, d) for s in range(n) for d in range(n)
             if s != d and t[s, d] > 0
             and not (unroutable is not None and unroutable[s, d])]
    pairs.sort(key=lambda p: -t[p])
    for _ in range(sweeps):
        changed = 0
        for s, d in pairs:
            cur = int(choice[s, d])
            cur_links = pair_links(cur, s, d)
            if cur_links is None:
                continue  # current route leaves the degraded graph
            best_oi, best_peak = cur, max(
                (load[c] for c in cur_links), default=0.0)
            for oi in range(len(orders)):
                if oi == cur:
                    continue
                alt = pair_links(oi, s, d)
                if alt is None:
                    continue
                # peak among affected links if we moved this pair
                peak = 0.0
                for c in alt:
                    peak = max(peak, load[c]
                               + (0 if c in cur_links else t[s, d] / bw[c]))
                if peak < best_peak - 1e-15:
                    best_oi, best_peak = oi, peak
            if best_oi != cur:
                for c in cur_links:
                    load[c] -= t[s, d] / bw[c]
                for c in pair_links(best_oi, s, d):
                    load[c] += t[s, d] / bw[c]
                choice[s, d] = best_oi
                changed += 1
        if changed == 0:
            break
    return BiDORTable(choice=choice, orders=orders, costs=table.costs,
                      port_tables=table.port_tables,
                      unroutable=table.unroutable)
