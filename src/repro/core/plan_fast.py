"""Device-resident Q-StaR planning pipeline (jit-compiled end to end).

``build_plan`` strings four host-side numpy stages together — possibility
weights (eq. 5–7), the consecutive-channel joint possibility, the
channel-level evolution (eq. 1–3), and BiDOR's eq. 10 route-cost
minimization — with a host round-trip between each.  At ICI-fabric scale
(32×32 / 64×64 tori) the O(C·N²) loops are intractable on the host and the
round-trips dominate even where they are not.  :func:`build_plan_fast` is
the same pipeline as ONE jitted device computation:

* **One possibility pass.**  The per-destination possibility traffic

      V[c, d] = Σ_s T[s,d] · [dist(s,u) + 1 + dist(n,d) == dist(s,d)]

  (channel c = (u, n)) is the only O(C·N²) work in the whole plan, and
  every downstream weight is a cheap contraction of it: eq. 5 is the row
  sum ``W = V·1``, eq. 7 is the gather ``W_drn[c] = V[c, n]`` (the
  draining predicate is the minimal-path predicate at d = n), and — by the
  triangle inequality over the channel edges — the consecutive-channel
  joint possibility factorizes exactly:

      dist(s,u) + 2 + dist(n2,d) == dist(s,d)
        ⇔  ⟨c1 minimal for (s,d)⟩  ∧  dist(n,d) == 1 + dist(n2,d)

  so ``J[c1, c2] = Σ_d V[c1, d] · [dist(n,d) == 1 + dist(n2,d)]`` costs
  O(P·N) instead of O(P·N²) (P ≈ 3C consecutive pairs).  The pass runs as
  the Pallas kernel (:mod:`repro.kernels.possibility`) on backends that
  compile it and as a chunked jnp reduction elsewhere — identical math.

* **Sparse evolution.**  The channel-level transfer matrix is nonzero only
  on the P consecutive pairs, so eq. (2)–(3) iterate with two
  segment-sums per step (O(P)) instead of the dense (C, C) matvec, fused
  with the node aggregation in a single ``lax.while_loop``.

* **Fused BiDOR.**  Eq. 10 route costs and fault feasibility walk the DOR
  next-hop tables on device (``lax.scan`` over the diameter), and the
  tie-tolerant argmin emits the choice table directly — no numpy between
  N-Rank and the bitmap artifact.

Fault-aware replanning reuses the SAME compiled computation: hard-failed
channels are masked (``live``) rather than dropped, with the degraded hop
distances passed as data, so every fault pattern hits the one cached
compilation.  The masked formulation is algebraically identical to
planning on ``Topology.degrade(..., drop=True)`` (down channels carry zero
possibility weight, leave every denominator, and never receive evolution
weight), which property tests assert against the numpy oracle.

Precision policy: ``precision="auto"`` plans in fp64 on CPU (native, and
bit-stable against the fp64 host oracle's choice tables) and fp32 on
TPU/GPU, where BiDOR's tie tolerance (1e-5 relative, vs fp32's ~1e-7
rounding) absorbs the accumulation difference; see EXPERIMENTS.md
§Planner performance.
"""

from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.trace import NULL_TRACER

from .bidor import TIE_TOL, BiDORTable
from .certify import CertificationError, apply_repair, certify_table
from .nrank import ITER_TH, W_TH, NRankResult, initial_weights
from .qstar import QStarPlan
from .routes import dimension_orders, next_hop_table, next_port_table
from .topology import Topology

__all__ = ["build_plan_fast", "build_plans_batched", "plan_statics",
           "joint_possibility_fast", "plan_cache_key"]

# Jitted plan computations actually executed (cache bypasses bump nothing):
# the "did a warm re-run re-plan?" signal for tests and service logs.
DEVICE_BUILDS = 0


def _resolve_precision(precision: str) -> str:
    if precision == "auto":
        return "fp64" if jax.default_backend() == "cpu" else "fp32"
    return precision


def _precision_scope(precision: str):
    """Context manager selecting the accumulation dtype of the fast path."""
    precision = _resolve_precision(precision)
    if precision == "fp64":
        return jax.experimental.enable_x64()
    if precision != "fp32":
        raise ValueError(f"unknown precision {precision!r}")
    return contextlib.nullcontext()


def _use_pallas_default() -> bool:
    """Compiled Pallas where the backend supports it; chunked jnp else."""
    from repro.kernels.possibility.ops import backend_supports_pallas
    return backend_supports_pallas()


def _v_block(n: int) -> int:
    """Channel-chunk size of the possibility pass: keeps one block's
    (B, N, N) mask around 100 MB."""
    return int(max(8, min(256, (1 << 24) // max(n * n, 1))))


# --------------------------------------------------------------------- #
# per-topology statics (host-built once, cached)
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class PlanStatics:
    """Trace-time constants of one topology: channel/pair indexing, DOR
    next-hop tables, and the jitted plan computation built over them."""

    n: int
    c: int
    npairs: int
    diam: int
    orders: tuple
    us: jnp.ndarray          # (C,) channel sources
    ns: jnp.ndarray          # (C,) channel heads
    pair_c1: jnp.ndarray     # (P,) consecutive-pair first channel
    pair_c2: jnp.ndarray     # (P,) consecutive-pair second channel
    nh: jnp.ndarray          # (O, N, N) DOR next-hop tables
    port_tables: np.ndarray  # (O, N, N) int8, host (BiDOR artifact)
    core: object             # jitted single-plan computation
    core_batched: object     # jitted vmapped computation
    jvals: object = None     # jitted joint-possibility values (lazy)


_STATICS_CACHE: dict[tuple, PlanStatics] = {}
_DIST_CACHE: dict[tuple, np.ndarray] = {}
_CACHE_CAP = 16


def _topo_key(topo: Topology) -> tuple:
    return (topo.name, topo.dims, topo.wrap, topo.channels.tobytes())


def _consecutive_pairs(channels: np.ndarray, n: int):
    """(c1, c2) channel pairs with head(c1) == src(c2), u-turns excluded.

    ``channels`` is lexicographically sorted (topology construction), so
    the out-channels of node ``v`` are the contiguous run starting at
    ``searchsorted(us, v)``.
    """
    us = channels[:, 0].astype(np.int64)
    ns = channels[:, 1].astype(np.int64)
    c = len(channels)
    outdeg = np.bincount(us, minlength=n)
    start = np.concatenate([[0], np.cumsum(outdeg)])
    reps = outdeg[ns]                          # out-degree at each head
    c1 = np.repeat(np.arange(c), reps)
    pos = np.arange(len(c1)) - np.repeat(np.cumsum(reps) - reps, reps)
    c2 = start[ns[c1]] + pos
    keep = ns[c2] != us[c1]                    # u→n→u is never minimal
    return c1[keep].astype(np.int32), c2[keep].astype(np.int32)


def _possibility_v(dist, t, us, ns, offset: int, block: int,
                   use_pallas: bool):
    """Per-destination possibility traffic V (C, N) — the one O(C·N²)
    pass.  Pallas kernel where it compiles, chunked jnp elsewhere."""
    c = us.shape[0]
    if use_pallas:
        from repro.kernels.possibility.kernel import possibility_v_pallas
        from repro.kernels.possibility.ops import backend_supports_pallas
        du = dist[:, us]                       # (N, C)
        dn = dist[ns, :]                       # (C, N)
        # an explicit use_pallas on a backend with no compiled lowering
        # (CPU debugging) still works — through the interpreter
        return possibility_v_pallas(du, dn, t, dist, offset=offset,
                                    interpret=not backend_supports_pallas())

    pad = (-c) % block
    us_p = jnp.concatenate([us, jnp.zeros(pad, us.dtype)]) if pad else us
    ns_p = jnp.concatenate([ns, jnp.zeros(pad, ns.dtype)]) if pad else ns

    def one_block(ab):
        a, b = ab
        du = dist[:, a].T                      # (B, N)
        dn = dist[b, :]                        # (B, N)
        lhs = du[:, :, None] + offset + dn[:, None, :]   # (B, N, N)
        mask = (lhs == dist[None]).astype(t.dtype)
        return jnp.einsum("bsd,sd->bd", mask, t)         # (B, N)

    v = jax.lax.map(one_block, (us_p.reshape(-1, block),
                                ns_p.reshape(-1, block)))
    return v.reshape(-1, dist.shape[0])[:c]


def _factored_v(dist, t, us, ns, block, use_pallas):
    """V[c, d] — per-destination possibility traffic of every channel.

    The eq. 4 predicate factorizes (triangle inequality over the channel
    edge):  dist(s,u)+1+dist(n,d) == dist(s,d)
      ⇔  [dist(s,u)+dist(u,d) == dist(s,d)]   (u on a minimal path)
       ∧ [dist(u,d) == 1+dist(n,d)]           ((u,n) in d's min-DAG)
    so the only O(N³) work is the channel-free on-path traffic
    OP[u,d] = Σ_s T[s,d]·[dist(s,u)+dist(u,d) == dist(s,d)] — the
    offset-0 instance of the possibility primitive — and V is a gather:
    V[c,d] = dag[c,d]·OP[u_c,d].  A degree-k topology does k× less
    compare work than the direct (C, N, N) reduction.
    """
    idn = jnp.arange(dist.shape[0], dtype=jnp.int32)
    op = _possibility_v(dist, t, idn, idn, 0, block, use_pallas)
    dag = (dist[us, :] == 1 + dist[ns, :]).astype(t.dtype)
    return dag * op[us, :]


def _joint_vals(dist, v, ns, pair_c1, pair_c2):
    """Joint possibility on the consecutive pairs: the same triangle-
    inequality factorization gives
    J[c1,c2] = Σ_d V[c1,d]·[dist(n,d) == 1+dist(n2,d)] — O(P·N)."""
    n1, n2 = ns[pair_c1], ns[pair_c2]
    jmask = (dist[n1, :] == 1 + dist[n2, :]).astype(v.dtype)
    return (v[pair_c1] * jmask).sum(1)


def _make_core(statics_arrays: dict, n: int, c: int, diam: int,
               block: int, use_pallas: bool):
    """Build the single-plan device computation for one topology."""
    us = statics_arrays["us"]
    ns = statics_arrays["ns"]
    pair_c1 = statics_arrays["pair_c1"]
    pair_c2 = statics_arrays["pair_c2"]
    nh = statics_arrays["nh"]
    seg = jax.ops.segment_sum

    def core(dist, t, w0_eff, use_w0, live, down_pair, w_th, iter_th):
        f = t.dtype
        tiny = jnp.asarray(1e-300 if f == jnp.float64 else 1e-30, f)
        livef = live.astype(f)

        # ---- possibility pass: eq. 5/7 and the joint, all from the
        # factorized V (see _factored_v / _joint_vals) ---- #
        v = _factored_v(dist, t, us, ns, block, use_pallas)
        v = v * livef[:, None]
        w = v.sum(1)                                  # eq. (5)
        w_drn = v[jnp.arange(c), ns]                  # eq. (7): d == n
        jflat = _joint_vals(dist, v, ns, pair_c1, pair_c2) * livef[pair_c2]
        # channel-level transfer values on the consecutive pairs
        rowsum = seg(jflat, pair_c1, num_segments=c)
        p_drn_c = jnp.clip(jnp.where(w > 0, w_drn / jnp.maximum(w, tiny),
                                     0.0), 0.0, 1.0)
        mvals = jnp.where(rowsum[pair_c1] > 0,
                          jflat / jnp.maximum(rowsum[pair_c1], tiny),
                          0.0) * (1.0 - p_drn_c[pair_c1])

        # ---- initial channel weights (eq. 1 split over min channels) -- #
        mask_cd = ((1 + dist[ns, :]) == dist[us, :]) & live[:, None]
        cnt = seg(mask_cd.astype(f), us, num_segments=n)      # (N, N)
        share = mask_cd * t[us, :]
        denom = cnt[us]
        w0c = jnp.where(denom > 0, share / jnp.maximum(denom, tiny),
                        0.0).sum(1)
        w0_base = t.sum(1)                                    # eq. (1)
        outdeg = seg(livef, us, num_segments=n)
        scale = jnp.where(w0_base > 0,
                          w0_eff / jnp.maximum(w0_base, tiny), 0.0)
        extra = jnp.where(w0_base > 0, 0.0, w0_eff)
        w0c_warm = (w0c * scale[us]
                    + extra[us] / jnp.maximum(outdeg[us], 1.0)) * livef
        w0c = jnp.where(use_w0, w0c_warm, w0c * livef)
        w0_node = jnp.where(use_w0, w0_eff, w0_base)

        # ---- evolution: eq. (2)-(3), sparse over consecutive pairs ---- #
        def cond(state):
            wc, _, it = state
            return jnp.logical_and(jnp.sum(wc) >= w_th, it < iter_th)

        def body(state):
            wc, w_nr, it = state
            w_nr = w_nr + seg(wc, ns, num_segments=n)   # arrivals (eq. 3)
            wc = seg(wc[pair_c1] * mvals, pair_c2,
                     num_segments=c)                    # drain+continue
            return wc, w_nr, it + 1

        wcf, w_nr, it = jax.lax.while_loop(
            cond, body, (w0c, w0_node, jnp.int32(0)))
        w_final = seg(wcf, ns, num_segments=n)

        # ---- node-level transfer probabilities (eq. 8-9 diagnostics) -- #
        denom_n = seg(w, us, num_segments=n)
        p = jnp.where(denom_n[us] > 0, w / jnp.maximum(denom_n[us], tiny),
                      0.0)

        # ---- BiDOR: eq. 10 cost walk + fault feasibility, fused ------ #
        dst = jnp.arange(n, dtype=jnp.int32)[None, :]
        cur0 = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None],
                                (n, n))

        def walk(nh_o):
            def step(carry, _):
                cur, acc, ok = carry
                nxt = nh_o[cur, dst]
                moving = nxt != cur
                acc = acc + jnp.where(moving, w_nr[nxt], 0.0)
                ok = ok & ~(moving & down_pair[cur, nxt])
                return (nxt, acc, ok), None

            init = (cur0, jnp.broadcast_to(w_nr[:, None], (n, n)),
                    jnp.ones((n, n), bool))
            (_, acc, ok), _ = jax.lax.scan(step, init, None, length=diam)
            return acc, ok

        per_order = [walk(nh[oi]) for oi in range(nh.shape[0])]
        costs = jnp.stack([a for a, _ in per_order])
        feas = jnp.stack([o for _, o in per_order])
        eye = jnp.eye(n, dtype=bool)
        unroutable = ~feas.any(0) & ~eye
        big = jnp.where(unroutable[None], costs, jnp.inf)
        costs_m = jnp.where(feas, costs, big)
        best = costs_m.min(0)
        tol = TIE_TOL * (1.0 + jnp.abs(best))
        is_min = costs_m <= best + tol
        choice = jnp.where(eye, 0, jnp.argmax(is_min, 0)).astype(jnp.int8)
        return dict(choice=choice, costs=costs_m, unroutable=unroutable,
                    w_nr=w_nr, w0=w0_node, w_final=w_final, it=it,
                    p=p, p_drn=p_drn_c, w=w)

    return core


def plan_statics(topo: Topology, *, binary_only: bool = True,
                 use_pallas: bool | None = None) -> PlanStatics:
    """Host-built trace-time constants for ``build_plan_fast`` (cached per
    topology; bandwidth changes hit the same entry)."""
    if use_pallas is None:
        use_pallas = _use_pallas_default()
    key = _topo_key(topo) + (binary_only, use_pallas)
    hit = _STATICS_CACHE.get(key)
    if hit is not None:
        return hit
    n, c = topo.num_nodes, topo.num_channels
    orders = tuple(map(tuple, dimension_orders(topo.ndim,
                                               binary_only=binary_only)))
    c1, c2 = _consecutive_pairs(topo.channels, n)
    nh = np.stack([next_hop_table(topo, o) for o in orders])
    ports = np.stack([next_port_table(topo, o) for o in orders])
    # route horizon, not BFS diameter: the eq. 10 walk follows DOR routes,
    # whose length express shortcuts may leave above the BFS distances
    diam = topo.route_horizon
    arrays = dict(
        us=jnp.asarray(topo.channels[:, 0].astype(np.int32)),
        ns=jnp.asarray(topo.channels[:, 1].astype(np.int32)),
        pair_c1=jnp.asarray(c1), pair_c2=jnp.asarray(c2),
        nh=jnp.asarray(nh.astype(np.int32)),
    )
    core = _make_core(arrays, n, c, diam, _v_block(n), use_pallas)
    statics = PlanStatics(
        n=n, c=c, npairs=len(c1), diam=diam, orders=orders,
        us=arrays["us"], ns=arrays["ns"],
        pair_c1=arrays["pair_c1"], pair_c2=arrays["pair_c2"],
        nh=arrays["nh"], port_tables=ports,
        core=jax.jit(core),
        core_batched=jax.jit(jax.vmap(
            core, in_axes=(None, 0, 0, 0, None, None, None, None))),
    )
    if len(_STATICS_CACHE) >= _CACHE_CAP:
        _STATICS_CACHE.pop(next(iter(_STATICS_CACHE)))
    _STATICS_CACHE[key] = statics
    return statics


def _down_ids(topo: Topology, down_channels) -> np.ndarray:
    if down_channels is None:
        return np.zeros(0, np.int64)
    down = np.asarray(down_channels)
    if down.dtype == bool:
        return np.nonzero(down)[0]
    return np.unique(down.astype(np.int64))


def _distances_for(topo: Topology, down: np.ndarray) -> np.ndarray:
    """Hop distances of the graph minus the down channels (cached)."""
    if down.size == 0:
        return topo.distances
    key = (_topo_key(topo), down.tobytes())
    hit = _DIST_CACHE.get(key)
    if hit is None:
        hit = topo.degrade(down, drop=True).distances
        if len(_DIST_CACHE) >= _CACHE_CAP:
            _DIST_CACHE.pop(next(iter(_DIST_CACHE)))
        _DIST_CACHE[key] = hit
    return hit


def _fault_arrays(topo: Topology, statics: PlanStatics, down_channels):
    """The masked-fault plan inputs shared by the single and batched
    builders: (down ids, degraded distances, live mask, down node-pair
    mask)."""
    down = _down_ids(topo, down_channels)
    dist = _distances_for(topo, down)
    live = np.ones(statics.c, bool)
    live[down] = False
    down_pair = np.zeros((statics.n, statics.n), bool)
    if down.size:
        down_pair[topo.channels[down, 0], topo.channels[down, 1]] = True
    return down, dist, live, down_pair


def _assemble_plan(topo: Topology, traffic: np.ndarray, statics: PlanStatics,
                   out: dict, have_down: bool) -> QStarPlan:
    unroutable = np.asarray(out["unroutable"]) if have_down else None
    nr = NRankResult(
        w_nr=np.asarray(out["w_nr"], np.float64),
        w0=np.asarray(out["w0"], np.float64),
        w_final=np.asarray(out["w_final"], np.float64),
        iterations=int(out["it"]),
        p=np.asarray(out["p"], np.float64),
        p_drn=np.asarray(out["p_drn"], np.float64),
        w_possibility=np.asarray(out["w"], np.float64))
    table = BiDORTable(
        choice=np.asarray(out["choice"], np.int8), orders=statics.orders,
        costs=np.asarray(out["costs"], np.float64),
        port_tables=statics.port_tables, unroutable=unroutable)
    return QStarPlan(topology=topo, traffic=np.asarray(traffic), nrank=nr,
                     table=table)


def gate_plan(topo: Topology, plan: QStarPlan, *, tracer=None,
              label: str = "") -> QStarPlan:
    """Mandatory deadlock-freedom gate on every plan-producing path.

    Certifies the plan's table (``repro.core.certify``), attaches the
    certificate to the returned plan (``plan.cert``), folds a
    turn-prohibition repair back into the table when the certifier had
    to intervene, and raises :class:`CertificationError` when cycles
    survive repair — a rejected table must never reach a simulator or a
    cache.  Clean plans pass through bit-unchanged.
    """
    cert = certify_table(topo, plan.table, traffic=plan.traffic,
                         w_nr=plan.nrank.w_nr, tracer=tracer, label=label)
    if not cert.ok:
        raise CertificationError(
            f"plan for {topo.name} failed deadlock certification "
            f"({cert.cyclic_nodes} cyclic CDG nodes survive repair; "
            f"label={label!r})")
    if cert.verdict == "repaired":
        plan = dataclasses.replace(plan,
                                   table=apply_repair(plan.table, cert))
    return dataclasses.replace(plan, cert=cert)


def plan_cache_key(topo: Topology, traffic, *, down_channels=None,
                   k_orders: bool = False, w_th: float = W_TH,
                   iter_th: int = ITER_TH,
                   precision: str = "auto") -> str:
    """The content key a cold ``build_plan_fast`` call with these
    arguments uses against a :class:`repro.core.plan_cache.PlanCache` —
    callers that pre-screen the cache (the campaign executor) must key
    identically, including precision resolution."""
    from .plan_cache import plan_key
    return plan_key(topo, traffic, down_channels=down_channels,
                    k_orders=k_orders, w_th=w_th, iter_th=iter_th,
                    precision=_resolve_precision(precision))


def _cache_lookup(cache, topo, traffic, down_channels, k_orders, w_th,
                  iter_th, precision, w0):
    """(key, hit) for the persistent plan cache; (None, None) when the
    build is uncacheable (warm-started) or no cache is in play."""
    if cache is None or w0 is not None:
        return None, None
    key = plan_cache_key(topo, traffic, down_channels=down_channels,
                         k_orders=k_orders, w_th=w_th, iter_th=iter_th,
                         precision=precision)
    return key, cache.get(key, topo)


def build_plan_fast(topo: Topology, traffic: np.ndarray, *,
                    k_orders: bool = False,
                    w_th: float = W_TH, iter_th: int = ITER_TH,
                    w0: np.ndarray | None = None,
                    down_channels=None,
                    precision: str = "auto",
                    use_pallas: bool | None = None,
                    cache=None, tracer=None) -> QStarPlan:
    """Device-resident Q-StaR pipeline — ``build_plan(mode="channel")``
    as one jitted call (possibility → joint → evolution → BiDOR, no host
    round-trips).

    Semantics match :func:`repro.core.qstar.build_plan` with
    ``mode="channel"``, including the warm-start ``w0`` carry and
    fault-aware planning: ``down_channels`` masks the failed channels out
    of both the possibility sets (via degraded hop distances, computed
    host-side and passed as data so every fault pattern reuses the one
    compiled plan) and the eq. 10 minimization; ``table.unroutable``
    flags pairs no dimension order can serve.

    ``cache`` is an optional :class:`repro.core.plan_cache.PlanCache`:
    cold (``w0``-less) builds are served from / stored into it by content
    key, skipping the device computation entirely on a hit.

    ``tracer`` (a :class:`repro.obs.trace.TraceWriter`) records the
    build as a span — statics/compile+device wall split in its args —
    and cache hits as instants.
    """
    global DEVICE_BUILDS
    tracer = tracer if tracer is not None else NULL_TRACER
    key, hit = _cache_lookup(cache, topo, traffic, down_channels,
                             k_orders, w_th, iter_th, precision, w0)
    if hit is not None:
        tracer.instant("plan_cache_hit", cat="plan",
                       args={"nodes": topo.num_nodes})
        cert = cache.get_cert(key)
        if cert is not None and cert.verdict == "clean":
            # admission gate satisfied by the stored certificate
            return dataclasses.replace(hit, cert=cert)
        # pre-certifier entry (or a stored repair): re-run the gate
        return gate_plan(topo, hit, tracer=tracer, label="cache_hit")
    t_all = tracer.now_us()
    statics = plan_statics(topo, binary_only=not k_orders,
                           use_pallas=use_pallas)
    down, dist, live, down_pair = _fault_arrays(topo, statics,
                                                down_channels)
    t_dev = tracer.now_us()
    DEVICE_BUILDS += 1
    if cache is not None:
        cache.stats.device_builds += 1
    with _precision_scope(precision):
        t = jnp.asarray(np.asarray(traffic, np.float64))
        w0_eff = jnp.asarray(np.asarray(
            initial_weights(traffic) if w0 is None else w0, np.float64))
        out = statics.core(jnp.asarray(dist), t, w0_eff,
                           jnp.asarray(w0 is not None),
                           jnp.asarray(live), jnp.asarray(down_pair),
                           jnp.asarray(float(w_th)), jnp.int32(iter_th))
        out = jax.device_get(out)
    plan = _assemble_plan(topo, traffic, statics, out, bool(down.size))
    plan = gate_plan(topo, plan, tracer=tracer, label="build_plan_fast")
    t_end = tracer.now_us()
    tracer.complete(
        "build_plan_fast", t_all, t_end - t_all, cat="plan",
        args={"nodes": topo.num_nodes, "warm": w0 is not None,
              "faults": int(down.size),
              "statics_ms": round((t_dev - t_all) / 1e3, 3),
              "device_ms": round((t_end - t_dev) / 1e3, 3)})
    if key is not None:
        cache.put(key, plan, k_orders=k_orders, cert=plan.cert)
    return plan


def build_plans_batched(topo: Topology, traffics, *,
                        w0s=None,
                        k_orders: bool = False,
                        w_th: float = W_TH, iter_th: int = ITER_TH,
                        down_channels=None,
                        precision: str = "auto",
                        use_pallas: bool | None = None,
                        cache=None, tracer=None) -> list[QStarPlan]:
    """Plans for many traffic matrices on one topology in a single vmapped
    device call — the campaign's (pattern, scenario) axis.  Each returned
    plan is identical to its ``build_plan_fast`` equivalent (vmapped
    ``while_loop`` lanes freeze once their own termination hits).

    ``down_channels`` (one fault pattern shared by the whole batch, e.g. a
    ``fault_region_mesh``'s dead channels) masks the failed channels out of
    every plan exactly as in :func:`build_plan_fast`.

    ``cache`` serves/stores cold lanes by content key (see
    :func:`build_plan_fast`); when every lane hits, no device computation
    runs at all.  ``tracer`` records the batched build as a span and
    per-lane cache hits/misses as instants.
    """
    global DEVICE_BUILDS
    tracer = tracer if tracer is not None else NULL_TRACER
    statics = plan_statics(topo, binary_only=not k_orders,
                           use_pallas=use_pallas)
    down, dist, live, down_pair = _fault_arrays(topo, statics,
                                                down_channels)
    tms = [np.asarray(t, np.float64) for t in traffics]
    if w0s is None:
        w0s = [None] * len(tms)
    if cache is not None:
        cached: dict[int, QStarPlan] = {}
        keys: dict[int, str] = {}
        for i, (tm, w0) in enumerate(zip(tms, w0s)):
            key, hit = _cache_lookup(cache, topo, tm, down_channels,
                                     k_orders, w_th, iter_th, precision,
                                     w0)
            if hit is not None:
                cert = cache.get_cert(key)
                if cert is not None and cert.verdict == "clean":
                    hit = dataclasses.replace(hit, cert=cert)
                else:
                    hit = gate_plan(topo, hit, tracer=tracer,
                                    label=f"cache_hit:{i}")
                cached[i] = hit
                tracer.instant("plan_cache_hit", cat="plan",
                               args={"lane": i, "nodes": topo.num_nodes})
            elif key is not None:
                keys[i] = key
                tracer.instant("plan_cache_miss", cat="plan",
                               args={"lane": i, "nodes": topo.num_nodes})
        if len(cached) < len(tms):
            need = [i for i in range(len(tms)) if i not in cached]
            built = build_plans_batched(
                topo, [tms[i] for i in need],
                w0s=[w0s[i] for i in need], k_orders=k_orders,
                w_th=w_th, iter_th=iter_th, down_channels=down_channels,
                precision=precision, use_pallas=use_pallas,
                tracer=tracer)
            for i, plan in zip(need, built):
                cached[i] = plan
                if i in keys:
                    cache.put(keys[i], plan, k_orders=k_orders,
                              cert=plan.cert)
            cache.stats.device_builds += 1
        return [cached[i] for i in range(len(tms))]
    n = statics.n
    # the single-plan chunking budgets ~one (block, N, N) mask; a vmapped
    # batch multiplies that by its lane count, so large batches advance
    # in slices that keep the peak working set bounded
    group = max(1, (1 << 26) // max(_v_block(n) * n * n, 1))
    plans = []
    DEVICE_BUILDS += 1
    t_span = tracer.now_us()
    with _precision_scope(precision):
        for lo in range(0, len(tms), group):
            tms_g, w0s_g = tms[lo:lo + group], w0s[lo:lo + group]
            t_b = jnp.asarray(np.stack(tms_g))
            w0_b = jnp.asarray(np.stack(
                [initial_weights(t) if w0 is None
                 else np.asarray(w0, np.float64)
                 for t, w0 in zip(tms_g, w0s_g)]))
            use_b = jnp.asarray(np.array([w0 is not None for w0 in w0s_g]))
            out = jax.device_get(statics.core_batched(
                jnp.asarray(dist), t_b, w0_b, use_b,
                jnp.asarray(live), jnp.asarray(down_pair),
                jnp.asarray(float(w_th)), jnp.int32(iter_th)))
            for i, tm in enumerate(tms_g):
                lane = {k: np.asarray(v)[i] for k, v in out.items()}
                plan = _assemble_plan(topo, tm, statics, lane,
                                      have_down=bool(down.size))
                plans.append(gate_plan(topo, plan, tracer=tracer,
                                       label="build_plans_batched"))
    tracer.complete("build_plans_batched", t_span,
                    tracer.now_us() - t_span, cat="plan",
                    args={"nodes": topo.num_nodes, "lanes": len(tms),
                          "faults": int(down.size)})
    return plans


def joint_possibility_fast(topo: Topology, traffic: np.ndarray,
                           precision: str = "auto",
                           use_pallas: bool | None = None) -> np.ndarray:
    """Device path for :func:`repro.core.nrank.joint_possibility`: the
    dense (C, C) consecutive-channel joint weights via the V-contraction
    (O(C·N²) + O(P·N) instead of O(P·N²))."""
    statics = plan_statics(topo, use_pallas=use_pallas)
    if statics.jvals is None:
        if use_pallas is None:
            use_pallas = _use_pallas_default()
        block = _v_block(statics.n)

        def jvals(dist, t):
            v = _factored_v(dist, t, statics.us, statics.ns, block,
                            use_pallas)
            return _joint_vals(dist, v, statics.ns, statics.pair_c1,
                               statics.pair_c2)

        statics.jvals = jax.jit(jvals)   # cached with the topology statics
    jvals = statics.jvals
    with _precision_scope(precision):
        flat = np.asarray(jax.device_get(jvals(
            jnp.asarray(topo.distances),
            jnp.asarray(np.asarray(traffic, np.float64)))), np.float64)
    j = np.zeros((statics.c, statics.c), np.float64)
    j[np.asarray(statics.pair_c1), np.asarray(statics.pair_c2)] = flat
    return j
