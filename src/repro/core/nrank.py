"""N-Rank — the evolutionary model of paper §3.2.

Pipeline (all offline, eq. numbers from the paper):

1. possibility sets / weights  (eq. 4–7)   → ``possibility_weights``
2. transfer & draining probabilities (8–9) → ``transition_probabilities``
3. evolution: init (1), iterate (2–3), terminate → ``evolve`` (jax)

The 2D-mesh-specific "minimum rectangle" membership of eq. (4) is
implemented through the topology-agnostic minimal-path predicate::

    ⟨s,d⟩ ∈ P^{u,n}  ⇔  dist(s,u) + 1 + dist(n,d) == dist(s,d)

which is equivalent on meshes (a channel lies inside MinRect(s,d) with a
non-detouring orientation iff it lies on some minimal s→d path) and remains
well-defined on tori / multi-pod graphs where MinRect is not.  Equivalence
on meshes is property-tested against the literal eq. (4) in
``tests/test_core_nrank.py``.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .topology import Topology

__all__ = [
    "NRankResult",
    "possibility_weights",
    "transition_probabilities",
    "evolve",
    "nrank",
    "nrank_channel",
    "joint_possibility",
]

# paper §3.2.1 defaults
W_TH = 0.01
ITER_TH = 100


@dataclasses.dataclass(frozen=True)
class NRankResult:
    """Output of the N-Rank evolution."""

    w_nr: np.ndarray          # (N,) NR-weights — likelihood of heavy load
    w0: np.ndarray            # (N,) initial weights (eq. 1)
    w_final: np.ndarray       # (N,) residual weight at termination
    iterations: int
    p: np.ndarray             # (C,) transfer probability per channel (eq. 8)
    p_drn: np.ndarray         # (C,) draining probability per channel (eq. 9)
    w_possibility: np.ndarray  # (C,) possibility weight W^{u,n} (eq. 5)


def possibility_weights(dist: np.ndarray, traffic: np.ndarray,
                        channels: np.ndarray,
                        chunk: int = 256) -> tuple[np.ndarray, np.ndarray]:
    """Possibility weights ``W`` (eq. 5) and draining weights ``W_drn``
    (eq. 7) for every channel.

    Args:
      dist: (N, N) hop distances.
      traffic: (N, N) traffic matrix T.
      channels: (C, 2) directed channels (u, n).
      chunk: channels processed per vectorized block (memory control).

    Returns:
      (W, W_drn), each (C,) float64.

    This is the O(C·N²) hot spot of N-Rank; ``repro.kernels.possibility``
    provides the Pallas TPU kernel with this function as its oracle.
    """
    dist = np.asarray(dist, dtype=np.int64)
    traffic = np.asarray(traffic, dtype=np.float64)
    c = channels.shape[0]
    w = np.empty(c, dtype=np.float64)
    w_drn = np.empty(c, dtype=np.float64)
    for lo in range(0, c, chunk):
        hi = min(lo + chunk, c)
        us = channels[lo:hi, 0]
        ns = channels[lo:hi, 1]
        # mask[b, s, d] = channel b on a minimal s→d path
        lhs = dist[:, us].T[:, :, None] + 1 + dist[ns, :][:, None, :]
        mask = lhs == dist[None, :, :]
        w[lo:hi] = (mask * traffic[None]).sum(axis=(1, 2))
        # draining: additionally d == n (eq. 6) ⇒ dist(s,u)+1 == dist(s,n)
        drn_mask = (dist[:, us].T + 1) == dist[:, ns].T  # (b, s)
        w_drn[lo:hi] = (drn_mask * traffic[:, ns].T).sum(axis=1)
    return w, w_drn


def transition_probabilities(
        topo: Topology, traffic: np.ndarray,
        w: np.ndarray | None = None,
        w_drn: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Transfer/draining probabilities (eq. 8–9) and dense transition
    matrices for the evolution.

    Returns:
      p:    (C,) transfer probability per channel.
      p_drn:(C,) draining probability per channel.
      A:    (N, N) with A[u, n] = p^{u,n}            (for eq. 3)
      A_drn:(N, N) with A_drn[u, n] = p^{u,n}(1 − p_drn^{u,n})  (for eq. 2)
    """
    if w is None or w_drn is None:
        w, w_drn = possibility_weights(topo.distances, traffic, topo.channels)
    n = topo.num_nodes
    us, ns = topo.channels[:, 0], topo.channels[:, 1]
    denom = np.zeros(n, dtype=np.float64)
    np.add.at(denom, us, w)
    with np.errstate(invalid="ignore", divide="ignore"):
        p = np.where(denom[us] > 0, w / np.maximum(denom[us], 1e-300), 0.0)
        p_drn = np.where(w > 0, w_drn / np.maximum(w, 1e-300), 0.0)
    p_drn = np.clip(p_drn, 0.0, 1.0)
    a = np.zeros((n, n), dtype=np.float64)
    a_drn = np.zeros((n, n), dtype=np.float64)
    a[us, ns] = p
    a_drn[us, ns] = p * (1.0 - p_drn)
    return p, p_drn, a, a_drn


@partial(jax.jit, static_argnames=("iter_th",))
def _evolve_jax(a: jax.Array, a_drn: jax.Array, w0: jax.Array,
                w_th: float, iter_th: int):
    """Eq. (2)–(3) iterated until Σw < w_th or iter ≥ iter_th (jax)."""

    def cond(state):
        w, _, it = state
        return jnp.logical_and(jnp.sum(w) >= w_th, it < iter_th)

    def body(state):
        w, w_nr, it = state
        arrived = w @ a                 # Σ_u w^u p^{u,n}        (eq. 3 term)
        w_nr = w_nr + arrived
        w = w @ a_drn                   # eq. (2)
        return w, w_nr, it + 1

    w, w_nr, it = jax.lax.while_loop(cond, body, (w0, w0, jnp.int32(0)))
    return w, w_nr, it


def evolve(a: np.ndarray, a_drn: np.ndarray, w0: np.ndarray,
           w_th: float = W_TH, iter_th: int = ITER_TH):
    """Run the evolution; returns (w_final, w_nr, iterations).

    ``w0`` is the full initial-weight carry: the quasi-static re-planner
    (:mod:`repro.noc.ctrl`) seeds it with the previous plan's residual
    fixed point on top of eq. (1), so successive plans evolve from the
    load state the old plan left behind instead of from scratch.
    """
    w, w_nr, it = _evolve_jax(jnp.asarray(a), jnp.asarray(a_drn),
                              jnp.asarray(w0), float(w_th), int(iter_th))
    return np.asarray(w), np.asarray(w_nr), int(it)


def initial_weights(traffic: np.ndarray) -> np.ndarray:
    """Eq. (1): w0[n] = Σ_{n'} T[n, n']."""
    return np.asarray(traffic, dtype=np.float64).sum(axis=1)


def joint_possibility(topo: Topology, traffic: np.ndarray,
                      chunk: int = 4096,
                      use_kernel: bool = False) -> np.ndarray:
    """Joint possibility weights for *consecutive* channels.

    ``J[c1, c2]`` (nonzero only when c2 starts where c1 ends) is the total
    traffic that can traverse c1 = (u, n) immediately followed by
    c2 = (n, n') on one minimal path:

        J = Σ_{s,d} T[s,d] · [dist(s,u) + 2 + dist(n',d) == dist(s,d)]

    This is the channel-level tightening of the paper's "routing algorithms
    never take detours" assumption (§3.2.2): a node-level memoryless walk
    can hop u→n→u, which no detour-free packet ever does; conditioning the
    transfer on the incoming channel removes exactly those impossible
    continuations.  Stored dense (C, C) — C is small (≤ ~4N).

    ``use_kernel=True`` routes through the compiled device path
    (:func:`repro.core.plan_fast.joint_possibility_fast` — O(N³) + O(P·N)
    instead of this oracle's O(P·N²)); this host loop is the oracle it is
    property-tested against.
    """
    if use_kernel:
        from .plan_fast import joint_possibility_fast
        return joint_possibility_fast(topo, traffic)
    dist = np.asarray(topo.distances, np.int64)
    t = np.asarray(traffic, np.float64)
    c = topo.num_channels
    chans = topo.channels
    j = np.zeros((c, c), np.float64)
    # enumerate consecutive pairs
    out_of: dict[int, list[int]] = {}
    for ci, (u, n) in enumerate(chans):
        out_of.setdefault(int(u), []).append(ci)
    pairs = []
    for c1, (u, n) in enumerate(chans):
        for c2 in out_of.get(int(n), []):
            n2 = int(chans[c2, 1])
            if n2 != int(u):  # a u→n→u continuation is never minimal anyway
                pairs.append((c1, c2, int(u), n2))
    pairs = np.array(pairs, np.int64).reshape(-1, 4)
    for lo in range(0, len(pairs), chunk):
        blk = pairs[lo:lo + chunk]
        us, n2s = blk[:, 2], blk[:, 3]
        lhs = dist[:, us].T[:, :, None] + 2 + dist[n2s, :][:, None, :]
        mask = lhs == dist[None, :, :]
        j[blk[:, 0], blk[:, 1]] = (mask * t[None]).sum(axis=(1, 2))
    return j


def nrank_channel(topo: Topology, traffic: np.ndarray,
                  w_th: float = W_TH, iter_th: int = ITER_TH,
                  w0: np.ndarray | None = None,
                  use_kernel: bool = False) -> NRankResult:
    """N-Rank with channel-level evolution state (primary interpretation).

    Identical workflow to §3.2 but the evolving weight lives on channels, so
    a quantum of weight can only continue onto channels that share a minimal
    path with the channel it arrived on.  The literal node-level evolution
    (``nrank``) lets weight diffuse into regions real traffic cannot reach
    without detours, which inverts the predicted trend on edge-I/O
    topologies (see EXPERIMENTS.md §Fidelity); this variant restores the
    paper's own reported behaviour (Table 1, Fig. 8) and is what
    ``build_plan`` uses by default.

    ``w0`` (optional, node-level) overrides the eq. (1) initial weights —
    the warm-start carry of the online re-planner.  Channel-level initial
    weights are rescaled per source so each node still splits its initial
    weight over its minimal outgoing channels.

    ``use_kernel=True`` computes the possibility stages (eq. 5/7 and the
    joint) on the compiled device paths instead of the host loops; the
    evolution and aggregation stay as below.  For the fully fused,
    device-resident pipeline use :func:`repro.core.plan_fast.build_plan_fast`.
    """
    traffic = np.asarray(traffic, dtype=np.float64)
    n, c = topo.num_nodes, topo.num_channels
    chans = topo.channels
    us, ns = chans[:, 0], chans[:, 1]
    if use_kernel:
        from repro.kernels.possibility import ops as _pops
        w, w_drn = _pops.possibility_weights(topo.distances, traffic, chans)
        w = np.asarray(w, np.float64)
        w_drn = np.asarray(w_drn, np.float64)
    else:
        w, w_drn = possibility_weights(topo.distances, traffic, chans)
    with np.errstate(invalid="ignore", divide="ignore"):
        p_drn = np.where(w > 0, w_drn / np.maximum(w, 1e-300), 0.0)
    p_drn = np.clip(p_drn, 0.0, 1.0)
    j = joint_possibility(topo, traffic, use_kernel=use_kernel)
    row = j.sum(1)
    with np.errstate(invalid="ignore", divide="ignore"):
        q = np.where(row[:, None] > 0, j / np.maximum(row, 1e-300)[:, None], 0.0)
    # transfer matrix: arrive at n, drain p_drn, continue per q
    m = q * (1.0 - p_drn)[:, None]            # (C, C)
    # initial channel weights: split each source's traffic equally over its
    # minimal outgoing channels per destination
    dist = np.asarray(topo.distances, np.int64)
    # mask[c, d] = channel c on a minimal path from its own source u to d
    mask = (1 + dist[ns, :]) == dist[us, :]
    counts = np.zeros((n, topo.num_nodes), np.float64)
    np.add.at(counts, us, mask.astype(np.float64))
    share = np.where(mask, traffic[us, :], 0.0)
    denom = counts[us, :]
    with np.errstate(invalid="ignore", divide="ignore"):
        w0c = np.where(denom > 0, share / np.maximum(denom, 1e-300), 0.0).sum(1)
    w0_node = initial_weights(traffic)
    if w0 is not None:
        w0_eff = np.asarray(w0, np.float64)
        outdeg = np.bincount(us, minlength=n).astype(np.float64)
        with np.errstate(invalid="ignore", divide="ignore"):
            scale = np.where(w0_node > 0,
                             w0_eff / np.maximum(w0_node, 1e-300), 0.0)
            extra = np.where(w0_node > 0, 0.0, w0_eff)
        w0c = w0c * scale[us] + extra[us] / np.maximum(outdeg[us], 1.0)
        w0_node = w0_eff

    # aggregation matrix: node arrivals from channel weights
    agg = np.zeros((c, n), np.float64)
    agg[np.arange(c), ns] = 1.0

    def cond(state):
        wc, _, it = state
        return jnp.logical_and(jnp.sum(wc) >= w_th, it < iter_th)

    def body(state):
        wc, w_nr, it = state
        w_nr = w_nr + wc @ aggj      # arrivals at nodes this hop (eq. 3)
        wc = wc @ mj                 # drain + continue (eq. 2)
        return wc, w_nr, it + 1

    # fp64 evolution (scoped x64): keeps this oracle and the fused device
    # pipeline (`plan_fast`, fp64 on CPU) within summation-order noise,
    # so tie-tolerance-boundary choice flips cannot separate them.
    with jax.experimental.enable_x64():
        wc = jnp.asarray(w0c)
        mj = jnp.asarray(m)
        aggj = jnp.asarray(agg)
        wcf, w_nr, it = jax.lax.while_loop(
            cond, body, (wc, jnp.asarray(w0_node), jnp.int32(0)))
    w_final = np.zeros(n)
    np.add.at(w_final, ns, np.asarray(wcf))
    p, p_drn_n, _, _ = transition_probabilities(topo, traffic, w, w_drn)
    return NRankResult(w_nr=np.asarray(w_nr), w0=w0_node, w_final=w_final,
                       iterations=int(it), p=p, p_drn=p_drn_n,
                       w_possibility=w)


def nrank(topo: Topology, traffic: np.ndarray,
          w_th: float = W_TH, iter_th: int = ITER_TH,
          use_kernel: bool = False,
          w0: np.ndarray | None = None) -> NRankResult:
    """Full N-Rank: topology + traffic distribution → NR-weights.

    ``w0`` (optional) replaces the eq. (1) initial weights — the online
    re-planner's warm-start carry (previous plan's residual on top of the
    fresh initial weights).
    """
    traffic = np.asarray(traffic, dtype=np.float64)
    if traffic.shape != (topo.num_nodes,) * 2:
        raise ValueError(
            f"traffic shape {traffic.shape} != {(topo.num_nodes,)*2}")
    if use_kernel:
        from repro.kernels.possibility import ops as _pops
        w, w_drn = _pops.possibility_weights(
            topo.distances, traffic, topo.channels)
        w, w_drn = np.asarray(w, np.float64), np.asarray(w_drn, np.float64)
    else:
        w, w_drn = possibility_weights(topo.distances, traffic, topo.channels)
    p, p_drn, a, a_drn = transition_probabilities(topo, traffic, w, w_drn)
    if w0 is None:
        w0 = initial_weights(traffic)
    else:
        w0 = np.asarray(w0, dtype=np.float64)
    w_final, w_nr, it = evolve(a, a_drn, w0, w_th, iter_th)
    return NRankResult(w_nr=w_nr, w0=w0, w_final=w_final, iterations=it,
                       p=p, p_drn=p_drn, w_possibility=w)
