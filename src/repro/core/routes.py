"""Dimension-order routes (XY / YX and their k-dim generalizations).

BiDOR (paper §3.3) chooses between the two DOR routes ``R_0 = XY`` and
``R_1 = YX`` for every ⟨s, d⟩ pair.  On k-dimensional topologies we
generalize to the k! dimension orders; order index 0 is always the
ascending order (X-first — "XY") and order 1 on 2D topologies is YX, so the
paper's binary scheme is the ``orders[:2]`` special case.

Everything here is offline numpy (route tables are computed once and
hard-coded, mirroring the paper's bitmap deployment model).
"""

from __future__ import annotations

import itertools
import numpy as np

from .topology import Topology

__all__ = [
    "dimension_orders",
    "next_hop_table",
    "next_port_table",
    "route_nodes",
    "route_costs",
    "walk_routes",
    "min_rect_contains_channel",
]


def dimension_orders(ndim: int, binary_only: bool = False) -> list[tuple[int, ...]]:
    """All DOR orders.  2D → [(0, 1), (1, 0)] = [XY, YX]."""
    orders = sorted(itertools.permutations(range(ndim)))
    if binary_only:
        # paper-faithful pair: ascending and descending
        return [orders[0], orders[-1]]
    return orders


def _step_dir(cur: np.ndarray, dst: np.ndarray, size: int, wrap: bool) -> np.ndarray:
    """Per-node signed step (−1/0/+1) along one dimension toward dst."""
    delta = dst - cur
    if not wrap:
        return np.sign(delta)
    fwd = (dst - cur) % size
    bwd = (cur - dst) % size
    step = np.where(fwd == 0, 0, np.where(fwd <= bwd, 1, -1))
    return step


def _express_steps(topo: Topology) -> dict[int, list[tuple[int, np.ndarray]]]:
    """Express-hop availability per dimension: dim → [(magnitude, (N, 2)
    bool per node and sign)], magnitudes descending.  Empty dict when the
    topology has only unit-step channels (the common case)."""
    classes = topo._express_classes
    if not classes:
        return {}
    avail = {cls: np.zeros((topo.num_nodes, 2), bool) for cls in classes}
    for u, n in topo.channels:
        k, step = topo._channel_step(int(u), int(n))
        if abs(step) > 1:
            avail[(k, abs(step))][int(u), 0 if step > 0 else 1] = True
    out: dict[int, list[tuple[int, np.ndarray]]] = {}
    for (k, mag), av in sorted(avail.items(), key=lambda kv: -kv[0][1]):
        out.setdefault(k, []).append((mag, av))
    return out


def next_hop_table(topo: Topology, order: tuple[int, ...]) -> np.ndarray:
    """(N, N) int32: next node on the DOR route (cur, dst) → nxt.

    ``table[n, n] == n``.  On wrapping dimensions the minimal direction is
    taken (ties go to +, deterministically).  Where the topology has
    express channels, the walker takes the longest non-overshooting hop
    available at the current node (monotone progress within the active
    dimension, so DOR's turn restrictions — and deadlock freedom — are
    untouched); on unit-step topologies this is exactly the classic
    coordinate walk.
    """
    n = topo.num_nodes
    coords = topo.coords  # (N, ndim)
    cur = coords[:, None, :]  # (N, 1, ndim)
    dst = coords[None, :, :]  # (1, N, ndim)
    nxt_coord = np.broadcast_to(cur, (n, n, topo.ndim)).copy()
    moved = np.zeros((n, n), dtype=bool)
    express = _express_steps(topo)
    for k in order:
        size, wrap = topo.dims[k], topo.wrap[k]
        step = _step_dir(cur[..., k], dst[..., k], size, wrap)
        take = (~moved) & (step != 0)
        mag = np.ones((n, n), dtype=np.int64)
        if k in express and not wrap:
            need = np.abs(dst[..., k] - cur[..., k])  # (N, N)
            for m, av in express[k]:                  # magnitudes desc
                has = np.where(step > 0, av[:, :1], av[:, 1:])  # (N, N)
                use = (mag == 1) & has & (m <= need)
                mag = np.where(use, m, mag)
        nxt_coord[..., k] = np.where(
            take, (nxt_coord[..., k] + step * mag) % size,
            nxt_coord[..., k])
        moved |= take
    # collapse coordinates back to node ids
    table = (nxt_coord * topo.coord_strides).sum(-1).astype(np.int32)
    return table


def next_port_table(topo: Topology, order: tuple[int, ...]) -> np.ndarray:
    """(N, N) int8: output port of the DOR next hop; local port at dst."""
    nh = next_hop_table(topo, order)
    n = topo.num_nodes
    ports = np.full((n, n), topo.port_local, dtype=np.int8)
    neigh = topo.neighbor_table  # (N, P)
    for p in range(topo.num_ports - 1):
        match = (nh == neigh[:, p][:, None]) & (nh != np.arange(n)[:, None])
        ports[match] = p
    return ports


def walk_routes(topo: Topology, order: tuple[int, ...]) -> np.ndarray:
    """(N, N, L+1) int32 node sequences of every DOR route, padded with the
    destination (L = the route horizon — the BFS diameter on unit-step
    topologies; express shortcuts can push BFS distances below route
    lengths, so the horizon is the safe bound)."""
    nh = next_hop_table(topo, order)
    n = topo.num_nodes
    diam = topo.route_horizon
    seq = np.empty((n, n, diam + 1), dtype=np.int32)
    cur = np.broadcast_to(np.arange(n)[:, None], (n, n)).copy()
    dst = np.broadcast_to(np.arange(n)[None, :], (n, n))
    seq[..., 0] = cur
    for h in range(1, diam + 1):
        cur = nh[cur, dst]
        seq[..., h] = cur
    return seq


def route_nodes(topo: Topology, s: int, d: int, order: tuple[int, ...]) -> list[int]:
    """The explicit node sequence s → d under a DOR order (both endpoints
    included, as in the paper's Fig. 7 example)."""
    nh = next_hop_table(topo, order)
    seq = [s]
    cur = s
    for _ in range(topo.num_nodes + 1):
        if cur == d:
            break
        cur = int(nh[cur, d])
        seq.append(cur)
    else:  # pragma: no cover
        raise RuntimeError(f"route {s}->{d} did not terminate")
    return seq


def route_costs(topo: Topology, w_nr: np.ndarray,
                orders: list[tuple[int, ...]]) -> np.ndarray:
    """(len(orders), N, N) cumulative w_NR along every DOR route — eq. (10).

    Cost includes both endpoints (Fig. 7 sums all nodes on the path).
    Vectorized as a table walk: N² routes advance one hop per step.
    """
    n = topo.num_nodes
    w_nr = np.asarray(w_nr, dtype=np.float64)
    diam = topo.route_horizon
    costs = np.empty((len(orders), n, n), dtype=np.float64)
    dst = np.broadcast_to(np.arange(n)[None, :], (n, n))
    for oi, order in enumerate(orders):
        nh = next_hop_table(topo, order)
        cur = np.broadcast_to(np.arange(n)[:, None], (n, n)).copy()
        acc = w_nr[cur].copy()
        for _ in range(diam):
            nxt = nh[cur, dst]
            acc += np.where(nxt != cur, w_nr[nxt], 0.0)
            cur = nxt
        costs[oi] = acc
    return costs


def min_rect_contains_channel(topo: Topology, s: int, d: int,
                              u: int, n: int) -> bool:
    """Literal eq. (4) predicate for 2D meshes: Chan(u,n) ⊂ MinRect(s,d)
    *and* oriented toward d (no detours).  Used by tests to validate the
    general graph predicate in :mod:`repro.core.nrank`."""
    if topo.ndim != 2 or any(topo.wrap):
        raise ValueError("MinRect is defined for non-wrapping 2D meshes")
    (sx, sy), (dx, dy) = topo.coords[s], topo.coords[d]
    (ux, uy), (nx, ny) = topo.coords[u], topo.coords[n]
    lox, hix = min(sx, dx), max(sx, dx)
    loy, hiy = min(sy, dy), max(sy, dy)
    inside = (lox <= ux <= hix and lox <= nx <= hix and
              loy <= uy <= hiy and loy <= ny <= hiy)
    if not inside:
        return False
    # direction consistency: the hop must move toward d
    step_x, step_y = nx - ux, ny - uy
    if step_x != 0:
        return np.sign(step_x) == np.sign(dx - sx) and dx != sx
    return np.sign(step_y) == np.sign(dy - sy) and dy != sy
