"""Traffic matrices — the second input of N-Rank (paper §3.2).

``T[s, d]`` is the fraction of total traffic sourced at node ``s`` destined
to node ``d`` (``Σ T = 1``, zero diagonal).  The synthetic patterns follow
Dally & Towles [3] and the paper's evaluation (§4.2): Uniform, Shuffle,
Permutation, Overturn.  All builders respect the topology's ``io_weights``
so the edge-I/O configuration (Fig. 1c/1d) falls out naturally.
"""

from __future__ import annotations

import numpy as np

from .topology import Topology

__all__ = [
    "uniform",
    "shuffle",
    "permutation",
    "overturn",
    "transpose",
    "hotspot",
    "tornado",
    "alltoall",
    "from_pair_counts",
    "PATTERNS",
]


def _endpoint_weights(topo: Topology) -> np.ndarray:
    w = np.asarray(topo.io_weights, dtype=np.float64)
    if w.sum() <= 0:
        raise ValueError("topology has no I/O-capable nodes")
    return w


def _normalize(t: np.ndarray) -> np.ndarray:
    np.fill_diagonal(t, 0.0)
    s = t.sum()
    if s <= 0:
        raise ValueError("empty traffic matrix")
    return t / s


def uniform(topo: Topology) -> np.ndarray:
    """Uniformly distributed traffic over I/O-weighted endpoint pairs."""
    w = _endpoint_weights(topo)
    return _normalize(np.outer(w, w))


def _bits(n: int) -> int:
    b = 0
    while (1 << b) < n:
        b += 1
    return b


def shuffle(topo: Topology) -> np.ndarray:
    """Perfect shuffle: destination = rotate-left of the source id's bits.

    Endpoints without I/O (weight 0) re-target the nearest following
    I/O-capable node so the pattern stays total on edge-I/O topologies.
    """
    n = topo.num_nodes
    w = _endpoint_weights(topo)
    b = max(_bits(n), 1)
    t = np.zeros((n, n), dtype=np.float64)
    io_nodes = np.nonzero(w > 0)[0]
    for s in io_nodes:
        d = ((s << 1) | (s >> (b - 1))) & ((1 << b) - 1)
        d %= n
        if w[d] <= 0:  # snap to the closest I/O node
            d = int(io_nodes[np.argmin(np.abs(io_nodes - d))])
        if d == s:
            d = int(io_nodes[(np.searchsorted(io_nodes, s) + 1) % len(io_nodes)])
        t[s, d] = w[s]
    return _normalize(t)


def permutation(topo: Topology, seed: int = 0) -> np.ndarray:
    """A fixed random permutation over the I/O-capable nodes (seeded)."""
    w = _endpoint_weights(topo)
    io_nodes = np.nonzero(w > 0)[0]
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(io_nodes))
    # de-fix any fixed points by rotating them
    fixed = np.nonzero(io_nodes[perm] == io_nodes)[0]
    if len(fixed):
        perm[fixed] = np.roll(perm[fixed], 1)
    t = np.zeros((topo.num_nodes,) * 2, dtype=np.float64)
    t[io_nodes, io_nodes[perm]] = w[io_nodes]
    return _normalize(t)


def overturn(topo: Topology) -> np.ndarray:
    """Overturn: each node sends to its spatial complement — the network
    "flipped upside down": coord_k → dims_k − 1 − coord_k."""
    n = topo.num_nodes
    w = _endpoint_weights(topo)
    dims = np.array(topo.dims)
    flipped = dims - 1 - topo.coords
    t = np.zeros((n, n), dtype=np.float64)
    for s in range(n):
        if w[s] <= 0:
            continue
        d = topo.node_id(flipped[s])
        if d == s or w[d] <= 0:
            continue
        t[s, d] = w[s]
    return _normalize(t)


def transpose(topo: Topology) -> np.ndarray:
    """Matrix-transpose pattern: (x, y) → (y, x) (2D only)."""
    if topo.ndim != 2 or topo.dims[0] != topo.dims[1]:
        raise ValueError("transpose needs a square 2D topology")
    n = topo.num_nodes
    w = _endpoint_weights(topo)
    t = np.zeros((n, n), dtype=np.float64)
    for s in range(n):
        if w[s] <= 0:
            continue
        x, y = topo.coords[s]
        d = topo.node_id((y, x))
        if d != s:
            t[s, d] = w[s]
    return _normalize(t)


def tornado(topo: Topology) -> np.ndarray:
    """Tornado: half-way shift along dimension 0 (adversarial on rings)."""
    n = topo.num_nodes
    w = _endpoint_weights(topo)
    t = np.zeros((n, n), dtype=np.float64)
    half = (topo.dims[0] - 1) // 2
    for s in range(n):
        if w[s] <= 0:
            continue
        c = topo.coords[s].copy()
        c[0] = (c[0] + half) % topo.dims[0]
        d = topo.node_id(c)
        if d != s:
            t[s, d] = w[s]
    return _normalize(t)


def hotspot(topo: Topology, hot_frac: float = 0.5,
            num_hot: int = 1, seed: int = 0) -> np.ndarray:
    """Uniform traffic with ``hot_frac`` of it redirected to hot nodes."""
    base = uniform(topo)
    w = _endpoint_weights(topo)
    io_nodes = np.nonzero(w > 0)[0]
    rng = np.random.default_rng(seed)
    hot = rng.choice(io_nodes, size=num_hot, replace=False)
    t = base * (1.0 - hot_frac)
    extra = np.zeros_like(base)
    extra[:, hot] = w[:, None]
    return _normalize(t + _normalize(extra) * hot_frac)


def alltoall(topo: Topology, skew: np.ndarray | None = None) -> np.ndarray:
    """Expert-parallel all-to-all: every I/O node sends to every other,
    optionally skewed per *destination* (hot experts receive more).

    ``skew`` is an (N,) relative weight per destination node (default
    uniform).  This is the ICI collective-scheduling matrix used by the
    linkload analyses and ``examples/qstar_ici_demo.py``.
    """
    w = _endpoint_weights(topo)
    s = np.ones(topo.num_nodes) if skew is None else np.asarray(
        skew, np.float64)
    if s.shape != (topo.num_nodes,):
        raise ValueError(f"skew shape {s.shape} != ({topo.num_nodes},)")
    return _normalize(np.outer(w, w * s))


def from_pair_counts(topo: Topology, counts: np.ndarray) -> np.ndarray:
    """Build T from measured (s, d) packet counts — the paper's 'statistical
    information' path for realistic workloads (§4.1)."""
    t = np.asarray(counts, dtype=np.float64).copy()
    if t.shape != (topo.num_nodes,) * 2:
        raise ValueError(f"counts shape {t.shape} != {(topo.num_nodes,)*2}")
    return _normalize(t)


PATTERNS = {
    "alltoall": alltoall,
    "uniform": uniform,
    "shuffle": shuffle,
    "permutation": permutation,
    "overturn": overturn,
    "transpose": transpose,
    "tornado": tornado,
    "hotspot": hotspot,
}
