"""Deadlock-freedom certification of plan-table routing artifacts.

Q-StaR's predictability claim rests on every deployed table being
deadlock-free.  The planner argues this *by construction* — each route is
a pure dimension-order route inside its own virtual-channel class — but
nothing verified the claim, and nothing at all protects hand-supplied
tables, degraded topologies, or future non-DOR planners.  This module
closes that gap with the classic channel-dependency-graph (CDG) argument
of Dally & Seitz:

* :func:`build_cdg` derives the CDG implied by (``port_tables``,
  ``choice``) over any :class:`~repro.core.topology.Topology` — every
  consecutive channel pair of every routed ⟨s, d⟩ route is a dependency
  edge.  The CDG node is the *virtual channel resource*
  ``(channel, order class, dateline layer)``:

  - **order class** — the simulator dedicates a VC class per dimension
    order (a flit's VC is its route's order index), so routes of
    different orders never block on the same buffer; the CDG therefore
    splits per order, which is exactly why mixing XY and YX pairs (the
    O1Turn hazard) stays deadlock-free here.
  - **dateline layer** — wrap (torus) channels are modelled with the
    standard dateline split: layer 1 is entered when the route crosses a
    wrap channel of that dimension (minimal DOR crosses each dateline at
    most once, so two layers suffice).  This mirrors the dateline VC
    discipline of torus wormhole routing; it is an explicit modelling
    assumption, stated here and in EXPERIMENTS.md.

* :func:`certify_table` runs an **iterative** Tarjan SCC over the CDG
  (explicit stack — no recursion limits at 64×64) and certifies the
  table clean, or — when cycles exist — attempts a **minimal
  turn-prohibition repair**: repeatedly forbid the lowest-weight turn
  inside a cyclic SCC (weight = traffic routed through the turn, scaled
  by the pivot node's N-Rank weight when available, so lightly-ranked
  turns are cut first), re-route the affected pairs onto an alternate
  order whose route avoids every prohibited turn, and shed pairs no
  order can serve.  The outcome is a :class:`Certificate` with verdict
  ``clean`` / ``repaired`` / ``rejected``.

Everything is offline numpy.  The clean-path check is fully vectorized
(one ``O(L·N²)`` table walk + a linear-time SCC), cheap enough to gate
every plan build and every online replan (``benchmarks/run.py
certify_scale``).  The repair path walks routes per pair in Python — it
only ever runs on genuinely broken tables, never in the standard
pipeline.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from .bidor import BiDORTable
from .topology import Topology

__all__ = ["Certificate", "CertificationError", "build_cdg",
           "certify_table", "certify_ports", "apply_repair",
           "cyclic_scc_nodes", "has_cycle_bruteforce"]


class CertificationError(RuntimeError):
    """A routing table failed certification and could not be repaired."""


@dataclasses.dataclass(frozen=True)
class Certificate:
    """Outcome of one deadlock-freedom check.

    ``verdict``: ``"clean"`` (the CDG is acyclic as supplied),
    ``"repaired"`` (cycles were broken by turn prohibition —
    ``choice`` / ``shed`` hold the repaired assignment), or
    ``"rejected"`` (cycles survived the repair budget; the table must
    not be deployed).
    """

    verdict: str
    cdg_nodes: int
    cdg_edges: int
    cyclic_nodes: int             # CDG nodes inside cyclic SCCs (pre-repair)
    prohibited_turns: np.ndarray  # (K, 2) int32 forbidden (chan, chan) turns
    # repaired per-pair assignment; None unless verdict == "repaired"
    choice: np.ndarray | None = None
    shed: np.ndarray | None = None      # (N, N) bool pairs shed by repair
    invalid_pairs: int = 0              # routes leaving the channel graph
    wall_ms: float = 0.0

    @property
    def ok(self) -> bool:
        return self.verdict in ("clean", "repaired")

    @property
    def shed_pairs(self) -> int:
        return int(self.shed.sum()) if self.shed is not None else 0

    # ---- (de)serialization: rides inside plan-cache npz payloads ---- #
    _VERDICTS = ("clean", "repaired", "rejected")

    def as_arrays(self) -> dict[str, np.ndarray]:
        out = {
            "cert_verdict": np.int64(self._VERDICTS.index(self.verdict)),
            "cert_nodes": np.int64(self.cdg_nodes),
            "cert_edges": np.int64(self.cdg_edges),
            "cert_cyclic": np.int64(self.cyclic_nodes),
            "cert_invalid": np.int64(self.invalid_pairs),
            "cert_prohibited": np.asarray(self.prohibited_turns,
                                          np.int32).reshape(-1, 2),
        }
        if self.choice is not None:
            out["cert_choice"] = np.asarray(self.choice, np.int8)
        if self.shed is not None:
            out["cert_shed"] = np.asarray(self.shed, bool)
        return out

    @classmethod
    def from_arrays(cls, arrays) -> "Certificate | None":
        if "cert_verdict" not in arrays:
            return None     # pre-certifier payload: caller re-certifies
        return cls(
            verdict=cls._VERDICTS[int(arrays["cert_verdict"])],
            cdg_nodes=int(arrays["cert_nodes"]),
            cdg_edges=int(arrays["cert_edges"]),
            cyclic_nodes=int(arrays["cert_cyclic"]),
            invalid_pairs=int(arrays["cert_invalid"]),
            prohibited_turns=np.asarray(arrays["cert_prohibited"],
                                        np.int32).reshape(-1, 2),
            choice=(np.asarray(arrays["cert_choice"], np.int8)
                    if "cert_choice" in arrays else None),
            shed=(np.asarray(arrays["cert_shed"], bool)
                  if "cert_shed" in arrays else None))

    def trace_args(self) -> dict:
        """Compact JSON-able summary for trace instants / metrics."""
        return {"verdict": self.verdict, "nodes": self.cdg_nodes,
                "edges": self.cdg_edges, "cyclic": self.cyclic_nodes,
                "prohibited": int(self.prohibited_turns.shape[0]),
                "shed": self.shed_pairs, "invalid": self.invalid_pairs,
                "wall_ms": round(self.wall_ms, 3)}


# --------------------------------------------------------------------- #
# channel attributes (dateline layering) + node-id packing
# --------------------------------------------------------------------- #
def _channel_geometry(topo: Topology):
    """Per-channel (dimension, is-wrap) arrays, vectorized."""
    u, v = topo.channels[:, 0], topo.channels[:, 1]
    delta = topo.coords[v] - topo.coords[u]          # (C, ndim)
    dim = np.abs(delta).argmax(axis=1).astype(np.int64)
    mag = np.abs(delta[np.arange(delta.shape[0]), dim])
    wrap = np.asarray(topo.wrap, bool)
    dims = np.asarray(topo.dims, np.int64)
    # a wrap link's raw coordinate delta spans the whole dimension; only
    # dimensions of extent > 2 have distinct wrap links (the grid builder
    # skips duplicates at extent 2)
    is_wrap = wrap[dim] & (mag == dims[dim] - 1) & (dims[dim] > 2)
    return dim, is_wrap


def _chan_lut(topo: Topology) -> np.ndarray:
    lut = np.full((topo.num_nodes, topo.num_nodes), -1, np.int64)
    lut[topo.channels[:, 0], topo.channels[:, 1]] = np.arange(
        topo.num_channels)
    return lut


def _next_tables(topo: Topology, port_tables: np.ndarray) -> np.ndarray:
    """(O, N, N) next-node tables implied by arbitrary port tables.

    The local port maps to the node itself (``neighbor_table``
    convention), so a route parks on its destination exactly like
    :func:`repro.core.routes.walk_routes`; ports with no channel resolve
    to −1 (an invalid marker the walkers treat as a broken route).
    """
    neigh = topo.neighbor_table                       # (N, P)
    n = topo.num_nodes
    pt = np.clip(np.asarray(port_tables, np.int64), 0, topo.num_ports - 1)
    return neigh[np.arange(n)[:, None], pt].astype(np.int64)


# CDG node id: ((channel * num_orders) + order class) * 2 + layer.
def _pack(cid, cls, layer, num_orders):
    return 2 * (cid * num_orders + cls) + layer


def _unpack_channel(node, num_orders):
    return (node // 2) // num_orders


# --------------------------------------------------------------------- #
# CDG construction (vectorized)
# --------------------------------------------------------------------- #
def build_cdg(topo: Topology, port_tables: np.ndarray,
              choice: np.ndarray, *,
              active: np.ndarray | None = None,
              traffic: np.ndarray | None = None,
              max_hops: int | None = None):
    """Channel-dependency graph of a routed table.

    Walks every active ⟨s, d⟩ route through its chosen order's port
    table (``O(L·N²)`` numpy, no per-pair Python) and accumulates the
    consecutive-channel dependency edges over the
    ``(channel, order class, dateline layer)`` node space (see the
    module docstring).

    Returns ``(edges, weights, invalid)``: unique ``(E, 2)`` int64 edge
    array over packed node ids, per-edge float64 weight (traffic routed
    through the turn; pair count when ``traffic`` is None), and the
    (N, N) bool mask of invalid pairs — routes that leave the channel
    graph or fail to reach their destination within ``max_hops``.
    """
    n = topo.num_nodes
    num_orders = int(np.asarray(port_tables).shape[0])
    choice = np.asarray(choice, np.int64)
    if active is None:
        active = ~np.eye(n, dtype=bool)
    else:
        active = np.asarray(active, bool) & ~np.eye(n, dtype=bool)
    hops = int(max_hops) if max_hops is not None else max(
        topo.route_horizon, 1)
    dim, is_wrap = _channel_geometry(topo)
    lut = _chan_lut(topo)
    nxt_tables = _next_tables(topo, port_tables)      # (O, N, N)
    w = (np.asarray(traffic, np.float64) if traffic is not None
         else np.ones((n, n)))

    src = np.broadcast_to(np.arange(n)[:, None], (n, n))
    dst = np.broadcast_to(np.arange(n)[None, :], (n, n))
    cur = src.copy()
    live = active.copy()                # still walking, still valid
    invalid = np.zeros((n, n), bool)
    prev_node = np.full((n, n), -1, np.int64)   # previous CDG node id
    wrapped = np.zeros((n, n), np.int64)        # per-dim wrap bitmask
    edge_chunks: list[np.ndarray] = []
    weight_chunks: list[np.ndarray] = []

    for _ in range(hops):
        nh = nxt_tables[choice, cur, dst]
        moving = live & (nh != cur)
        if not moving.any():
            break
        bad = moving & (nh < 0)
        cid = np.where(moving & ~bad, lut[cur, np.where(nh >= 0, nh, 0)],
                       -1)
        bad |= moving & (cid < 0)
        invalid |= bad
        live &= ~bad
        moving &= ~bad
        if moving.any():
            safe_cid = np.maximum(cid, 0)
            k = dim[safe_cid]
            wrap_hop = moving & is_wrap[safe_cid]
            layer = ((wrapped >> k) & 1) | wrap_hop.astype(np.int64)
            node = _pack(cid, choice, layer, num_orders)
            has_prev = moving & (prev_node >= 0)
            if has_prev.any():
                edge_chunks.append(np.stack(
                    [prev_node[has_prev], node[has_prev]], axis=-1))
                weight_chunks.append(w[src[has_prev], dst[has_prev]])
            wrapped = np.where(wrap_hop, wrapped | (1 << k), wrapped)
            prev_node = np.where(moving, node, prev_node)
        cur = np.where(moving, nh, cur)
        live &= (cur != dst)

    # pairs still short of their destination after the hop budget:
    # parked early (bogus local port) or non-terminating
    invalid |= live
    num_nodes = 2 * num_orders * topo.num_channels
    if edge_chunks:
        edges = np.concatenate(edge_chunks)
        wts = np.concatenate(weight_chunks)
        keys = edges[:, 0] * num_nodes + edges[:, 1]
        uniq, inv = np.unique(keys, return_inverse=True)
        weights = np.zeros(uniq.shape[0])
        np.add.at(weights, inv, wts)
        edges = np.stack([uniq // num_nodes, uniq % num_nodes], axis=-1)
    else:
        edges = np.zeros((0, 2), np.int64)
        weights = np.zeros(0)
    return edges, weights, invalid


# --------------------------------------------------------------------- #
# cycle detection: iterative Tarjan + the brute-force oracle
# --------------------------------------------------------------------- #
def cyclic_scc_nodes(num_nodes: int, edges: np.ndarray) -> np.ndarray:
    """Bool mask of CDG nodes on some dependency cycle.

    Tarjan's strongly-connected-components algorithm with an explicit
    stack (no recursion — a 64×64 torus CDG has ~130k nodes, far past
    Python's recursion limit).  A node is cyclic iff its SCC has size
    > 1 or it carries a self-loop.
    """
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    cyclic = np.zeros(num_nodes, bool)
    if edges.shape[0] == 0:
        return cyclic
    order = np.argsort(edges[:, 0], kind="stable")
    heads, tails = edges[order, 0], edges[order, 1]
    starts = np.searchsorted(heads, np.arange(num_nodes + 1))
    cyclic[edges[edges[:, 0] == edges[:, 1], 0]] = True   # self-loops

    UNVISITED = -1
    index = np.full(num_nodes, UNVISITED, np.int64)
    low = np.zeros(num_nodes, np.int64)
    on_stack = np.zeros(num_nodes, bool)
    stack: list[int] = []
    counter = 0
    # only nodes with outgoing edges can root a non-trivial SCC, but the
    # DFS must still visit edge *targets*; iterating heads suffices since
    # an SCC of size > 1 has every node on an edge head
    for root in np.unique(heads):
        root = int(root)
        if index[root] != UNVISITED:
            continue
        work = [(root, int(starts[root]))]
        index[root] = low[root] = counter
        counter += 1
        stack.append(root)
        on_stack[root] = True
        while work:
            v, ei = work[-1]
            if ei < starts[v + 1]:
                work[-1] = (v, ei + 1)
                u = int(tails[ei])
                if index[u] == UNVISITED:
                    index[u] = low[u] = counter
                    counter += 1
                    stack.append(u)
                    on_stack[u] = True
                    work.append((u, int(starts[u])))
                elif on_stack[u]:
                    low[v] = min(low[v], index[u])
            else:
                work.pop()
                if work:
                    p = work[-1][0]
                    low[p] = min(low[p], low[v])
                if low[v] == index[v]:          # v roots an SCC
                    comp = []
                    while True:
                        u = stack.pop()
                        on_stack[u] = False
                        comp.append(u)
                        if u == v:
                            break
                    if len(comp) > 1:
                        cyclic[comp] = True
    return cyclic


def has_cycle_bruteforce(num_nodes: int, edges: np.ndarray) -> bool:
    """Brute-force cycle existence via DFS back-edge detection.

    The property-test oracle (``tests/test_certify.py``): an independent,
    obviously-correct implementation the Tarjan verdict is checked
    against on small random graphs.  Iterative (explicit stack), with
    the classic white/gray/black coloring.
    """
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    adj: list[list[int]] = [[] for _ in range(num_nodes)]
    for a, b in edges:
        adj[int(a)].append(int(b))
    color = np.zeros(num_nodes, np.int8)        # 0 white 1 gray 2 black
    for root in range(num_nodes):
        if color[root]:
            continue
        work = [(root, 0)]
        color[root] = 1
        while work:
            v, ei = work[-1]
            if ei < len(adj[v]):
                work[-1] = (v, ei + 1)
                u = adj[v][ei]
                if color[u] == 1:
                    return True                 # back edge: cycle
                if color[u] == 0:
                    color[u] = 1
                    work.append((u, 0))
            else:
                color[v] = 2
                work.pop()
    return False


# --------------------------------------------------------------------- #
# certification + repair
# --------------------------------------------------------------------- #
def certify_ports(topo: Topology, port_tables: np.ndarray,
                  choice: np.ndarray, *,
                  unroutable: np.ndarray | None = None,
                  traffic: np.ndarray | None = None,
                  w_nr: np.ndarray | None = None,
                  repair: bool = True,
                  max_repair_rounds: int = 64,
                  tracer=None, label: str = "") -> Certificate:
    """Certify (or repair) an arbitrary (``port_tables``, ``choice``).

    Args:
      port_tables: (O, N, N) int next-output-port tables.
      choice: (N, N) per-pair order index.
      unroutable: pairs already shed upstream — excluded from the CDG
        (their traffic never enters the network).
      traffic: turn weights for the repair policy (uniform when None).
      w_nr: per-node N-Rank weights; when given, a turn's repair weight
        is scaled by the weight of the node the turn pivots on, so
        repair prohibits the lowest-N-Rank-weight turns first.
      repair: attempt turn-prohibition repair on a cyclic CDG; False
        certifies only (verdict ``clean`` or ``rejected``).
      tracer: optional :class:`repro.obs.trace.TraceWriter`; emits a
        ``certify`` span plus a per-check verdict instant.

    Returns a :class:`Certificate`.  Raising on rejection is the
    caller's policy (the plan gates raise :class:`CertificationError`).
    """
    t0 = time.perf_counter()
    tr0 = tracer.now_us() if tracer is not None and tracer.enabled else 0.0
    n = topo.num_nodes
    port_tables = np.asarray(port_tables)
    num_orders = int(port_tables.shape[0])
    choice = np.asarray(choice, np.int64)
    active = ~np.eye(n, dtype=bool)
    if unroutable is not None:
        active &= ~np.asarray(unroutable, bool)
    # arbitrary tables may take non-minimal paths; the N-hop cap keeps
    # the walk finite on ANY table, while well-formed DOR-like tables
    # (local port on the diagonal) get the tight route-horizon bound
    hops = max(topo.route_horizon, 1) if _ejects_at_destination(
        topo, port_tables) else n
    edges, _, invalid = build_cdg(
        topo, port_tables, choice, active=active, traffic=traffic,
        max_hops=hops)
    num_cdg_nodes = 2 * num_orders * topo.num_channels
    cyc = cyclic_scc_nodes(num_cdg_nodes, edges)
    cyclic0 = int(cyc.sum())

    if cyclic0 == 0 or not repair:
        cert = Certificate(
            verdict="clean" if cyclic0 == 0 else "rejected",
            cdg_nodes=num_cdg_nodes, cdg_edges=int(edges.shape[0]),
            cyclic_nodes=cyclic0,
            prohibited_turns=np.zeros((0, 2), np.int32),
            invalid_pairs=int(invalid.sum()),
            wall_ms=(time.perf_counter() - t0) * 1e3)
    else:
        cert = _repair(topo, port_tables, choice, active, traffic, w_nr,
                       hops, max_repair_rounds, num_cdg_nodes,
                       int(edges.shape[0]), cyclic0, int(invalid.sum()),
                       t0)
    if tracer is not None and tracer.enabled:
        tracer.complete("certify", tr0, tracer.now_us() - tr0,
                        cat="certify",
                        args=dict(cert.trace_args(), label=label))
        tracer.instant(f"certify_{cert.verdict}", cat="certify",
                       args=dict(cert.trace_args(), label=label))
    return cert


def _ejects_at_destination(topo: Topology,
                           port_tables: np.ndarray) -> bool:
    """Every order parks routes on their destination (local port on the
    (d, d) diagonal) — the precondition for the route-horizon hop cap."""
    idx = np.arange(topo.num_nodes)
    diag = np.asarray(port_tables)[..., idx, idx]
    return bool((diag == topo.port_local).all())


def _route_turns(nxt_tables, lut, dim, is_wrap, num_orders,
                 oi: int, cls: int, s: int, d: int, max_hops: int):
    """One route's packed (node, node) turn list; None if invalid."""
    cur, turns, prev, wrapped = s, [], -1, 0
    for _ in range(max_hops):
        if cur == d:
            return turns
        nh = int(nxt_tables[oi, cur, d])
        if nh == cur or nh < 0:
            return None
        c = int(lut[cur, nh])
        if c < 0:
            return None
        k = int(dim[c])
        wrap_hop = bool(is_wrap[c])
        layer = ((wrapped >> k) & 1) | int(wrap_hop)
        node = _pack(c, cls, layer, num_orders)
        if prev >= 0:
            turns.append((prev, node))
        if wrap_hop:
            wrapped |= 1 << k
        prev = node
        cur = nh
    return turns if cur == d else None


def _repair(topo, port_tables, choice, active, traffic, w_nr, hops,
            max_rounds, num_cdg_nodes, edges0, cyclic0, invalid0, t0):
    """Turn-prohibition repair (pair-level Python; broken tables only)."""
    n = topo.num_nodes
    num_orders = int(port_tables.shape[0])
    dim, is_wrap = _channel_geometry(topo)
    lut = _chan_lut(topo)
    nxt_tables = _next_tables(topo, port_tables)
    t = (np.asarray(traffic, np.float64) if traffic is not None
         else np.ones((n, n)))
    wn = np.asarray(w_nr, np.float64) if w_nr is not None else None
    chan_head = topo.channels[:, 1]     # turn (c1 -> c2) pivots on head(c1)

    choice = np.asarray(choice, np.int64).copy()
    shed = np.zeros((n, n), bool)
    prohibited: set[tuple[int, int]] = set()    # channel-level turns

    def pair_turns(oi, s, d):
        return _route_turns(nxt_tables, lut, dim, is_wrap, num_orders,
                            oi, oi, s, d, hops)

    def uses_prohibited(turns):
        return any((_unpack_channel(a, num_orders),
                    _unpack_channel(b, num_orders)) in prohibited
                   for a, b in turns)

    def try_reroute(s, d):
        """Move (s, d) to an order avoiding all prohibited turns, else
        shed it."""
        for oi in range(num_orders):
            if oi == int(choice[s, d]):
                continue
            alt = pair_turns(oi, s, d)
            if alt is None or uses_prohibited(alt):
                continue
            choice[s, d] = oi
            routes[(s, d)] = alt
            return
        shed[s, d] = True
        del routes[(s, d)]

    # per-pair turn lists of the CURRENT assignment
    routes: dict[tuple[int, int], list] = {}
    for s in range(n):
        for d in range(n):
            if not active[s, d]:
                continue
            turns = pair_turns(int(choice[s, d]), s, d)
            if turns is None:
                shed[s, d] = True       # invalid route: shed outright
            else:
                routes[(s, d)] = turns

    for _ in range(max_rounds):
        # rebuild the edge multiset + weights from live routes
        edge_w: dict[tuple[int, int], float] = {}
        edge_pairs: dict[tuple[int, int], list] = {}
        for (s, d), turns in routes.items():
            for e in turns:
                edge_w[e] = edge_w.get(e, 0.0) + float(t[s, d])
                edge_pairs.setdefault(e, []).append((s, d))
        if not edge_w:
            break
        earr = np.array(sorted(edge_w), np.int64).reshape(-1, 2)
        cyc = cyclic_scc_nodes(num_cdg_nodes, earr)
        in_cycle = [e for e in edge_w if cyc[e[0]] and cyc[e[1]]]
        if not in_cycle:
            break
        # lowest-weight turn inside a cyclic SCC; N-Rank scaling prefers
        # cutting turns that pivot on lightly-ranked routers
        def turn_weight(e):
            wgt = edge_w[e]
            if wn is not None:
                wgt *= float(wn[chan_head[_unpack_channel(e[0],
                                                          num_orders)]])
            return (wgt, e)             # deterministic tie-break
        cut = min(in_cycle, key=turn_weight)
        prohibited.add((_unpack_channel(cut[0], num_orders),
                        _unpack_channel(cut[1], num_orders)))
        # re-route every pair whose current route now uses a prohibited
        # turn (the channel-level ban can hit several layered edges)
        for (s, d) in [p for e in list(edge_pairs)
                       if (_unpack_channel(e[0], num_orders),
                           _unpack_channel(e[1], num_orders)) in prohibited
                       for p in edge_pairs[e]]:
            if (s, d) in routes and uses_prohibited(routes[(s, d)]):
                try_reroute(s, d)
    else:
        return Certificate(
            verdict="rejected", cdg_nodes=num_cdg_nodes, cdg_edges=edges0,
            cyclic_nodes=cyclic0,
            prohibited_turns=np.array(sorted(prohibited),
                                      np.int32).reshape(-1, 2),
            invalid_pairs=invalid0,
            wall_ms=(time.perf_counter() - t0) * 1e3)

    # final verification of the repaired assignment
    final_edges = set()
    for turns in routes.values():
        final_edges.update(turns)
    earr = (np.array(sorted(final_edges), np.int64).reshape(-1, 2)
            if final_edges else np.zeros((0, 2), np.int64))
    verdict = ("rejected" if cyclic_scc_nodes(num_cdg_nodes, earr).any()
               else "repaired")
    return Certificate(
        verdict=verdict, cdg_nodes=num_cdg_nodes, cdg_edges=edges0,
        cyclic_nodes=cyclic0,
        prohibited_turns=(np.array(sorted(prohibited),
                                   np.int32).reshape(-1, 2)
                          if prohibited else np.zeros((0, 2), np.int32)),
        choice=choice.astype(np.int8) if verdict == "repaired" else None,
        shed=shed if verdict == "repaired" else None,
        invalid_pairs=invalid0,
        wall_ms=(time.perf_counter() - t0) * 1e3)


def certify_table(topo: Topology, table: BiDORTable, *,
                  traffic: np.ndarray | None = None,
                  w_nr: np.ndarray | None = None,
                  repair: bool = True,
                  tracer=None, label: str = "") -> Certificate:
    """Certify a :class:`~repro.core.bidor.BiDORTable` (see
    :func:`certify_ports`).  Pairs the table already sheds
    (``table.unroutable``) are excluded from the CDG."""
    return certify_ports(topo, table.port_tables, table.choice,
                         unroutable=table.unroutable, traffic=traffic,
                         w_nr=w_nr, repair=repair, tracer=tracer,
                         label=label)


def apply_repair(table: BiDORTable, cert: Certificate) -> BiDORTable:
    """Fold a ``repaired`` certificate back into the table artifact:
    the repaired choice replaces the original, and repair-shed pairs
    merge into ``unroutable`` (admission control sheds them upstream)."""
    if cert.verdict != "repaired":
        raise ValueError(f"certificate verdict is {cert.verdict!r}")
    unroutable = cert.shed.copy()
    if table.unroutable is not None:
        unroutable |= table.unroutable
    return dataclasses.replace(table, choice=cert.choice,
                               unroutable=unroutable)
