"""Q-StaR core: the paper's contribution (N-Rank + BiDOR) in JAX/numpy."""

from .topology import (Topology, mesh2d, mesh2d_edge_io, torus, multipod,
                       cmesh, express_mesh, fault_region_mesh)
from . import traffic
from .nrank import NRankResult, nrank, nrank_channel, possibility_weights
from .bidor import BiDORTable, bidor, bidor_k, dor_table
from .qstar import (QStarPlan, build_plan, predicted_node_load, link_load,
                    link_load_stats)
from .plan_fast import (build_plan_fast, build_plans_batched, gate_plan,
                        joint_possibility_fast)
from .routes import dimension_orders, route_nodes, next_port_table
from .certify import (Certificate, CertificationError, apply_repair,
                      build_cdg, certify_ports, certify_table,
                      cyclic_scc_nodes, has_cycle_bruteforce)

__all__ = [
    "Topology", "mesh2d", "mesh2d_edge_io", "torus", "multipod",
    "cmesh", "express_mesh", "fault_region_mesh",
    "traffic",
    "NRankResult", "nrank", "nrank_channel", "possibility_weights",
    "BiDORTable", "bidor", "bidor_k", "dor_table",
    "QStarPlan", "build_plan", "predicted_node_load", "link_load",
    "link_load_stats",
    "build_plan_fast", "build_plans_batched", "gate_plan",
    "joint_possibility_fast",
    "dimension_orders", "route_nodes", "next_port_table",
    "Certificate", "CertificationError", "apply_repair", "build_cdg",
    "certify_ports", "certify_table", "cyclic_scc_nodes",
    "has_cycle_bruteforce",
]
