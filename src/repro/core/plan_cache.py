"""Persistent content-addressed cache of Q-StaR plans.

A mega-sweep re-plans the same (topology, traffic, fault-mask) triples
over and over: every re-run of a campaign, every resumed job, and every
scenario whose initial plan equals a previous cell's rebuilds bit-identical
choice tables from scratch.  This module makes the plan a cacheable
artifact:

* **Keying is by content, not identity.**  :func:`plan_key` hashes the
  topology fingerprint (name, dims, wrap, coords, channels, io_weights,
  channel_bw — everything the plan math reads), the traffic matrix bytes,
  the down-channel fault mask, and the plan hyper-parameters
  (``k_orders``, ``w_th``, ``iter_th``, resolved precision).  Two specs
  that build the same plan share one entry, whatever Python objects they
  came from.
* **Entries are atomic npz files.**  One ``<key>.npz`` per plan under the
  cache directory, written to a temp name and ``os.replace``d into place
  (the ``repro.train.checkpoint`` idiom) — readers never see a partial
  entry, and concurrent writers of the same key are idempotent.
* **Only cold (``w0``-less) builds are cached.**  A warm-started replan
  depends on the carried fixed point, which is run-history, not content —
  caching it would alias different histories onto one key.
* **Stats are first-class.**  :attr:`PlanCache.stats` counts hits, misses
  and stores; ``repro.core.plan_fast`` bumps ``device_builds`` whenever a
  jitted plan computation actually runs, so tests can assert a warm
  re-run skipped compilation entirely.

The cache stores the *plan outputs* (choice/costs/unroutable + the N-Rank
arrays); trace-time statics (port tables, dimension orders) are rebuilt
from the topology via :func:`repro.core.plan_fast.plan_statics`, which is
host-side and cheap.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile

import numpy as np

from .bidor import BiDORTable
from .certify import Certificate
from .nrank import NRankResult
from .qstar import QStarPlan
from .topology import Topology

__all__ = ["PlanCache", "plan_key", "topology_fingerprint"]


def _hash_update_array(h, a: np.ndarray) -> None:
    a = np.ascontiguousarray(a)
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(a.tobytes())


def topology_fingerprint(topo: Topology) -> str:
    """Stable content hash of everything the planner reads from a
    topology (also the manifest key of campaign-service jobs)."""
    h = hashlib.sha256()
    h.update(topo.name.encode())
    h.update(json.dumps([list(topo.dims),
                         [bool(w) for w in topo.wrap]]).encode())
    for a in (topo.coords, topo.channels, topo.io_weights,
              topo.channel_bw):
        _hash_update_array(h, np.asarray(a))
    return h.hexdigest()


def plan_key(topo: Topology, traffic: np.ndarray, *,
             down_channels=None, k_orders: bool = False,
             w_th: float, iter_th: int, precision: str) -> str:
    """Content key of one cold plan build (see module docstring)."""
    h = hashlib.sha256()
    h.update(topology_fingerprint(topo).encode())
    _hash_update_array(h, np.asarray(traffic, np.float64))
    if down_channels is None:
        down = np.zeros(0, np.int64)
    else:
        down = np.asarray(down_channels)
        if down.dtype == bool:
            down = np.nonzero(down)[0]
        down = np.unique(down.astype(np.int64))
    _hash_update_array(h, down)
    h.update(json.dumps({"k_orders": bool(k_orders),
                         "w_th": float(w_th), "iter_th": int(iter_th),
                         "precision": str(precision)}).encode())
    return h.hexdigest()


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    stores: int = 0
    # bumped by repro.core.plan_fast whenever a jitted plan computation
    # actually executes — the "did we re-jit / re-plan?" test signal
    device_builds: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class PlanCache:
    """On-disk plan store; safe to share between jobs and processes."""

    def __init__(self, directory: str):
        self.dir = str(directory)
        os.makedirs(self.dir, exist_ok=True)
        self.stats = CacheStats()

    # ---------------------------------------------------------------- #
    def _path(self, key: str) -> str:
        return os.path.join(self.dir, f"{key}.npz")

    def __contains__(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def get(self, key: str, topo: Topology) -> QStarPlan | None:
        """Load the plan stored under ``key`` (None on miss).

        ``topo`` must be the topology the key was computed from — the
        statics (port tables, orders) are rebuilt from it rather than
        stored, so an entry is a few small arrays, not a topology dump.
        """
        path = self._path(key)
        if not os.path.exists(path):
            self.stats.misses += 1
            return None
        from .plan_fast import plan_statics
        with np.load(path, allow_pickle=False) as z:
            d = {k: z[k] for k in z.files}
        statics = plan_statics(topo, binary_only=not bool(d["k_orders"]))
        unroutable = (d["unroutable"].astype(bool)
                      if d["unroutable"].size else None)
        table = BiDORTable(
            choice=d["choice"].astype(np.int8), orders=statics.orders,
            costs=d["costs"], port_tables=statics.port_tables,
            unroutable=unroutable)
        nr = NRankResult(
            w_nr=d["w_nr"], w0=d["w0"], w_final=d["w_final"],
            iterations=int(d["iterations"]), p=d["p"], p_drn=d["p_drn"],
            w_possibility=d["w_possibility"])
        self.stats.hits += 1
        return QStarPlan(topology=topo, traffic=d["traffic"], nrank=nr,
                         table=table)

    def get_cert(self, key: str) -> Certificate | None:
        """Deadlock-freedom certificate stored alongside the plan.

        Returns None on a cache miss *or* when the entry predates the
        certifier (no ``cert_*`` arrays) — either way the caller must
        re-certify before deploying the plan.  Does not touch hit/miss
        stats; certificate reads piggyback on a prior :meth:`get`.
        """
        path = self._path(key)
        if not os.path.exists(path):
            return None
        with np.load(path, allow_pickle=False) as z:
            d = {k: z[k] for k in z.files if k.startswith("cert_")}
        return Certificate.from_arrays(d)

    def put(self, key: str, plan: QStarPlan, *,
            k_orders: bool = False,
            cert: Certificate | None = None) -> None:
        """Store a plan atomically (idempotent for a given key).

        ``cert`` rides inside the entry so admission of a cached plan
        can reuse the stored verdict; it defaults to the certificate the
        build gate attached to the plan itself.
        """
        path = self._path(key)
        if os.path.exists(path):
            return
        if cert is None:
            cert = plan.cert
        t = plan.table
        nr = plan.nrank
        payload = dict(
            choice=t.choice,
            costs=np.asarray(t.costs, np.float64),
            unroutable=(t.unroutable if t.unroutable is not None
                        else np.zeros(0, bool)),
            w_nr=np.asarray(nr.w_nr, np.float64),
            w0=np.asarray(nr.w0, np.float64),
            w_final=np.asarray(nr.w_final, np.float64),
            iterations=np.int64(nr.iterations),
            p=np.asarray(nr.p, np.float64),
            p_drn=np.asarray(nr.p_drn, np.float64),
            w_possibility=np.asarray(nr.w_possibility, np.float64),
            traffic=np.asarray(plan.traffic, np.float64),
            k_orders=np.bool_(k_orders),
        )
        if cert is not None:
            payload.update(cert.as_arrays())
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **payload)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        self.stats.stores += 1
