"""Serving substrate: caches, prefill/decode steps, batch engine."""

from repro.serve.engine import ServeEngine, make_prefill, make_serve_step

__all__ = ["ServeEngine", "make_prefill", "make_serve_step"]
