"""Serving: prefill + decode steps and a simple batched continuous engine.

``make_serve_step``/``make_prefill`` produce the jitted functions the
dry-run lowers for the ``decode_*``/``prefill_*`` shapes.  ``ServeEngine``
is the runnable example driver: static batch, greedy sampling, per-slot
lengths — enough to serve batched requests end-to-end on CPU.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.models.common import ModelConfig


def make_prefill(cfg: ModelConfig):
    mod = registry.model_module(cfg)

    def prefill(params, tokens, cache, **kw):
        return mod.prefill(cfg, params, tokens, cache, **kw)

    return jax.jit(prefill)


def make_serve_step(cfg: ModelConfig):
    """One-token decode for the whole batch (the dry-run ``serve_step``)."""
    mod = registry.model_module(cfg)

    def serve_step(params, tokens, cache, index, **kw):
        logits, cache = mod.decode_step(cfg, params, tokens, cache, index,
                                        **kw)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return jax.jit(serve_step)


@dataclasses.dataclass
class ServeEngine:
    """Greedy batched decoding over a fixed slot batch."""

    cfg: ModelConfig
    params: object
    max_len: int

    def __post_init__(self):
        self._prefill = make_prefill(self.cfg)
        self._step = make_serve_step(self.cfg)

    def generate(self, prompts: np.ndarray, num_tokens: int,
                 enc_out=None) -> np.ndarray:
        """prompts: (B, P) int32 → (B, num_tokens) generated ids."""
        b, plen = prompts.shape
        cache = registry.init_cache(self.cfg, b, self.max_len)
        kw = {"enc_out": enc_out} if self.cfg.family == "encdec" else {}
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      cache, **kw)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = [np.asarray(tok)]
        index = plen
        for _ in range(num_tokens - 1):
            tok, cache = self._step(self.params, tok, cache,
                                    jnp.int32(index), **kw)
            out.append(np.asarray(tok))
            index += 1
        return np.concatenate(out, axis=1)
