"""InternLM2-1.8B [arXiv:2403.17297] — dense GQA."""

from repro.models.common import ModelConfig
from repro.configs.base import ArchSpec, FULL_ATTN_SHAPES, register

FULL = ModelConfig(
    name="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92544, head_dim=128, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="internlm2-1.8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, dtype="float32",
    attn_q_chunk=16, attn_kv_chunk=16, remat=False,
)

register(ArchSpec(
    arch_id="internlm2-1.8b", full=FULL, smoke=SMOKE,
    shapes=FULL_ATTN_SHAPES, skipped_shapes=("long_500k",),
    notes="pure full-attention arch: long_500k skipped",
))
