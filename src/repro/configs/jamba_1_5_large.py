"""Jamba-1.5-Large 398B [arXiv:2403.19887] — hybrid Mamba+attention MoE.

1 attention layer per 8 (attn_period=8), MoE every other layer (16 experts,
top-2).  72 layers = 9 scanned super-blocks.  Sub-quadratic (mamba-dominant)
⇒ runs the long_500k shape.
"""

from repro.models.common import ModelConfig
from repro.configs.base import ArchSpec, SUBQUADRATIC_SHAPES, register

FULL = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536, head_dim=128,
    moe_experts=16, moe_topk=2, moe_period=2,
    attn_period=8, mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    rope_theta=10_000.0, capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    moe_experts=4, moe_topk=2, moe_period=2, attn_period=4,
    mamba_d_state=4, mamba_d_conv=4, mamba_expand=2, mamba_chunk=8,
    dtype="float32", attn_q_chunk=16, attn_kv_chunk=16, remat=False,
    capacity_factor=2.0,
)

register(ArchSpec(
    arch_id="jamba-1.5-large-398b", full=FULL, smoke=SMOKE,
    shapes=SUBQUADRATIC_SHAPES, skipped_shapes=(),
    notes="hybrid: attention KV only every 8th layer; long_500k runs",
))
