"""DBRX-132B [hf:databricks/dbrx-base] — fine-grained MoE, 16 experts top-4."""

from repro.models.common import ModelConfig
from repro.configs.base import ArchSpec, FULL_ATTN_SHAPES, register

FULL = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352, head_dim=128,
    moe_experts=16, moe_topk=4, moe_period=1,
    rope_theta=500_000.0, capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="dbrx-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab=256,
    moe_experts=4, moe_topk=2, moe_period=1, capacity_factor=2.0,
    dtype="float32", attn_q_chunk=16, attn_kv_chunk=16, remat=False,
)

register(ArchSpec(
    arch_id="dbrx-132b", full=FULL, smoke=SMOKE,
    shapes=FULL_ATTN_SHAPES, skipped_shapes=("long_500k",),
    notes="expert-parallel all-to-all — primary Q-StaR collective target; "
          "full attention ⇒ long_500k skipped",
))
