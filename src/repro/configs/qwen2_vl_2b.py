"""Qwen2-VL-2B [arXiv:2409.12191] — VLM backbone with M-RoPE.

The vision frontend is a stub: input_specs() supplies merged patch/token
embeddings plus (3, B, S) t/h/w position ids (dynamic resolution collapses
to position bookkeeping, which M-RoPE consumes).
"""

from repro.models.common import ModelConfig
from repro.configs.base import ArchSpec, FULL_ATTN_SHAPES, register

FULL = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936, head_dim=128,
    mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, mrope_sections=(2, 3, 3),
    dtype="float32", attn_q_chunk=16, attn_kv_chunk=16, remat=False,
)

register(ArchSpec(
    arch_id="qwen2-vl-2b", full=FULL, smoke=SMOKE,
    shapes=FULL_ATTN_SHAPES, skipped_shapes=("long_500k",),
    notes="M-RoPE backbone, stub patch frontend; long_500k skipped",
))
