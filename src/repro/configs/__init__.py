"""Assigned-architecture configuration registry."""

from repro.configs.base import (ARCH_MODULES, SHAPES, ArchSpec, ShapeSpec,
                                get_arch, list_archs)

__all__ = ["ARCH_MODULES", "SHAPES", "ArchSpec", "ShapeSpec", "get_arch",
           "list_archs"]
