"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B] — MLA (multi-head latent attention).

MLA ranks follow the HF config: q_lora_rank 768, kv_lora_rank 256,
qk_nope 64 + qk_rope 32 per head, v_head_dim 64; the decode cache stores
only (c_kv, k_rope) = 288 values/token (vs 2·40·96 for vanilla GQA).
"""

from repro.models.common import ModelConfig
from repro.configs.base import ArchSpec, FULL_ATTN_SHAPES, register

FULL = ModelConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab=73448, head_dim=64,
    mla=True, q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="minicpm3-4b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
    head_dim=16, mla=True, q_lora_rank=32, kv_lora_rank=16,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    dtype="float32", attn_q_chunk=16, attn_kv_chunk=16, remat=False,
)

register(ArchSpec(
    arch_id="minicpm3-4b", full=FULL, smoke=SMOKE,
    shapes=FULL_ATTN_SHAPES, skipped_shapes=("long_500k",),
    notes="MLA compressed KV cache; long_500k skipped (full attention)",
))
