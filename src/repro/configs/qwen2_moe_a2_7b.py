"""Qwen2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 60 routed top-4 + 4 shared."""

from repro.models.common import ModelConfig
from repro.configs.base import ArchSpec, FULL_ATTN_SHAPES, register

FULL = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=151936, head_dim=128,
    moe_experts=60, moe_topk=4, moe_shared=4, moe_period=1,
    rope_theta=1_000_000.0, capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="qwen2-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=48, vocab=256,
    moe_experts=6, moe_topk=2, moe_shared=2, moe_period=1,
    capacity_factor=2.0,
    dtype="float32", attn_q_chunk=16, attn_kv_chunk=16, remat=False,
)

register(ArchSpec(
    arch_id="qwen2-moe-a2.7b", full=FULL, smoke=SMOKE,
    shapes=FULL_ATTN_SHAPES, skipped_shapes=("long_500k",),
    notes="fine-grained 60-expert all-to-all — Q-StaR collective target; "
          "full attention ⇒ long_500k skipped",
))
