"""StableLM-3B [hf:stabilityai/stablelm-2-1_6b family] — dense."""

from repro.models.common import ModelConfig
from repro.configs.base import ArchSpec, FULL_ATTN_SHAPES, register

FULL = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab=50304, head_dim=80, rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="stablelm-3b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, dtype="float32",
    attn_q_chunk=16, attn_kv_chunk=16, remat=False,
)

register(ArchSpec(
    arch_id="stablelm-3b", full=FULL, smoke=SMOKE,
    shapes=FULL_ATTN_SHAPES, skipped_shapes=("long_500k",),
    notes="pure full-attention arch: long_500k skipped",
))
