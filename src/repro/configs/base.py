"""Architecture × input-shape registry (the assignment's 40 cells).

Every architecture exposes:
  * ``full``  — the exact published configuration (dry-run only; parameters
    are never materialized on CPU, see ``registry.abstract_params``);
  * ``smoke`` — a reduced same-family configuration for CPU tests
    (small widths, few experts, tiny vocab), exercised by
    ``tests/test_arch_smoke.py``.

Shapes (per the assignment):
  train_4k     seq 4096,   global_batch 256   → train_step
  prefill_32k  seq 32768,  global_batch 32    → prefill (serve)
  decode_32k   KV 32768,   global_batch 128   → serve_step (1 new token)
  long_500k    KV 524288,  global_batch 1     → serve_step; SSM/hybrid only
                (quadratic-attention archs skip it — DESIGN.md §4)
"""

from __future__ import annotations

import dataclasses

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    full: ModelConfig
    smoke: ModelConfig
    shapes: tuple[str, ...]          # applicable shape names
    skipped_shapes: tuple[str, ...]  # with reasons in DESIGN.md §4
    notes: str = ""


_REGISTRY: dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _REGISTRY:
        _load_all()
    return _REGISTRY[arch_id]


def list_archs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


_LOADED = False
ARCH_MODULES = [
    "codeqwen1_5_7b", "internlm2_1_8b", "minicpm3_4b", "stablelm_3b",
    "jamba_1_5_large", "whisper_base", "xlstm_1_3b", "dbrx_132b",
    "qwen2_moe_a2_7b", "qwen2_vl_2b",
]


def _load_all():
    global _LOADED
    if _LOADED:
        return
    import importlib
    for m in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _LOADED = True


# common shape groups
FULL_ATTN_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
SUBQUADRATIC_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


# ------------------------------------------------------------------ #
# §Perf optimized variants (hillclimb results; baselines stay intact)
# ------------------------------------------------------------------ #
OPTIMIZED_OVERRIDES: dict[str, dict] = {
    # A2: pad 60 routed experts to 64 ⇒ clean 16-way EP all-to-all
    "qwen2-moe-a2.7b": dict(moe_pad_to=64),
    # B1: 4× larger mLSTM chunks ⇒ 4× fewer (C, n) state round-trips
    "xlstm-1.3b": dict(xlstm_chunk=256),
}


def optimized_config(arch_id: str):
    spec = get_arch(arch_id)
    over = OPTIMIZED_OVERRIDES.get(arch_id, {})
    return spec.full.replace(**over) if over else spec.full
