"""xLSTM-1.3B [arXiv:2405.04517] — sLSTM + mLSTM blocks (xLSTM[7:1]).

d_ff = 0: mLSTM blocks carry their own 2× up/down projection.  Recurrent
state is O(1) in sequence length ⇒ long_500k runs.
"""

from repro.models.common import ModelConfig
from repro.configs.base import ArchSpec, SUBQUADRATIC_SHAPES, register

FULL = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, head_dim=512,
    slstm_period=8, xlstm_proj_factor=2.0,
)

SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0, vocab=256,
    head_dim=16, slstm_period=4, xlstm_chunk=8,
    dtype="float32", remat=False,
)

register(ArchSpec(
    arch_id="xlstm-1.3b", full=FULL, smoke=SMOKE,
    shapes=SUBQUADRATIC_SHAPES, skipped_shapes=(),
    notes="recurrent-state decode (no KV cache); long_500k runs",
))
