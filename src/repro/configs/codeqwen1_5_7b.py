"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B] — dense, full MHA KV."""

from repro.models.common import ModelConfig
from repro.configs.base import ArchSpec, FULL_ATTN_SHAPES, register

FULL = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=13440, vocab=92416, head_dim=128, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="codeqwen1.5-7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=160, vocab=256, dtype="float32",
    attn_q_chunk=16, attn_kv_chunk=16, remat=False,
)

register(ArchSpec(
    arch_id="codeqwen1.5-7b", full=FULL, smoke=SMOKE,
    shapes=FULL_ATTN_SHAPES, skipped_shapes=("long_500k",),
    notes="pure full-attention arch: long_500k skipped (DESIGN.md §4)",
))
