"""Whisper-base [arXiv:2212.04356] — encoder-decoder audio backbone.

The conv frontend is a stub: input_specs() supplies precomputed frame
embeddings (B, 1500, d).  Decode shapes exercise the text decoder with
self-KV caches + encoder output.
"""

from repro.models.common import ModelConfig
from repro.configs.base import ArchSpec, FULL_ATTN_SHAPES, register

FULL = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, enc_layers=6, enc_seq=1500,
    d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, head_dim=64, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, enc_layers=2, enc_seq=32,
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
    tie_embeddings=True, dtype="float32",
    attn_q_chunk=16, attn_kv_chunk=16, remat=False,
)

register(ArchSpec(
    arch_id="whisper-base", full=FULL, smoke=SMOKE,
    shapes=FULL_ATTN_SHAPES, skipped_shapes=("long_500k",),
    notes="enc-dec (not encoder-only) ⇒ decode shapes run on the decoder; "
          "full attention ⇒ long_500k skipped; frontend stubbed",
))
