"""Post-SPMD HLO analysis: per-device FLOPs, HBM bytes, collective bytes.

Why not ``compiled.cost_analysis()``?  XLA's cost analysis counts a
``while`` body ONCE, but every scanned layer stack / flash-attention chunk
loop in this codebase is a while loop — naive cost analysis understates
FLOPs by 9–72×.  This module parses ``compiled.as_text()`` (per-device
shapes, post-partitioning), recovers while trip counts from their condition
computations, and propagates execution counts through the call graph.

Accounting model (roofline-oriented):
  * FLOPs: ``dot`` ops — 2 · prod(result dims) · prod(contracting dims)
    (elementwise flops are ignored; matmuls dominate every cell here).
  * HBM bytes: per top-level instruction, operands + result, with
    slice-accurate special cases (dynamic-slice/gather read the slice, not
    the operand; dynamic-update-slice writes the update in place).  Fusion
    internals are not double counted (fused computations are skipped; the
    fusion instruction's operands/result are the traffic) — this models a
    perfectly fused TPU executable, i.e. the optimistic roofline.
  * Collectives: per-op bytes (max of result/operand estimate) + ring-wire
    bytes with the group size parsed from ``replica_groups``.
"""

from __future__ import annotations

import dataclasses
import math
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s+(ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(.*\))?\s*->.*{\s*$")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(text: str) -> int:
    """Total bytes of all shapes appearing in a type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_dims(text: str) -> tuple[str, list[int]] | None:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    result_type: str
    operands: list[str]
    attrs: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: dict[str, Instruction]
    is_entry: bool = False


def _parse_operands(rest: str) -> tuple[list[str], str]:
    """Split `opcode(%a, %b), attr=...` into operand names and attrs."""
    depth = 0
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
            if depth == 1:
                start = i + 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                inner = rest[start:i]
                attrs = rest[i + 1:]
                ops = re.findall(r"%([\w\.\-]+)", inner)
                return ops, attrs
    return [], rest


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
            m = _COMP_RE.match(line.strip())
            name = None
            if m:
                name = m.group(1)
            else:
                m2 = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", line.strip())
                name = m2.group(1) if m2 else f"comp{len(comps)}"
            cur = Computation(name=name, instructions={},
                              is_entry=line.strip().startswith("ENTRY"))
            comps[name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        root_kw, name, rhs = m.groups()
        # rhs = "TYPE opcode(...), attrs"
        om = re.match(r"((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:{[^}]*})?)+)\s+([\w\-]+)\(",
                      rhs)
        if not om:
            continue
        rtype, opcode = om.groups()
        rest = rhs[om.start(2):]
        ops, attrs = _parse_operands(rest[len(opcode):])
        cur.instructions[name] = Instruction(
            name=name, opcode=opcode, result_type=rtype,
            operands=ops, attrs=attrs,
            line=("ROOT " if root_kw else "") + line.strip())
    return comps


def _trip_count(cond: Computation) -> int:
    """Extract the while trip count from its condition computation."""
    consts = {}
    for ins in cond.instructions.values():
        if ins.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                consts[ins.name] = int(m.group(1))
    # ROOT compare(%iv, %const), direction=LT
    for ins in cond.instructions.values():
        if ins.opcode == "compare" and "direction=LT" in ins.attrs:
            for op in ins.operands:
                if op in consts:
                    return max(consts[op], 1)
    if consts:
        return max(max(consts.values()), 1)
    return 1


def _group_size(attrs: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_replica_groups(attrs: str,
                         num_devices: int) -> tuple[tuple[int, ...], ...]:
    """Expand ``replica_groups`` to explicit device-id groups.

    Handles both printed forms:

    * iota form ``[G,S]<=[d0,d1,...]`` with an optional transpose
      ``T(p0,p1,...)`` — ``arange(prod(dims)).reshape(dims)``, transposed,
      then reshaped to (G, S) row groups;
    * explicit form ``{{0,1},{2,3}}``.

    An op with no ``replica_groups`` attribute (or an empty ``{}``)
    addresses every device: one group of ``range(num_devices)``.
    """
    m = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?",
        attrs)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = list(range(math.prod(dims)))
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            import numpy as _np
            ids = list(_np.arange(math.prod(dims)).reshape(dims)
                       .transpose(perm).reshape(-1))
        return tuple(tuple(int(ids[r * s + c]) for c in range(s))
                     for r in range(g))
    m = re.search(r"replica_groups=\{(\{[0-9, ]*\}(?:,\s*\{[0-9, ]*\})*)\}",
                  attrs)
    if m:
        groups = []
        for grp in re.findall(r"\{([0-9, ]*)\}", m.group(1)):
            ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if ids:
                groups.append(tuple(ids))
        if groups:
            return tuple(groups)
    return (tuple(range(num_devices)),)


def parse_source_target_pairs(attrs: str) -> tuple[tuple[int, int], ...]:
    """``source_target_pairs={{0,1},{1,2}}`` → ((0, 1), (1, 2))."""
    m = re.search(
        r"source_target_pairs=\{(\{\d+,\s*\d+\}(?:,\s*\{\d+,\s*\d+\})*)\}",
        attrs)
    if not m:
        return ()
    return tuple(
        (int(a), int(b))
        for a, b in re.findall(r"\{(\d+),\s*(\d+)\}", m.group(1)))


_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota", "broadcast", "reshape",
             "transpose", "convert", "partition-id", "replica-id",
             "custom-call", "conditional", "opt-barrier", "rng-bit-generator"}


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0      # Σ per-op bytes (spec formula)
    collective_wire_bytes: float = 0.0  # ring-algorithm wire estimate
    collective_counts: dict = dataclasses.field(default_factory=dict)
    while_trip_counts: list = dataclasses.field(default_factory=list)

    def merged(self, other: "HloStats", mult: float) -> "HloStats":
        out = HloStats(
            flops=self.flops + mult * other.flops,
            hbm_bytes=self.hbm_bytes + mult * other.hbm_bytes,
            collective_bytes=self.collective_bytes
            + mult * other.collective_bytes,
            collective_wire_bytes=self.collective_wire_bytes
            + mult * other.collective_wire_bytes,
            collective_counts=dict(self.collective_counts),
            while_trip_counts=self.while_trip_counts
            + other.while_trip_counts,
        )
        for k, v in other.collective_counts.items():
            out.collective_counts[k] = out.collective_counts.get(k, 0) \
                + mult * v
        return out


def _instr_shape_dims(comp: Computation, name: str):
    ins = comp.instructions.get(name)
    if ins is None:
        return None
    return _result_dims(ins.result_type)


def analyze_computation(comps, comp: Computation, num_devices: int,
                        _memo) -> HloStats:
    if comp.name in _memo:
        return _memo[comp.name]
    stats = HloStats()
    for ins in comp.instructions.values():
        op = ins.opcode
        if op == "while":
            body = cond = None
            bm = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
            cm = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
            if bm and bm.group(1) in comps:
                body = comps[bm.group(1)]
            if cm and cm.group(1) in comps:
                cond = comps[cm.group(1)]
            trips = _trip_count(cond) if cond else 1
            stats.while_trip_counts.append(trips)
            if body is not None:
                inner = analyze_computation(comps, body, num_devices, _memo)
                stats = stats.merged(inner, trips)
            continue
        if op == "fusion":
            fm = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
            fused = comps.get(fm.group(1)) if fm else None
            # traffic = operands + result, EXCEPT operands that the fused
            # computation only dynamic-slices: a scan body slicing one row
            # out of a loop-invariant array reads the slice, not the array
            in_bytes = 0.0
            sliced = _slice_only_param_bytes(fused) if fused else {}
            for oi, o in enumerate(ins.operands):
                if o not in comp.instructions:
                    continue
                full = _shape_bytes(comp.instructions[o].result_type)
                in_bytes += sliced.get(oi, full)
            out_bytes = _shape_bytes(ins.result_type)
            if fused is not None and _root_is_dus(fused):
                out_bytes = min(out_bytes, _dus_update_bytes(fused))
            stats.hbm_bytes += in_bytes + out_bytes
            # flops inside the fused computation (dots can be fused)
            if fused is not None:
                inner = analyze_computation(comps, fused, num_devices,
                                            _memo)
                stats.flops += inner.flops
            continue
        if op in _SKIP_OPS:
            continue
        if op == "dot":
            rd = _result_dims(ins.result_type)
            lhs = _instr_shape_dims(comp, ins.operands[0]) \
                if ins.operands else None
            flops = 0.0
            if rd:
                n = math.prod(rd[1]) if rd[1] else 1
                k = 1
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                               ins.attrs)
                if cm and lhs:
                    for d in cm.group(1).split(","):
                        if d:
                            k *= lhs[1][int(d)]
                flops = 2.0 * n * k
            stats.flops += flops
            in_bytes = sum(
                _shape_bytes(comp.instructions[o].result_type)
                for o in ins.operands if o in comp.instructions)
            stats.hbm_bytes += in_bytes + _shape_bytes(ins.result_type)
            continue
        if any(op.startswith(c) for c in COLLECTIVES):
            base = op.replace("-start", "")
            out_bytes = _shape_bytes(ins.result_type)
            in_bytes = sum(
                _shape_bytes(comp.instructions[o].result_type)
                for o in ins.operands if o in comp.instructions)
            size = max(out_bytes, in_bytes)
            g = _group_size(ins.attrs, num_devices)
            if base.startswith("all-reduce"):
                wire = 2 * (g - 1) / max(g, 1) * size
            elif base.startswith("collective-permute"):
                wire = out_bytes
            else:  # all-gather / reduce-scatter / all-to-all
                wire = (g - 1) / max(g, 1) * size
            stats.collective_bytes += size
            stats.collective_wire_bytes += wire
            key = base.split(".")[0]
            stats.collective_counts[key] = \
                stats.collective_counts.get(key, 0) + 1
            continue
        if op in ("dynamic-slice", "gather"):
            stats.hbm_bytes += 2 * _shape_bytes(ins.result_type)
            continue
        if op in ("dynamic-update-slice", "scatter"):
            upd = (comp.instructions[ins.operands[1]].result_type
                   if len(ins.operands) > 1
                   and ins.operands[1] in comp.instructions else "")
            ub = _shape_bytes(upd)
            stats.hbm_bytes += 2 * ub if ub else _shape_bytes(
                ins.result_type)
            continue
        # generic op: operands + result
        in_bytes = sum(
            _shape_bytes(comp.instructions[o].result_type)
            for o in ins.operands if o in comp.instructions)
        stats.hbm_bytes += in_bytes + _shape_bytes(ins.result_type)
    _memo[comp.name] = stats
    return stats




def _slice_only_param_bytes(fused: "Computation") -> dict[int, float]:
    """Parameter index → charged bytes, for fused-computation parameters
    consumed ONLY by dynamic-slice/gather ops (charge the slice results)."""
    out: dict[int, float] = {}
    params: dict[str, int] = {}
    for ins in fused.instructions.values():
        if ins.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", ins.line)
            if m:
                params[ins.name] = int(m.group(1))
    for pname, pidx in params.items():
        consumers = [i for i in fused.instructions.values()
                     if pname in i.operands and i.opcode != "parameter"]
        if consumers and all(c.opcode in ("dynamic-slice", "gather")
                             for c in consumers):
            out[pidx] = sum(_shape_bytes(c.result_type) for c in consumers)
    return out


def _root_is_dus(fused: "Computation") -> bool:
    for ins in fused.instructions.values():
        if "ROOT" in ins.line and ins.opcode == "dynamic-update-slice":
            return True
    return False


def _dus_update_bytes(fused: "Computation") -> float:
    for ins in fused.instructions.values():
        if "ROOT" in ins.line and ins.opcode == "dynamic-update-slice":
            if len(ins.operands) > 1:
                upd = ins.operands[1]
                if upd in fused.instructions:
                    return 2 * _shape_bytes(
                        fused.instructions[upd].result_type)
            return _shape_bytes(ins.result_type)
    return 0.0


# ---------------------------------------------------------------------- #
# per-collective-op extraction (the ML-traffic derivation input)
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One collective instruction of the entry program, with its execution
    multiplicity through the while-loop call graph.

    ``size_bytes``/``wire_bytes`` are per-participant per-execution (the
    same accounting as :class:`HloStats`); ``count`` is the number of times
    the op executes per entry call (product of enclosing while trip
    counts).  ``groups`` are explicit device-id groups; ``pairs`` is the
    ``source_target_pairs`` list (collective-permute only, else empty).
    """

    name: str
    kind: str                               # all-reduce / all-gather / ...
    size_bytes: float
    wire_bytes: float
    groups: tuple[tuple[int, ...], ...]
    pairs: tuple[tuple[int, int], ...] = ()
    count: float = 1.0

    @property
    def group_size(self) -> int:
        return len(self.groups[0]) if self.groups else 1

    @property
    def fabric_bytes(self) -> float:
        """Total wire bytes this op puts on the fabric per entry call —
        the sum over all participants of all groups, times ``count``.

        Ring accounting (paper §2 collective model): an all-reduce over a
        g-group moves ``2(g-1)·size`` bytes around the ring in total, an
        all-gather/reduce-scatter/all-to-all ``(g-1)·size``, and a
        collective-permute ``size`` per source→target pair.
        """
        if self.kind == "collective-permute":
            return self.count * len(self.pairs) * self.size_bytes
        total = 0.0
        factor = 2.0 if self.kind == "all-reduce" else 1.0
        for grp in self.groups:
            g = len(grp)
            if g > 1:
                total += factor * (g - 1) * self.size_bytes
        return self.count * total


def collective_ops(text: str, num_devices: int = 1) -> list[CollectiveOp]:
    """Walk the entry program (while-trip-count aware, like
    :func:`analyze_hlo_text`) and return every collective op with its
    replica groups and execution multiplicity.

    ``*-done`` halves of async pairs are skipped — the ``*-start`` op
    carries the payload; counting both would double the traffic.
    """
    comps = parse_hlo(text)
    entry = None
    for c in comps.values():
        if c.is_entry:
            entry = c
            break
    if entry is None:
        entry = max(comps.values(), key=lambda c: len(c.instructions))
    out: list[CollectiveOp] = []

    def walk(comp: Computation, mult: float) -> None:
        for ins in comp.instructions.values():
            op = ins.opcode
            if op == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
                if bm and bm.group(1) in comps:
                    body = comps[bm.group(1)]
                if cm and cm.group(1) in comps:
                    cond = comps[cm.group(1)]
                trips = _trip_count(cond) if cond else 1
                if body is not None:
                    walk(body, mult * trips)
                continue
            if not any(op.startswith(c) for c in COLLECTIVES):
                continue
            if op.endswith("-done"):
                continue
            base = op.replace("-start", "")
            kind = base.split(".")[0]
            out_bytes = _shape_bytes(ins.result_type)
            in_bytes = sum(
                _shape_bytes(comp.instructions[o].result_type)
                for o in ins.operands if o in comp.instructions)
            size = max(out_bytes, in_bytes)
            groups = parse_replica_groups(ins.attrs, num_devices)
            pairs = ()
            if kind == "collective-permute":
                size = out_bytes
                pairs = parse_source_target_pairs(ins.attrs)
                wire = out_bytes
            else:
                g = len(groups[0]) if groups else 1
                if kind == "all-reduce":
                    wire = 2 * (g - 1) / max(g, 1) * size
                else:
                    wire = (g - 1) / max(g, 1) * size
            out.append(CollectiveOp(
                name=ins.name, kind=kind, size_bytes=float(size),
                wire_bytes=float(wire), groups=groups, pairs=pairs,
                count=mult))

    walk(entry, 1.0)
    return out


def collective_flow_totals(ops: list[CollectiveOp]) -> dict[str, float]:
    """Per-kind fabric wire bytes (Σ :attr:`CollectiveOp.fabric_bytes`) —
    the conservation target the derived flow matrices must sum to
    (``repro.noc.mltraffic``, ``tests/test_mltraffic.py``)."""
    totals: dict[str, float] = {}
    for op in ops:
        totals[op.kind] = totals.get(op.kind, 0.0) + op.fabric_bytes
    return totals


def _called_by_fusion(comps) -> set[str]:
    fused = set()
    for comp in comps.values():
        for ins in comp.instructions.values():
            if ins.opcode == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
                if m:
                    fused.add(m.group(1))
    return fused


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions: older
    releases return a one-element list of per-program dicts, newer ones the
    dict itself."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def analyze_hlo_text(text: str, num_devices: int = 1) -> HloStats:
    comps = parse_hlo(text)
    entry = None
    for c in comps.values():
        if c.is_entry:
            entry = c
            break
    if entry is None:  # fall back to the largest computation
        entry = max(comps.values(), key=lambda c: len(c.instructions))
    return analyze_computation(comps, entry, num_devices, {})


# ---------------------------------------------------------------------- #
# roofline terms (TPU v5e)
# ---------------------------------------------------------------------- #
PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    collective_bytes: float
    model_flops: float
    num_chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops × chips) — remat/redundancy waste."""
        total = self.flops * self.num_chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Roofline-implied MFU: model flops / (chips · peak · bound_s)."""
        denom = self.num_chips * PEAK_FLOPS * self.bound_s
        return self.model_flops / denom if denom else 0.0


def roofline_terms(stats: HloStats, num_chips: int,
                   model_flops: float) -> Roofline:
    """Per-device stats → the three roofline terms (seconds)."""
    return Roofline(
        compute_s=stats.flops / PEAK_FLOPS,
        memory_s=stats.hbm_bytes / HBM_BW,
        collective_s=stats.collective_wire_bytes / ICI_BW,
        flops=stats.flops,
        hbm_bytes=stats.hbm_bytes,
        collective_bytes=stats.collective_bytes,
        model_flops=model_flops,
        num_chips=num_chips,
    )
