"""Pallas TPU kernel: chunked selective scan (Mamba SSM inner loop).

Grid: (batch, d_inner blocks, sequence chunks) — the chunk axis is
innermost and sequential; the (di_blk, ds) hidden state lives in VMEM
scratch across chunk visits, so HBM traffic is exactly the streamed
inputs/outputs (the parallel-scan formulation would spill S×di×ds
intermediates).  Within a chunk the recurrence runs as a fori loop over
time steps on VMEM-resident tiles — d_state is tiny (16), so each step is
VPU elementwise work on (di_blk, ds) tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(delta_ref, a_ref, b_ref, c_ref, x_ref, y_ref, h_scr, *,
            chunk: int):
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...]                       # (di_blk, ds)
    delta = delta_ref[0]                 # (chunk, di_blk)
    x = x_ref[0]                         # (chunk, di_blk)
    bmat = b_ref[0]                      # (chunk, ds)
    cmat = c_ref[0]                      # (chunk, ds)

    def step(t, carry):
        h, ys = carry
        ad = jnp.exp(delta[t][:, None] * a)              # (di_blk, ds)
        h = ad * h + (delta[t] * x[t])[:, None] * bmat[t][None, :]
        y = jnp.sum(h * cmat[t][None, :], axis=1)        # (di_blk,)
        ys = jax.lax.dynamic_update_index_in_dim(ys, y, t, 0)
        return h, ys

    ys0 = jnp.zeros((chunk, delta.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h_scr[...], ys0))
    h_scr[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "chunk",
                                             "interpret"))
def selective_scan_pallas(delta, a, b, c, x, *, block_d: int = 512,
                          chunk: int = 64, interpret: bool = True):
    """Shapes as in ref.selective_scan; S must be a chunk multiple and Di a
    block multiple (ops.py pads)."""
    bs, s, di = x.shape
    ds = a.shape[1]
    bd = min(block_d, di)
    ck = min(chunk, s)
    grid = (bs, di // bd, s // ck)
    y = pl.pallas_call(
        functools.partial(_kernel, chunk=ck),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ck, bd), lambda bi, dj, cj: (bi, cj, dj)),
            pl.BlockSpec((bd, ds), lambda bi, dj, cj: (dj, 0)),
            pl.BlockSpec((1, ck, ds), lambda bi, dj, cj: (bi, cj, 0)),
            pl.BlockSpec((1, ck, ds), lambda bi, dj, cj: (bi, cj, 0)),
            pl.BlockSpec((1, ck, bd), lambda bi, dj, cj: (bi, cj, dj)),
        ],
        out_specs=pl.BlockSpec((1, ck, bd), lambda bi, dj, cj: (bi, cj, dj)),
        out_shape=jax.ShapeDtypeStruct((bs, s, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, ds), jnp.float32)],
        interpret=interpret,
    )(delta, a, b, c, x)
    return y
