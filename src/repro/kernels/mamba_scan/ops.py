"""Jitted wrapper with padding for the selective-scan kernel."""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import selective_scan_pallas


def selective_scan(delta, a, b, c, x, *, block_d=512, chunk=64,
                   interpret=True):
    bs, s, di = x.shape
    ck = min(chunk, s)
    pad_s = (-s) % ck
    bd = min(block_d, di)
    pad_d = (-di) % bd
    if pad_s or pad_d:
        pw3 = ((0, 0), (0, pad_s), (0, pad_d))
        pw2 = ((0, 0), (0, pad_s), (0, 0))
        delta = jnp.pad(delta, pw3)
        x = jnp.pad(x, pw3)
        b = jnp.pad(b, pw2)
        c = jnp.pad(c, pw2)
        a = jnp.pad(a, ((0, pad_d), (0, 0)))
    y = selective_scan_pallas(delta, a, b, c, x, block_d=bd, chunk=ck,
                              interpret=interpret)
    return y[:, :s, :di]
