"""Oracle: sequential selective-scan recurrence (pure jnp, O(S) scan)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan(delta, a, b, c, x, h0=None):
    """h_t = exp(Δ_t A) ⊙ h_{t−1} + (Δ_t x_t) B_t;  y_t = h_t · C_t.

    delta, x: (B, S, Di); a: (Di, Ds); b, c: (B, S, Ds).
    Returns (y (B, S, Di), h_final (B, Di, Ds)).
    """
    bs, s, di = x.shape
    ds = a.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bs, di, ds), jnp.float32)

    def step(h, t):
        ad = jnp.exp(delta[:, t, :, None] * a[None])
        h = ad * h + (delta[:, t] * x[:, t])[..., None] * b[:, t, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c[:, t])
        return h, y

    h, ys = jax.lax.scan(step, h0, jnp.arange(s))
    return ys.transpose(1, 0, 2), h
