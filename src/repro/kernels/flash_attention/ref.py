"""Oracle: the chunked pure-jnp flash reference from the model layer
(already itself validated against naive softmax attention)."""

from __future__ import annotations

from repro.models.layers.attention import flash_attention_ref


def flash_attention(q, k, v, *, causal=True):
    """q: (B, H, S, D) kernel layout → reference in (B, S, H, D) layout."""
    out = flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, q_chunk=128, kv_chunk=128)
    return out.transpose(0, 2, 1, 3)
