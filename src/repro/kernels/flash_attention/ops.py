"""Jitted wrapper: padding, layout, GQA mapping, dispatch."""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import flash_attention_pallas


def _pad(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention(q, k, v, *, causal=True, mask_len=None,
                    block_q=128, block_kv=128, interpret=True):
    """Model-layer layout (B, S, H, D) / (B, S, KV, D) → (B, S, H, Dv).

    ``mask_len`` falls back to the pure-jnp reference (serving path)."""
    if mask_len is not None:
        from repro.models.layers.attention import flash_attention_ref
        return flash_attention_ref(q, k, v, causal=causal,
                                   bias_mask_len=mask_len)
    b, sq, h, d = q.shape
    qt = _pad(q.transpose(0, 2, 1, 3), block_q, 2)
    kt = _pad(k.transpose(0, 2, 1, 3), block_kv, 2)
    vt = _pad(v.transpose(0, 2, 1, 3), block_kv, 2)
    out = flash_attention_pallas(qt, kt, vt, causal=causal,
                                 block_q=block_q, block_kv=block_kv,
                                 interpret=interpret)
    return out[:, :, :sq].transpose(0, 2, 1, 3)
