"""Pallas TPU flash-attention forward (causal / full, GQA-aware).

Grid: (batch·heads, q blocks, kv blocks) — the kv axis is innermost, so the
(m, l, acc) online-softmax state lives in VMEM scratch across kv visits and
is flushed to the output block on the last kv step.  GQA is handled in the
index maps: the K/V block for head ``h`` reads kv-head ``h // group``, so
grouped keys are never materialized per-head in HBM.

Block shapes default to (128, head_dim) — MXU-aligned (head_dim is a
multiple of 128 for every assigned arch except whisper/minicpm (64); Pallas
pads the lane dimension).  Causal blocks strictly above the diagonal are
skipped with ``pl.when`` (no FLOPs, no VMEM traffic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, scale: float, kv_len: int, block_q: int,
            block_kv: int, num_kv_blocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    if causal:
        run = kj * block_kv <= (qi + 1) * block_q - 1
    else:
        run = kj >= 0

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bkv, d)
        v = v_ref[0].astype(jnp.float32)          # (bkv, dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        kabs = kj * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_kv), 1)
        s = jnp.where(kabs < kv_len, s, NEG_INF)
        if causal:
            qabs = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, 1), 0)
            s = jnp.where(kabs <= qabs, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kj == num_kv_blocks - 1)
    def _flush():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_kv", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = True):
    """q: (B, H, Sq, D); k/v: (B, KV, Skv, D) → (B, H, Sq, Dv)."""
    b, h, sq, d = q.shape
    _, kvh, skv, dv = v.shape
    group = h // kvh
    scale = d ** -0.5
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    nq = -(-sq // bq)
    nk = -(-skv // bkv)
    grid = (b * h, nq, nk)

    def qmap(bh, qi, kj):
        return (bh, qi, 0)

    def kvmap(bh, qi, kj):
        bi = bh // h
        hi = bh % h
        return (bi * kvh + hi // group, kj, 0)

    out = pl.pallas_call(
        functools.partial(
            _kernel, causal=causal, scale=scale, kv_len=skv,
            block_q=bq, block_kv=bkv, num_kv_blocks=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), qmap),
            pl.BlockSpec((1, bkv, d), kvmap),
            pl.BlockSpec((1, bkv, dv), kvmap),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), qmap),
        out_shape=jax.ShapeDtypeStruct((b * h, nq * bq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(b * h, sq, d),
      k.reshape(b * kvh, skv, d),
      v.reshape(b * kvh, skv, dv))
    return out[:, :sq].reshape(b, h, sq, dv)
