"""Pure-jnp oracle for the possibility-weight kernel (N-Rank eq. 5/7).

Dense reformulation used by both the oracle and the Pallas kernel:

    W[c]     = Σ_{s,d} T[s,d] · [Du[s,c] + 1 + Dn[c,d] == D[s,d]]
    W_drn[c] = Σ_s    Tn[s,c] · [Du[s,c] + 1 == Dsn[s,c]]

with Du = dist[:, us], Dn = dist[ns, :], Dsn = dist[:, ns],
Tn[s, c] = T[s, ns[c]] — all gathered once on the host.
"""

from __future__ import annotations

import jax.numpy as jnp


def possibility_weights_dense(du, dn, dsn, tn, dist, traffic,
                              offset: int = 1):
    """du: (N, C) int32; dn: (C, N); dsn: (N, C); tn: (N, C) f32;
    dist: (N, N) int32; traffic: (N, N) f32 → (W (C,), W_drn (C,)).
    ``offset=1`` is eq. 5/7; ``offset=2`` the consecutive-pair predicate
    (W_drn then carries no meaning)."""
    lhs = du.T[:, :, None] + offset + dn[:, None, :]      # (C, N, N)
    mask = (lhs == dist[None]).astype(traffic.dtype)
    w = jnp.einsum("csd,sd->c", mask, traffic)
    drn = ((du + offset) == dsn).astype(traffic.dtype)    # (N, C)
    w_drn = jnp.einsum("sc,sc->c", drn, tn)
    return w, w_drn
