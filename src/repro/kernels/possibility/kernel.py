"""Pallas TPU kernel: N-Rank possibility weights (the O(C·N²) hot spot).

Grid: (channel blocks, source blocks); destinations are reduced inside the
kernel.  The W accumulator lives in the output block (revisited across the
s-dimension of the grid — Pallas keeps the block in VMEM between visits
because the index_map ignores the s axis).  All tiles are (128-multiple)
MXU/VPU-aligned; compares and multiply-reduces are VPU work, so the kernel
is HBM-bandwidth-bound — tiling T once per (c, s) block instead of the
naive C passes over T is the win over the jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(du_ref, dn_ref, dsn_ref, tn_ref, t_ref, dist_ref,
            w_ref, wdrn_ref):
    sb = pl.program_id(1)
    du = du_ref[...]           # (BS, BC)
    dn = dn_ref[...]           # (BC, N)
    dist = dist_ref[...]       # (BS, N)
    t = t_ref[...]             # (BS, N)
    lhs = du.T[:, :, None] + 1 + dn[:, None, :]     # (BC, BS, N)
    mask = (lhs == dist[None]).astype(t.dtype)
    w_part = jnp.einsum("csd,sd->c", mask, t)       # (BC,)
    drn = ((du + 1) == dsn_ref[...]).astype(t.dtype)
    wdrn_part = jnp.sum(drn * tn_ref[...], axis=0)  # (BC,)

    @pl.when(sb == 0)
    def _init():
        w_ref[...] = jnp.zeros_like(w_ref)
        wdrn_ref[...] = jnp.zeros_like(wdrn_ref)

    w_ref[...] += w_part
    wdrn_ref[...] += wdrn_part


@functools.partial(jax.jit, static_argnames=("block_c", "block_s",
                                             "interpret"))
def possibility_weights_pallas(du, dn, dsn, tn, traffic, dist,
                               block_c: int = 128, block_s: int = 128,
                               interpret: bool = True):
    n, c = du.shape
    bc = min(block_c, c)
    bs = min(block_s, n)
    grid = (-(-c // bc), -(-n // bs))
    w, wdrn = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, bc), lambda cb, sb: (sb, cb)),   # du
            pl.BlockSpec((bc, n), lambda cb, sb: (cb, 0)),     # dn
            pl.BlockSpec((bs, bc), lambda cb, sb: (sb, cb)),   # dsn
            pl.BlockSpec((bs, bc), lambda cb, sb: (sb, cb)),   # tn
            pl.BlockSpec((bs, n), lambda cb, sb: (sb, 0)),     # traffic
            pl.BlockSpec((bs, n), lambda cb, sb: (sb, 0)),     # dist
        ],
        out_specs=[
            pl.BlockSpec((bc,), lambda cb, sb: (cb,)),
            pl.BlockSpec((bc,), lambda cb, sb: (cb,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c,), traffic.dtype),
            jax.ShapeDtypeStruct((c,), traffic.dtype),
        ],
        interpret=interpret,
    )(du, dn, dsn, tn, traffic, dist)
    return w, wdrn
