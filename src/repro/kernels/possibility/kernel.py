"""Pallas TPU kernels: N-Rank possibility weights (the O(C·N²) hot spot).

Two variants share one blocking scheme — grid (channel blocks, source
blocks), destinations reduced inside the kernel:

* ``possibility_weights_pallas`` — the classic (W, W_drn) reduction
  (eq. 5/7), accumulated per channel block.
* ``possibility_v_pallas`` — the per-destination possibility traffic
  ``V[c, d]`` consumed by the fused planning pipeline
  (:mod:`repro.core.plan_fast`): W is its row sum, W_drn its ``d = n``
  gather, and the consecutive-channel joint possibility a cheap O(P·N)
  contraction of it.

The accumulator lives in the output block (revisited across the
s-dimension of the grid — Pallas keeps the block in VMEM between visits
because the index_map ignores the s axis).  All tiles are (128-multiple)
MXU/VPU-aligned; compares and multiply-reduces are VPU work, so the
kernels are HBM-bandwidth-bound — tiling T once per (c, s) block instead
of the naive C passes over T is the win over the jnp oracle.

``offset`` generalizes the minimal-path predicate to k-hop continuations
(``offset=1`` is eq. 4/5; ``offset=2`` the consecutive-pair predicate).
``interpret`` defaults to False — the compiled path; CPU callers (no
Pallas backend) must opt into interpret mode explicitly, which
``repro.kernels.possibility.ops`` does automatically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(du_ref, dn_ref, dsn_ref, tn_ref, t_ref, dist_ref,
            w_ref, wdrn_ref, *, offset: int):
    sb = pl.program_id(1)
    du = du_ref[...]           # (BS, BC)
    dn = dn_ref[...]           # (BC, N)
    dist = dist_ref[...]       # (BS, N)
    t = t_ref[...]             # (BS, N)
    lhs = du.T[:, :, None] + offset + dn[:, None, :]     # (BC, BS, N)
    mask = (lhs == dist[None]).astype(t.dtype)
    w_part = jnp.einsum("csd,sd->c", mask, t)       # (BC,)
    drn = ((du + offset) == dsn_ref[...]).astype(t.dtype)
    wdrn_part = jnp.sum(drn * tn_ref[...], axis=0)  # (BC,)

    @pl.when(sb == 0)
    def _init():
        w_ref[...] = jnp.zeros_like(w_ref)
        wdrn_ref[...] = jnp.zeros_like(wdrn_ref)

    w_ref[...] += w_part
    wdrn_ref[...] += wdrn_part


@functools.partial(jax.jit, static_argnames=("block_c", "block_s",
                                             "offset", "interpret"))
def possibility_weights_pallas(du, dn, dsn, tn, traffic, dist,
                               block_c: int = 128, block_s: int = 128,
                               offset: int = 1,
                               interpret: bool = False):
    n, c = du.shape
    bc = min(block_c, c)
    bs = min(block_s, n)
    grid = (-(-c // bc), -(-n // bs))
    w, wdrn = pl.pallas_call(
        functools.partial(_kernel, offset=offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, bc), lambda cb, sb: (sb, cb)),   # du
            pl.BlockSpec((bc, n), lambda cb, sb: (cb, 0)),     # dn
            pl.BlockSpec((bs, bc), lambda cb, sb: (sb, cb)),   # dsn
            pl.BlockSpec((bs, bc), lambda cb, sb: (sb, cb)),   # tn
            pl.BlockSpec((bs, n), lambda cb, sb: (sb, 0)),     # traffic
            pl.BlockSpec((bs, n), lambda cb, sb: (sb, 0)),     # dist
        ],
        out_specs=[
            pl.BlockSpec((bc,), lambda cb, sb: (cb,)),
            pl.BlockSpec((bc,), lambda cb, sb: (cb,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c,), traffic.dtype),
            jax.ShapeDtypeStruct((c,), traffic.dtype),
        ],
        interpret=interpret,
    )(du, dn, dsn, tn, traffic, dist)
    return w, wdrn


def _v_kernel(du_ref, dn_ref, t_ref, dist_ref, v_ref, *, offset: int):
    sb = pl.program_id(1)
    du = du_ref[...]           # (BS, BC)
    dn = dn_ref[...]           # (BC, N)
    dist = dist_ref[...]       # (BS, N)
    t = t_ref[...]             # (BS, N)
    lhs = du.T[:, :, None] + offset + dn[:, None, :]     # (BC, BS, N)
    mask = (lhs == dist[None]).astype(t.dtype)
    v_part = jnp.einsum("csd,sd->cd", mask, t)      # (BC, N)

    @pl.when(sb == 0)
    def _init():
        v_ref[...] = jnp.zeros_like(v_ref)

    v_ref[...] += v_part


@functools.partial(jax.jit, static_argnames=("block_c", "block_s",
                                             "offset", "interpret"))
def possibility_v_pallas(du, dn, traffic, dist,
                         block_c: int = 128, block_s: int = 128,
                         offset: int = 1,
                         interpret: bool = False):
    """Per-destination possibility traffic V (C, N):
    ``V[c, d] = Σ_s T[s,d]·[du[s,c] + offset + dn[c,d] == dist[s,d]]``."""
    n, c = du.shape
    bc = min(block_c, c)
    bs = min(block_s, n)
    grid = (-(-c // bc), -(-n // bs))
    return pl.pallas_call(
        functools.partial(_v_kernel, offset=offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, bc), lambda cb, sb: (sb, cb)),   # du
            pl.BlockSpec((bc, n), lambda cb, sb: (cb, 0)),     # dn
            pl.BlockSpec((bs, n), lambda cb, sb: (sb, 0)),     # traffic
            pl.BlockSpec((bs, n), lambda cb, sb: (sb, 0)),     # dist
        ],
        out_specs=pl.BlockSpec((bc, n), lambda cb, sb: (cb, 0)),
        out_shape=jax.ShapeDtypeStruct((c, n), traffic.dtype),
        interpret=interpret,
    )(du, dn, traffic, dist)
