"""Public op: possibility weights with host-side gather preparation.

Defaults are the COMPILED paths: on backends with Pallas support
(TPU/GPU) the Pallas kernel runs compiled; elsewhere (CPU) the call
auto-falls back to the dense jnp oracle, which XLA jit-compiles — the
interpreter is never the default anywhere.  Pass ``use_pallas`` /
``interpret`` explicitly to pin a path (tests run the Pallas kernel in
interpret mode on CPU to keep it covered).
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from .kernel import possibility_weights_pallas
from .ref import possibility_weights_dense

_dense_jit = functools.partial(jax.jit, static_argnames=("offset",))(
    possibility_weights_dense)


def backend_supports_pallas() -> bool:
    """Compiled Pallas lowering exists on TPU/GPU only."""
    return jax.default_backend() in ("tpu", "gpu")


def _prepare(dist, traffic, channels):
    us = channels[:, 0]
    ns = channels[:, 1]
    dist = np.asarray(dist, np.int32)
    du = dist[:, us]                     # (N, C)
    dn = dist[ns, :]                     # (C, N)
    dsn = dist[:, ns]                    # (N, C)
    t = np.asarray(traffic, np.float32)
    tn = t[:, ns]                        # (N, C)
    return (jnp.asarray(du), jnp.asarray(dn), jnp.asarray(dsn),
            jnp.asarray(tn), jnp.asarray(t), jnp.asarray(dist))


def possibility_weights(dist, traffic, channels,
                        use_pallas: bool | None = None,
                        interpret: bool | None = None,
                        offset: int = 1):
    """(W, W_drn) per channel — eq. 5/7 (``offset=1``) or the k-hop
    continuation predicate (``offset=2`` for consecutive pairs; W_drn is
    then meaningless and should be ignored).

    ``use_pallas=None`` resolves to the backend's compiled support;
    ``interpret=None`` resolves to compiled where supported and to the
    interpreter only when the Pallas path was explicitly requested on a
    backend that cannot compile it.
    """
    if use_pallas is None:
        use_pallas = backend_supports_pallas()
    if interpret is None:
        interpret = use_pallas and not backend_supports_pallas()
    du, dn, dsn, tn, t, d = _prepare(dist, traffic, channels)
    if use_pallas:
        return possibility_weights_pallas(du, dn, dsn, tn, t, d,
                                          offset=offset,
                                          interpret=interpret)
    return _dense_jit(du, dn, dsn, tn, d, t, offset=offset)
