"""Public op: possibility weights with host-side gather preparation."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .kernel import possibility_weights_pallas
from .ref import possibility_weights_dense


def _prepare(dist, traffic, channels):
    us = channels[:, 0]
    ns = channels[:, 1]
    dist = np.asarray(dist, np.int32)
    du = dist[:, us]                     # (N, C)
    dn = dist[ns, :]                     # (C, N)
    dsn = dist[:, ns]                    # (N, C)
    t = np.asarray(traffic, np.float32)
    tn = t[:, ns]                        # (N, C)
    return (jnp.asarray(du), jnp.asarray(dn), jnp.asarray(dsn),
            jnp.asarray(tn), jnp.asarray(t), jnp.asarray(dist))


def possibility_weights(dist, traffic, channels, use_pallas: bool = True,
                        interpret: bool = True):
    du, dn, dsn, tn, t, d = _prepare(dist, traffic, channels)
    if use_pallas:
        return possibility_weights_pallas(du, dn, dsn, tn, t, d,
                                          interpret=interpret)
    return possibility_weights_dense(du, dn, dsn, tn, d, t)
