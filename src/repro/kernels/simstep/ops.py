"""Public op: the fused flit-step with backend-aware dispatch.

Mirrors :mod:`repro.kernels.possibility.ops`: defaults are the COMPILED
paths.  On backends with Pallas support (TPU/GPU) the fused cycle runs
as a Pallas kernel — whole-array when the state fits the VMEM budget,
else the blocked node-tile grid (:mod:`.kernel`); elsewhere (CPU) the
call auto-falls back to the fused dense jnp body, which XLA
jit-compiles — the interpreter is never the default anywhere.  Pass
``use_pallas`` / ``interpret`` explicitly (or set
``SimConfig.sim_tile_nodes``) to pin a path; the differential battery
runs the Pallas kernels in interpret mode on CPU to keep them covered.

Capacity math is DERIVED, not hand-maintained: the footprint the gate
compares against the budget comes from ``jax.eval_shape`` over the
actual initial state plus the abstract table shapes
(``repro.noc.sim.abstract_tables``), so a new state key (telemetry
rings, watchdog counters, whatever comes next) is counted the moment it
exists.  The budget itself is overridable (``SIMSTEP_VMEM_BUDGET`` env,
``--simstep-vmem-budget`` on the benchmark CLI), and every dispatch
decision is logged once per distinct (path, size, algo, tile) via
:class:`repro.obs.log.EventLog` — set ``SIMSTEP_LOG=0`` to silence.

The entry point is :func:`make_step`: it returns a drop-in replacement
for the unfused ``repro.noc.sim._make_step`` transition — same
``step(tables, state, cycle) -> (state, None)`` contract, same state
pytree, bit-identical arrays — selected by ``SimConfig.use_kernel``.
"""

from __future__ import annotations

import math
import os
import sys

import jax

from repro.noc.simconfig import Algo, SimConfig
from repro.obs.log import EventLog
from .kernel import make_simstep_blocked, make_simstep_pallas
from .ref import (MOV_W, TABLE_TILE_AXES, make_cycle_fn, make_cycle_parts,
                  split_rand, tile_state_keys)


def backend_supports_pallas() -> bool:
    """Compiled Pallas lowering exists on TPU/GPU only."""
    return jax.default_backend() in ("tpu", "gpu")


# Default on-chip budget (VMEM is ~16 MB/core on TPU, minus headroom for
# compiler scratch).  Override per run with SIMSTEP_VMEM_BUDGET.
VMEM_BUDGET_BYTES = 10 * 2**20


def vmem_budget_bytes() -> int:
    """The active on-chip budget: ``SIMSTEP_VMEM_BUDGET`` (bytes) when
    set, else :data:`VMEM_BUDGET_BYTES`."""
    env = os.environ.get("SIMSTEP_VMEM_BUDGET", "").strip()
    return int(env) if env else VMEM_BUDGET_BYTES


def _sizes(meta: dict, cfg: SimConfig):
    """(state shapes minus the PRNG key, abstract tables) — the traced
    operands of one simulation cell, as ShapeDtypeStructs.  eval_shape
    stages ``fresh_state`` without allocating anything."""
    from repro.noc import sim  # deferred: sim dispatches back into us
    state = dict(jax.eval_shape(lambda: sim.fresh_state(meta, cfg)))
    state.pop("key")  # advanced outside the kernel
    return state, sim.abstract_tables(meta)


def _nbytes(spec) -> int:
    return math.prod(spec.shape) * spec.dtype.itemsize


def state_footprint_bytes(meta: dict, cfg: SimConfig) -> int:
    """Bytes the whole-array kernel must hold on chip: the full state
    pytree (PRNG key excluded) plus the traced tables — derived from
    the real array shapes, never a parallel formula."""
    state, tables = _sizes(meta, cfg)
    return (sum(_nbytes(s) for s in state.values())
            + sum(_nbytes(s) for s in tables))


def blocked_tile_bytes(meta: dict, cfg: SimConfig, tile_nodes: int) -> int:
    """Estimated on-chip bytes for one grid step of the blocked kernel
    at ``tile_nodes`` nodes per tile: double-buffered tile blocks
    (state slices in+out, table/rand slices in, the ``mov`` halo out)
    plus the whole-array residents (coords, channel tables, the
    ``fs_pre`` snapshot).  Derived from the same eval_shape sizes as
    :func:`state_footprint_bytes`."""
    state, tables = _sizes(meta, cfg)
    n, nin = meta["N"], meta["NIN"]
    pv = meta["P"] * meta["V"]
    tn = tile_nodes
    nin_t = tn * pv
    node_keys, input_keys, _scalars = tile_state_keys(cfg)
    streamed = resident = 0
    for field, spec in zip(tables._fields, tables):
        ax = TABLE_TILE_AXES[field]
        if ax is None:
            resident += _nbytes(spec)
        else:
            kind, axis = ax
            size = tn if kind == "node" else nin_t
            frac = size / spec.shape[axis]
            streamed += int(_nbytes(spec) * frac)
    for k in node_keys:
        streamed += 2 * _nbytes(state[k]) * tn // n      # in + out
    for k in input_keys:
        streamed += 2 * _nbytes(state[k]) * nin_t // nin  # in + out
    streamed += tn * 4 * (2 + max(meta["NDIM"], 1))  # rand draws
    streamed += tn * meta["P"] * MOV_W * 4           # mov halo out
    resident += nin * 4                              # fs_pre snapshot
    return 2 * streamed + resident  # ×2: grid-pipeline double buffering


def auto_tile_nodes(meta: dict, cfg: SimConfig,
                    budget: int | None = None) -> int:
    """Largest node-tile size that divides the network and fits the
    blocked kernel's per-step budget; 0 when no tile fits (the caller
    then falls back to the dense body)."""
    budget = vmem_budget_bytes() if budget is None else budget
    n = meta["N"]
    for tn in sorted((d for d in range(1, n + 1) if n % d == 0),
                     reverse=True):
        if blocked_tile_bytes(meta, cfg, tn) <= budget:
            return tn
    return 0


def resolve_path(meta: dict, cfg: SimConfig,
                 use_pallas: bool | None = None,
                 interpret: bool | None = None,
                 supported: bool | None = None,
                 budget: int | None = None) -> tuple[str, int, bool]:
    """The dispatch ladder: ``(path, tile_nodes, interpret)`` with
    ``path`` one of ``"whole"`` / ``"blocked"`` / ``"dense"``.

    * ``use_pallas=False`` pins the fused dense body.
    * ``cfg.sim_tile_nodes > 0`` pins the blocked kernel at that tile.
    * ``use_pallas=True`` pins the whole-array kernel.
    * auto (all ``None``/0): on a Pallas backend, whole-array while the
      state fits the budget, else the largest fitting tile, else dense;
      on CPU, dense.

    ``interpret`` resolves to compiled where supported; forcing a
    Pallas path on CPU runs the interpreter for the whole-array kernel,
    while the blocked path prefers its compiled ``vmap`` flavor unless
    ``interpret=True`` asks for the Pallas interpreter explicitly.
    """
    supported = (backend_supports_pallas() if supported is None
                 else supported)
    budget = vmem_budget_bytes() if budget is None else budget
    tile = int(getattr(cfg, "sim_tile_nodes", 0))
    if use_pallas is False:
        return "dense", 0, False
    if tile > 0:
        return "blocked", tile, bool(interpret) and not supported
    if use_pallas:
        interp = (interpret if interpret is not None else not supported)
        return "whole", 0, bool(interp)
    if not supported:
        return "dense", 0, False
    if state_footprint_bytes(meta, cfg) <= budget:
        return "whole", 0, False
    tile = auto_tile_nodes(meta, cfg, budget)
    if tile:
        return "blocked", tile, False
    return "dense", 0, False


# Dispatch decisions are diagnosable from the job log: one line per
# distinct (path, nodes, algo, tile) on stderr unless SIMSTEP_LOG=0.
_LOG = EventLog(
    verbose=os.environ.get("SIMSTEP_LOG", "1").lower()
    not in ("0", "false", "off"),
    stream=sys.stderr)
_LOGGED: set = set()


def _log_dispatch(path: str, meta: dict, cfg: SimConfig, tile: int,
                  interpret: bool) -> None:
    key = (path, meta["N"], int(cfg.algo), tile, bool(interpret))
    if key in _LOGGED:
        return
    _LOGGED.add(key)
    _LOG.event("simstep_dispatch", cat="kernel", path=path,
               nodes=meta["N"], algo=Algo(cfg.algo).name,
               tile_nodes=tile, interpret=bool(interpret),
               footprint_bytes=state_footprint_bytes(meta, cfg),
               budget_bytes=vmem_budget_bytes())


def make_step(meta: dict, cfg: SimConfig,
              use_pallas: bool | None = None,
              interpret: bool | None = None):
    """Build the fused per-cycle transition for one simulation cell.

    Path selection is :func:`resolve_path` (whole-array Pallas /
    blocked Pallas / fused dense, by backend, footprint and
    ``cfg.sim_tile_nodes``); the decision is logged via
    :mod:`repro.obs.log`.  All paths are bit-identical — forcing one
    can change the op schedule, never a result.
    """
    path, tile, interp = resolve_path(meta, cfg, use_pallas, interpret)
    if path == "whole":
        run_cycle = make_simstep_pallas(make_cycle_fn(meta, cfg),
                                        interpret=interp)
    elif path == "blocked":
        tile_fn, finish_fn = make_cycle_parts(meta, cfg)
        compiled = backend_supports_pallas()
        flavor = "pallas" if (compiled or interp) else "xla"
        run_cycle = make_simstep_blocked(
            meta, cfg, tile_fn, finish_fn, tile, flavor=flavor,
            interpret=interp and not compiled)
    else:
        run_cycle = make_cycle_fn(meta, cfg)
    _log_dispatch(path, meta, cfg, tile, interp)
    algo = Algo(cfg.algo)
    n, ndim = meta["N"], meta["NDIM"]

    def step(tables, state, cycle):
        # PRNG advance stays outside the kernel (no key ops in Pallas);
        # split_rand consumes the key exactly like the unfused step, so
        # the streams stay aligned cycle for cycle.
        key, rand = split_rand(state["key"], algo, n, ndim)
        core = {k: v for k, v in state.items() if k != "key"}
        core = run_cycle(tables, core, rand, cycle)
        core["key"] = key
        return core, None

    return step
