"""Public op: the fused flit-step with backend-aware dispatch.

Mirrors :mod:`repro.kernels.possibility.ops`: defaults are the COMPILED
paths.  On backends with Pallas support (TPU/GPU) the fused cycle runs
as one Pallas kernel; elsewhere (CPU) the call auto-falls back to the
fused dense jnp body, which XLA jit-compiles — the interpreter is never
the default anywhere.  Pass ``use_pallas`` / ``interpret`` explicitly
to pin a path (the differential battery runs the Pallas kernel in
interpret mode on CPU to keep it covered).

The entry point is :func:`make_step`: it returns a drop-in replacement
for the unfused ``repro.noc.sim._make_step`` transition — same
``step(tables, state, cycle) -> (state, None)`` contract, same state
pytree, bit-identical arrays — selected by ``SimConfig.use_kernel``.
"""

from __future__ import annotations

import jax

from repro.noc.simconfig import Algo, SimConfig
from .kernel import make_simstep_pallas
from .ref import make_cycle_fn, split_rand


def backend_supports_pallas() -> bool:
    """Compiled Pallas lowering exists on TPU/GPU only."""
    return jax.default_backend() in ("tpu", "gpu")


# On-chip budget for the whole-array kernel (VMEM is ~16 MB/core on
# TPU); above it the auto path uses the fused dense body instead — the
# single-program kernel would not fit until the flit buffer is blocked
# over node ranges (see kernel.py's capacity note).
VMEM_BUDGET_BYTES = 10 * 2**20


def state_footprint_bytes(meta: dict, cfg: SimConfig) -> int:
    """Approximate bytes the kernel must hold on chip: the state pytree
    plus the traced tables (all int32/float32; small vectors ignored)."""
    n, p, v, nin, c = (meta["N"], meta["P"], meta["V"], meta["NIN"],
                       meta["C"])
    o = meta["O"]
    words = (nin * cfg.buf_per_vc * 10          # packed flits (NF words)
             + n * cfg.src_queue_pkts * 5       # packed qpkts (NQ words)
             + 3 * n * n                        # next_seq, exp_seq, rbits
             + n * p * v + n * p                # out_held, rr
             + 8 * nin + 10 * n + 5 * c         # per-input/node/chan vecs
             + o * n * n + 3 * n * n)           # port/esc tables, choice, cdf
    if cfg.telemetry:
        # repro.obs.probe ring buffers ride the state pytree too
        words += cfg.tel_slots * (c + 1 + 4 + cfg.tel_occ_bins
                                  + cfg.lat_bins)
    if cfg.watchdog:
        # repro.noc.watchdog stall/throttle/trip counters
        words += nin + n + 2
    return 4 * words


def make_step(meta: dict, cfg: SimConfig,
              use_pallas: bool | None = None,
              interpret: bool | None = None):
    """Build the fused per-cycle transition for one simulation cell.

    ``use_pallas=None`` resolves to the backend's compiled support AND
    the state fitting the on-chip budget (past it, the whole-array
    kernel cannot hold the packed flit records in VMEM, so the auto
    path runs the fused dense body even on TPU/GPU — pass
    ``use_pallas=True`` to force the kernel anyway); ``interpret=None``
    resolves to compiled where supported and to the interpreter only
    when the Pallas path was explicitly requested on a backend that
    cannot compile it.
    """
    if use_pallas is None:
        use_pallas = (backend_supports_pallas()
                      and state_footprint_bytes(meta, cfg)
                      <= VMEM_BUDGET_BYTES)
    if interpret is None:
        interpret = use_pallas and not backend_supports_pallas()
    cycle_fn = make_cycle_fn(meta, cfg)
    run_cycle = (make_simstep_pallas(cycle_fn, interpret=interpret)
                 if use_pallas else cycle_fn)
    algo = Algo(cfg.algo)
    n, ndim = meta["N"], meta["NDIM"]

    def step(tables, state, cycle):
        # PRNG advance stays outside the kernel (no key ops in Pallas);
        # split_rand consumes the key exactly like the unfused step, so
        # the streams stay aligned cycle for cycle.
        key, rand = split_rand(state["key"], algo, n, ndim)
        core = {k: v for k, v in state.items() if k != "key"}
        core = run_cycle(tables, core, rand, cycle)
        core["key"] = key
        return core, None

    return step
