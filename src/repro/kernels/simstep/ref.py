"""Fused per-cycle flit-step: the simulator hot path, tile-decomposed.

:func:`make_cycle_parts` builds the full per-cycle transition — packet
generation, source-queue pushes, flit injection, table-routed port
selection, switch allocation, flit movement, credit/lock updates and
statistics — as TWO jnp functions over the packed flit records:

* ``tile_fn`` — everything a node range can do on its own slice of the
  state (stages 1–6 below, *except* the network receive-pushes): one
  tile of nodes plus their input-VC FIFOs, reading only one whole-array
  operand (``fs_pre``, the pre-cycle FIFO occupancy, for credit checks
  that target neighbour inputs).  Besides its updated slice it emits a
  ``mov`` record per (node, output port) — the granted winner flit with
  its routing decision, the "halo" of flits about to cross tile
  boundaries — and a small vector of integer partial sums.
* ``finish_fn`` — the cross-tile epilogue on the re-assembled state:
  receive-side FIFO pushes (a flit granted toward a neighbour lands in
  that neighbour's input, which may live in another tile), watchdog
  livelock throttling, and all global statistics/telemetry, consuming
  only ``mov`` + the partials.

The same parts serve every backend (dispatched by
:mod:`repro.kernels.simstep.ops`):

* dense fallback — :func:`make_cycle_fn` composes ``tile_fn`` over the
  whole network (one tile) with ``finish_fn``; XLA jit-compiles it
  directly (the CPU path);
* whole-array Pallas — :mod:`repro.kernels.simstep.kernel` hands every
  operand to a single-program ``pallas_call`` running the same
  composition on chip;
* blocked Pallas — the kernel module grids ``tile_fn`` over node tiles
  with per-tile BlockSpecs (double-buffered HBM→VMEM streaming) and
  runs ``finish_fn`` outside the kernel, so networks whose state
  exceeds VMEM still run the Pallas path.

**Exact-equivalence contract.**  The unfused oracle is
``repro.noc.sim._make_step``; every place these bodies differ from it
is an integer-exact or provably bit-identical rewrite:

* destination sampling — the O(N²) dense CDF compare-and-count becomes
  a vectorized binary search.  CDF rows are cumsums of non-negative
  float32, hence non-decreasing, so the upper-bound partition point
  equals the dense ``(cdf <= u).sum(1)`` count.
* ``next_seq`` and the reorder bookkeeping — dense one-hot row updates
  become int32 scatters at the same (per-row unique) indices.
* credit/adaptive reads go through ``fs_pre`` (the pre-cycle FIFO
  sizes) instead of the live post-injection array.  Equal by
  construction: every *consumed* read targets a network receive port
  (via the ``recv_port`` table, which never maps to the local port),
  and same-cycle injection only touches local-port FIFOs.  Unconsumed
  reads (invalid heads, missing-port sentinels clipped in range) are
  masked by ``valid``/``elig`` before they can propagate — exactly as
  in the oracle.
* receive pushes moved after allocation of *all* tiles — order-safe
  because push slots derive from post-pop ``fifo_start``/``fifo_size``
  (the oracle's own pops-then-pushes order) and each cycle's push
  indices are unique (point-to-point links: one winner per channel).

The per-tile/epilogue split itself changes no values: switch
allocation is per-node (argmin over a node's own inputs), all stage
1–6 state writes land in the owning tile, and the integer partial sums
are order-independent.

Everything else is copied operation-for-operation (same op order, same
dtypes, same clip/sentinel conventions).  RNG is hoisted out of the
body: :func:`split_rand` consumes the per-lane key with the identical
split/draw sequence as the unfused step, and the drawn uniforms enter
the body as data — required by the Pallas paths (no key ops inside a
kernel) and bit-preserving by construction.  The differential battery
(``tests/test_simstep_kernel.py``) pins fused == unfused from
randomized mid-flight states across topologies, algorithms and all
three dispatch paths.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.noc.simconfig import (Algo, SimConfig, NF, F_SRC, F_DST,
                                 F_INTER, F_SEQ, F_TIME, F_HOPS, F_ORDER,
                                 F_HEAD, F_TAIL, F_PHASE, Q_DST, Q_INTER,
                                 Q_ORDER, Q_TIME, Q_SEQ)
from repro.obs.probe import resolved_epoch

# Python literal, not a jnp scalar: the Pallas path traces the cycle
# body as a kernel, which must not capture concrete device arrays.
_BIG = 1 << 30

# Node count from which the O(N²)-avoiding rewrites beat the dense
# formulations (measured on CPU; on accelerators the kernel's win is
# memory residency, which is size-independent).
_WIDE_N = 256

# State keys the cycle body transforms — everything in
# ``repro.noc.sim.fresh_state`` except the PRNG key, which the step
# wrapper (ops.make_step) advances outside the kernel.  With
# ``SimConfig.telemetry`` the state additionally carries the
# ``repro.obs.probe.TEL_KEYS`` ring buffers, and with
# ``SimConfig.watchdog`` the ``repro.noc.watchdog.WD_KEYS`` counters;
# the kernel wrappers are generic over the state dict's keys, so both
# flow through every backend unchanged.
CORE_KEYS = (
    "flits", "fifo_start", "fifo_size", "lock_op", "lock_ov", "out_held",
    "rr", "qpkts", "q_start", "q_size", "prog", "next_seq", "exp_seq",
    "rbits", "node_fwd", "eject_flits", "chan_fwd", "chan_seen", "lat_sum",
    "lat_cnt", "lat_max", "lat_hist", "reorder_max", "injected", "offered",
    "dropped", "eject_total", "meas_cnt", "rate", "cycle0", "inject_until",
    "measure_until",
)

# --------------------------------------------------------------------- #
# tile-decomposition layout (shared with kernel.py's BlockSpecs and
# ops.py's capacity math — ONE source of truth for what streams per tile)
# --------------------------------------------------------------------- #
# State keys tile_fn reads/writes, by leading axis: node-major (N, ...)
# vs input-major (NIN, ...); scalars ride alongside read-only.
TILE_NODE_KEYS = ("out_held", "rr", "qpkts", "q_start", "q_size", "prog",
                  "next_seq")
TILE_INPUT_KEYS = ("flits", "fifo_start", "fifo_size", "lock_op", "lock_ov")
TILE_SCALAR_KEYS = ("rate", "cycle0", "inject_until")


def tile_state_keys(cfg: SimConfig):
    """(node_keys, input_keys, scalar_keys) the tile body touches for
    this config — the watchdog adds one array to each tiled class."""
    node = TILE_NODE_KEYS + (("wd_throttle",) if cfg.watchdog else ())
    inp = TILE_INPUT_KEYS + (("wd_stall",) if cfg.watchdog else ())
    return node, inp, TILE_SCALAR_KEYS


# How each ``_Tables`` field blocks over a node tile: axis kind is
# "node" (leading dim N, or axis 1 for the (O, N, N) port tables),
# "input" (leading dim NIN), or None (whole-array: either tiny or
# genuinely global).  ``chan_src_n``/``chan_src_p`` are epilogue-only
# but kept here so the kernel wrappers stay generic over all fields.
TABLE_TILE_AXES = dict(
    port=("node", 1), choice=("node", 0), neighbor=("node", 0),
    recv_port=("node", 0), cdf=("node", 0), p_gen=("node", 0),
    coords=None, strides=None, n_of=("input", 0), p_of=("input", 0),
    v_of=("input", 0), chan_src_n=None, chan_src_p=None,
    chan_of=("node", 0), chan_bw=None, esc_port=("node", 0),
)

# tile_fn's ``mov`` halo record per (node, out-port): the NF flit words
# of the granted winner, its routing decision (op, ov, route_phase) and
# the grant flag — everything the epilogue needs for receive pushes,
# watchdog livelock handling and statistics.
MOV_W = NF + 4
# tile_fn's integer partial sums (order-independent across tiles).
N_PART = 5
(PART_GEN, PART_PUSH, PART_SHED, PART_INJ, PART_STALL) = range(N_PART)


def split_rand(key, algo: Algo, n: int, ndim: int):
    """Advance one lane's PRNG by exactly one cycle.

    Identical key consumption to the unfused step: one 5-way split per
    cycle, a 3-way split of the metadata key, and per-algorithm draws
    from the same subkeys — so the fused and unfused paths see the same
    random bits cycle for cycle.  Returns (new_key, rand dict)."""
    key, kg, kd, km, _kv = jax.random.split(key, 5)
    k1, k2, k3 = jax.random.split(km, 3)
    rand = {"u": jax.random.uniform(kg, (n,)),
            "ud": jax.random.uniform(kd, (n,))}
    if algo == Algo.O1TURN:
        rand["ob"] = jax.random.bernoulli(k1, 0.5, (n,))
    elif algo == Algo.VALIANT:
        rand["ri"] = jax.random.randint(k2, (n,), 0, n)
    elif algo == Algo.ROMM:
        rand["ur"] = jax.random.uniform(k3, (n, ndim))
    return key, rand


def make_cycle_parts(meta: dict, cfg: SimConfig):
    """Build the tile-decomposed per-cycle transition:
    ``(tile_fn, finish_fn)``.

    ``tile_fn(t, ts, rand, fs_pre, cycle, node0) -> (new_ts, mov, parts)``
        runs stages 1–6 (minus receive pushes) for one node tile.
        ``t`` is a ``_Tables`` whose fields are sliced to the tile per
        :data:`TABLE_TILE_AXES`; ``ts`` the tile's state slice
        (:func:`tile_state_keys`) plus the read-only scalars; ``rand``
        this cycle's draws sliced to the tile's nodes; ``fs_pre`` the
        whole-network PRE-cycle ``fifo_size``; ``cycle`` the in-chunk
        cycle index; ``node0`` the tile's first absolute node id
        (python int or traced scalar).  ``new_ts`` holds the updated
        node/input keys only; ``mov`` is (tn, P, MOV_W) int32; ``parts``
        (N_PART,) int32.

    ``finish_fn(t, state, mov, parts, cycle) -> state``
        the epilogue over the re-assembled full state (``t`` unsliced,
        ``mov`` (N, P, MOV_W), ``parts`` summed over tiles).
    """
    algo = Algo(cfg.algo)
    n, p, v, nin = meta["N"], meta["P"], meta["V"], meta["NIN"]
    p_local = meta["P_LOCAL"]
    num_orders = meta["O"]
    if algo == Algo.ODDEVEN and meta["NDIM"] != 2:
        raise ValueError("odd-even routing is a 2D turn model; "
                         f"topology has ndim={meta['NDIM']}")
    b, q, l = cfg.buf_per_vc, cfg.src_queue_pkts, cfg.packet_len
    pv = p * v
    two_phase = algo in (Algo.VALIANT, Algo.ROMM)
    tel_epoch = resolved_epoch(cfg)  # 0 ⇔ telemetry off
    watchdog = bool(cfg.watchdog)
    # the O(N²)-rewrite gate stays a function of the NETWORK size, not
    # the tile size: both formulations are exact, so this choice can
    # never change a result, only the op schedule
    wide = n >= _WIDE_N
    # binary-search iteration count: the [0, n] interval at least halves
    # every guarded step, so bit_length(n) steps always converge
    search_iters = max(int(n).bit_length(), 1)

    def sample_dst(cdf, ud):
        """Upper-bound binary search per source row: the count of CDF
        entries <= ud — bit-identical to the unfused dense
        ``(cdf <= ud[:, None]).sum(1)`` because each row is
        non-decreasing (cumsum of non-negative float32).  ``cdf`` may be
        a row-slice (tile) of the full table; columns stay full-width."""
        rows = jnp.arange(cdf.shape[0])
        lo = jnp.zeros(cdf.shape[0], jnp.int32)
        hi = jnp.full((cdf.shape[0],), n, jnp.int32)
        for _ in range(search_iters):
            mid = (lo + hi) // 2
            le = cdf[rows, jnp.clip(mid, 0, n - 1)] <= ud
            upd = lo < hi
            lo = jnp.where(upd & le, mid + 1, lo)
            hi = jnp.where(upd & ~le, mid, hi)
        return lo

    def fifo_push(state, idx, ok, records, nfull):
        """Append packed flit ``records`` (K, NF) to FIFOs ``idx`` where
        ``ok`` — ONE scatter with a contiguous NF-word payload.
        ``nfull`` is the FIFO count of the (possibly tile-sliced)
        arrays; out-of-range ⇒ dropped."""
        slot = (state["fifo_start"][idx] + state["fifo_size"][idx]) % b
        safe_idx = jnp.where(ok, idx, nfull)
        state["flits"] = state["flits"].at[safe_idx, slot].set(
            records, mode="drop")
        state["fifo_size"] = state["fifo_size"].at[safe_idx].add(
            1, mode="drop")
        return state

    def gen_metadata(t, rand, src_l, src_a, dst):
        """Per-algo packet metadata (order, inter) from the hoisted
        draws — same arithmetic as the unfused ``gen_metadata``.
        ``src_l`` indexes tile-sliced tables (choice), ``src_a`` the
        whole-array ones (coords); identical when the tile is the whole
        network."""
        tn = src_l.shape[0]
        if algo == Algo.XY:
            order = jnp.zeros(tn, jnp.int32)
        elif algo == Algo.YX:
            order = jnp.full((tn,), num_orders - 1, jnp.int32)
        elif algo == Algo.O1TURN:
            order = jnp.where(rand["ob"], num_orders - 1, 0).astype(
                jnp.int32)
        elif algo == Algo.BIDOR:
            order = t.choice[src_l, dst]
        else:
            order = jnp.zeros(tn, jnp.int32)
        if algo == Algo.VALIANT:
            inter = rand["ri"]
        elif algo == Algo.ROMM:
            cs, cd = t.coords[src_a], t.coords[dst]
            lo = jnp.minimum(cs, cd)
            hi = jnp.maximum(cs, cd)
            ic = lo + (rand["ur"] * (hi - lo + 1)).astype(jnp.int32)
            ic = jnp.clip(ic, lo, hi)
            inter = (ic * t.strides).sum(-1)
        else:
            inter = jnp.full((tn,), -1, jnp.int32)
        return order, inter

    def oddeven_route(t, cur, src, target, free_by_port):
        """Chiu's minimal adaptive odd-even ROUTE + credit-based selection.

        Ports: 0=+x(E) 1=−x(W) 2=+y 3=−y.  Returns the chosen port.
        ``cur``/``src``/``target`` are absolute node ids (coords is a
        whole-array table).
        """
        cx = t.coords[cur, 0]
        sx = t.coords[src, 0]
        dx = t.coords[target, 0] - cx
        dy = t.coords[target, 1] - t.coords[cur, 1]
        y_port = jnp.where(dy > 0, 2, 3)
        east_ok = (dx > 0) & ((dy == 0)
                              | (t.coords[target, 0] % 2 == 1) | (dx != 1))
        y_ok_east = (dx > 0) & (dy != 0) & ((cx % 2 == 1) | (cx == sx))
        west_ok = dx < 0
        y_ok_west = (dx < 0) & (dy != 0) & (cx % 2 == 0)
        y_ok_straight = (dx == 0) & (dy != 0)
        x_port = jnp.where(dx > 0, 0, 1)
        x_ok = east_ok | west_ok
        y_ok = y_ok_east | y_ok_west | y_ok_straight
        fx = jnp.take_along_axis(free_by_port, x_port[:, None], 1)[:, 0]
        fy = jnp.take_along_axis(free_by_port, y_port[:, None], 1)[:, 0]
        prefer_y = y_ok & ((~x_ok) | (fy > fx))
        return jnp.where(prefer_y, y_port, x_port), x_ok, y_ok

    def tile_fn(t, ts, rand, fs_pre, cycle, node0):
        # iotas built inside the body: under a Pallas trace they are
        # kernel ops, not captured host constants (which pallas_call
        # rejects); under the dense jit XLA folds them away identically.
        # Row indices are TILE-LOCAL (they address the sliced arrays);
        # ``na`` carries the absolute node ids for everything that
        # compares against or stamps node identities.
        tn = t.p_gen.shape[0]
        nin_t = tn * pv
        nl = jnp.arange(tn)                 # local node rows
        na = node0 + nl                     # absolute node ids
        til = jnp.arange(nin_t)             # local input rows
        nli = til // pv                     # local node of each input
        cycle = ts["cycle0"] + cycle        # absolute cycle
        new_ts = {k: ts[k] for k in ts
                  if k not in TILE_SCALAR_KEYS}

        # ---------------- 1. packet generation (open loop) -------------- #
        u, ud = rand["u"], rand["ud"]
        gen = (u < (t.p_gen * (ts["rate"] / l))) \
            & (cycle < ts["inject_until"])
        if watchdog:
            # livelock throttle: mask generation at throttled sources —
            # mask only (draws are hoisted), identical to the unfused
            # step.  The throttle SET (a cross-tile scatter from moving
            # flits) lives in finish_fn; oracle ordering is preserved
            # because the oracle's same-cycle set is likewise invisible
            # to this read (it happens in stage 6).
            gen = gen & (ts["wd_throttle"] <= 0)
            new_ts["wd_throttle"] = jnp.maximum(ts["wd_throttle"] - 1, 0)
        raw_dst = (sample_dst(t.cdf, ud) if wide
                   else (t.cdf <= ud[:, None]).sum(1))
        dst = jnp.clip(raw_dst, 0, n - 1).astype(jnp.int32)
        order, inter = gen_metadata(t, rand, nl, na, dst)
        space = ts["q_size"] < q
        push = gen & space
        seq = ts["next_seq"][nl, dst]
        # row s bumps column dst[s] (rows distinct): scatter or one-hot
        if wide:
            new_ts["next_seq"] = ts["next_seq"].at[nl, dst].add(
                push.astype(jnp.int32))
        else:
            new_ts["next_seq"] = ts["next_seq"] + (
                push[:, None] & (jnp.arange(n)[None, :] == dst[:, None]))
        slot = (ts["q_start"] + ts["q_size"]) % q
        row = jnp.where(push, nl, tn)  # drop when not pushing
        qrec = jnp.stack(
            [dst, inter, order, jnp.full((tn,), cycle, jnp.int32), seq], -1)
        new_ts["qpkts"] = ts["qpkts"].at[row, slot].set(qrec, mode="drop")
        new_ts["q_size"] = ts["q_size"] + push

        # ---------------- 2. flit injection (1/cycle/node) -------------- #
        hs = ts["q_start"]
        hpkt = new_ts["qpkts"][nl, hs]  # (tn, NQ)
        h_dst = hpkt[:, Q_DST]
        h_inter = hpkt[:, Q_INTER]
        h_order = hpkt[:, Q_ORDER]
        h_seq = hpkt[:, Q_SEQ]
        h_time = hpkt[:, Q_TIME]
        fl_head = ts["prog"] == 0
        fl_tail = ts["prog"] == l - 1
        phase0 = (h_inter < 0) | (h_inter == na)
        if algo in (Algo.XY, Algo.YX):
            vc_in = (na + h_dst) % v
        elif algo in (Algo.O1TURN, Algo.BIDOR):
            vc_in = h_order % v
        elif two_phase:
            vc_in = phase0.astype(jnp.int32) % v
        else:  # ODDEVEN: local VC with more space
            base = (nl * p + p_local) * v
            sizes = jnp.stack([ts["fifo_size"][base + k]
                               for k in range(v)], 1)
            vc_in = jnp.argmin(sizes, 1).astype(jnp.int32)
        lf_idx = (nl * p + p_local) * v + vc_in
        can = (new_ts["q_size"] > 0) & (ts["fifo_size"][lf_idx] < b)
        inj_rec = jnp.stack(
            [na, h_dst, h_inter, h_seq, h_time,
             jnp.zeros(tn, jnp.int32), h_order, fl_head.astype(jnp.int32),
             fl_tail.astype(jnp.int32), phase0.astype(jnp.int32)], -1)
        new_ts = fifo_push(new_ts, lf_idx, can, inj_rec, nin_t)
        new_ts["prog"] = jnp.where(can, ts["prog"] + 1, ts["prog"])
        done = can & (new_ts["prog"] >= l)
        new_ts["prog"] = jnp.where(done, 0, new_ts["prog"])
        new_ts["q_start"] = jnp.where(done, (hs + 1) % q, hs)
        new_ts["q_size"] = new_ts["q_size"] - done

        # ---------------- 3. head-of-line + routing --------------------- #
        st_ = ts["fifo_start"]
        g_all = new_ts["flits"][til, st_]  # (NIN_T, NF) one gather
        g = dict(src=g_all[:, F_SRC], dst=g_all[:, F_DST],
                 inter=g_all[:, F_INTER], seq=g_all[:, F_SEQ],
                 time=g_all[:, F_TIME], hops=g_all[:, F_HOPS],
                 order=g_all[:, F_ORDER], head=g_all[:, F_HEAD] != 0,
                 tail=g_all[:, F_TAIL] != 0, phase=g_all[:, F_PHASE] != 0)
        valid = new_ts["fifo_size"] > 0
        route_phase = g["phase"] | (g["inter"] < 0) | (g["inter"] == t.n_of)
        target = jnp.where(route_phase, g["dst"], g["inter"])
        target = jnp.clip(target, 0, n - 1)
        at_dest = target == t.n_of
        locked = ts["lock_op"] >= 0

        # receiver free space per (input, port): for adaptive selection.
        # Reads go through the PRE-cycle whole-network snapshot; every
        # consumed location is a network receive port (untouched by
        # same-cycle injection), so this equals the oracle's live read.
        if algo == Algo.ODDEVEN:
            recv_base = (t.neighbor * p + t.recv_port) * v  # (tn, P)
            free_pv = jnp.stack(
                [b - fs_pre[recv_base + k] for k in range(v)],
                -1)  # (tn, P, V)
            free_port_total = free_pv.sum(-1)  # (tn, P)
            op_ad, _, _ = oddeven_route(
                t, t.n_of, g["src"], target, free_port_total[nli])
            # VC choice: freer VC at the chosen port, must be un-held
            held = ts["out_held"][nli, op_ad] >= 0  # (NIN_T, V)
            f = free_pv[nli, op_ad]  # (NIN_T, V)
            f = jnp.where(held, -1, f)
            ov_route = jnp.argmax(f, -1).astype(jnp.int32)
            op_route = op_ad
        else:
            if algo == Algo.XY:
                eff_order = jnp.zeros(nin_t, jnp.int32)
            elif algo == Algo.YX:
                eff_order = jnp.full((nin_t,), num_orders - 1, jnp.int32)
            elif two_phase:
                eff_order = jnp.zeros(nin_t, jnp.int32)
            else:
                eff_order = g["order"]
            op_route = t.port[eff_order, nli, target]
            if algo in (Algo.XY, Algo.YX):
                ov_route = t.v_of
            elif two_phase:
                ov_route = route_phase.astype(jnp.int32) % v
            else:
                ov_route = g["order"] % v
        op = jnp.where(at_dest, p_local, op_route)
        ov = jnp.where(at_dest, 0, ov_route)
        op = jnp.where(locked, ts["lock_op"], op)
        ov = jnp.where(locked, ts["lock_ov"], ov)
        if watchdog:
            # deadlock escape: stalled heads misroute one hop via the
            # acyclic DOR escape table on the highest VC (escape lane) —
            # same ops as the unfused step
            esc = (ts["wd_stall"] >= cfg.wd_stall_cycles) \
                & valid & g["head"] & ~locked & ~at_dest
            op = jnp.where(esc, t.esc_port[nli, target], op)
            ov = jnp.where(esc, v - 1, ov)

        # ---------------- 4. eligibility -------------------------------- #
        is_eject = op == p_local
        nei = t.neighbor[nli, jnp.clip(op, 0, p - 1)]
        rp = t.recv_port[nli, jnp.clip(op, 0, p - 1)]
        recv_idx = (nei * p + rp) * v + ov
        has_credit = is_eject | (fs_pre[
            jnp.clip(recv_idx, 0, nin - 1)] < b)
        vc_free = ts["out_held"][nli, jnp.clip(op, 0, p - 1), ov] == -1
        needs_alloc = g["head"] & ~locked & ~is_eject
        cycf = cycle.astype(jnp.float32)
        chan_live = (jnp.floor((cycf + 1.0) * t.chan_bw)
                     - jnp.floor(cycf * t.chan_bw)) >= 1.0
        chan_live = jnp.concatenate(
            [chan_live, jnp.zeros((1,), bool)])  # sentinel: no channel
        chan_ok = is_eject | chan_live[
            t.chan_of[nli, jnp.clip(op, 0, p - 1)]]
        elig = valid & has_credit & chan_ok & (vc_free | ~needs_alloc)

        # ---------------- 5. switch allocation (round-robin) ------------ #
        # all output ports allocated at once: score (tn, PV, P), winner
        # per (node, port) column — ports are independent, so this is
        # exactly the per-port round-robin pick; allocation never crosses
        # a node, so it never crosses a tile either
        in_local = til % pv  # input index within its node
        clip_op = jnp.clip(op, 0, p - 1)
        elig2 = elig.reshape(tn, pv)
        op2 = op.reshape(tn, pv)
        mask_po = elig2[:, :, None] & (op2[:, :, None]
                                       == jnp.arange(p)[None, None, :])
        score = (jnp.arange(pv)[None, :, None]
                 - ts["rr"][:, None, :]) % pv
        score = jnp.where(mask_po, score, _BIG)
        win = jnp.argmin(score, 1).astype(jnp.int32)      # (tn, P)
        ok = score.min(1) < _BIG
        grants = jnp.where(ok, win, -1)
        new_ts["rr"] = jnp.where(ok, (win + 1) % pv, ts["rr"])

        # ---------------- 6. move granted flits (tile part) ------------- #
        granted = grants >= 0  # (tn, P)
        # input-centric pop flag: input i moved iff it won its output port
        popped = elig & (grants[nli, clip_op] == in_local)
        win_nin = jnp.where(granted,
                            nl[:, None] * pv + grants, nin_t)  # drop idx
        win_flat = jnp.clip(win_nin, 0, nin_t - 1).reshape(-1)
        # winner records + routing decision, ONE gather of NF+3 words
        g_ext = jnp.concatenate(
            [g_all, op[:, None], ov[:, None],
             route_phase.astype(jnp.int32)[:, None]], -1)
        w_ext = g_ext[win_flat].reshape(tn, p, NF + 3)
        # pops (elementwise — ``popped`` marks at most one flit per input)
        new_ts["fifo_start"] = jnp.where(popped, (st_ + 1) % b, st_)
        new_ts["fifo_size"] = new_ts["fifo_size"] - popped
        # receive-side pushes happen in finish_fn: the destination input
        # may belong to another tile.  ``mov`` carries everything needed.
        # wormhole locks (elementwise): set on head (non-tail), clear on
        # tail
        set_lock_i = popped & g["head"] & ~g["tail"]
        clr_lock_i = popped & g["tail"]
        new_ts["lock_op"] = jnp.where(
            set_lock_i, op, jnp.where(clr_lock_i, -1, ts["lock_op"]))
        new_ts["lock_ov"] = jnp.where(
            set_lock_i, ov, jnp.where(clr_lock_i, -1, ts["lock_ov"]))
        # out_held bookkeeping (elementwise over (tn, P, V); net ports
        # only)
        w_op = w_ext[..., NF]
        w_all = w_ext[..., :NF]
        net = granted & (w_op != p_local)
        w_head = w_all[..., F_HEAD] != 0
        w_tail = w_all[..., F_TAIL] != 0
        w_ov = w_ext[..., NF + 1]
        hold_set = granted & w_head & ~w_tail & net
        hold_clr = granted & w_tail & net
        vmask = ((hold_set | hold_clr)[..., None]
                 & (jnp.arange(v)[None, None, :] == w_ov[..., None]))
        hold_val = jnp.where(hold_set, grants, -1)
        new_ts["out_held"] = jnp.where(vmask, hold_val[..., None],
                                       ts["out_held"])
        stall_trips = jnp.int32(0)
        if watchdog:
            # stall bookkeeping — identical op for op to the unfused
            # oracle; the livelock throttle/trip (from moving flits,
            # cross-tile) completes in finish_fn
            new_stall = jnp.where(valid & ~popped, ts["wd_stall"] + 1, 0)
            stall_trips = (new_stall == cfg.wd_stall_cycles).sum()
            new_ts["wd_stall"] = new_stall

        mov = jnp.concatenate(
            [w_ext, granted.astype(jnp.int32)[..., None]], -1)
        parts = jnp.stack([gen.sum(), push.sum(), (gen & ~space).sum(),
                           can.sum(), stall_trips]).astype(jnp.int32)
        return new_ts, mov, parts

    def finish_fn(t, state, mov, parts, cycle):
        n_arange = jnp.arange(n)
        cycle = state["cycle0"] + cycle    # absolute cycle
        measuring = (cycle >= cfg.warmup) & (cycle < state["measure_until"])
        state["meas_cnt"] += measuring.astype(jnp.int32)
        state["offered"] += jnp.where(measuring, parts[PART_GEN], 0)
        state["dropped"] += jnp.where(measuring, parts[PART_SHED], 0)
        state["injected"] += parts[PART_INJ]

        # ------------- 6b. receive-side pushes (cross-tile) ------------- #
        w_ext = mov[..., :NF + 3]
        granted = mov[..., NF + 3] != 0    # (N, P)
        w_all = w_ext[..., :NF]
        w_op = w_ext[..., NF]
        w_ov = w_ext[..., NF + 1]
        w_phase = w_ext[..., NF + 2]
        # pushes (network ports only): one packed scatter.  Slot indices
        # derive from post-pop fifo_start/fifo_size (Phase A already
        # popped), matching the oracle's pops-then-pushes order; the
        # per-cycle push targets are unique (one winner per channel), so
        # scatter order across tiles cannot matter.
        net = granted & (w_op != p_local)
        dest_nei = t.neighbor[n_arange[:, None], jnp.clip(w_op, 0, p - 1)]
        dest_rp = t.recv_port[n_arange[:, None], jnp.clip(w_op, 0, p - 1)]
        dest_idx = (dest_nei * p + dest_rp) * v + w_ov
        push_rec = w_all.at[..., F_HOPS].add(1)
        push_rec = push_rec.at[..., F_PHASE].set(w_phase)
        state = fifo_push(state, dest_idx.reshape(-1), net.reshape(-1),
                          push_rec.reshape(-1, NF), nin)
        if watchdog:
            # livelock trip/throttle from the moving flits — identical
            # values to the oracle: the stage-1 decrement (tile phase)
            # read pre-cycle throttles, and this set overwrites it, so
            # final = where(livelocked-source, C, decremented) either way
            state["wd_trips"] = state["wd_trips"].at[0].add(
                parts[PART_STALL])
            hops_now = push_rec[..., F_HOPS]
            lv = net & (hops_now > cfg.wd_hop_limit)
            lv_src = jnp.where(lv, w_all[..., F_SRC], n)
            state["wd_throttle"] = state["wd_throttle"].at[
                lv_src.reshape(-1)].set(cfg.wd_throttle_cycles, mode="drop")
            state["wd_trips"] = state["wd_trips"].at[1].add(
                (net & (hops_now == cfg.wd_hop_limit + 1)).sum())

        # ---------------- 7. statistics --------------------------------- #
        state["node_fwd"] = state["node_fwd"] + jnp.where(
            measuring, granted.sum(1), 0)
        state["chan_fwd"] = state["chan_fwd"] + (
            net & measuring)[t.chan_src_n, t.chan_src_p]
        state["chan_seen"] = state["chan_seen"] + (
            net[t.chan_src_n, t.chan_src_p])
        ej_n = granted[:, p_local]
        wl = w_ext[:, p_local, :]  # (N, NF+3) local-port winner records
        state["eject_total"] += ej_n.sum()
        state["eject_flits"] = state["eject_flits"] + jnp.where(
            measuring, ej_n, 0)
        tail_ej = ej_n & (wl[:, F_TAIL] != 0)
        lat = (cycle - wl[:, F_TIME]) + wl[:, F_HOPS] + 1  # +1: eject hop
        lat_ok = tail_ej & (wl[:, F_TIME] >= cfg.warmup)
        state["lat_sum"] += jnp.where(lat_ok, lat, 0).sum()
        state["lat_cnt"] += lat_ok.sum()
        state["lat_max"] = jnp.maximum(
            state["lat_max"], jnp.where(lat_ok, lat, 0).max())
        hbin = jnp.minimum(lat // cfg.lat_bin_width, cfg.lat_bins - 1)
        state["lat_hist"] = state["lat_hist"].at[
            jnp.where(lat_ok, hbin, cfg.lat_bins)].add(1, mode="drop")
        # reorder tracking (≤ 1 tail eject per node per cycle: the local
        # port) — per-flow rows updated by scatter at unique indices
        te = tail_ej
        src_v = wl[:, F_SRC]
        seq_v = wl[:, F_SEQ]
        src_safe = jnp.where(te, src_v, 0)
        exp = state["exp_seq"][n_arange, src_safe]
        bits = state["rbits"][n_arange, src_safe]
        off = seq_v - exp
        in_win = (off >= 0) & (off < 32)
        off_c = jnp.clip(off, 0, 31).astype(jnp.uint32)
        bits2 = jnp.where(te & in_win,
                          bits | (jnp.uint32(1) << off_c),
                          bits)
        lowmask = (bits2 & ~(bits2 + 1))  # trailing ones
        run = jax.lax.population_count(lowmask)
        advance = te & ((bits2 & 1) == 1)
        exp2 = jnp.where(advance, exp + run, exp)
        run_c = jnp.minimum(run, 31).astype(jnp.uint32)
        bits3 = jnp.where(advance,
                          jnp.where(run >= 32, jnp.uint32(0), bits2 >> run_c),
                          bits2)
        if wide:
            touch_row = jnp.where(te, n_arange, n)  # drop untouched nodes
            state["exp_seq"] = state["exp_seq"].at[
                touch_row, src_safe].set(exp2, mode="drop")
            state["rbits"] = state["rbits"].at[
                touch_row, src_safe].set(bits3, mode="drop")
        else:
            src_oh = te[:, None] & (n_arange[None, :] == src_safe[:, None])
            state["exp_seq"] = jnp.where(src_oh, exp2[:, None],
                                         state["exp_seq"])
            state["rbits"] = jnp.where(src_oh, bits3[:, None],
                                       state["rbits"])
        occ = jax.lax.population_count(state["rbits"]).sum(1) * l
        state["reorder_max"] = jnp.maximum(
            state["reorder_max"],
            jnp.where(measuring, occ.max(), 0).astype(jnp.int32))

        # ------------- 8. telemetry probes (optional) ------------------- #
        # Identical op for op to the unfused oracle's block: reads
        # existing cycle values, writes only the tel_* ring buffers,
        # consumes no RNG — core statistics stay bit-identical with
        # telemetry on or off, on every backend.
        if tel_epoch:
            slot = (cycle // tel_epoch) % cfg.tel_slots
            state["tel_cycles"] = state["tel_cycles"].at[slot].add(1)
            state["tel_chan"] = state["tel_chan"].at[slot].add(
                net[t.chan_src_n, t.chan_src_p].astype(jnp.int32))
            state["tel_counts"] = state["tel_counts"].at[slot].add(
                jnp.stack([parts[PART_GEN], parts[PART_PUSH],
                           parts[PART_SHED],
                           tail_ej.sum()]).astype(jnp.int32))
            nb = cfg.tel_occ_bins
            obin = jnp.minimum(state["q_size"].sum() * nb // (n * q),
                               nb - 1)
            state["tel_qocc"] = state["tel_qocc"].at[slot, obin].add(1)
            state["tel_lat"] = state["tel_lat"].at[
                slot, jnp.where(tail_ej, hbin, cfg.lat_bins)].add(
                1, mode="drop")
        return state

    return tile_fn, finish_fn


def make_cycle_fn(meta: dict, cfg: SimConfig):
    """Build ``cycle_fn(tables, state, rand, cycle) -> state`` — the
    fused per-cycle transition over the core state arrays (no PRNG
    key; ``rand`` carries this cycle's draws from :func:`split_rand`,
    ``cycle`` is the in-chunk cycle index).

    This is the single-tile composition of :func:`make_cycle_parts`
    (the whole network as one tile at ``node0 = 0``), so the dense
    fallback, the whole-array Pallas kernel and the blocked grid all
    execute the SAME decomposed body — the blocked path cannot diverge
    from the others by construction.
    """
    tile_fn, finish_fn = make_cycle_parts(meta, cfg)
    node_keys, input_keys, scalar_keys = tile_state_keys(cfg)

    def cycle_fn(t, state, rand, cycle):
        fs_pre = state["fifo_size"]
        ts = {k: state[k] for k in node_keys + input_keys + scalar_keys}
        new_ts, mov, parts = tile_fn(t, ts, rand, fs_pre, cycle, 0)
        state = dict(state)
        state.update(new_ts)
        return finish_fn(t, state, mov, parts, cycle)

    return cycle_fn
