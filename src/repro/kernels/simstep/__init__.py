"""Fused flit-step kernel: the simulator's per-cycle hot path as one
on-chip pass (Pallas on TPU/GPU, fused dense jnp on CPU), bit-identical
to the unfused ``repro.noc.sim`` step it replaces."""

from .ops import (backend_supports_pallas, make_step, resolve_path,
                  state_footprint_bytes, vmem_budget_bytes)
from .ref import CORE_KEYS, make_cycle_fn, make_cycle_parts, split_rand

__all__ = ["backend_supports_pallas", "make_step", "resolve_path",
           "state_footprint_bytes", "vmem_budget_bytes", "make_cycle_fn",
           "make_cycle_parts", "split_rand", "CORE_KEYS"]
