"""Pallas wrapper: one fused kernel invocation per simulated cycle.

The kernel is a single program over whole-array blocks: every lookup
table, state array and pre-drawn random array is handed to one
``pallas_call``, the fused body (:func:`repro.kernels.simstep.ref.
make_cycle_fn`) runs on the loaded values, and each state array is
written back — the entire per-cycle pipeline (generation, injection,
routing, allocation, movement, statistics) executes out of on-chip
memory instead of bouncing ~40 intermediate arrays through HBM the way
the unfused jnp chain does.

Because the body is the *same function* the dense fallback jit-compiles,
the Pallas path can never diverge from the fallback; the differential
battery (``tests/test_simstep_kernel.py``) pins both to the unfused
oracle.  ``interpret=True`` executes the kernel through the Pallas
interpreter — the CPU coverage path, auto-selected by ``ops`` when the
Pallas route is forced on a backend without compiled support.

Capacity note: with whole-array blocks the full state must fit VMEM on
TPU.  At the default flow-control parameters that holds through 16×16
(~4 MB packed flits); past 32×32 (~13 MB) the flit buffer needs to be
blocked over node ranges before the compiled path is practical.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def make_simstep_pallas(cycle_fn, *, interpret: bool = False):
    """Wrap a fused cycle body as ``run_cycle(tables, core, rand, cycle)``.

    ``tables`` is the simulator's ``_Tables`` NamedTuple, ``core`` the
    state dict without the PRNG key, ``rand`` this cycle's hoisted
    draws.  Scalars ride as (1,)-shaped refs (TPU refs are rank ≥ 1)
    and are squeezed back around the body, so the body sees exactly the
    shapes the dense path sees.
    """

    def run_cycle(tables, core, rand, cycle):
        skeys = sorted(core)
        rkeys = sorted(rand)
        nt = len(tables)
        ns, nr = len(skeys), len(rkeys)
        raw = (list(tables) + [core[k] for k in skeys]
               + [rand[k] for k in rkeys]
               + [jnp.asarray(cycle, jnp.int32)])
        scal = [x.ndim == 0 for x in raw]
        ins = [x[None] if s else x for x, s in zip(raw, scal)]
        n_in = len(ins)
        out_scal = scal[nt:nt + ns]
        out_shape = [jax.ShapeDtypeStruct(ins[nt + i].shape,
                                          ins[nt + i].dtype)
                     for i in range(ns)]

        def body(*refs):
            vals = [r[...] for r in refs[:n_in]]
            vals = [v[0] if s else v for v, s in zip(vals, scal)]
            t = type(tables)(*vals[:nt])
            st = dict(zip(skeys, vals[nt:nt + ns]))
            rd = dict(zip(rkeys, vals[nt + ns:nt + ns + nr]))
            new = cycle_fn(t, st, rd, vals[-1])
            for ref, k, s in zip(refs[n_in:], skeys, out_scal):
                ref[...] = new[k][None] if s else new[k]

        outs = pl.pallas_call(body, out_shape=out_shape,
                              interpret=interpret)(*ins)
        return {k: (o[0] if s else o)
                for k, o, s in zip(skeys, outs, out_scal)}

    return run_cycle
