"""Pallas wrappers: the fused flit-step as on-chip kernel invocations.

Two kernel shapes, both built on the tile-decomposed cycle body of
:mod:`repro.kernels.simstep.ref`:

* **whole-array** (:func:`make_simstep_pallas`) — a single program over
  whole-array blocks: every lookup table, state array and pre-drawn
  random array is handed to one ``pallas_call``, the fused body runs on
  the loaded values, and each state array is written back.  The entire
  per-cycle pipeline executes out of on-chip memory — but the full
  state must fit VMEM, which at the default flow-control parameters
  holds through 16×16 and fails past 32×32.
* **blocked** (:func:`make_simstep_blocked`) — a multi-program grid
  over node tiles: per grid step, Pallas streams one tile's flit/queue
  records plus the tile's slices of the routing tables HBM→VMEM
  (double-buffered automatically by the TPU grid pipeline), runs the
  per-tile phase (``tile_fn``: generation → injection → routing →
  allocation → pops), and writes back the tile plus a ``mov`` halo of
  granted flits.  The cross-tile epilogue (``finish_fn``: receive
  pushes, watchdog livelock, statistics) runs as plain jnp outside the
  kernel on the re-assembled state — it is O(N·P) scatter/reduce work
  with none of the O(N²) tables, so it stays cheap.  Only the active
  tile (plus the small whole-array operands: coords, channel tables and
  the pre-cycle FIFO-occupancy snapshot ``fs_pre``) is ever resident,
  so 64×64+ networks run the Pallas path instead of the dense
  fallback.

Because every path executes the *same* ``tile_fn``/``finish_fn`` pair
(the whole-array kernel and the dense fallback compose them over one
tile), no path can diverge from another; the differential battery
(``tests/test_simstep_kernel.py``) pins all of them to the unfused
oracle.  ``interpret=True`` executes the kernels through the Pallas
interpreter — the CPU coverage path; the blocked dispatcher
additionally offers an ``xla`` flavor (the tile grid as a ``vmap``
over reshaped tile axes) as the *compiled* CPU realization of the
same decomposition.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MOV_W, N_PART, TABLE_TILE_AXES, tile_state_keys

__all__ = ["make_simstep_pallas", "make_simstep_blocked"]


def make_simstep_pallas(cycle_fn, *, interpret: bool = False):
    """Wrap a fused cycle body as ``run_cycle(tables, core, rand, cycle)``.

    ``tables`` is the simulator's ``_Tables`` NamedTuple, ``core`` the
    state dict without the PRNG key, ``rand`` this cycle's hoisted
    draws.  Scalars ride as (1,)-shaped refs (TPU refs are rank ≥ 1)
    and are squeezed back around the body, so the body sees exactly the
    shapes the dense path sees.
    """

    def run_cycle(tables, core, rand, cycle):
        skeys = sorted(core)
        rkeys = sorted(rand)
        nt = len(tables)
        ns, nr = len(skeys), len(rkeys)
        raw = (list(tables) + [core[k] for k in skeys]
               + [rand[k] for k in rkeys]
               + [jnp.asarray(cycle, jnp.int32)])
        scal = [x.ndim == 0 for x in raw]
        ins = [x[None] if s else x for x, s in zip(raw, scal)]
        n_in = len(ins)
        out_scal = scal[nt:nt + ns]
        out_shape = [jax.ShapeDtypeStruct(ins[nt + i].shape,
                                          ins[nt + i].dtype)
                     for i in range(ns)]

        def body(*refs):
            vals = [r[...] for r in refs[:n_in]]
            vals = [v[0] if s else v for v, s in zip(vals, scal)]
            t = type(tables)(*vals[:nt])
            st = dict(zip(skeys, vals[nt:nt + ns]))
            rd = dict(zip(rkeys, vals[nt + ns:nt + ns + nr]))
            new = cycle_fn(t, st, rd, vals[-1])
            for ref, k, s in zip(refs[n_in:], skeys, out_scal):
                ref[...] = new[k][None] if s else new[k]

        outs = pl.pallas_call(body, out_shape=out_shape,
                              interpret=interpret)(*ins)
        return {k: (o[0] if s else o)
                for k, o, s in zip(skeys, outs, out_scal)}

    return run_cycle


# --------------------------------------------------------------------- #
# blocked grid
# --------------------------------------------------------------------- #
def _table_block(field, shape, tn, nin_t):
    """(block_shape, index_map) for one ``_Tables`` field per the
    :data:`TABLE_TILE_AXES` layout — whole-array fields use a constant
    index map (fetched once, kept resident across grid steps)."""
    ax = TABLE_TILE_AXES[field]
    rank = len(shape)
    if ax is None:
        return tuple(shape), (lambda i, _r=rank: (0,) * _r)
    kind, axis = ax
    size = tn if kind == "node" else nin_t
    blk = tuple(size if d == axis else shape[d] for d in range(rank))
    idx = (lambda i, _r=rank, _a=axis:
           tuple(i if d == _a else 0 for d in range(_r)))
    return blk, idx


def _lead_block(shape, lead):
    """(block_shape, index_map) tiling the leading axis to ``lead``."""
    rank = len(shape)
    blk = (lead,) + tuple(shape[1:])
    idx = (lambda i, _r=rank: (i,) + (0,) * (_r - 1))
    return blk, idx


def make_simstep_blocked(meta: dict, cfg, tile_fn, finish_fn,
                         tile_nodes: int, *, flavor: str = "pallas",
                         interpret: bool = False):
    """Wrap the tile-decomposed cycle body as a blocked
    ``run_cycle(tables, core, rand, cycle)`` over ``tile_nodes``-node
    tiles.

    ``flavor``:

    * ``"pallas"`` — grid ``pallas_call``: one program per tile, tiled
      BlockSpecs stream the tile's state/table slices HBM→VMEM (the TPU
      grid pipeline double-buffers consecutive tiles automatically);
      ``interpret=True`` runs it through the Pallas interpreter (CPU
      coverage).
    * ``"xla"`` — the same tile decomposition as a ``jax.vmap`` over
      reshaped (ntiles, tile, ...) axes — the compiled CPU realization
      (tile bodies are data-parallel; batching them is value-identical
      since the body has no cross-tile reductions).

    Both end with the identical jnp ``finish_fn`` epilogue on the
    re-assembled state.  Requires ``tile_nodes`` to divide the node
    count.
    """
    n, p, v, nin = meta["N"], meta["P"], meta["V"], meta["NIN"]
    pv = p * v
    tn = int(tile_nodes)
    if tn <= 0 or n % tn:
        raise ValueError(
            f"sim_tile_nodes={tile_nodes} must be a positive divisor of "
            f"the node count ({n})")
    ntiles = n // tn
    nin_t = tn * pv
    node_keys, input_keys, scalar_keys = tile_state_keys(cfg)
    if flavor not in ("pallas", "xla"):
        raise ValueError(f"unknown blocked flavor {flavor!r}")

    def finish(tables, core, new_ts, mov, parts, cycle):
        state = dict(core)
        state.update(new_ts)
        return finish_fn(tables, state, mov, parts, cycle)

    if flavor == "xla":

        def run_cycle(tables, core, rand, cycle):
            fs_pre = core["fifo_size"]

            def by_node(x):
                return x.reshape((ntiles, tn) + x.shape[1:])

            def by_input(x):
                return x.reshape((ntiles, nin_t) + x.shape[1:])

            t_stk, t_ax = [], []
            for field, val in zip(type(tables)._fields, tables):
                ax = TABLE_TILE_AXES[field]
                if ax is None:
                    t_stk.append(val)
                    t_ax.append(None)
                elif ax[0] == "input":
                    t_stk.append(by_input(val))
                    t_ax.append(0)
                else:  # node-tiled at ax[1]
                    axis = ax[1]
                    shp = val.shape
                    t_stk.append(val.reshape(
                        shp[:axis] + (ntiles, tn) + shp[axis + 1:]))
                    t_ax.append(axis)
            t_stk = type(tables)(*t_stk)
            t_ax = type(tables)(*t_ax)
            ts = {k: by_node(core[k]) for k in node_keys}
            ts.update({k: by_input(core[k]) for k in input_keys})
            ts.update({k: core[k] for k in scalar_keys})
            ts_ax = {k: 0 for k in node_keys + input_keys}
            ts_ax.update({k: None for k in scalar_keys})
            rand_stk = {k: by_node(val) for k, val in rand.items()}
            node0s = jnp.arange(ntiles, dtype=jnp.int32) * tn
            new_ts, mov, parts = jax.vmap(
                tile_fn, in_axes=(t_ax, ts_ax, 0, None, None, 0))(
                t_stk, ts, rand_stk, fs_pre, jnp.asarray(cycle, jnp.int32),
                node0s)
            new_ts = {k: val.reshape((-1,) + val.shape[2:])
                      for k, val in new_ts.items()}
            return finish(tables, core, new_ts,
                          mov.reshape(n, p, MOV_W), parts.sum(0), cycle)

        return run_cycle

    # ----------------------------- pallas ----------------------------- #
    def run_cycle(tables, core, rand, cycle):
        rkeys = sorted(rand)
        fs_pre = core["fifo_size"]
        ins, in_specs = [], []
        for field, val in zip(type(tables)._fields, tables):
            blk, idx = _table_block(field, val.shape, tn, nin_t)
            ins.append(val)
            in_specs.append(pl.BlockSpec(blk, idx))
        state_keys = node_keys + input_keys
        for k in state_keys:
            lead = tn if k in node_keys else nin_t
            blk, idx = _lead_block(core[k].shape, lead)
            ins.append(core[k])
            in_specs.append(pl.BlockSpec(blk, idx))
        for k in scalar_keys:  # scalars ride as (1,) refs
            ins.append(jnp.asarray(core[k])[None])
            in_specs.append(pl.BlockSpec((1,), lambda i: (0,)))
        for k in rkeys:  # all draws are node-keyed
            blk, idx = _lead_block(rand[k].shape, tn)
            ins.append(rand[k])
            in_specs.append(pl.BlockSpec(blk, idx))
        ins.append(fs_pre)
        in_specs.append(pl.BlockSpec((nin,), lambda i: (0,)))
        ins.append(jnp.asarray(cycle, jnp.int32)[None])
        in_specs.append(pl.BlockSpec((1,), lambda i: (0,)))

        out_shape, out_specs = [], []
        for k in state_keys:
            lead = tn if k in node_keys else nin_t
            blk, idx = _lead_block(core[k].shape, lead)
            out_shape.append(jax.ShapeDtypeStruct(core[k].shape,
                                                  core[k].dtype))
            out_specs.append(pl.BlockSpec(blk, idx))
        out_shape.append(jax.ShapeDtypeStruct((n, p, MOV_W), jnp.int32))
        out_specs.append(pl.BlockSpec((tn, p, MOV_W), lambda i: (i, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((ntiles, N_PART), jnp.int32))
        out_specs.append(pl.BlockSpec((1, N_PART), lambda i: (i, 0)))

        nt, nst = len(tables), len(state_keys)
        nsc, nr = len(scalar_keys), len(rkeys)

        def body(*refs):
            vals = [r[...] for r in refs[:len(ins)]]
            t = type(tables)(*vals[:nt])
            ts = dict(zip(state_keys, vals[nt:nt + nst]))
            ts.update({k: v[0] for k, v in
                       zip(scalar_keys, vals[nt + nst:nt + nst + nsc])})
            rd = dict(zip(rkeys, vals[nt + nst + nsc:
                                      nt + nst + nsc + nr]))
            fs = vals[-2]
            cyc = vals[-1][0]
            node0 = pl.program_id(0) * tn
            new_ts, mov, parts = tile_fn(t, ts, rd, fs, cyc, node0)
            outs = refs[len(ins):]
            for ref, k in zip(outs[:nst], state_keys):
                ref[...] = new_ts[k]
            outs[nst][...] = mov
            outs[nst + 1][...] = parts[None]

        outs = pl.pallas_call(
            body, grid=(ntiles,), in_specs=in_specs, out_specs=out_specs,
            out_shape=out_shape, interpret=interpret)(*ins)
        new_ts = dict(zip(state_keys, outs[:nst]))
        mov, parts = outs[nst], outs[nst + 1]
        return finish(tables, core, new_ts, mov, parts.sum(0), cycle)

    return run_cycle
