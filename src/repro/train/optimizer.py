"""AdamW in pure JAX with optional int8 block-quantized moments.

The quantized-moment mode (8-bit Adam, after Dettmers et al.) is the memory
recipe that lets dbrx-132b / jamba-398b train on a single 256-chip v5e pod:
bf16 params + fp32 master + int8 (m, v) ≈ 8 bytes/param fully sharded.
Moments are stored as int8 with per-block (256) absmax scales and
dequantized on the fly inside the update — the update math itself is fp32.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any

Q_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"      # "float32" | "int8"
    z_loss: float = 1e-4


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (1 + jnp.cos(
        jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


# ---------------------------------------------------------------------- #
# int8 block quantization
# ---------------------------------------------------------------------- #
def _block_of(shape) -> int:
    """Block size along the LAST axis — the codes keep the parameter's
    exact shape (so they inherit the parameter's sharding; a flat-block
    layout would force full all-gathers at every update)."""
    last = shape[-1] if shape else 1
    return Q_BLOCK if last % Q_BLOCK == 0 else last


def quantize_i8(x: jax.Array):
    """fp32 → (int8 codes in x.shape, fp32 scales (*, last/block))."""
    blk = _block_of(x.shape)
    xb = x.reshape(x.shape[:-1] + (x.shape[-1] // blk, blk))
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    codes = jnp.round(
        xb / jnp.maximum(scale[..., None], 1e-12)).astype(jnp.int8)
    return codes.reshape(x.shape), scale


def dequantize_i8(codes: jax.Array, scale: jax.Array, shape):
    blk = _block_of(shape)
    xb = codes.reshape(shape[:-1] + (shape[-1] // blk, blk))
    return (xb.astype(jnp.float32) * scale[..., None]).reshape(shape)


# ---------------------------------------------------------------------- #
# state
# ---------------------------------------------------------------------- #
def init_opt_state(cfg: OptConfig, params: Params):
    def zero_moment(p):
        if cfg.moment_dtype == "int8":
            blk = _block_of(p.shape)
            sshape = p.shape[:-1] + (p.shape[-1] // blk,)
            return {"q": jnp.zeros(p.shape, jnp.int8),
                    "s": jnp.zeros(sshape, jnp.float32)}
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "m": jax.tree.map(zero_moment, params),
        "v": jax.tree.map(zero_moment, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _read_moment(cfg: OptConfig, mom, shape):
    if cfg.moment_dtype == "int8":
        return dequantize_i8(mom["q"], mom["s"], shape)
    return mom


def _write_moment(cfg: OptConfig, val):
    if cfg.moment_dtype == "int8":
        q, s = quantize_i8(val)
        return {"q": q, "s": s}
    return val


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _decay_mask(path) -> bool:
    """Weight decay on matrices only (skip norms/biases/scalars)."""
    names = [getattr(k, "key", str(k)) for k in path]
    leaf = names[-1] if names else ""
    return not any(s in leaf for s in ("scale", "bias", "b_in", "b_out",
                                       "bi", "bf", "dt_bias", "conv_b"))


def adamw_update(cfg: OptConfig, params: Params, grads: Params, opt_state):
    """One AdamW step; returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])

    new_p, new_m, new_v = [], [], []
    for (path, p), g, m0, v0 in zip(flat_p, flat_g, flat_m, flat_v):
        g = g.astype(jnp.float32) * clip
        m = _read_moment(cfg, m0, p.shape)
        v = _read_moment(cfg, v0, p.shape)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and _decay_mask(path):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new = p.astype(jnp.float32) - lr * upd
        new_p.append(new.astype(p.dtype))
        new_m.append(_write_moment(cfg, m))
        new_v.append(_write_moment(cfg, v))

    params2 = jax.tree_util.tree_unflatten(treedef, new_p)
    opt2 = {"m": jax.tree_util.tree_unflatten(treedef, new_m),
            "v": jax.tree_util.tree_unflatten(treedef, new_v),
            "step": step}
    return params2, opt2, {"grad_norm": gnorm, "lr": lr}
