"""Fault tolerance for 1000+-node operation.

Pieces (all exercised by tests on CPU; the multi-host paths degrade to
no-ops at world size 1):

* ``resume_or_init`` — auto-restart contract: restore the latest complete
  checkpoint if one exists, else initialize fresh.  Combined with the
  atomic-rename writer this gives at-least-once training progress across
  preemptions.
* ``PreemptionHandler`` — SIGTERM/SIGINT → finish the in-flight step, write
  a final checkpoint, exit cleanly (the TPU-pod eviction pattern).
* ``ElasticMesh`` — recompute the largest usable (data, model) mesh from
  the currently-live device count and reshard a checkpointed state onto it
  (lost-host resume).  Model parallel degree is preserved; the data axis
  shrinks — per-chip batch grows, global batch is preserved by raising
  gradient accumulation.
* ``StragglerMonitor`` — EWMA of per-step wall time; flags steps slower
  than ``threshold ×`` the moving average.  On real pods the flagged hosts
  are the candidates for ``ElasticMesh`` eviction; here it drives tests and
  logging.
"""

from __future__ import annotations

import dataclasses
import signal
import time

import jax
import numpy as np

from repro.train.checkpoint import CheckpointManager


def resume_or_init(mgr: CheckpointManager, like_state):
    """Restore latest checkpoint into ``like_state``'s structure, or return
    (like_state, step=0) if none exists."""
    if mgr.latest_step() is None:
        return like_state, 0
    state, step = mgr.restore(like_state)
    return state, step


class PreemptionHandler:
    """SIGTERM-graceful checkpointing.

    >>> handler = PreemptionHandler()
    >>> while training:
    ...     state = train_step(state)
    ...     if handler.should_stop:
    ...         mgr.save(step, state); break
    """

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.should_stop = False
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handle)
            except ValueError:  # non-main thread (tests)
                pass

    def _handle(self, signum, frame):
        self.should_stop = True

    def restore_handlers(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


@dataclasses.dataclass
class ElasticMesh:
    """Largest (data, model) mesh for the live device count.

    ``model`` parallel degree is pinned (weights are laid out for it);
    ``data`` shrinks to what remains — e.g. losing 2 of 16 hosts on a
    (16, 16) mesh yields (14, 16).
    """

    model_degree: int

    def build(self, devices=None):
        devices = devices if devices is not None else jax.devices()
        n = len(devices)
        data = n // self.model_degree
        if data < 1:
            raise RuntimeError(
                f"{n} devices cannot sustain model degree "
                f"{self.model_degree}")
        use = devices[: data * self.model_degree]
        mesh_devs = np.array(use).reshape(data, self.model_degree)
        return jax.sharding.Mesh(mesh_devs, ("data", "model"))

    def grad_accum_for(self, global_batch: int, per_chip_batch: int,
                       mesh) -> int:
        """Keep the global batch constant as the data axis shrinks."""
        data = mesh.shape["data"]
        per_step = data * per_chip_batch
        return max(1, -(-global_batch // per_step))


class StragglerMonitor:
    """EWMA step-time tracker with threshold-based flagging."""

    def __init__(self, alpha: float = 0.1, threshold: float = 2.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ewma = None
        self.count = 0
        self.flagged: list[tuple[int, float]] = []
        self._t0 = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> bool:
        """Record one step; returns True if it was a straggler step."""
        dt = time.monotonic() - self._t0
        return self.observe(dt)

    def observe(self, dt: float) -> bool:
        self.count += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = (self.count > self.warmup
                        and dt > self.threshold * self.ewma)
        if is_straggler:
            self.flagged.append((self.count, dt))
        else:
            # stragglers don't poison the moving average
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler
