"""Training substrate: optimizer, data, checkpointing, fault tolerance."""
