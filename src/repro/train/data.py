"""Deterministic synthetic LM data pipeline.

Stateless and hash-addressed: batch contents are a pure function of
(seed, step, position), so (a) every host generates exactly its own shard
with no coordination, (b) restoring from a checkpoint resumes the stream
bit-exactly from the step counter alone — no separate data-state to
checkpoint, which is the property large-cluster pipelines need for
fault-tolerant restarts.

Tokens follow a Zipf-like marginal (realistic softmax pressure) with a
learnable-structure component: token t+1 correlates with token t through a
hash mixer so models actually reduce loss on it.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.1


def _mix(x: np.ndarray) -> np.ndarray:
    """64-bit splitmix-style hash (vectorized, modular arithmetic)."""
    with np.errstate(over="ignore"):
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return x ^ (x >> np.uint64(31))


class SyntheticLM:
    """get_batch(step[, shard, num_shards]) → dict(tokens, labels, mask)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipf CDF over the vocab for marginal realism
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        w = ranks ** -cfg.zipf_a
        self.cdf = np.cumsum(w) / w.sum()

    def _tokens(self, step: int, rows: np.ndarray) -> np.ndarray:
        c = self.cfg
        s = np.arange(c.seq_len + 1, dtype=np.uint64)[None, :]
        r = rows.astype(np.uint64)[:, None]
        with np.errstate(over="ignore"):  # modular uint64 hashing
            base = _mix(np.uint64(c.seed) * np.uint64(0x9E3779B97F4A7C15)
                        + np.uint64(step + 1) * np.uint64(0xD1B54A32D192ED03)
                        + r * np.uint64(0x8CB92BA72F3D8DD7) + s)
            # structure: token depends on its predecessor's hash too
            prev = _mix(base >> np.uint64(17))
            u = ((base ^ np.roll(prev, 1, axis=1))
                 >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        toks = np.searchsorted(self.cdf, u).astype(np.int32)
        return np.clip(toks, 0, c.vocab - 1)

    def get_batch(self, step: int, shard: int = 0, num_shards: int = 1):
        c = self.cfg
        assert c.global_batch % num_shards == 0
        per = c.global_batch // num_shards
        rows = np.arange(shard * per, (shard + 1) * per)
        toks = self._tokens(step, rows)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((per, c.seq_len), np.float32),
        }
