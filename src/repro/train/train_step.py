"""Loss + train step with gradient-accumulation microbatching."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import registry
from repro.models.common import ModelConfig
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


def cross_entropy(logits, labels, mask=None, z_loss: float = 0.0):
    """logits: (B, S, V) f32; labels: (B, S) int32; mask: (B, S) {0,1}."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(cfg: ModelConfig, params, batch):
    """batch: dict(tokens, labels[, mask, positions, embeds])."""
    logits, aux = registry.forward(
        cfg, params, batch["tokens"],
        positions=batch.get("positions"), embeds=batch.get("embeds"))
    ce = cross_entropy(logits, batch["labels"], batch.get("mask"),
                       z_loss=1e-4)
    return ce + aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    grad_accum: int = 1):
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``grad_accum > 1`` splits the per-device batch into microbatches and
    accumulates grads in fp32 via ``lax.scan`` — the standard activation-
    memory lever for the ≥100B configs.
    """
    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(cfg, p, b), has_aux=True)

    def compute_grads(params, batch):
        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def micro(batch_i):
            # (B, ...) -> (A, B/A, ...) with microbatches INTERLEAVED over
            # the batch-sharded dim: reshape (B/A, A) then move A first, so
            # every microbatch keeps the full data-parallel width (reshaping
            # to (A, B/A) directly would confine each microbatch to a 1/A
            # slice of the data axis and replicate it everywhere else).
            def split(path, x):
                a = grad_accum
                # M-RoPE position ids carry a leading (3,) axis: the batch
                # dimension is axis 1
                ax = 1 if (path and getattr(path[-1], "key", "")
                           == "positions" and x.ndim == 3
                           and x.shape[0] == 3) else 0
                y = x.reshape(x.shape[:ax]
                              + (x.shape[ax] // a, a) + x.shape[ax + 1:])
                return jnp.moveaxis(y, ax + 1, 0)
            return jax.tree_util.tree_map_with_path(split, batch_i)

        mb = micro(batch)

        def body(carry, b):
            acc, loss_a = carry
            (loss, _), grads = grad_fn(params, b)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, loss_a + loss), None

        # p * 0 (not jnp.zeros): inherits each param's sharding, so the
        # fp32 accumulator is FSDP/TP-sharded instead of replicated
        zeros = jax.tree.map(
            lambda p: (p * 0).astype(jnp.float32), params)
        (gsum, loss_sum), _ = jax.lax.scan(
            body, (zeros, jnp.float32(0)), mb)
        grads = jax.tree.map(lambda g: g / grad_accum, gsum)
        loss = loss_sum / grad_accum
        return loss, {"ce": loss, "aux": jnp.float32(0)}, grads

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        loss, metrics, grads = compute_grads(params, batch)
        params2, opt2, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt)
        out = {"loss": loss, **metrics, **opt_metrics}
        return {"params": params2, "opt": opt2}, out

    return train_step


def init_train_state(cfg: ModelConfig, opt_cfg: OptConfig, key):
    params = registry.init(cfg, key)
    return {"params": params, "opt": init_opt_state(opt_cfg, params)}
