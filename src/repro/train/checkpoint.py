"""Sharded, atomic, async checkpointing with keep-k retention.

Layout (one directory per step, atomically renamed into place):

    ckpt_dir/
      step_000100/
        manifest.json      # pytree structure, shapes, dtypes, writer meta
        <leaf-id>.npy      # one file per leaf (process-local shards on
                           # multi-host: each process writes its addressable
                           # shard, suffix .p<process_index>)
      step_000200/ ...

Fault-tolerance contract:
  * writes go to ``step_X.tmp`` then ``os.replace`` → readers never see a
    partial checkpoint;
  * ``latest_step`` scans for complete manifests only;
  * ``restore`` rebuilds the pytree and ``device_put``s each leaf with the
    sharding of a provided ``like`` tree — restoring onto a *different* mesh
    (elastic resume after losing hosts) is therefore just passing the new
    target tree (see repro.train.fault_tolerance).
  * async mode: the device→host transfer is synchronous (consistent
    snapshot), file I/O happens on a daemon thread; ``wait()`` joins.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ #
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def latest_step(self) -> int | None:
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    # ------------------------------------------------------------------ #
    def save(self, step: int, state, async_: bool = False) -> None:
        """Snapshot ``state`` (device→host now; file I/O maybe async)."""
        self.wait()
        leaves, treedef = _flatten(state)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        manifest = {
            "treedef": str(treedef),
            "num_leaves": len(host),
            "step": step,
            "process_index": jax.process_index(),
            "leaves": [{"shape": list(a.shape), "dtype": str(a.dtype)}
                       for a in host],
        }

        def write():
            final = self._step_dir(step)
            tmp = final + f".tmp{jax.process_index()}"
            os.makedirs(tmp, exist_ok=True)
            for i, a in enumerate(host):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), a)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if async_:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for name in os.listdir(self.dir)
            if (m := re.fullmatch(r"step_(\d+)", name)))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------ #
    def restore(self, like, step: int | None = None):
        """Load a checkpoint into the structure/shardings of ``like``.

        ``like`` may be a pytree of arrays OR ShapeDtypeStructs with
        ``.sharding`` set (elastic resume onto a new mesh).
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self._step_dir(step)
        leaves, treedef = _flatten(like)
        out = []
        for i, ref in enumerate(leaves):
            a = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
            assert tuple(a.shape) == tuple(ref.shape), (
                f"leaf {i}: ckpt {a.shape} vs target {ref.shape}")
            sharding = getattr(ref, "sharding", None)
            if sharding is not None and not isinstance(
                    sharding, jax.sharding.SingleDeviceSharding):
                out.append(jax.device_put(a.astype(ref.dtype), sharding))
            else:
                out.append(jax.numpy.asarray(a.astype(ref.dtype)))
        return jax.tree_util.tree_unflatten(treedef, out), step
