"""Activation-sharding hints usable from model code.

``hint(x, axes...)`` applies ``with_sharding_constraint`` against the
ambient physical mesh (``with mesh:``), silently dropping axes that are
absent from the mesh or don't divide the dimension — so model code can
state *logical* intent (batch over ("pod","data"), features over "model")
and still run un-meshed on a single CPU device (tests) or on any mesh
shape.

Why this exists: XLA SPMD propagation through nested ``while`` loops
(layer scan × flash-attention chunk scan × grad-accum scan) routinely gives
up and replicates loop-carried activations.  Anchoring the batch/TP axes at
block boundaries pins the loop-state shardings and removes the involuntary
full rematerializations (observed 16× activation replication without
these).
"""

from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

BATCH = ("pod", "data")
TP = ("model",)
DP = ("data",)


def _ambient_mesh():
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:  # pragma: no cover - jax internals moved
        pass
    return None


def hint(x, *axes):
    """Constrain ``x`` (one entry per dim; None/() = unconstrained)."""
    mesh = _ambient_mesh()
    if mesh is None or x.ndim != len(axes):
        return x
    spec = []
    for dim, want in zip(x.shape, axes):
        if want is None:
            spec.append(None)
            continue
        if isinstance(want, str):
            want = (want,)
        present = tuple(a for a in want if a in mesh.shape)
        size = math.prod(mesh.shape[a] for a in present) if present else 1
        if not present or size <= 1 or dim % size != 0:
            spec.append(None)
        else:
            spec.append(present if len(present) > 1 else present[0])
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def hint_batch(x):
    """Batch-major activation: (B, ...)."""
    return hint(x, BATCH, *([None] * (x.ndim - 1)))


def hint_bsd(x):
    """(B, S, D) residual-stream activation."""
    return hint(x, BATCH, None, None)


def hint_bsf(x):
    """(B, S, F) TP-sharded hidden activation."""
    return hint(x, BATCH, None, TP)


def hint_bshd(x):
    """(B, S, H, D) attention heads."""
    return hint(x, BATCH, None, TP, None)


def hint_expert(x):
    """(E, C, D/F) MoE expert buffers: EP (experts over model) when E
    divides the model axis, else expert-TP on the hidden dim."""
    mesh = _ambient_mesh()
    if mesh is None or "model" not in mesh.shape:
        return x
    msize = mesh.shape["model"]
    if x.shape[0] % msize == 0:
        return hint(x, "model", None, None)
    return hint(x, None, None, "model")
