"""Sharding policies: DP / FSDP / TP / EP / SP as PartitionSpec rules.

Strategy (MaxText-style, adapted per family):

* **DP**: batch over ``("pod", "data")``.
* **FSDP (ZeRO-3)**: weight matrices additionally sharded over ``data`` on
  a non-TP dim; XLA SPMD inserts the just-in-time all-gathers.
* **TP**: head/FFN/expert-hidden dims over ``model``.
* **EP**: expert dim over ``model`` when ``E % model == 0`` (dbrx, jamba),
  otherwise per-expert TP (qwen2-moe's 60 experts).
* **SP**: for ``long_500k`` (batch 1) the KV-cache/sequence dim shards over
  ``data`` — sequence-parallel decode.

Every rule passes through :func:`fit_spec`, which drops an axis when the
dim is not divisible by the axis size (e.g. whisper's 51865 vocab), so all
40 (arch × shape) cells lower without manual exceptions.

Leaves are matched by their *basename* in the params pytree; trailing-dim
specs are left-padded with ``None`` for stacked-layer leading dims.
"""

from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig

Tree = Any

DATA = ("pod", "data")  # batch axes (pod present only on multi-pod meshes)


def _axes_in_mesh(mesh: Mesh, axes):
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    present = tuple(a for a in axes if a in mesh.shape)
    if not present:
        return None
    return present if len(present) > 1 else present[0]


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def fit_spec(mesh: Mesh, shape, spec) -> P:
    """Drop sharding on dims that don't divide by the axis size; pad the
    spec with leading Nones to the rank of ``shape``."""
    spec = tuple(spec)
    if len(spec) < len(shape):
        spec = (None,) * (len(shape) - len(spec)) + spec
    spec = spec[-len(shape):] if len(spec) > len(shape) else spec
    out = []
    for dim, axes in zip(shape, spec):
        axes = _axes_in_mesh(mesh, axes)
        if axes is None or dim % _axis_size(mesh, axes) != 0:
            out.append(None)
        else:
            out.append(axes)
    return P(*out)


# ---------------------------------------------------------------------- #
# parameter rules (by leaf basename; trailing dims)
# ---------------------------------------------------------------------- #
def _param_rules(cfg: ModelConfig, mesh: Mesh):
    ep = (cfg.is_moe
          and cfg.moe_experts % mesh.shape.get("model", 1) == 0)
    # KV projections: TP over model only when kv-heads divide the axis —
    # otherwise replicate KV (standard GQA practice; sharding partial heads
    # forces per-chunk all-gathers inside the attention loop)
    kv_tp = cfg.n_kv_heads % mesh.shape.get("model", 1) == 0
    kv_spec = ("data", "model") if kv_tp else ("data", None)
    rules: dict[tuple[str, int], tuple] = {
        # (basename, trailing ndim) -> spec for trailing dims
        # embed table: vocab dim replicated (gather-friendly), d on FSDP
        ("table", 2): (None, "data"),
        ("w", 2): ("model", "data"),            # lm head
        ("wq", 2): ("data", "model"),
        ("wk", 2): kv_spec,
        ("wv", 2): kv_spec,
        ("wo", 2): ("model", "data"),
        ("w_gate", 2): ("data", "model"),
        ("w_up", 2): ("data", "model"),
        ("w_down", 2): ("model", "data"),
        ("w_in", 2): ("data", "model"),
        ("w_out", 2): ("model", "data"),
        ("b_in", 1): ("model",),
        ("q_down", 2): ("data", None),
        ("q_up", 2): (None, "model"),
        ("kv_down", 2): ("data", None),
        ("kv_up", 2): (None, "model"),
        ("in_proj", 2): ("data", "model"),
        ("x_proj", 2): ("model", None),
        ("dt_proj", 2): (None, "model"),
        ("conv_w", 2): (None, "model"),
        ("conv_b", 1): ("model",),
        ("dt_bias", 1): ("model",),
        ("d_skip", 1): ("model",),
        ("a_log", 2): ("model", None),
        ("out_proj", 2): ("model", "data"),
        ("up", 2): ("data", "model"),
        ("down", 2): ("model", "data"),
        ("r", 3): (None, None, "model"),
        ("out", 2): ("model", "data"),
        ("pos", 2): (None, "data"),
        # MoE expert tensors (trailing 3 dims: E, in, out)
        ("w_gate", 3): ("model", "data", None) if ep else (None, "data", "model"),
        ("w_up", 3): ("model", "data", None) if ep else (None, "data", "model"),
        ("w_down", 3): ("model", None, "data") if ep else (None, "model", "data"),
        ("router", 2): (None, None),
    }
    if cfg.family == "ssm":
        # mLSTM: contraction dim of wq/wk/wv matches model-sharded dp acts
        rules[("wq", 2)] = ("model", None)
        rules[("wk", 2)] = ("model", None)
        rules[("wv", 2)] = ("model", None)
    return rules


def _leaf_name(path) -> str:
    for k in reversed(path):
        name = getattr(k, "key", None)
        if name is not None:
            return str(name)
    return ""


def param_specs(cfg: ModelConfig, mesh: Mesh, params_tree: Tree) -> Tree:
    """PartitionSpec tree matching ``params_tree`` (arrays or SDS)."""
    rules = _param_rules(cfg, mesh)

    def spec_for(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        for nd in range(len(shape), 0, -1):
            if (name, nd) in rules:
                return fit_spec(mesh, shape, rules[(name, nd)])
        return P()  # replicate (norm scales, biases, small tensors)

    return jax.tree_util.tree_map_with_path(spec_for, params_tree)


def opt_specs(cfg: ModelConfig, mesh: Mesh, opt_tree: Tree,
              pspecs: Tree) -> Tree:
    """Optimizer-state specs: fp32 moments mirror the params; int8
    quantized blocks shard their flat block dim over (data×model)."""

    rules = _param_rules(cfg, mesh)

    def spec_for(path, leaf):
        names = [str(getattr(k, "key", k)) for k in path]
        if names and names[0] == "step":
            return P()
        # int8 codes keep the param shape; scales drop the last-dim blocks.
        # Both inherit the underlying parameter's rule so updates stay
        # resharding-free (codes exactly; scale blocks are contiguous
        # sub-ranges of the param's last-dim shards).
        lookup = path
        if names and names[-1] in ("q", "s"):
            lookup = path[:-1]
        sub = lookup[1:] if len(lookup) > 1 else lookup
        name = _leaf_name(sub) or _leaf_name(lookup)
        for nd in range(len(leaf.shape), 0, -1):
            if (name, nd) in rules:
                return fit_spec(mesh, leaf.shape, rules[(name, nd)])
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, opt_tree)


# ---------------------------------------------------------------------- #
# batch / cache rules
# ---------------------------------------------------------------------- #
def batch_specs(mesh: Mesh, batch_tree: Tree) -> Tree:
    """tokens/labels/mask: batch over (pod, data); positions may lead with
    the (3,) M-RoPE axis."""

    def spec_for(path, leaf):
        shape = leaf.shape
        name = _leaf_name(path)
        if name == "positions" and len(shape) == 3:
            return fit_spec(mesh, shape, (None, DATA, None))
        if name == "embeds":
            return fit_spec(mesh, shape, (DATA, None, None))
        return fit_spec(mesh, shape, (DATA,) + (None,) * (len(shape) - 1))

    return jax.tree_util.tree_map_with_path(spec_for, batch_tree)


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache_tree: Tree,
                seq_parallel: bool) -> Tree:
    """KV / recurrent-state cache sharding.

    Default: batch over (pod, data), kv-heads (or head_dim fallback) over
    model.  ``seq_parallel`` (long_500k, batch 1): sequence dim over data.
    """

    def spec_for(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        nd = len(shape)
        if name in ("k", "v"):          # (L, B, S, KV, HD)
            if seq_parallel:
                spec = (None, None, "data", "model", None)
                s = fit_spec(mesh, shape, spec)
                if s[3] is None:        # kv not divisible → shard head_dim
                    s = fit_spec(mesh, shape,
                                 (None, None, "data", None, "model"))
                return s
            s = fit_spec(mesh, shape, (None, DATA, None, "model", None))
            if s[3] is None:
                s = fit_spec(mesh, shape, (None, DATA, None, None, "model"))
            return s
        if name in ("c_kv", "k_rope"):  # MLA latents (L, B, S, R)
            if seq_parallel:
                return fit_spec(mesh, shape, (None, None, "data", None))
            return fit_spec(mesh, shape, (None, DATA, None, None))
        if name == "conv":              # (SB, ap-1, B, dc-1, di)
            return fit_spec(mesh, shape,
                            (None, None, DATA, None, "model"))
        if name == "ssm":               # (SB, ap-1, B, di, ds)
            return fit_spec(mesh, shape,
                            (None, None, DATA, "model", None))
        if name == "c" and nd >= 4:     # mLSTM (SB, sp-1, B, H, dh, dh)
            if seq_parallel:
                return fit_spec(mesh, shape,
                                (None, None, None, None, "data", "model"))
            return fit_spec(mesh, shape,
                            (None,) * (nd - 4) + (DATA, None, "model", None))
        if name in ("n", "h", "m") or name == "c":
            base = (None,) * (nd - 3) + (DATA, None, "model")
            return fit_spec(mesh, shape, base)
        return fit_spec(mesh, shape, (None,) * nd)

    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)


def to_shardings(mesh: Mesh, spec_tree: Tree) -> Tree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def abstract_with_sharding(mesh: Mesh, shapes_tree: Tree,
                           spec_tree: Tree) -> Tree:
    """ShapeDtypeStructs with NamedShardings attached (dry-run inputs)."""
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)),
        shapes_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))
