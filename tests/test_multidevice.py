"""Multi-device campaign parity: the ``shard_map`` lane-parallel runner
must produce BIT-IDENTICAL results to single-device execution.

Lanes — (rate, seed) campaign points — are fully independent, so
splitting the batch axis over a ("lane",) device mesh is exact SPMD:
same ops, same bits, per-device slices.  These tests pin that claim at
the CampaignResult level (the unit every benchmark consumes) and at the
raw state level, on the fake host devices injected by ``conftest.py``
(the ``multi_device_count`` fixture skips with the reason when the
XLA flag could not land before jax initialized).
"""

import dataclasses

import numpy as np
import pytest

from repro.core import mesh2d, traffic
from repro.noc import Algo, CampaignSpec, SimConfig, run_campaign
from repro.noc import sim

TOPO = mesh2d(4, 4)
UNI = traffic.uniform(TOPO)


def _spec(multi_device, lanes_rates, lanes_seeds, **kw):
    return CampaignSpec(
        topo=TOPO, algos=(Algo.XY, Algo.BIDOR),
        patterns=(("uniform", UNI),),
        rates=lanes_rates, seeds=lanes_seeds,
        base=SimConfig(cycles=900, warmup=250, drain=100),
        multi_device=multi_device, **kw)


def _point_fields(res):
    out = []
    for p in res.points:
        r = p.result
        out.append((p.algo, p.pattern, p.rate, p.seed,
                    r.injected_flits, r.ejected_flits, r.in_flight_flits,
                    r.reorder_value, r.meas_cycles, r.throughput,
                    r.avg_latency, r.p99_latency, r.link_load_max,
                    tuple(np.asarray(r.node_load).tolist())))
    return out


def test_sharded_campaign_bit_identical(multi_device_count):
    """8 lanes over the device mesh == the single-device batch, every
    statistic equal to the last bit (floats included: both paths run
    the same reductions on the same integers)."""
    ndev = multi_device_count
    rates, seeds = (0.1, 0.3, 0.5, 0.7), (0, 1)
    assert (len(rates) * len(seeds)) % ndev == 0, \
        "test grid must divide over the fake devices"
    res_multi = run_campaign(_spec(True, rates, seeds))
    res_single = run_campaign(_spec(False, rates, seeds))
    assert _point_fields(res_multi) == _point_fields(res_single)


def test_sharded_campaign_with_chunked_early_exit(multi_device_count):
    """Chunked execution (the saturation early-exit path) hot-swaps the
    runner every chunk; sharding must stay exact across chunk
    boundaries with the donated carry."""
    rates, seeds = (0.15, 0.45, 0.75, 1.0), (0, 1)
    res_multi = run_campaign(_spec(True, rates, seeds, chunk=300))
    res_single = run_campaign(_spec(False, rates, seeds, chunk=300))
    assert _point_fields(res_multi) == _point_fields(res_single)


def test_sharded_runner_state_parity_both_step_paths(multi_device_count):
    """Raw runner-level parity for the fused AND unfused transitions:
    the full state pytree (packed flits, locks, counters, keys) is
    equal bit for bit after 400 cycles."""
    points = [(r, s) for r in (0.1, 0.3, 0.5, 0.7) for s in (0, 1)]
    for use_kernel in (True, False):
        cfg = SimConfig(cycles=400, warmup=100, use_kernel=use_kernel)
        tables, meta = sim.build_tables(TOPO, UNI, None, cfg.num_vcs)
        out_m = sim.get_runner(meta, cfg, 400, num_lanes=len(points),
                               multi_device=True)(
            tables, sim.make_states(meta, cfg, points))
        out_s = sim.get_runner(meta, cfg, 400, num_lanes=len(points),
                               multi_device=False)(
            tables, sim.make_states(meta, cfg, points))
        out_m, out_s = (dict(out_m), dict(out_s))
        bad = [k for k in out_s
               if not np.array_equal(np.asarray(out_m[k]),
                                     np.asarray(out_s[k]))]
        assert not bad, (use_kernel, bad)


def test_multi_device_validates_lane_divisibility(multi_device_count):
    ndev = multi_device_count
    cfg = SimConfig(cycles=300, warmup=100)
    tables, meta = sim.build_tables(TOPO, UNI, None, cfg.num_vcs)
    with pytest.raises(ValueError, match="divide"):
        sim.get_runner(meta, cfg, 300, num_lanes=ndev + 1,
                       multi_device=True)


def test_controlled_run_sharded_parity(multi_device_count):
    """The control plane's epoch loop (event application + counter
    reads between chunks) under the sharded runner equals the
    single-device run, fault scenario included."""
    from repro.noc import LinkFail, ReplanConfig, Scenario
    from repro.noc.ctrl import run_controlled
    from repro.core import build_plan

    plan = build_plan(TOPO, UNI)
    cfg = SimConfig(algo=Algo.BIDOR, cycles=1200, warmup=300)
    scen = Scenario(
        "linkfail_online",
        events=(LinkFail(cycle=600, links=((5, 6), (6, 5)),
                         bw_scale=0.25),),
        policy="online", replan=ReplanConfig(epoch=300))
    kw = dict(rates=[0.2, 0.3, 0.4, 0.5], seeds=[0, 1],
              bidor_table=plan.table, nrank0=plan.nrank)
    res_m = run_controlled(TOPO, UNI, cfg, scen, multi_device=True, **kw)
    res_s = run_controlled(TOPO, UNI, cfg, scen, multi_device=False, **kw)
    assert np.array_equal(res_m.link_peak, res_s.link_peak)
    for a, b in zip(res_m.results, res_s.results):
        assert dataclasses.asdict(
            dataclasses.replace(a, node_load=0)) == dataclasses.asdict(
            dataclasses.replace(b, node_load=0))
        assert np.array_equal(a.node_load, b.node_load)
