"""Q-StaR ICI collectives: decomposition correctness (16-dev subprocess)
and the offline link-load analysis."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import torus, bidor

# The ICI collective scheduler is a planned subsystem; skip cleanly (at
# collection time) until repro.dist lands.
pytest.importorskip("repro.dist.qstar_collectives",
                    reason="repro.dist not merged yet")
from repro.dist.qstar_collectives import (
    alltoall_traffic, build_ici_plan, ici_link_loads)


def test_decomposed_all_to_all_semantics():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=16",
               PYTHONPATH=os.pathsep.join(sys.path))
    script = os.path.join(os.path.dirname(__file__),
                          "_subproc_collectives.py")
    res = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "bidor OK" in res.stdout


def test_ici_bidor_reduces_max_link_load_under_skew():
    """Skewed all-to-all (hot experts) on a 8×8 ICI torus: the BiDOR
    schedule must cut the max link load vs all-XY."""
    topo = torus(8, 8)
    rng = np.random.default_rng(0)
    skew = 1.0 + 4.0 * (rng.random(64) < 0.15)   # a few hot destinations
    t = alltoall_traffic(topo, skew=skew)
    _, table = build_ici_plan(topo, t)
    xy = bidor(topo, np.zeros(topo.num_nodes))
    l_xy = ici_link_loads(topo, t, xy)
    l_bd = ici_link_loads(topo, t, table)
    assert l_bd["max"] <= l_xy["max"] * 1.001
    assert l_bd["cv"] < l_xy["cv"]


def test_ici_plan_on_uniform_alltoall_no_regression():
    """Uniform all-to-all on a symmetric torus is already balanced under
    XY; BiDOR must tie (never regress) there."""
    topo = torus(8, 8)
    t = alltoall_traffic(topo)
    nr, table = build_ici_plan(topo, t)
    loads = ici_link_loads(topo, t, table)
    xy = ici_link_loads(topo, t, bidor(topo, np.zeros(64)))
    assert loads["max"] <= xy["max"] * 1.001
    assert nr.iterations <= 100
