"""Training substrate: optimizer, quantized moments, data determinism,
checkpointing, fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, SyntheticLM
from repro.train.fault_tolerance import (
    ElasticMesh, PreemptionHandler, StragglerMonitor, resume_or_init)
from repro.train.optimizer import (
    OptConfig, adamw_update, dequantize_i8, init_opt_state, quantize_i8,
    schedule)


# --------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------- #
def test_schedule_warmup_and_decay():
    cfg = OptConfig(peak_lr=1e-3, min_lr=1e-4, warmup_steps=10,
                    decay_steps=100)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert np.isclose(float(schedule(cfg, jnp.int32(10))), 1e-3)
    assert np.isclose(float(schedule(cfg, jnp.int32(100))), 1e-4, rtol=0.01)
    assert float(schedule(cfg, jnp.int32(5))) < 1e-3


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.sampled_from([(7,), (3, 256), (4, 100), (2, 3, 512)]))
def test_int8_quantization_roundtrip_error_bound(seed, shape):
    """Property: |dequant(quant(x)) − x| ≤ blockmax/127 per element."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32) * 10)
    q, s = quantize_i8(x)
    y = dequantize_i8(q, s, x.shape)
    assert q.shape == x.shape
    err = np.abs(np.asarray(y - x))
    assert err.max() <= np.abs(np.asarray(x)).max() / 127 + 1e-6


def test_adamw_minimizes_quadratic():
    cfg = OptConfig(peak_lr=0.1, warmup_steps=0, decay_steps=10_000,
                    weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(cfg, params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, m = adamw_update(cfg, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_int8_matches_fp32_roughly():
    k = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(k, (4, 256))}
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (4, 256))}
    outs = {}
    for md in ("float32", "int8"):
        cfg = OptConfig(peak_lr=1e-2, warmup_steps=0, weight_decay=0.0,
                        moment_dtype=md)
        p, o = dict(params), init_opt_state(cfg, params)
        for _ in range(5):
            p, o, _ = adamw_update(cfg, p, grads, o)
        outs[md] = np.asarray(p["w"])
    # int8 moments track fp32 closely but not exactly — compare update
    # direction and magnitude, not elementwise equality
    diff = np.abs(outs["float32"] - outs["int8"])
    base = np.abs(outs["float32"] - np.asarray(params["w"])) + 1e-6
    assert np.median(diff / base) < 0.5


def test_grad_clipping_bounds_update():
    cfg = OptConfig(peak_lr=1.0, warmup_steps=0, clip_norm=1.0,
                    weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(cfg, params)
    _, _, m = adamw_update(cfg, params, {"w": jnp.full(3, 1e6)}, opt)
    assert float(m["grad_norm"]) > 1e5  # reported raw


# --------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------- #
def test_data_deterministic_and_sharded_consistently():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8, seed=3)
    d = SyntheticLM(cfg)
    b1 = d.get_batch(5)
    b2 = d.get_batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # sharded generation must tile the global batch exactly
    parts = [d.get_batch(5, shard=i, num_shards=4)["tokens"]
             for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b1["tokens"])


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=2)
    d = SyntheticLM(cfg)
    b = d.get_batch(0)
    assert b["tokens"].shape == (2, 8)
    assert b["labels"].shape == (2, 8)
    # labels[t] == tokens[t+1] within the same underlying stream
    b_long = d._tokens(0, np.arange(2))
    np.testing.assert_array_equal(b["labels"], b_long[:, 1:])


def test_data_steps_differ():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=4)
    d = SyntheticLM(cfg)
    assert not np.array_equal(d.get_batch(0)["tokens"],
                              d.get_batch(1)["tokens"])


# --------------------------------------------------------------------- #
# checkpointing + fault tolerance
# --------------------------------------------------------------------- #
def _tiny_state(key=0):
    k = jax.random.PRNGKey(key)
    return {"params": {"w": jax.random.normal(k, (4, 8)),
                       "b": jnp.zeros(8)},
            "opt": {"m": jnp.ones((4, 8)), "step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _tiny_state()
    mgr.save(100, state)
    restored, step = mgr.restore(state)
    assert step == 100
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state, restored)


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _tiny_state()
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.latest_step() == 4
    dirs = sorted(os.listdir(tmp_path))
    assert "step_00000001" not in dirs and "step_00000004" in dirs
    assert len([d for d in dirs if d.startswith("step_")]) == 2


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = _tiny_state()
    mgr.save(5, state, async_=True)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_atomicity_no_partial_visible(tmp_path):
    """A manifest only appears after the atomic rename."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    assert mgr.latest_step() is None
    # a stray tmp dir must not be picked up
    os.makedirs(tmp_path / "step_00000009.tmp0")
    assert mgr.latest_step() is None


def test_resume_or_init(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    fresh = _tiny_state()
    state, step = resume_or_init(mgr, fresh)
    assert step == 0
    mgr.save(42, state)
    state2, step2 = resume_or_init(mgr, fresh)
    assert step2 == 42


def test_elastic_mesh_shrinks_data_axis():
    # the data axis absorbs every live device (conftest may expose fake
    # host devices, so build meshes from explicit device slices and pin
    # concrete grad-accum expectations)
    ndev = len(jax.devices())
    em = ElasticMesh(model_degree=1)
    mesh = em.build(jax.devices())
    assert mesh.shape["model"] == 1 and mesh.shape["data"] == ndev
    mesh1 = em.build(jax.devices()[:1])          # (1, 1)
    assert mesh1.shape["data"] == 1
    assert em.grad_accum_for(global_batch=64, per_chip_batch=4,
                             mesh=mesh1) == 16
    if ndev >= 2:                                # (2, 1): accum halves
        mesh2 = em.build(jax.devices()[:2])
        assert mesh2.shape["data"] == 2
        assert em.grad_accum_for(global_batch=64, per_chip_batch=4,
                                 mesh=mesh2) == 8


def test_elastic_mesh_rejects_insufficient_devices():
    em = ElasticMesh(model_degree=64)
    with pytest.raises(RuntimeError):
        em.build(jax.devices())


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(alpha=0.5, threshold=2.0, warmup=2)
    flags = [mon.observe(0.1) for _ in range(8)]
    assert not any(flags)
    assert mon.observe(0.5) is True      # 5× the EWMA
    assert mon.observe(0.1) is False     # EWMA not poisoned
    assert len(mon.flagged) == 1


def test_preemption_handler():
    h = PreemptionHandler(signals=())
    assert h.should_stop is False
    h._handle(None, None)
    assert h.should_stop is True


def test_checkpoint_restore_onto_new_topology(tmp_path):
    """Elastic resume: restore with a different target sharding tree
    (ShapeDtypeStructs carry the new shardings)."""
    mgr = CheckpointManager(str(tmp_path))
    state = _tiny_state()
    mgr.save(9, state)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, step = mgr.restore(like)
    assert step == 9
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state, restored)
