"""Per-architecture smoke tests: reduced same-family config, one forward
+ one train step on CPU, asserting output shapes and finiteness.

The FULL configs are exercised only by the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_arch, list_archs
from repro.models import registry
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        batch["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
    if cfg.family == "encdec":
        batch["embeds"] = jax.random.normal(
            ks[2], (B, cfg.enc_seq, cfg.d_model), cfg.jdtype)
    return batch


def test_all_ten_archs_registered():
    assert len(list_archs()) == 10


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_matches_assignment(arch):
    cfg = get_arch(arch).full
    assert cfg.n_layers > 0 and cfg.vocab > 1000
    # every cell of the assignment is representable
    for shape in get_arch(arch).shapes:
        assert shape in SHAPES


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward(arch):
    spec = get_arch(arch)
    cfg = spec.smoke
    key = jax.random.PRNGKey(0)
    params = registry.init(cfg, key)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = registry.forward(
        cfg, params, batch["tokens"],
        positions=batch.get("positions"), embeds=batch.get("embeds"))
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    spec = get_arch(arch)
    cfg = spec.smoke
    oc = OptConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=10)
    state = init_train_state(cfg, oc, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, oc))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    before = registry.init(cfg, jax.random.PRNGKey(0))
    diffs = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        state["params"], before)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ["codeqwen1.5-7b", "minicpm3-4b",
                                  "jamba-1.5-large-398b", "xlstm-1.3b",
                                  "whisper-base"])
def test_smoke_decode_step(arch):
    """One-token decode with the reduced config (serve_step path)."""
    spec = get_arch(arch)
    cfg = spec.smoke
    mod = registry.model_module(cfg)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    cache = registry.init_cache(cfg, B, S)
    tok = jnp.zeros((B, 1), jnp.int32)
    kw = {}
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.enc_seq, cfg.d_model))
        kw["enc_out"] = mod.encode(cfg, params, frames)
    logits, cache2 = mod.decode_step(cfg, params, tok, cache, jnp.int32(3),
                                     **kw)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structurally unchanged
    assert (jax.tree_util.tree_structure(cache)
            == jax.tree_util.tree_structure(cache2))
