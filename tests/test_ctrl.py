"""Control plane (repro.noc.ctrl): event schedules, estimation, drift
detection, fault-aware re-planning, and the plan hot-swap path."""

import numpy as np
import pytest

from repro.core import build_plan, link_load, mesh2d, traffic
from repro.core.bidor import route_feasibility
from repro.core.nrank import nrank_channel
from repro.core.routes import dimension_orders, walk_routes
from repro.noc import (Algo, DriftDetector, LinkFail, LinkRecover,
                       ReplanConfig, Scenario, SimConfig, TrafficDrift,
                       TrafficEstimator, run_controlled)
from repro.noc.sim import run_sweep

TOPO = mesh2d(4, 4)
UNI = traffic.uniform(TOPO)
CFG = SimConfig(algo=Algo.BIDOR, cycles=3000, warmup=500,
                injection_rate=0.35)
PLAN = build_plan(TOPO, UNI)
FAIL_LINKS = ((5, 6), (6, 5))


# ---------------------------------------------------------------------- #
# hot swap / identity
# ---------------------------------------------------------------------- #
@pytest.mark.slow
def test_empty_schedule_hot_swap_is_bit_identical_to_fresh_run():
    """The chunked, table-swapping control loop with NO events must equal
    the single-call sweep exactly — the hot-swap path itself cannot
    perturb the simulation."""
    for algo in (Algo.BIDOR, Algo.XY, Algo.ODDEVEN):
        cfg = CFG.replace(algo=algo)
        table = PLAN.table if algo == Algo.BIDOR else None
        ctrl = run_controlled(
            TOPO, UNI, cfg,
            Scenario("empty", replan=ReplanConfig(epoch=400)),
            bidor_table=table)
        ref = run_sweep(TOPO, UNI, cfg, [cfg.injection_rate],
                        bidor_table=table)[0]
        r = ctrl.results[0]
        assert r.injected_flits == ref.injected_flits, algo
        assert r.ejected_flits == ref.ejected_flits, algo
        assert r.in_flight_flits == ref.in_flight_flits, algo
        assert r.reorder_value == ref.reorder_value, algo
        assert np.isclose(r.avg_latency, ref.avg_latency), algo
        assert not ctrl.replans


def test_lanes_match_sweep_grid():
    rates, seeds = [0.2, 0.4], [0, 7]
    ctrl = run_controlled(TOPO, UNI, CFG, None, rates=rates, seeds=seeds,
                          bidor_table=PLAN.table)
    assert ctrl.points == [(r, s) for r in rates for s in seeds]
    for (rate, seed), res in zip(ctrl.points, ctrl.results):
        ref = run_sweep(TOPO, UNI, CFG, [rate], bidor_table=PLAN.table,
                        seeds=[seed])[0]
        assert res.injected_flits == ref.injected_flits, (rate, seed)


# ---------------------------------------------------------------------- #
# the headline: online replanning beats the stale plan under a failure
# ---------------------------------------------------------------------- #
def test_online_replan_beats_stale_on_max_link_load_under_failure():
    fail = (LinkFail(cycle=1500, links=FAIL_LINKS, bw_scale=0.25),)
    rc = ReplanConfig(epoch=500)
    stale = run_controlled(
        TOPO, UNI, CFG, Scenario("f", events=fail, policy="stale",
                                 replan=rc), bidor_table=PLAN.table)
    online = run_controlled(
        TOPO, UNI, CFG, Scenario("f", events=fail, policy="online",
                                 replan=rc), bidor_table=PLAN.table)
    assert not stale.replans
    assert online.replans and online.replans[0].trigger == "fault"
    assert online.link_peak[0] < stale.link_peak[0]
    # replanning must not cost delivered throughput
    assert (online.results[0].throughput
            >= stale.results[0].throughput * 0.98)


def test_oracle_replans_at_every_event():
    ev = (LinkFail(cycle=1000, links=FAIL_LINKS, bw_scale=0.5),
          LinkRecover(cycle=2000, links=FAIL_LINKS))
    res = run_controlled(
        TOPO, UNI, CFG, Scenario("fr", events=ev, policy="oracle",
                                 replan=ReplanConfig(epoch=500)),
        bidor_table=PLAN.table)
    assert [r.cycle for r in res.replans] == [1000, 2000]
    assert all(r.trigger == "event" for r in res.replans)


def test_drift_detection_triggers_online_replan():
    drift = (TrafficDrift(cycle=1000, traffic=traffic.transpose(TOPO)),)
    res = run_controlled(
        TOPO, UNI, CFG,
        Scenario("d", events=drift, policy="online",
                 replan=ReplanConfig(epoch=500, drift_threshold=0.15)),
        bidor_table=PLAN.table)
    drifts = [r for r in res.replans if r.trigger == "drift"]
    assert drifts and drifts[0].cycle >= 1000
    assert drifts[0].drift_distance > 0.15


def test_events_apply_to_non_bidor_algorithms_without_replanning():
    """Events are the environment: adaptive routing sees the degraded
    link (and its saturation) but never replans."""
    fail = (LinkFail(cycle=1000, links=FAIL_LINKS, bw_scale=0.25),)
    res = run_controlled(
        TOPO, UNI, CFG.replace(algo=Algo.ODDEVEN),
        Scenario("f", events=fail, policy="online",
                 replan=ReplanConfig(epoch=500)))
    assert not res.replans
    r = res.results[0]
    assert r.injected_flits == r.ejected_flits + r.in_flight_flits


def test_hard_failure_sheds_unroutable_pairs_and_conserves_flits():
    """bw=0 on a row link: same-row pairs crossing it are unroutable
    under both DOR orders; the online planner sheds them at the source
    and the network still conserves flits."""
    fail = (LinkFail(cycle=1000, links=FAIL_LINKS, bw_scale=0.0),)
    res = run_controlled(
        TOPO, UNI, CFG,
        Scenario("hard", events=fail, policy="online",
                 replan=ReplanConfig(epoch=500)),
        bidor_table=PLAN.table)
    assert res.replans and res.replans[0].unroutable_pairs > 0
    r = res.results[0]
    assert r.injected_flits == r.ejected_flits + r.in_flight_flits
    assert r.ejected_flits > 0


def test_traffic_drift_does_not_unshed_while_fault_persists():
    """A traffic epoch arriving while a hard fault is still active must
    keep the shed pairs shed: re-enabling them would wedge packets on a
    table that routes over the dead (never-live) channel."""
    ev = (LinkFail(cycle=800, links=FAIL_LINKS, bw_scale=0.0),
          # same matrix: below any drift threshold, so no further replan
          TrafficDrift(cycle=1600, traffic=UNI))
    res = run_controlled(
        TOPO, UNI, CFG,
        Scenario("fd", events=ev, policy="online",
                 replan=ReplanConfig(epoch=400, drift_threshold=0.9)),
        bidor_table=PLAN.table)
    assert len(res.replans) == 1  # only the fault replan
    r = res.results[0]
    assert r.injected_flits == r.ejected_flits + r.in_flight_flits
    # nothing may be wedged behind the dead link at drain: with the shed
    # intact, deliveries continue all run (vs ~0 if pairs were re-enabled)
    assert r.ejected_flits > 0.8 * r.injected_flits


def test_recovery_restores_shed_traffic():
    """After a hard failure sheds unroutable pairs, a LinkRecover replan
    must restore their generation — the shed may not outlive the fault."""
    fail_only = (LinkFail(cycle=800, links=FAIL_LINKS, bw_scale=0.0),)
    fail_rec = fail_only + (LinkRecover(cycle=1600, links=FAIL_LINKS),)
    rc = ReplanConfig(epoch=400)
    shed = run_controlled(
        TOPO, UNI, CFG, Scenario("f", events=fail_only, policy="online",
                                 replan=rc), bidor_table=PLAN.table)
    rec = run_controlled(
        TOPO, UNI, CFG, Scenario("fr", events=fail_rec, policy="online",
                                 replan=rc), bidor_table=PLAN.table)
    assert rec.replans[0].unroutable_pairs > 0
    assert rec.replans[-1].unroutable_pairs == 0
    # restored generation injects more than the permanently shed run
    assert (rec.results[0].injected_flits
            > shed.results[0].injected_flits)


# ---------------------------------------------------------------------- #
# components
# ---------------------------------------------------------------------- #
def test_traffic_estimator_converges_to_observed_mix():
    est = TrafficEstimator(3, ema=0.5)
    assert est.matrix is None
    target = np.array([[0, 2, 0], [0, 0, 1], [1, 0, 0]], float)
    for _ in range(12):
        est.update(target * 100)
    m = est.matrix
    np.testing.assert_allclose(m, target / target.sum(), atol=1e-6)
    est.update(np.zeros((3, 3)))  # empty epoch: no-op, not a wipe
    np.testing.assert_allclose(est.matrix, m)


def test_drift_detector_reference_and_reset():
    det = DriftDetector(threshold=0.2)
    a = np.array([10.0, 10.0, 0.0, 0.0])
    b = np.array([0.0, 0.0, 10.0, 10.0])
    assert not det.update(a)        # first profile pins the reference
    assert not det.update(a * 3)    # same distribution, any scale
    assert det.update(b)            # total shift
    assert det.last_distance == pytest.approx(1.0)
    det.reset()
    assert not det.update(b)        # new reference after replan


def test_degrade_and_feasibility_are_consistent():
    c = TOPO.channel_index(5, 6)
    hard = TOPO.degrade([(5, 6)], bw_scale=0.0)
    assert hard.down_channels.tolist() == [c]
    assert TOPO.channel_bw[c] == 1.0  # original untouched
    feas = route_feasibility(TOPO, dimension_orders(2), [c])
    # same-row pairs crossing the link: neither XY nor YX feasible
    assert not feas[:, 5, 6].any() and not feas[:, 4, 7].any()
    # other-row pairs keep at least one order
    assert feas[:, 1, 10].any()
    plan = build_plan(TOPO, UNI, down_channels=np.array([c]))
    un = plan.table.unroutable
    assert un is not None and un[5, 6] and un[4, 7] and not un[1, 10]
    # every non-shed chosen route avoids the failed channel
    for oi, order in enumerate(dimension_orders(2)):
        seq = walk_routes(TOPO, order)
        sel = (plan.table.choice == oi) & ~un
        np.fill_diagonal(sel, False)
        for s, d in zip(*np.nonzero(sel)):
            nodes = seq[s, d]
            for h in range(len(nodes) - 1):
                a, b = int(nodes[h]), int(nodes[h + 1])
                if a == b:
                    break
                assert (a, b) != (5, 6), (s, d, oi)


def test_link_load_shed_and_infinite_bottleneck():
    c = TOPO.channel_index(5, 6)
    hard = TOPO.degrade([c], bw_scale=0.0)
    # fault-blind table (no unroutable): planned load over a dead link
    # is an infinite bottleneck
    blind = build_plan(TOPO, UNI).table
    assert np.isinf(link_load(hard, UNI, blind).max())
    # fault-aware table sheds those pairs: all-finite loads
    aware = build_plan(hard, UNI, down_channels=hard.down_channels).table
    ll = link_load(hard, UNI, aware)
    assert np.isfinite(ll).all()
    assert ll[c] == 0.0


def test_nrank_warm_start_carry():
    cold = nrank_channel(TOPO, UNI)
    warm = nrank_channel(TOPO, UNI, w0=cold.w0 + cold.w_final)
    assert warm.iterations <= cold.iterations + 2
    # the carry only adds weight: trends must stay strongly aligned
    corr = np.corrcoef(cold.w_nr, warm.w_nr)[0, 1]
    assert corr > 0.99
    # w0=None is exactly the cold start (regression guard)
    again = nrank_channel(TOPO, UNI, w0=None)
    np.testing.assert_array_equal(cold.w_nr, again.w_nr)


def test_scenario_validation():
    with pytest.raises(ValueError):
        Scenario("bad", events=(LinkFail(cycle=100, links=FAIL_LINKS),
                                LinkFail(cycle=50, links=FAIL_LINKS)))
    with pytest.raises(ValueError):
        Scenario("bad", policy="psychic")


def test_rate_scale_drift_event():
    ev = (TrafficDrift(cycle=1000, traffic=UNI, rate_scale=0.0),)
    res = run_controlled(TOPO, UNI, CFG.replace(algo=Algo.XY),
                         Scenario("off", events=ev, policy="stale",
                                  replan=ReplanConfig(epoch=500)))
    r = res.results[0]
    # injection stops at the event: far fewer flits than the full run
    full = run_sweep(TOPO, UNI, CFG.replace(algo=Algo.XY), [0.35])[0]
    assert r.injected_flits < full.injected_flits * 0.6


def test_estimator_prior_backs_cold_start_and_empty_windows():
    """The offline prior owns the cold-start fallback: matrix() serves
    it (diagonal zeroed, normalized) until the first packets, an
    all-zero window keeps the current estimate instead of dividing by
    it, and the first real observation replaces the prior outright."""
    est = TrafficEstimator(4, prior=np.ones((4, 4)))
    m = est.matrix
    assert m is not None and np.isfinite(m).all()
    assert m.sum() == pytest.approx(1.0)
    assert np.all(np.diag(m) == 0)
    est.update(np.zeros((4, 4)))          # empty window: guarded no-op
    np.testing.assert_array_equal(est.matrix, m)
    c = np.zeros((4, 4))
    c[0, 1] = 5.0
    est.update(c)
    assert est.matrix[0, 1] == pytest.approx(1.0)
    assert TrafficEstimator(4).matrix is None       # nothing to serve
    assert TrafficEstimator(4, prior=np.zeros((4, 4))).matrix is None


def test_cold_start_fault_replans_before_any_packet():
    """Regression for the cycle-0 cold start: a fault in the very first
    epoch with ZERO injected packets (rate 0) must still replan — the
    estimator serves the offline prior, and the resulting table clears
    deadlock certification (replan() raises CertificationError
    otherwise).  Previously the zero-observation window left matrix()
    None and only a caller-side special case kept fault triggers alive."""
    fail = (LinkFail(cycle=1, links=FAIL_LINKS, bw_scale=0.25),)
    cfg = CFG.replace(cycles=1200, warmup=100)
    out = run_controlled(
        TOPO, UNI, cfg,
        Scenario("cold", events=fail, policy="online",
                 replan=ReplanConfig(epoch=400)),
        rates=[0.0], bidor_table=PLAN.table)
    assert out.replans and out.replans[0].trigger == "fault"
    assert out.replans[0].cycle <= 400
    assert out.replans[0].unroutable_pairs == 0
