"""Model-zoo correctness: forward shapes, decode≡forward equivalence,
flash-attention oracle properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import ModelConfig
from repro.models import registry
from repro.models.layers.attention import flash_attention_ref

B, S, V = 2, 24, 96


def _base(**kw):
    d = dict(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
             n_kv_heads=2, d_ff=128, vocab=V, dtype="float32",
             attn_q_chunk=8, attn_kv_chunk=8, mamba_chunk=8, xlstm_chunk=8,
             remat=False)
    d.update(kw)
    return ModelConfig(**d)


CONFIGS = {
    "dense": _base(),
    "moe": _base(family="moe", moe_experts=4, moe_topk=2, moe_shared=1,
                 capacity_factor=2.0),
    "mla": _base(mla=True, q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                 qk_rope_dim=8, v_head_dim=16),
    "vlm": _base(family="vlm", mrope_sections=(2, 3, 3)),
    "hybrid": _base(family="hybrid", n_layers=4, attn_period=4,
                    moe_experts=4, moe_topk=2, moe_period=2,
                    capacity_factor=2.0),
    "ssm": _base(family="ssm", n_layers=4, slstm_period=4, d_ff=0),
}


def _tokens(key):
    return jax.random.randint(key, (B, S), 0, V)


def _positions(cfg):
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.mrope_sections:
        return jnp.broadcast_to(pos[None], (3, B, S))
    return pos


# --------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("fam", list(CONFIGS))
def test_forward_shapes_and_finite(fam):
    cfg = CONFIGS[fam]
    params = registry.init(cfg, jax.random.PRNGKey(0))
    logits, aux = registry.forward(cfg, params, _tokens(jax.random.PRNGKey(1)),
                                   positions=_positions(cfg))
    assert logits.shape == (B, S, V)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


def test_encdec_forward():
    cfg = _base(family="encdec", enc_layers=2, n_kv_heads=4)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(2), (B, 16, 64))
    logits, _ = registry.forward(cfg, params, _tokens(jax.random.PRNGKey(1)),
                                 embeds=frames)
    assert logits.shape == (B, S, V)
    assert np.isfinite(np.asarray(logits)).all()


# --------------------------------------------------------------------- #
# decode ≡ forward (the key serving-correctness invariant)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("fam", ["dense", "mla", "hybrid", "ssm"])
def test_prefill_plus_decode_matches_forward(fam):
    cfg = CONFIGS[fam]
    mod = registry.model_module(cfg)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    tokens = _tokens(jax.random.PRNGKey(1))
    full, _ = registry.forward(cfg, params, tokens)
    full = np.asarray(full)

    split = S // 2
    cache = registry.init_cache(cfg, B, S)
    logits_a, cache = mod.prefill(cfg, params, tokens[:, :split], cache)
    outs = [np.asarray(logits_a)]
    for t in range(split, S):
        step_logits, cache = mod.decode_step(
            cfg, params, tokens[:, t:t + 1], cache, jnp.int32(t))
        outs.append(np.asarray(step_logits))
    stitched = np.concatenate(outs, axis=1)
    np.testing.assert_allclose(stitched, full, rtol=2e-3, atol=2e-3)


def test_encdec_decode_matches_forward():
    cfg = _base(family="encdec", enc_layers=2, n_kv_heads=4, remat=False)
    mod = registry.model_module(cfg)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    tokens = _tokens(jax.random.PRNGKey(1))
    frames = jax.random.normal(jax.random.PRNGKey(2), (B, 16, 64))
    enc = mod.encode(cfg, params, frames)
    full, _ = mod.decode(cfg, params, tokens, enc)
    full = np.asarray(full)
    cache = registry.init_cache(cfg, B, S)
    logits, cache = mod.prefill(cfg, params, tokens[:, :4], cache, enc_out=enc)
    outs = [np.asarray(logits)]
    for t in range(4, S):
        lg, cache = mod.decode_step(cfg, params, tokens[:, t:t + 1], cache,
                                    jnp.int32(t), enc_out=enc)
        outs.append(np.asarray(lg))
    np.testing.assert_allclose(np.concatenate(outs, 1), full,
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------- #
# flash attention oracle vs naive softmax
# --------------------------------------------------------------------- #
def _naive_attention(q, k, v, causal, mask_len=None):
    b, sq, h, dk = q.shape
    _, skv, kv, dv = v.shape
    g = h // kv
    qr = q.reshape(b, sq, kv, g, dk)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qr, k) * dk ** -0.5
    if causal:
        off = skv - sq
        mask = (jnp.arange(skv)[None, :]
                <= jnp.arange(sq)[:, None] + off)
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    if mask_len is not None:
        ml = mask_len[:, None, None, None, None] if mask_len.ndim == 1 \
            else mask_len[:, :, None, None, None]
        s = jnp.where(jnp.arange(skv)[None, None, None, None, :] < ml,
                      s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", w, v)
    return o.reshape(b, sq, h, dv)


@pytest.mark.parametrize("sq,skv,h,kv,causal", [
    (16, 16, 4, 4, True), (16, 16, 4, 2, True), (8, 24, 4, 2, False),
    (1, 24, 4, 1, False), (17, 17, 2, 1, True), (24, 24, 8, 2, False),
])
def test_flash_ref_matches_naive(sq, skv, h, kv, causal):
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, sq, h, 16))
    k = jax.random.normal(k2, (B, skv, kv, 16))
    v = jax.random.normal(k3, (B, skv, kv, 16))
    out = flash_attention_ref(q, k, v, causal=causal, q_chunk=7, kv_chunk=5)
    ref = _naive_attention(q, k, v, causal)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_flash_ref_mask_len_per_query():
    key = jax.random.PRNGKey(4)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, 6, 4, 8))
    k = jax.random.normal(k2, (B, 20, 2, 8))
    v = jax.random.normal(k3, (B, 20, 2, 8))
    ml = jnp.broadcast_to(10 + jnp.arange(6)[None], (B, 6))
    out = flash_attention_ref(q, k, v, causal=False, q_chunk=4, kv_chunk=8,
                              bias_mask_len=ml)
    ref = _naive_attention(q, k, v, False, mask_len=ml)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_moe_all_tokens_routed_with_high_capacity():
    """With capacity_factor ≫ 1 no token is dropped: output differs from
    zero everywhere and aux loss ≈ its minimum for near-uniform routing."""
    cfg = CONFIGS["moe"]
    from repro.models.layers.ffn import moe_apply, moe_init
    p = moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    y, aux = moe_apply(cfg, p, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0


def test_mamba_chunked_scan_invariant_to_chunk_size():
    from repro.models.layers.recurrent import mamba_apply, mamba_init
    cfg1 = _base(mamba_chunk=4)
    cfg2 = _base(mamba_chunk=24)
    p = mamba_init(cfg1, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg1.d_model)) * 0.1
    y1 = mamba_apply(cfg1, p, x)
    y2 = mamba_apply(cfg2, p, x)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)


def test_mlstm_chunked_scan_invariant_to_chunk_size():
    from repro.models.layers.recurrent import mlstm_apply, mlstm_init
    cfg1 = _base(xlstm_chunk=4)
    cfg2 = _base(xlstm_chunk=24)
    p = mlstm_init(cfg1, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg1.d_model)) * 0.1
    y1 = mlstm_apply(cfg1, p, x)
    y2 = mlstm_apply(cfg2, p, x)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)
