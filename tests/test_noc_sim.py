"""Behavioural tests for the flit-level NoC simulator."""

import numpy as np
import pytest

from repro.core import mesh2d, mesh2d_edge_io, traffic, build_plan
from repro.noc import Algo, SimConfig, run_sim
from repro.noc.sim import run_sweep, run_trace
from repro.noc.workload import clos_leaf_trace

TOPO = mesh2d(5, 5)
UNI = traffic.uniform(TOPO)
FAST = dict(cycles=2000, warmup=600)


def _run(algo, rate=0.15, topo=TOPO, tm=UNI, **kw):
    cfg = SimConfig(algo=algo, injection_rate=rate, **{**FAST, **kw})
    table = None
    if algo == Algo.BIDOR:
        table = build_plan(topo, tm).table
    return run_sim(topo, tm, cfg, bidor_table=table)


@pytest.mark.parametrize("algo", list(Algo))
def test_flit_conservation(algo):
    """Injected flits are either ejected or still buffered — never lost."""
    r = _run(algo)
    assert r.injected_flits == r.ejected_flits + r.in_flight_flits
    assert r.ejected_flits > 0


@pytest.mark.parametrize("algo", [Algo.XY, Algo.YX, Algo.BIDOR])
def test_deterministic_algos_have_zero_reorder(algo):
    """§3.3.2: quasi-static routing is free from out-of-order transmission."""
    r = _run(algo, rate=0.3)
    assert r.reorder_value == 0


def test_oblivious_algos_reorder_under_load():
    r = _run(Algo.O1TURN, rate=0.45)
    assert r.reorder_value > 0


def test_throughput_tracks_offered_below_saturation():
    for algo in [Algo.XY, Algo.BIDOR, Algo.ODDEVEN]:
        r = _run(algo, rate=0.2)
        assert abs(r.throughput - 0.2) < 0.035, (algo, r.throughput)


def test_latency_at_least_distance_bound():
    """Min avg latency ≥ 2·E[dist] (2-cycle hops) at very low load."""
    r = _run(Algo.XY, rate=0.02)
    d = TOPO.distances
    mean_dist = (UNI * d).sum()
    assert r.avg_latency >= 2 * mean_dist
    # and not absurdly larger at near-zero load (queueing ≈ serialization)
    assert r.avg_latency <= 2 * mean_dist + 4 * 4  # + packet serialization


def test_throughput_monotone_then_saturates():
    rs = run_sweep(TOPO, UNI, SimConfig(algo=Algo.XY, **FAST),
                   [0.05, 0.2, 0.4])
    thr = [r.throughput for r in rs]
    assert thr[0] < thr[1] < thr[2]


def test_yx_is_transpose_symmetric_to_xy():
    """YX on uniform traffic ≈ XY (statistically): same mean latency ±10%."""
    rx = _run(Algo.XY, rate=0.25)
    ry = _run(Algo.YX, rate=0.25)
    assert abs(rx.avg_latency - ry.avg_latency) / rx.avg_latency < 0.1


def test_valiant_latency_higher_at_low_load():
    """Valiant doubles path length — visible at low load."""
    rv = _run(Algo.VALIANT, rate=0.05)
    rx = _run(Algo.XY, rate=0.05)
    assert rv.avg_latency > rx.avg_latency * 1.3


def test_bidor_zero_table_routes_like_xy():
    """With all-zero w_NR the BiDOR bitmap degenerates to pure XY."""
    from repro.core.bidor import bidor
    tab = bidor(TOPO, np.zeros(25))
    cfg = SimConfig(algo=Algo.BIDOR, injection_rate=0.15, **FAST)
    r = run_sim(TOPO, UNI, cfg, bidor_table=tab)
    assert r.reorder_value == 0
    assert r.injected_flits == r.ejected_flits + r.in_flight_flits


def test_edge_io_only_edge_nodes_inject():
    topo = mesh2d_edge_io(5, 5)
    tm = traffic.uniform(topo)
    r = _run(Algo.XY, rate=0.2, topo=topo, tm=tm)
    # interior nodes forward but never source/sink traffic; with XY routing
    # the center column/row still carries transit flits
    assert r.ejected_flits > 0
    assert r.injected_flits == r.ejected_flits + r.in_flight_flits


def test_oddeven_adaptive_delivers_under_hotspot():
    tm = traffic.hotspot(TOPO, hot_frac=0.4)
    r = _run(Algo.ODDEVEN, rate=0.15, tm=tm)
    assert r.ejected_flits > 0
    assert r.injected_flits == r.ejected_flits + r.in_flight_flits


def test_single_flit_packets():
    r = _run(Algo.XY, rate=0.2, packet_len=1)
    assert r.injected_flits == r.ejected_flits + r.in_flight_flits
    assert r.throughput > 0.15


def test_trace_driven_run():
    topo = mesh2d_edge_io(5, 5)
    segments, agg = clos_leaf_trace(topo, num_epochs=3, base_rate=0.15)
    plan = build_plan(topo, agg)
    cfg = SimConfig(algo=Algo.BIDOR, cycles=1500, warmup=400)
    res, lcvs = run_trace(topo, segments, cfg, bidor_table=plan.table)
    assert len(lcvs) == 3
    assert res.ejected_flits > 0
    assert res.reorder_value == 0  # quasi-static ⇒ in-order even on traces


def test_no_deadlock_at_high_load():
    """At 2× saturation every algorithm must keep making progress."""
    for algo in [Algo.XY, Algo.O1TURN, Algo.VALIANT, Algo.ROMM,
                 Algo.ODDEVEN, Algo.BIDOR]:
        r = _run(algo, rate=1.5)
        # sustained ejection in the measurement window
        assert r.throughput > 0.1, (algo, r.throughput)


def test_queue_occupancy_zero_capacity_is_zero_not_nan():
    """An all-zero traffic matrix has no I/O-capable sources, so the
    queue capacity is 0; occupancy must be exactly 0.0 — a NaN here
    poisons the >= saturation comparison and latches the early exit."""
    from repro.noc.sim import (build_tables, queue_occupancy,
                               source_queue_meta)

    cfg = SimConfig(**FAST)
    tables, _meta = build_tables(TOPO, np.zeros_like(UNI), None,
                                 cfg.num_vcs)
    io_mask, qcap = source_queue_meta(tables, cfg)
    assert qcap == 0.0 and not io_mask.any()
    occ = queue_occupancy(tables, cfg, np.ones((3, TOPO.num_nodes)),
                          (io_mask, qcap))
    assert occ.shape == (3,)
    assert np.all(occ == 0.0)
    assert np.all(np.isfinite(occ))
