"""Static route-analysis tests: ``predicted_node_load`` / ``link_load``.

These two functions score a quasi-static routing table against a traffic
matrix without running the simulator — they drive the ICI link-load work
and the Fig. 1 overlays, so their accounting must be exact: conservation
properties over random traffic plus a hand-computed 3×3 fixture.
"""

import numpy as np

from _propcheck import given, settings, st
from repro.core import mesh2d, traffic, build_plan
from repro.core.bidor import bidor
from repro.core.qstar import link_load, predicted_node_load


def _xy_table(topo):
    """All-zero w_NR ⇒ every pair picks order 0 (pure XY)."""
    return bidor(topo, np.zeros(topo.num_nodes))


def _random_traffic(topo, rnd):
    n = topo.num_nodes
    t = np.array([[rnd.random() for _ in range(n)] for _ in range(n)])
    np.fill_diagonal(t, 0.0)
    return t / t.sum()


# --------------------------------------------------------------------- #
# conservation properties
# --------------------------------------------------------------------- #
@settings(max_examples=15, deadline=None)
@given(st.integers(3, 6), st.integers(3, 6),
       st.randoms(use_true_random=False))
def test_link_load_conserves_total_hop_count(w, h, rnd):
    """Σ_c load_c · bw_c == Σ_{s,d} T[s,d] · dist(s,d): DOR routes are
    minimal, so every unit of traffic crosses exactly dist channels."""
    topo = mesh2d(w, h)
    t = _random_traffic(topo, rnd)
    plan = build_plan(topo, t)
    ll = link_load(topo, t, plan.table)
    expected = (t * topo.distances).sum()
    assert np.isclose((ll * topo.channel_bw).sum(), expected, rtol=1e-9)


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 6), st.integers(3, 6),
       st.randoms(use_true_random=False))
def test_node_load_conserves_total_node_visits(w, h, rnd):
    """Σ_n load_n == Σ_{s,d} T[s,d] · (dist(s,d) + 1): a minimal route
    visits dist+1 nodes, endpoints included."""
    topo = mesh2d(w, h)
    t = _random_traffic(topo, rnd)
    plan = build_plan(topo, t)
    load = predicted_node_load(topo, t, plan.table)
    expected = (t * (topo.distances + 1)).sum()
    assert np.isclose(load.sum(), expected, rtol=1e-9)


@settings(max_examples=12, deadline=None)
@given(st.integers(3, 5), st.integers(3, 5), st.integers(0, 2**31 - 1))
def test_bidor_max_load_dominates_dor_on_hotspot(w, h, seed):
    """On hotspot traffic the N-Rank-guided table must not concentrate
    more load on its hottest node than plain XY does — the paper's whole
    point (§3.3: spread pairs across the XY/YX routes)."""
    topo = mesh2d(w, h)
    t = traffic.hotspot(topo, hot_frac=0.5, num_hot=1, seed=seed)
    plan = build_plan(topo, t)
    peak_xy = predicted_node_load(topo, t, _xy_table(topo)).max()
    peak_bd = predicted_node_load(topo, t, plan.table).max()
    assert peak_bd <= peak_xy + 1e-12


@settings(max_examples=12, deadline=None)
@given(st.integers(3, 5), st.integers(3, 5), st.integers(0, 2**31 - 1))
def test_bidor_max_link_load_dominates_dor_on_hotspot(w, h, seed):
    topo = mesh2d(w, h)
    t = traffic.hotspot(topo, hot_frac=0.5, num_hot=1, seed=seed)
    plan = build_plan(topo, t)
    peak_xy = link_load(topo, t, _xy_table(topo)).max()
    peak_bd = link_load(topo, t, plan.table).max()
    assert peak_bd <= peak_xy + 1e-12


# --------------------------------------------------------------------- #
# exact hand-computed 3×3 fixture
# --------------------------------------------------------------------- #
# Node ids on the 3×3 mesh (id = y*3 + x):   6 7 8
#                                            3 4 5
#                                            0 1 2
def test_single_flow_xy_route_3x3():
    """T[0,8]=1 under XY: 0→1→2→5→8 (x first, then y)."""
    topo = mesh2d(3, 3)
    t = np.zeros((9, 9))
    t[0, 8] = 1.0
    tab = _xy_table(topo)
    load = predicted_node_load(topo, t, tab)
    expected = np.zeros(9)
    expected[[0, 1, 2, 5, 8]] = 1.0
    np.testing.assert_allclose(load, expected)
    ll = link_load(topo, t, tab)
    hot = {(int(u), int(v)) for (u, v), l in zip(topo.channels, ll)
           if l > 0}
    assert hot == {(0, 1), (1, 2), (2, 5), (5, 8)}
    assert np.isclose(ll.sum(), 4.0)  # 4 channel crossings


def test_single_flow_yx_route_3x3():
    """Forcing order 1 for ⟨0, 8⟩ must walk 0→3→6→7→8 (y first)."""
    topo = mesh2d(3, 3)
    t = np.zeros((9, 9))
    t[0, 8] = 1.0
    tab = _xy_table(topo)
    choice = tab.choice.copy()
    choice[0, 8] = 1
    import dataclasses
    tab_yx = dataclasses.replace(tab, choice=choice)
    load = predicted_node_load(topo, t, tab_yx)
    expected = np.zeros(9)
    expected[[0, 3, 6, 7, 8]] = 1.0
    np.testing.assert_allclose(load, expected)
    hot = {(int(u), int(v))
           for (u, v), l in zip(topo.channels, link_load(topo, t, tab_yx))
           if l > 0}
    assert hot == {(0, 3), (3, 6), (6, 7), (7, 8)}


def test_two_weighted_flows_3x3():
    """Loads add linearly: 0→8 (w=0.75, XY) + 2→0 (w=0.25, same row)."""
    topo = mesh2d(3, 3)
    t = np.zeros((9, 9))
    t[0, 8] = 0.75
    t[2, 0] = 0.25
    tab = _xy_table(topo)
    load = predicted_node_load(topo, t, tab)
    expected = np.zeros(9)
    expected[[0, 1, 2, 5, 8]] += 0.75   # 0→1→2→5→8
    expected[[2, 1, 0]] += 0.25         # 2→1→0
    np.testing.assert_allclose(load, expected)
    ll = link_load(topo, t, tab)
    lut = {(int(u), int(v)): float(l)
           for (u, v), l in zip(topo.channels, ll)}
    assert np.isclose(lut[(0, 1)], 0.75)
    assert np.isclose(lut[(1, 0)], 0.25)  # opposite directions distinct
    assert np.isclose(lut[(2, 1)], 0.25)
    assert np.isclose(lut[(5, 8)], 0.75)
