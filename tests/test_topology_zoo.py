"""Topology-zoo invariants: the graphs beyond 2D mesh/torus, and the
plan tables the pipeline builds over them.

Covers (ISSUE 4):
  * channel / reverse-channel consistency — every directed channel has its
    reverse, and the receiver-port pairing holds for express port classes;
  * minimal-path feasibility of every plan table — walking a plan's
    (choice, port_tables) artifact reaches the destination within the
    route horizon using only existing (and, on degraded graphs, live)
    channels, minimally on unit-step graphs;
  * ``Topology.degrade`` round-trips on the new graphs;
  * the channel-aware next-hop walker is bit-identical to the classic
    coordinate walk on unit-step topologies (the goldens' guarantee), and
    actually takes express hops where they exist.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import (build_plan_fast, cmesh, express_mesh,
                        fault_region_mesh, mesh2d, multipod, torus, traffic)
from repro.core.routes import dimension_orders, next_hop_table

ZOO = {
    "torus3d": lambda: torus(4, 4, 4),
    "cmesh": lambda: cmesh(4, 4, concentration=4),
    "express": lambda: express_mesh(6, 6, interval=2),
    "fault_region": lambda: fault_region_mesh(6, 6, (2, 2, 3, 3)),
}
UNIT_STEP = ("torus3d", "cmesh", "fault_region")   # no express channels


@pytest.fixture(scope="module", params=sorted(ZOO))
def zoo_topo(request):
    return ZOO[request.param]()


# --------------------------------------------------------------------- #
# graph invariants
# --------------------------------------------------------------------- #
def test_reverse_channel_consistency(zoo_topo):
    topo = zoo_topo
    for c, (u, n) in enumerate(topo.channels):
        rev = topo.channel_index(int(n), int(u))
        # a channel arrives at the receiver on the port its reverse
        # channel transmits from — including express port pairs
        assert topo.port_of_channel_at_receiver[c] == topo.channel_port[rev]
        # +dir ports are even, −dir odd, and they pair up
        assert topo.channel_port[c] // 2 == topo.channel_port[rev] // 2
        assert topo.channel_port[c] != topo.channel_port[rev]


def test_ports_consistent(zoo_topo):
    topo = zoo_topo
    # every (node, out-port) maps to at most one channel
    keys = set(zip(topo.channels[:, 0].tolist(), topo.channel_port.tolist()))
    assert len(keys) == topo.num_channels
    assert topo.channel_port.max() < topo.port_local
    # neighbor table round-trips the channel list
    nt = topo.neighbor_table
    for c, (u, n) in enumerate(topo.channels):
        assert nt[int(u), topo.channel_port[c]] == int(n)
    assert (nt[:, topo.port_local] == np.arange(topo.num_nodes)).all()


def test_express_ports_are_distinct():
    topo = express_mesh(6, 6, interval=2)
    assert topo.num_ports == 2 * 2 + 2 * 2 + 1   # base + 2 express classes
    s, d = topo.node_id((0, 0)), topo.node_id((4, 0))
    c = topo.channel_index(s, topo.node_id((2, 0)))
    assert topo.channel_port[c] >= 4              # express port class
    # express hop is actually taken: 0 -> 4 along x in 2 hops, not 4
    nh = next_hop_table(topo, (0, 1))
    cur, hops = s, 0
    while cur != d:
        cur, hops = int(nh[cur, d]), hops + 1
    assert hops == 2


def test_next_hop_identity_on_unit_topologies():
    """The channel-aware walker must reproduce the classic coordinate walk
    bit-for-bit wherever there are no express channels (the goldens)."""
    def naive(topo, order):
        n = topo.num_nodes
        cur = topo.coords[:, None, :]
        dst = topo.coords[None, :, :]
        nxt = np.broadcast_to(cur, (n, n, topo.ndim)).copy()
        moved = np.zeros((n, n), bool)
        for k in order:
            size, wrap = topo.dims[k], topo.wrap[k]
            delta = dst[..., k] - cur[..., k]
            if not wrap:
                step = np.sign(delta)
            else:
                fwd, bwd = delta % size, (-delta) % size
                step = np.where(fwd == 0, 0, np.where(fwd <= bwd, 1, -1))
            take = (~moved) & (step != 0)
            nxt[..., k] = np.where(take, (nxt[..., k] + step) % size,
                                   nxt[..., k])
            moved |= take
        strides = np.ones(topo.ndim, np.int64)
        for k in range(1, topo.ndim):
            strides[k] = strides[k - 1] * topo.dims[k - 1]
        return (nxt * strides).sum(-1).astype(np.int32)

    for topo in (mesh2d(5, 5), torus(4, 4), torus(3, 4, 5),
                 multipod(2, 4, 4), cmesh(4, 4)):
        for order in dimension_orders(topo.ndim):
            assert np.array_equal(next_hop_table(topo, order),
                                  naive(topo, order)), (topo.name, order)


# --------------------------------------------------------------------- #
# plan-table feasibility
# --------------------------------------------------------------------- #
def _walk_plan(topo, table, s, d):
    """Follow the plan artifact exactly as the table-routed simulator
    does: port = port_tables[choice[s, d], cur, d], hop = neighbor."""
    nt = topo.neighbor_table
    oi = int(table.choice[s, d])
    cur, hops, chans = s, 0, []
    while cur != d and hops <= topo.route_horizon:
        p = int(table.port_tables[oi, cur, d])
        if p == topo.port_local:
            break   # premature eject
        nxt = int(nt[cur, p])
        assert nxt >= 0, f"plan routes {s}->{d} over missing port {p}@{cur}"
        chans.append(topo.channel_index(cur, nxt))
        cur, hops = nxt, hops + 1
    return cur, hops, chans


def test_plan_tables_feasible(zoo_topo):
    topo = zoo_topo
    down = topo.down_channels
    tm = traffic.uniform(topo)
    plan = build_plan_fast(topo, tm,
                           down_channels=down if down.size else None)
    table = plan.table
    n = topo.num_nodes
    unroutable = (np.zeros((n, n), bool) if table.unroutable is None
                  else table.unroutable)
    unit = not topo._express_classes
    dist = topo.distances
    checked = 0
    for s in range(n):
        for d in range(n):
            if s == d or unroutable[s, d]:
                continue
            cur, hops, chans = _walk_plan(topo, table, s, d)
            assert cur == d, f"plan route {s}->{d} ends at {cur}"
            if unit:
                # minimal-path: exactly the (degraded-graph) hop distance
                assert hops == dist[s, d], (s, d, hops, dist[s, d])
            else:
                assert hops <= topo.route_horizon
            if down.size:
                assert not set(chans) & set(down.tolist()), \
                    f"plan route {s}->{d} crosses a down channel"
            checked += 1
    assert checked > 0


def test_fault_region_sheds_only_blocked_pairs():
    topo = fault_region_mesh(6, 6, (2, 2, 3, 3))
    plan = build_plan_fast(topo, traffic.uniform(topo),
                           down_channels=topo.down_channels)
    unroutable = plan.table.unroutable
    dead = topo.io_weights <= 0
    # every pair touching a dead router is unroutable; live pairs are
    # unroutable iff BOTH dimension orders cross the region (straight
    # lines through it), e.g. (0, 2) -> (5, 2) — and (0,0)->(5,5) is not
    assert unroutable[np.ix_(dead, ~dead)].all()
    s, d = topo.node_id((0, 2)), topo.node_id((5, 2))
    assert unroutable[s, d]
    s2, d2 = topo.node_id((0, 0)), topo.node_id((5, 5))
    assert not unroutable[s2, d2]


# --------------------------------------------------------------------- #
# degrade round-trip
# --------------------------------------------------------------------- #
def test_degrade_round_trip(zoo_topo):
    topo = zoo_topo
    ids = [0, topo.num_channels // 2]
    deg = topo.degrade(ids, bw_scale=0.0)
    # indexing untouched: the simulator keeps the full channel set
    assert np.array_equal(deg.channels, topo.channels)
    assert np.array_equal(deg.channel_port, topo.channel_port)
    assert deg.num_ports == topo.num_ports
    assert (deg.channel_bw[ids] == 0).all()
    # restore: failed channels back at original width == original bw
    import dataclasses
    back = dataclasses.replace(deg, channel_bw=topo.channel_bw.copy())
    assert np.array_equal(back.channel_bw, topo.channel_bw)
    # drop view: channels gone, distances no shorter than the intact graph
    dropped = topo.degrade(ids, drop=True)
    assert dropped.num_channels == topo.num_channels - len(ids)
    finite = (topo.distances < 10**6) & (dropped.distances < 10**6)
    assert (dropped.distances[finite] >= topo.distances[finite]).all()


def test_degrade_scaled_bw(zoo_topo):
    topo = zoo_topo
    ids = [1]
    half = topo.degrade(ids, bw_scale=0.5)
    assert np.isclose(half.channel_bw[1], topo.channel_bw[1] * 0.5)
    untouched = np.ones(topo.num_channels, bool)
    untouched[ids] = False
    assert np.array_equal(half.channel_bw[untouched],
                          topo.channel_bw[untouched])
