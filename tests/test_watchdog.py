"""Runtime stall watchdog: off-path byte-identity, deadlock detection
with escape recovery, livelock throttling, and report plumbing.

The contract (ISSUE 8 tentpole, runtime layer):

* ``watchdog=False`` is BYTE-IDENTICAL to a build without the module —
  the state carries no ``wd_*`` keys and every step path (unfused,
  fused dense, Pallas interpret) emits exactly the ops it did before;
* ``watchdog=True`` on a healthy network never fires and never changes
  results: only the ``wd_*`` bookkeeping arrays differ;
* a hand-built cyclic ring table (the canonical true deadlock, which
  the static certifier would reject — here force-fed to the simulator)
  trips the deadlock counter within the threshold window and DRAINS via
  the Duato-style escape lane (DOR escape table + highest VC), ejecting
  far more flits than the wedged baseline;
* the fused step agrees with the unfused oracle bit-for-bit with the
  watchdog on, including the wd_* arrays themselves.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BiDORTable, build_plan, mesh2d, traffic
from repro.kernels import simstep
from repro.noc import sim
from repro.noc.simconfig import Algo, SimConfig
from repro.noc.watchdog import WD_KEYS, WatchdogReport

TOPO = mesh2d(4, 4)


def _cyclic_ring_table(topo) -> BiDORTable:
    """All traffic clockwise around the 2x2 ring 0→1→3→2→0: a true
    cyclic channel dependency that wedges every VC (same fixture as
    tests/test_certify.py, where the certifier rejects it)."""
    n = topo.num_nodes
    ring = [0, 1, 3, 2]
    nxt = {ring[i]: ring[(i + 1) % 4] for i in range(4)}
    neigh = np.asarray(topo.neighbor_table)
    p = neigh.shape[1]
    pt = np.zeros((1, n, n), np.int8)
    for cur in range(n):
        for dst in range(n):
            pt[0, cur, dst] = (
                topo.port_local if cur == dst else
                [k for k in range(p) if neigh[cur, k] == nxt[cur]][0])
    return BiDORTable(choice=np.zeros((n, n), np.int8), orders=((0, 1),),
                      costs=np.zeros((1, n, n), np.float32),
                      port_tables=pt)


def _strip_wd(state: dict) -> dict:
    return {k: v for k, v in state.items() if k not in WD_KEYS}


def _assert_states_equal(a, b, ctx):
    assert sorted(a) == sorted(b), (sorted(a), sorted(b), ctx)
    bad = [k for k in a if not np.array_equal(a[k], b[k])]
    assert not bad, f"state diverged on {bad} ({ctx})"


def _assert_results_equal(a, b, ctx):
    da, db = dataclasses.asdict(a), dataclasses.asdict(b)
    bad = [k for k in da if not np.array_equal(da[k], db[k])]
    assert not bad, f"SimResult diverged on {bad} ({ctx})"


# --------------------------------------------------------------------- #
# healthy network: watchdog on == watchdog off, on every step path
# --------------------------------------------------------------------- #
def test_watchdog_off_state_carries_no_wd_keys():
    cfg = SimConfig(algo=Algo.XY, use_kernel=False)
    _, meta = sim.build_tables(TOPO, traffic.uniform(TOPO), None,
                               cfg.num_vcs)
    state = sim.fresh_state(meta, cfg)
    assert not any(k in state for k in WD_KEYS)
    state_on = sim.fresh_state(meta, cfg.replace(watchdog=True))
    assert all(k in state_on for k in WD_KEYS)


def test_healthy_net_byte_identical_all_paths():
    """150 cycles of XY on a healthy mesh: the watchdog-on state minus
    its own wd_* arrays equals the watchdog-off state bit for bit, and
    unfused / fused-dense / Pallas-interpret agree with the watchdog on
    (wd_* arrays included).  No trips fire."""
    cfg_off = SimConfig(algo=Algo.XY, use_kernel=False)
    cfg_on = cfg_off.replace(watchdog=True)
    tables, meta = sim.build_tables(TOPO, traffic.uniform(TOPO), None,
                                    cfg_off.num_vcs)
    steps = {
        "unfused-off": sim._make_step(meta, cfg_off),
        "unfused": sim._make_step(meta, cfg_on),
        "fused": simstep.make_step(meta, cfg_on, use_pallas=False),
        "interpret": simstep.make_step(meta, cfg_on, use_pallas=True,
                                       interpret=True),
    }

    def run(step, cfg):
        st0 = sim.fresh_state(meta, cfg)
        st0["rate"] = jnp.float32(0.45)
        st0["key"] = sim.point_key(7, 0.45)
        out, _ = jax.lax.scan(lambda s, c: step(tables, s, c), st0,
                              jnp.arange(150))
        return jax.device_get(out)

    out_off = run(steps["unfused-off"], cfg_off)
    outs = {k: run(s, cfg_on) for k, s in steps.items() if k != "unfused-off"}
    _assert_states_equal(out_off, _strip_wd(outs["unfused"]),
                         "watchdog on vs off")
    _assert_states_equal(outs["unfused"], outs["fused"], "fused/wd-on")
    _assert_states_equal(outs["unfused"], outs["interpret"],
                         "interpret/wd-on")
    wd = WatchdogReport.from_state(outs["unfused"], cfg_on)
    assert wd is not None and not wd.tripped


def test_healthy_net_results_identical_watchdog_on():
    """run_sim end to end: identical SimResult with the watchdog armed,
    a None report when off, a quiet report when on."""
    cfg = SimConfig(algo=Algo.XY, cycles=1200, warmup=200,
                    injection_rate=0.3, use_kernel=False)
    tm = traffic.uniform(TOPO)
    r_off, wd_off = sim.run_sim(TOPO, tm, cfg, return_watchdog=True)
    r_on, wd_on = sim.run_sim(TOPO, tm, cfg.replace(watchdog=True),
                              return_watchdog=True)
    assert wd_off is None
    assert wd_on is not None and not wd_on.tripped
    assert wd_on.max_stall < cfg.wd_stall_cycles
    _assert_results_equal(r_off, r_on, "healthy run_sim wd on/off")


def test_bidor_plan_table_quiet_under_watchdog():
    """A certified plan table never trips the sentinel (the two layers
    agree: statically clean ⇒ dynamically quiet)."""
    tm = traffic.uniform(TOPO)
    plan = build_plan(TOPO, tm)
    cfg = SimConfig(algo=Algo.BIDOR, cycles=1500, warmup=200,
                    injection_rate=0.35, use_kernel=False,
                    watchdog=True, wd_stall_cycles=48)
    _, wd = sim.run_sim(TOPO, tm, cfg, plan.table, return_watchdog=True)
    assert wd is not None and wd.deadlock_trips == 0


# --------------------------------------------------------------------- #
# true deadlock: detection + escape recovery
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def _wedged():
    """The cyclic 2x2 ring under saturating load, with and without the
    watchdog, plus the fused replay of the watchdog run."""
    topo = mesh2d(2, 2)
    table = _cyclic_ring_table(topo)
    tm = traffic.uniform(topo)
    cfg = SimConfig(algo=Algo.BIDOR, cycles=3000, warmup=500,
                    injection_rate=0.6, use_kernel=False, num_vcs=2)
    cfg_wd = cfg.replace(watchdog=True, wd_stall_cycles=32)
    r0, wd0 = sim.run_sim(topo, tm, cfg, table, return_watchdog=True)
    r1, wd1 = sim.run_sim(topo, tm, cfg_wd, table, return_watchdog=True)
    r1f, wd1f = sim.run_sim(topo, tm, cfg_wd.replace(use_kernel=True),
                            table, return_watchdog=True)
    return r0, wd0, r1, wd1, r1f, wd1f, cfg_wd


def test_cyclic_table_trips_deadlock_watchdog(_wedged):
    _, wd0, _, wd1, _, _, cfg_wd = _wedged
    assert wd0 is None                      # watchdog off ⇒ no report
    assert wd1.deadlock_trips > 0
    # detection is prompt: stall ages are bounded by the threshold plus
    # the drain latency of one escape episode, nowhere near the wedged
    # baseline's thousands of cycles
    assert wd1.max_stall < 4 * cfg_wd.wd_stall_cycles


def test_escape_recovery_drains_the_ring(_wedged):
    r0, _, r1, _, _, _, _ = _wedged
    # the wedged baseline ejects almost nothing; the escape lane keeps
    # the network flowing (4x is conservative — measured ~6x)
    assert r1.ejected_flits > 4 * max(r0.ejected_flits, 1)
    # conservation still holds under misrouting
    assert r1.injected_flits == r1.ejected_flits + r1.in_flight_flits


def test_deadlock_recovery_fused_matches_unfused(_wedged):
    _, _, r1, wd1, r1f, wd1f, _ = _wedged
    _assert_results_equal(r1, r1f, "cyclic-ring fused vs unfused")
    assert wd1 == wd1f


def test_livelock_throttle_trips_on_runaway_packets():
    """With a tiny hop budget the escape misroutes themselves read as
    runaway packets: the livelock counter fires and sources throttle,
    without destroying the deadlock recovery."""
    topo = mesh2d(2, 2)
    table = _cyclic_ring_table(topo)
    tm = traffic.uniform(topo)
    cfg = SimConfig(algo=Algo.BIDOR, cycles=3000, warmup=500,
                    injection_rate=0.6, use_kernel=False, num_vcs=2,
                    watchdog=True, wd_stall_cycles=32, wd_hop_limit=6,
                    wd_throttle_cycles=64)
    r, wd = sim.run_sim(topo, tm, cfg, table, return_watchdog=True)
    assert wd.livelock_trips > 0
    assert r.ejected_flits > 0
    assert r.injected_flits == r.ejected_flits + r.in_flight_flits


# --------------------------------------------------------------------- #
# report plumbing
# --------------------------------------------------------------------- #
def test_report_sums_over_lane_axis():
    cfg = SimConfig(watchdog=True, wd_stall_cycles=8)
    host = {"wd_trips": np.array([[2, 1], [3, 0]], np.int32),
            "wd_stall": np.array([[0, 9], [4, 0]], np.int32),
            "wd_throttle": np.array([[0, 5], [0, 0]], np.int32)}
    wd = WatchdogReport.from_state(host, cfg)
    assert wd == WatchdogReport(deadlock_trips=5, livelock_trips=1,
                                stalled_inputs=1, max_stall=9,
                                throttled_sources=1)
    assert wd.tripped
    assert wd.trace_args()["deadlock_trips"] == 5
    assert WatchdogReport.from_state({}, cfg) is None


def test_run_sweep_appends_watchdog_after_telemetry():
    cfg = SimConfig(algo=Algo.XY, cycles=600, warmup=100,
                    use_kernel=False, watchdog=True, telemetry=True)
    res, tel, wd = sim.run_sweep(TOPO, traffic.uniform(TOPO), cfg,
                                 [0.2], return_telemetry=True,
                                 return_watchdog=True)
    assert len(res) == 1
    assert tel is not None
    assert isinstance(wd, WatchdogReport) and not wd.tripped
