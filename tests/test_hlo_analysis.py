"""HLO analyzer correctness — the §Roofline methodology's foundation.

XLA's cost_analysis counts while bodies once; these tests pin down that our
analyzer multiplies by trip counts (including nesting), prices dots from
contraction dims, charges slices at slice size, and prices collectives with
group-aware ring-wire formulas.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo import (HloStats, analyze_hlo_text, roofline_terms,
                                xla_cost_analysis)


def _compile(f, *sds):
    return jax.jit(f).lower(*sds).compile()


def test_scan_trip_count_flops():
    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=10)
        return y

    c = _compile(f, jax.ShapeDtypeStruct((256, 256), jnp.float32))
    st = analyze_hlo_text(c.as_text())
    assert st.flops == 2 * 256 ** 3 * 10
    assert 10 in st.while_trip_counts
    # XLA's own analysis undercounts by the trip count
    assert xla_cost_analysis(c)["flops"] == pytest.approx(st.flops / 10)


def test_nested_scan_flops_compose():
    def f(x):
        def outer(c, _):
            y, _ = jax.lax.scan(lambda d, _: (d @ d, None), c, None,
                                length=5)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    c = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    st = analyze_hlo_text(c.as_text())
    assert st.flops == 2 * 128 ** 3 * 20


def test_rectangular_dot_flops():
    def f(a, b):
        return a @ b

    c = _compile(f, jax.ShapeDtypeStruct((64, 512), jnp.float32),
                 jax.ShapeDtypeStruct((512, 96), jnp.float32))
    st = analyze_hlo_text(c.as_text())
    assert st.flops == 2 * 64 * 512 * 96


def test_scan_slicing_charged_at_slice_not_array():
    """A scan that reads one small row per step from a big invariant array
    must not be charged the whole array per step."""
    big_rows, row = 512, 1024

    def f(table):
        def body(acc, i):
            acc = acc + jax.lax.dynamic_index_in_dim(
                table, i, 0, keepdims=False)
            return acc, None
        acc, _ = jax.lax.scan(body, jnp.zeros((row,), jnp.float32),
                              jnp.arange(big_rows))
        return acc

    c = _compile(f, jax.ShapeDtypeStruct((big_rows, row), jnp.float32))
    st = analyze_hlo_text(c.as_text())
    table_bytes = big_rows * row * 4
    # must be ~O(table read once + per-step row traffic), far below
    # big_rows × full-table
    assert st.hbm_bytes < 20 * table_bytes
    assert st.hbm_bytes >= table_bytes  # the table is genuinely read


def test_collective_bytes_and_group_size():
    import os
    import subprocess
    import sys
    # needs >1 device: subprocess with 8 host devices
    code = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
from repro.analysis.hlo import analyze_hlo_text
mesh = Mesh(np.array(jax.devices()).reshape(8), ("d",))
sh = NamedSharding(mesh, P(None, "d"))
def f(a, b):
    return a @ b   # contraction over the sharded dim -> all-reduce
sds_a = jax.ShapeDtypeStruct((128, 1024), jnp.float32, sharding=sh)
sds_b = jax.ShapeDtypeStruct(
    (1024, 128), jnp.float32,
    sharding=NamedSharding(mesh, P("d", None)))
with mesh:
    c = jax.jit(f).lower(sds_a, sds_b).compile()
st = analyze_hlo_text(c.as_text(), 8)
assert st.collective_counts.get("all-reduce", 0) >= 1, st.collective_counts
full = 128 * 128 * 4
assert abs(st.collective_bytes - full) < full * 0.5, st.collective_bytes
# ring wire: 2*(g-1)/g * bytes
assert st.collective_wire_bytes == __import__("pytest").approx(
    2 * 7 / 8 * st.collective_bytes, rel=0.01)
print("OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


def test_roofline_terms_and_dominance():
    st = HloStats(flops=197e12, hbm_bytes=819e9 / 2,
                  collective_wire_bytes=50e9 / 4)
    rl = roofline_terms(st, num_chips=4, model_flops=4 * 197e12 * 0.5)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(0.5)
    assert rl.collective_s == pytest.approx(0.25)
    assert rl.dominant == "compute"
    assert rl.mfu_bound == pytest.approx(0.5)
    assert rl.useful_flops_ratio == pytest.approx(0.5)


def test_flash_attention_hlo_flops_are_causal_exact():
    """The chunked-causal pair list must compile to ~S²/2 attention FLOPs,
    not the rectangular S² (keeps MODEL/HLO ratios honest)."""
    from repro.models.layers.attention import flash_attention_ref
    b, s, h, d = 1, 1024, 1, 64

    def f(q, k, v):
        return flash_attention_ref(q, k, v, causal=True, q_chunk=128,
                                   kv_chunk=128)

    sds = [jax.ShapeDtypeStruct((b, s, h, d), jnp.float32)] * 3
    c = _compile(f, *sds)
    st = analyze_hlo_text(c.as_text())
    causal_flops = 2 * 2 * b * h * d * (s * s / 2)   # qk + pv over S²/2
    # allow the diagonal-block overcount (+1 block row) and misc dots
    assert st.flops < causal_flops * 1.35
    assert st.flops > causal_flops * 0.8
