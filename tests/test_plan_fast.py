"""Device-resident planning pipeline vs the numpy oracles.

``build_plan_fast`` must be a drop-in for ``build_plan(mode="channel")``:
identical BiDOR choice tables (the deployed artifact — exact), and
NR-weights matching to the fp32-evolution noise the host pipeline itself
carries (see EXPERIMENTS.md §Planner performance for the tolerance
policy).  Covered here: random meshes/tori, degraded topologies
(fault-masked planning vs the drop-topology oracle), warm-start ``w0``
carries, the compiled possibility/joint kernels, and the vmapped batched
builds.
"""

import dataclasses

import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core import (bidor, build_plan, build_plan_fast,
                        build_plans_batched, mesh2d, mesh2d_edge_io, torus,
                        traffic)
from repro.core.nrank import (initial_weights, joint_possibility,
                              nrank_channel, possibility_weights)
from repro.core.plan_fast import joint_possibility_fast
from repro.kernels.possibility import ops as poss_ops

# Tolerance policy bound (EXPERIMENTS.md §Planner performance): fp32 on
# accelerator backends.  On CPU both pipelines run fp64 and actually agree
# to ~1e-12; the bound stays at the policy level so the suite is
# backend-portable.
W_NR_RTOL = 2e-5


def _rand_traffic(topo, seed):
    rng = np.random.default_rng(seed)
    t = rng.random((topo.num_nodes,) * 2)
    np.fill_diagonal(t, 0)
    return t / t.sum()


# --------------------------------------------------------------------- #
# full-pipeline parity
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("topo_fn,pattern", [
    (lambda: mesh2d(5, 5), "uniform"),
    (lambda: mesh2d_edge_io(5, 5), "overturn"),
    (lambda: torus(8, 8), "uniform"),
    (lambda: mesh2d(4, 7), "shuffle"),
    (lambda: torus(6, 6), "transpose"),
])
def test_fast_plan_matches_oracle(topo_fn, pattern):
    topo = topo_fn()
    t = traffic.PATTERNS[pattern](topo)
    ref = build_plan(topo, t)
    fast = build_plan_fast(topo, t)
    np.testing.assert_array_equal(fast.table.choice, ref.table.choice)
    assert fast.nrank.iterations == ref.nrank.iterations
    np.testing.assert_allclose(fast.nrank.w_nr, ref.nrank.w_nr,
                               rtol=W_NR_RTOL, atol=1e-9)
    np.testing.assert_allclose(fast.nrank.w_possibility,
                               ref.nrank.w_possibility,
                               rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(fast.nrank.w_final, ref.nrank.w_final,
                               rtol=W_NR_RTOL, atol=1e-9)
    assert fast.table.orders == ref.table.orders
    np.testing.assert_array_equal(fast.table.port_tables,
                                  ref.table.port_tables)


@settings(max_examples=8, deadline=None)
@given(st.integers(3, 6), st.integers(3, 6), st.booleans(),
       st.integers(0, 2**31 - 1))
@pytest.mark.slow
def test_fast_plan_random(w, h, wrap, seed):
    topo = torus(w, h) if wrap and min(w, h) > 2 else mesh2d(w, h)
    t = _rand_traffic(topo, seed)
    ref = build_plan(topo, t)
    fast = build_plan_fast(topo, t)
    np.testing.assert_array_equal(fast.table.choice, ref.table.choice)
    assert fast.nrank.iterations == ref.nrank.iterations
    np.testing.assert_allclose(fast.nrank.w_nr, ref.nrank.w_nr,
                               rtol=W_NR_RTOL, atol=1e-9)


# --------------------------------------------------------------------- #
# degraded topologies: masked fast path vs the drop-topology oracle
# --------------------------------------------------------------------- #
@settings(max_examples=6, deadline=None)
@given(st.integers(4, 6), st.integers(4, 6), st.integers(0, 2**31 - 1))
def test_fast_plan_degraded(w, h, seed):
    topo = mesh2d(w, h)
    t = _rand_traffic(topo, seed)
    rng = np.random.default_rng(seed ^ 0x5EED)
    c = int(rng.integers(topo.num_channels))
    u, n = (int(x) for x in topo.channels[c])
    down = np.array([topo.channel_index(u, n), topo.channel_index(n, u)])
    bw = topo.channel_bw.copy()
    bw[down] = 0.0
    plan_topo = dataclasses.replace(topo, channel_bw=bw)
    # oracle: N-Rank on the dropped graph, fault-masked BiDOR
    nr = nrank_channel(plan_topo.degrade(down, drop=True), t)
    table = bidor(plan_topo, nr.w_nr, down_channels=down)
    fast = build_plan_fast(plan_topo, t, down_channels=down)
    np.testing.assert_array_equal(fast.table.choice, table.choice)
    np.testing.assert_array_equal(fast.table.unroutable, table.unroutable)
    assert fast.nrank.iterations == nr.iterations
    np.testing.assert_allclose(fast.nrank.w_nr, nr.w_nr,
                               rtol=W_NR_RTOL, atol=1e-9)


def test_fast_plan_no_faults_has_no_unroutable():
    topo = mesh2d(4, 4)
    fast = build_plan_fast(topo, traffic.uniform(topo))
    assert fast.table.unroutable is None


# --------------------------------------------------------------------- #
# warm-start carry
# --------------------------------------------------------------------- #
@settings(max_examples=6, deadline=None)
@given(st.integers(4, 6), st.integers(4, 6), st.integers(0, 2**31 - 1))
def test_fast_plan_warm_start(w, h, seed):
    topo = mesh2d(w, h)
    t0 = _rand_traffic(topo, seed)
    t1 = _rand_traffic(topo, seed + 1)
    prev = nrank_channel(topo, t0)
    w0 = initial_weights(t1) + prev.w_final
    ref = build_plan(topo, t1, w0=w0)
    fast = build_plan_fast(topo, t1, w0=w0)
    np.testing.assert_array_equal(fast.table.choice, ref.table.choice)
    assert fast.nrank.iterations == ref.nrank.iterations
    np.testing.assert_allclose(fast.nrank.w_nr, ref.nrank.w_nr,
                               rtol=W_NR_RTOL, atol=1e-9)
    np.testing.assert_allclose(fast.nrank.w0, ref.nrank.w0, rtol=1e-12)


# --------------------------------------------------------------------- #
# stage kernels: possibility weights and the joint possibility
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("topo_fn", [
    lambda: mesh2d(5, 5), lambda: torus(6, 6), lambda: mesh2d(3, 8),
])
def test_joint_possibility_fast_matches_oracle(topo_fn):
    topo = topo_fn()
    t = _rand_traffic(topo, 7)
    j_ref = joint_possibility(topo, t)
    j_fast = joint_possibility_fast(topo, t)
    np.testing.assert_allclose(j_fast, j_ref, rtol=1e-9, atol=1e-12)


def test_joint_possibility_use_kernel_threads_through():
    topo = torus(5, 5)
    t = _rand_traffic(topo, 11)
    np.testing.assert_allclose(joint_possibility(topo, t, use_kernel=True),
                               joint_possibility(topo, t),
                               rtol=1e-9, atol=1e-12)


def test_nrank_channel_use_kernel_matches_host():
    """The compiled possibility stages (fp32 kernel path) reproduce the
    host pipeline's plan: same iterations, close weights, same choices."""
    topo = mesh2d(5, 5)
    t = traffic.uniform(topo)
    host = nrank_channel(topo, t)
    dev = nrank_channel(topo, t, use_kernel=True)
    assert dev.iterations == host.iterations
    np.testing.assert_allclose(dev.w_nr, host.w_nr, rtol=1e-4, atol=1e-7)
    ref_tab = bidor(topo, host.w_nr)
    dev_tab = bidor(topo, dev.w_nr)
    np.testing.assert_array_equal(dev_tab.choice, ref_tab.choice)


def test_possibility_ops_compiled_default_matches_numpy_oracle():
    """ops.possibility_weights with all defaults (the compiled path on
    every backend — dense jnp where Pallas cannot compile) vs the numpy
    oracle."""
    topo = torus(8, 8)
    t = _rand_traffic(topo, 3)
    w_ref, wd_ref = possibility_weights(topo.distances, t, topo.channels)
    w, wd = poss_ops.possibility_weights(topo.distances, t, topo.channels)
    np.testing.assert_allclose(np.asarray(w), w_ref, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(wd), wd_ref, rtol=1e-4, atol=1e-6)


# --------------------------------------------------------------------- #
# batched (vmapped) plan builds
# --------------------------------------------------------------------- #
def test_batched_plans_match_single_builds():
    topo = mesh2d(5, 5)
    tms = [traffic.PATTERNS[p](topo)
           for p in ("uniform", "transpose", "shuffle")]
    batched = build_plans_batched(topo, tms)
    for tm, plan in zip(tms, batched):
        single = build_plan_fast(topo, tm)
        np.testing.assert_array_equal(plan.table.choice,
                                      single.table.choice)
        assert plan.nrank.iterations == single.nrank.iterations
        np.testing.assert_array_equal(plan.nrank.w_nr, single.nrank.w_nr)
        np.testing.assert_array_equal(plan.nrank.w_final,
                                      single.nrank.w_final)


def test_batched_plans_heterogeneous_iterations():
    """Lanes terminate independently under vmap: a pattern that converges
    in few iterations must not be perturbed by a slower lane."""
    topo = mesh2d_edge_io(5, 5)
    tms = [traffic.uniform(topo), traffic.PATTERNS["overturn"](topo)]
    batched = build_plans_batched(topo, tms)
    singles = [build_plan_fast(topo, tm) for tm in tms]
    its = [p.nrank.iterations for p in batched]
    assert its == [s.nrank.iterations for s in singles]
    assert len(set(its)) > 1, "fixture should exercise unequal lane lengths"
    for plan, single in zip(batched, singles):
        np.testing.assert_array_equal(plan.nrank.w_nr, single.nrank.w_nr)
