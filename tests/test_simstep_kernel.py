"""Differential battery: the fused flit-step kernel vs. the unfused step.

The contract is BIT-IDENTITY of the full state pytree — every packed
flit record, FIFO pointer, wormhole lock, statistic counter and PRNG
key — not statistical closeness.  Three layers:

* exhaustive (topology × algorithm) parity from fresh state;
* property-based parity from randomized MID-FLIGHT states (occupied
  VCs, held output ports, partially drained queues): the unfused
  oracle advances a fresh state by a sampled number of cycles at a
  sampled rate — every state it can reach is by construction a valid
  mid-flight state — then both paths step forward from that state and
  must agree array-for-array;
* the Pallas kernel in interpret mode (the CPU coverage path for the
  compiled TPU/GPU route) against the same oracle.

Runners come from ``sim.get_runner`` with ``use_kernel`` flipped, i.e.
exactly the code paths campaigns execute.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _propcheck import given, settings, st
from repro.core import build_plan, cmesh, mesh2d, torus, traffic
from repro.kernels import simstep
from repro.noc import sim
from repro.noc.simconfig import Algo, SimConfig

TOPOS = {
    "mesh4x4": mesh2d(4, 4),
    "torus4x4": torus(4, 4),
    "cmesh3x3c2": cmesh(3, 3, 2),
}
# one algorithm per distinct code path: deterministic DOR, plan-table
# quasi-static, random order, two-phase random intermediate, adaptive
ALGOS = (Algo.XY, Algo.BIDOR, Algo.O1TURN, Algo.ROMM, Algo.ODDEVEN)


@functools.lru_cache(maxsize=None)
def _cell(topo_name: str, algo: Algo):
    """(tables, meta, cfgs) for one differential cell, cached so the
    property test reuses jit compilations across examples."""
    topo = TOPOS[topo_name]
    tm = traffic.uniform(topo)
    table = build_plan(topo, tm).table if algo == Algo.BIDOR else None
    cfg_u = SimConfig(algo=algo, cycles=4000, warmup=50, use_kernel=False)
    tables, meta = sim.build_tables(topo, tm, table, cfg_u.num_vcs)
    return tables, meta, cfg_u, cfg_u.replace(use_kernel=True)


def _assert_states_equal(a, b, ctx):
    bad = [k for k in a if not np.array_equal(a[k], b[k])]
    assert not bad, f"fused diverged from unfused on {bad} ({ctx})"


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("topo_name", sorted(TOPOS))
def test_fused_bit_identical_from_fresh_state(topo_name, algo):
    """Every (topology, algorithm) cell: 150 cycles from fresh state,
    full state pytree equal bit for bit (two saturating-ish lanes)."""
    if algo == Algo.ODDEVEN and TOPOS[topo_name].ndim != 2:
        pytest.skip("odd-even is 2D-only")
    tables, meta, cfg_u, cfg_f = _cell(topo_name, algo)
    points = [(0.25, 0), (0.8, 1)]
    out_u = jax.device_get(sim.get_runner(meta, cfg_u, 150)(
        tables, sim.make_states(meta, cfg_u, points)))
    out_f = jax.device_get(sim.get_runner(meta, cfg_f, 150)(
        tables, sim.make_states(meta, cfg_f, points)))
    _assert_states_equal(out_u, out_f, f"{topo_name}/{algo.name}")


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(sorted(TOPOS)), st.sampled_from(ALGOS),
       st.sampled_from([40, 90, 160]),      # oracle warm-in (mid-flight)
       st.floats(0.05, 1.2), st.integers(0, 2**16),
       st.booleans())                       # drain the tail (inject halt)
def test_fused_bit_identical_from_midflight_state(topo_name, algo, warm,
                                                  rate, seed, drain):
    """Parity from randomized mid-flight states.  The unfused oracle
    advances ``warm`` cycles at a random rate/seed — leaving occupied
    VC FIFOs, held output ports and partially drained source queues —
    then both paths run 60 further cycles from that exact state (with
    injection optionally halted, exercising the drain phase) and the
    resulting pytrees must match bit for bit."""
    tables, meta, cfg_u, cfg_f = _cell(topo_name, algo)
    points = [(float(rate), int(seed) % 1000)]
    mid = sim.get_runner(meta, cfg_u, int(warm))(
        tables, sim.make_states(meta, cfg_u, points))
    if drain:  # injection stops mid-run: partially drained queues
        mid = dict(mid)
        mid["inject_until"] = jnp.full_like(mid["inject_until"],
                                            int(warm) + 20)
    out_u = jax.device_get(sim.get_runner(meta, cfg_u, 60)(tables, mid))
    out_f = jax.device_get(sim.get_runner(meta, cfg_f, 60)(tables, mid))
    _assert_states_equal(
        out_u, out_f,
        f"{topo_name}/{algo.name} warm={warm} rate={rate:.3f} "
        f"seed={seed} drain={drain}")


@pytest.mark.parametrize("algo", [Algo.XY, Algo.BIDOR, Algo.ODDEVEN])
def test_pallas_interpret_matches_unfused(algo):
    """The actual Pallas kernel (interpret mode on CPU — same kernel
    the compiled TPU/GPU path lowers) against the unfused oracle,
    through warm-up into a loaded network — both unbatched and under
    the jit(vmap(scan(...))) composition every campaign runner uses."""
    tables, meta, cfg_u, _ = _cell("mesh4x4", algo)
    step_u = sim._make_step(meta, cfg_u)
    step_p = simstep.make_step(meta, cfg_u, use_pallas=True,
                               interpret=True)
    st0 = sim.fresh_state(meta, cfg_u)
    st0["rate"] = jnp.float32(0.5)
    st0["key"] = sim.point_key(3, 0.5)

    def run(step, state):
        state, _ = jax.lax.scan(lambda s, c: step(tables, s, c), state,
                                jnp.arange(80))
        return jax.device_get(state)

    _assert_states_equal(run(step_u, st0), run(step_p, st0),
                         f"pallas-interpret/{algo.name}")

    def run_batched(step, batched):
        def one(state):
            state, _ = jax.lax.scan(lambda s, c: step(tables, s, c),
                                    state, jnp.arange(60))
            return state
        return jax.device_get(jax.jit(jax.vmap(one))(batched))

    batched = sim.make_states(meta, cfg_u, [(0.3, 0), (0.7, 1)])
    _assert_states_equal(run_batched(step_u, batched),
                         run_batched(step_p, batched),
                         f"pallas-interpret-vmapped/{algo.name}")


def test_wide_rewrites_bit_identical_when_forced():
    """The N >= _WIDE_N rewrites (binary-search destination sampling,
    scatter next_seq/reorder updates) checked against the oracle on a
    small mesh by forcing the gate open — the cheap fast-loop coverage
    of the code path that normally only runs at 16x16+."""
    from repro.kernels.simstep import ref as simstep_ref

    tables, meta, cfg_u, _ = _cell("mesh4x4", Algo.O1TURN)
    step_u = sim._make_step(meta, cfg_u)
    old = simstep_ref._WIDE_N
    simstep_ref._WIDE_N = 1
    try:
        step_w = simstep.make_step(meta, cfg_u, use_pallas=False)
    finally:
        simstep_ref._WIDE_N = old
    st0 = sim.fresh_state(meta, cfg_u)
    st0["rate"] = jnp.float32(0.6)
    st0["key"] = sim.point_key(9, 0.6)

    def run(step, state):
        state, _ = jax.lax.scan(lambda s, c: step(tables, s, c), state,
                                jnp.arange(120))
        return jax.device_get(state)

    _assert_states_equal(run(step_u, st0), run(step_w, st0),
                         "forced-wide/O1TURN")


@pytest.mark.slow
def test_fused_bit_identical_16x16_wide_path():
    """True-scale coverage of the size-gated rewrites: 16x16 (N = 256,
    the _WIDE_N threshold) fused vs unfused, bit for bit."""
    topo = mesh2d(16, 16)
    tm = traffic.uniform(topo)
    cfg_u = SimConfig(cycles=4000, warmup=30, use_kernel=False)
    tables, meta = sim.build_tables(topo, tm, None, cfg_u.num_vcs)
    points = [(0.3, 0)]
    out_u = jax.device_get(sim.get_runner(meta, cfg_u, 120)(
        tables, sim.make_states(meta, cfg_u, points)))
    cfg_f = cfg_u.replace(use_kernel=True)
    out_f = jax.device_get(sim.get_runner(meta, cfg_f, 120)(
        tables, sim.make_states(meta, cfg_f, points)))
    _assert_states_equal(out_u, out_f, "mesh16x16/XY")


def test_pallas_auto_gates_on_vmem_footprint():
    """The auto path must never hand a state that cannot fit on chip to
    the whole-array kernel: the 4x4 footprint sits under the budget,
    the 32x32 one over it (the dense fused body takes over there)."""
    from repro.kernels.simstep import ops as simstep_ops

    cfg = SimConfig()
    _, meta_small = sim.build_tables(TOPOS["mesh4x4"],
                                     traffic.uniform(TOPOS["mesh4x4"]),
                                     None, cfg.num_vcs)
    big = mesh2d(32, 32)
    _, meta_big = sim.build_tables(big, traffic.uniform(big), None,
                                   cfg.num_vcs)
    small_b = simstep_ops.state_footprint_bytes(meta_small, cfg)
    big_b = simstep_ops.state_footprint_bytes(meta_big, cfg)
    assert small_b < simstep_ops.VMEM_BUDGET_BYTES < big_b, \
        (small_b, big_b)


def test_fused_is_the_default_and_flag_reaches_runner():
    """SimConfig defaults to the fused kernel and the flag is part of
    the compilation cache key (flipping it cannot alias runners)."""
    assert SimConfig().use_kernel is True
    k_f = sim._cfg_key(SimConfig())
    k_u = sim._cfg_key(SimConfig(use_kernel=False))
    assert k_f != k_u
    assert dict(k_f)["use_kernel"] is True


def test_split_rand_matches_unfused_key_schedule():
    """The hoisted RNG consumes the lane key exactly like the unfused
    step: new key == first subkey of the 5-way split, and the draws
    come from the same subkeys."""
    key = jax.random.PRNGKey(7)
    new, rand = simstep.split_rand(key, Algo.O1TURN, 16, 2)
    k, kg, kd, km, _ = jax.random.split(key, 5)
    k1, _, _ = jax.random.split(km, 3)
    assert np.array_equal(new, k)
    assert np.array_equal(rand["u"], jax.random.uniform(kg, (16,)))
    assert np.array_equal(rand["ud"], jax.random.uniform(kd, (16,)))
    assert np.array_equal(rand["ob"],
                          jax.random.bernoulli(k1, 0.5, (16,)))
