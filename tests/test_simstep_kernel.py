"""Differential battery: the fused flit-step kernel vs. the unfused step.

The contract is BIT-IDENTITY of the full state pytree — every packed
flit record, FIFO pointer, wormhole lock, statistic counter and PRNG
key — not statistical closeness.  Three layers:

* exhaustive (topology × algorithm) parity from fresh state;
* property-based parity from randomized MID-FLIGHT states (occupied
  VCs, held output ports, partially drained queues): the unfused
  oracle advances a fresh state by a sampled number of cycles at a
  sampled rate — every state it can reach is by construction a valid
  mid-flight state — then both paths step forward from that state and
  must agree array-for-array;
* the Pallas kernel in interpret mode (the CPU coverage path for the
  compiled TPU/GPU route) against the same oracle.

Runners come from ``sim.get_runner`` with ``use_kernel`` flipped, i.e.
exactly the code paths campaigns execute.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _propcheck import given, settings, st
from repro.core import build_plan, cmesh, mesh2d, torus, traffic
from repro.kernels import simstep
from repro.noc import sim
from repro.noc.simconfig import Algo, SimConfig

TOPOS = {
    "mesh4x4": mesh2d(4, 4),
    "torus4x4": torus(4, 4),
    "cmesh3x3c2": cmesh(3, 3, 2),
}
# one algorithm per distinct code path: deterministic DOR, plan-table
# quasi-static, random order, two-phase random intermediate, adaptive
ALGOS = (Algo.XY, Algo.BIDOR, Algo.O1TURN, Algo.ROMM, Algo.ODDEVEN)


@functools.lru_cache(maxsize=None)
def _cell(topo_name: str, algo: Algo):
    """(tables, meta, cfgs) for one differential cell, cached so the
    property test reuses jit compilations across examples."""
    topo = TOPOS[topo_name]
    tm = traffic.uniform(topo)
    table = build_plan(topo, tm).table if algo == Algo.BIDOR else None
    cfg_u = SimConfig(algo=algo, cycles=4000, warmup=50, use_kernel=False)
    tables, meta = sim.build_tables(topo, tm, table, cfg_u.num_vcs)
    return tables, meta, cfg_u, cfg_u.replace(use_kernel=True)


def _assert_states_equal(a, b, ctx):
    bad = [k for k in a if not np.array_equal(a[k], b[k])]
    assert not bad, f"fused diverged from unfused on {bad} ({ctx})"


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("topo_name", sorted(TOPOS))
def test_fused_bit_identical_from_fresh_state(topo_name, algo):
    """Every (topology, algorithm) cell: 150 cycles from fresh state,
    full state pytree equal bit for bit (two saturating-ish lanes)."""
    if algo == Algo.ODDEVEN and TOPOS[topo_name].ndim != 2:
        pytest.skip("odd-even is 2D-only")
    tables, meta, cfg_u, cfg_f = _cell(topo_name, algo)
    points = [(0.25, 0), (0.8, 1)]
    out_u = jax.device_get(sim.get_runner(meta, cfg_u, 150)(
        tables, sim.make_states(meta, cfg_u, points)))
    out_f = jax.device_get(sim.get_runner(meta, cfg_f, 150)(
        tables, sim.make_states(meta, cfg_f, points)))
    _assert_states_equal(out_u, out_f, f"{topo_name}/{algo.name}")


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(sorted(TOPOS)), st.sampled_from(ALGOS),
       st.sampled_from([40, 90, 160]),      # oracle warm-in (mid-flight)
       st.floats(0.05, 1.2), st.integers(0, 2**16),
       st.booleans())                       # drain the tail (inject halt)
def test_fused_bit_identical_from_midflight_state(topo_name, algo, warm,
                                                  rate, seed, drain):
    """Parity from randomized mid-flight states.  The unfused oracle
    advances ``warm`` cycles at a random rate/seed — leaving occupied
    VC FIFOs, held output ports and partially drained source queues —
    then both paths run 60 further cycles from that exact state (with
    injection optionally halted, exercising the drain phase) and the
    resulting pytrees must match bit for bit."""
    tables, meta, cfg_u, cfg_f = _cell(topo_name, algo)
    points = [(float(rate), int(seed) % 1000)]
    mid = sim.get_runner(meta, cfg_u, int(warm))(
        tables, sim.make_states(meta, cfg_u, points))
    if drain:  # injection stops mid-run: partially drained queues
        mid = dict(mid)
        mid["inject_until"] = jnp.full_like(mid["inject_until"],
                                            int(warm) + 20)
    out_u = jax.device_get(sim.get_runner(meta, cfg_u, 60)(tables, mid))
    out_f = jax.device_get(sim.get_runner(meta, cfg_f, 60)(tables, mid))
    _assert_states_equal(
        out_u, out_f,
        f"{topo_name}/{algo.name} warm={warm} rate={rate:.3f} "
        f"seed={seed} drain={drain}")


@pytest.mark.parametrize("algo", [Algo.XY, Algo.BIDOR, Algo.ODDEVEN])
def test_pallas_interpret_matches_unfused(algo):
    """The actual Pallas kernel (interpret mode on CPU — same kernel
    the compiled TPU/GPU path lowers) against the unfused oracle,
    through warm-up into a loaded network — both unbatched and under
    the jit(vmap(scan(...))) composition every campaign runner uses."""
    tables, meta, cfg_u, _ = _cell("mesh4x4", algo)
    step_u = sim._make_step(meta, cfg_u)
    step_p = simstep.make_step(meta, cfg_u, use_pallas=True,
                               interpret=True)
    st0 = sim.fresh_state(meta, cfg_u)
    st0["rate"] = jnp.float32(0.5)
    st0["key"] = sim.point_key(3, 0.5)

    def run(step, state):
        state, _ = jax.lax.scan(lambda s, c: step(tables, s, c), state,
                                jnp.arange(80))
        return jax.device_get(state)

    _assert_states_equal(run(step_u, st0), run(step_p, st0),
                         f"pallas-interpret/{algo.name}")

    def run_batched(step, batched):
        def one(state):
            state, _ = jax.lax.scan(lambda s, c: step(tables, s, c),
                                    state, jnp.arange(60))
            return state
        return jax.device_get(jax.jit(jax.vmap(one))(batched))

    batched = sim.make_states(meta, cfg_u, [(0.3, 0), (0.7, 1)])
    _assert_states_equal(run_batched(step_u, batched),
                         run_batched(step_p, batched),
                         f"pallas-interpret-vmapped/{algo.name}")


def test_wide_rewrites_bit_identical_when_forced():
    """The N >= _WIDE_N rewrites (binary-search destination sampling,
    scatter next_seq/reorder updates) checked against the oracle on a
    small mesh by forcing the gate open — the cheap fast-loop coverage
    of the code path that normally only runs at 16x16+."""
    from repro.kernels.simstep import ref as simstep_ref

    tables, meta, cfg_u, _ = _cell("mesh4x4", Algo.O1TURN)
    step_u = sim._make_step(meta, cfg_u)
    old = simstep_ref._WIDE_N
    simstep_ref._WIDE_N = 1
    try:
        step_w = simstep.make_step(meta, cfg_u, use_pallas=False)
    finally:
        simstep_ref._WIDE_N = old
    st0 = sim.fresh_state(meta, cfg_u)
    st0["rate"] = jnp.float32(0.6)
    st0["key"] = sim.point_key(9, 0.6)

    def run(step, state):
        state, _ = jax.lax.scan(lambda s, c: step(tables, s, c), state,
                                jnp.arange(120))
        return jax.device_get(state)

    _assert_states_equal(run(step_u, st0), run(step_w, st0),
                         "forced-wide/O1TURN")


@pytest.mark.slow
def test_fused_bit_identical_16x16_wide_path():
    """True-scale coverage of the size-gated rewrites: 16x16 (N = 256,
    the _WIDE_N threshold) fused vs unfused, bit for bit."""
    topo = mesh2d(16, 16)
    tm = traffic.uniform(topo)
    cfg_u = SimConfig(cycles=4000, warmup=30, use_kernel=False)
    tables, meta = sim.build_tables(topo, tm, None, cfg_u.num_vcs)
    points = [(0.3, 0)]
    out_u = jax.device_get(sim.get_runner(meta, cfg_u, 120)(
        tables, sim.make_states(meta, cfg_u, points)))
    cfg_f = cfg_u.replace(use_kernel=True)
    out_f = jax.device_get(sim.get_runner(meta, cfg_f, 120)(
        tables, sim.make_states(meta, cfg_f, points)))
    _assert_states_equal(out_u, out_f, "mesh16x16/XY")


def test_pallas_auto_gates_on_vmem_footprint():
    """The auto path must never hand a state that cannot fit on chip to
    the whole-array kernel: the 4x4 footprint sits under the budget,
    the 32x32 one over it (the dense fused body takes over there)."""
    from repro.kernels.simstep import ops as simstep_ops

    cfg = SimConfig()
    _, meta_small = sim.build_tables(TOPOS["mesh4x4"],
                                     traffic.uniform(TOPOS["mesh4x4"]),
                                     None, cfg.num_vcs)
    big = mesh2d(32, 32)
    _, meta_big = sim.build_tables(big, traffic.uniform(big), None,
                                   cfg.num_vcs)
    small_b = simstep_ops.state_footprint_bytes(meta_small, cfg)
    big_b = simstep_ops.state_footprint_bytes(meta_big, cfg)
    assert small_b < simstep_ops.VMEM_BUDGET_BYTES < big_b, \
        (small_b, big_b)


def test_fused_is_the_default_and_flag_reaches_runner():
    """SimConfig defaults to the fused kernel and the flag is part of
    the compilation cache key (flipping it cannot alias runners)."""
    assert SimConfig().use_kernel is True
    k_f = sim._cfg_key(SimConfig())
    k_u = sim._cfg_key(SimConfig(use_kernel=False))
    assert k_f != k_u
    assert dict(k_f)["use_kernel"] is True


def _proper_tile(meta) -> int:
    """Largest tile that divides N without being the whole network —
    the multi-program grid actually has to stitch tiles together."""
    n = meta["N"]
    return max(d for d in range(1, n) if n % d == 0)


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("topo_name", sorted(TOPOS))
def test_blocked_bit_identical_from_fresh_state(topo_name, algo):
    """Every (topology, algorithm) cell through the BLOCKED path (the
    node-tile grid body, compiled ``vmap`` realization on CPU): 150
    cycles from fresh state, full state pytree equal bit for bit
    against the unfused oracle — the third leg of the battery."""
    if algo == Algo.ODDEVEN and TOPOS[topo_name].ndim != 2:
        pytest.skip("odd-even is 2D-only")
    tables, meta, cfg_u, cfg_f = _cell(topo_name, algo)
    cfg_b = cfg_f.replace(sim_tile_nodes=_proper_tile(meta))
    points = [(0.25, 0), (0.8, 1)]
    out_u = jax.device_get(sim.get_runner(meta, cfg_u, 150)(
        tables, sim.make_states(meta, cfg_u, points)))
    out_b = jax.device_get(sim.get_runner(meta, cfg_b, 150)(
        tables, sim.make_states(meta, cfg_b, points)))
    _assert_states_equal(
        out_u, out_b,
        f"blocked/{topo_name}/{algo.name} tile={cfg_b.sim_tile_nodes}")


@pytest.mark.parametrize("tile", [1, 4, 16])
def test_blocked_tile_sizes_straddle_gate(tile):
    """Tile sizes bracketing the grid's edge cases — one node per
    program, a middle split, and a single tile spanning the whole
    network (grid of 1) — all bit-identical on mesh4x4/BiDOR."""
    tables, meta, cfg_u, cfg_f = _cell("mesh4x4", Algo.BIDOR)
    cfg_b = cfg_f.replace(sim_tile_nodes=tile)
    points = [(0.6, 2)]
    out_u = jax.device_get(sim.get_runner(meta, cfg_u, 100)(
        tables, sim.make_states(meta, cfg_u, points)))
    out_b = jax.device_get(sim.get_runner(meta, cfg_b, 100)(
        tables, sim.make_states(meta, cfg_b, points)))
    _assert_states_equal(out_u, out_b, f"blocked-tile{tile}/BIDOR")


@pytest.mark.parametrize("algo", [Algo.XY, Algo.BIDOR, Algo.ODDEVEN])
def test_blocked_pallas_interpret_matches_unfused(algo):
    """The actual multi-program Pallas kernel (grid over node tiles,
    interpret mode on CPU — same kernel the compiled TPU/GPU blocked
    path lowers) against the unfused oracle."""
    tables, meta, cfg_u, _ = _cell("mesh4x4", algo)
    cfg_b = cfg_u.replace(sim_tile_nodes=8)
    step_u = sim._make_step(meta, cfg_u)
    step_p = simstep.make_step(meta, cfg_b, interpret=True)
    st0 = sim.fresh_state(meta, cfg_u)
    st0["rate"] = jnp.float32(0.5)
    st0["key"] = sim.point_key(3, 0.5)

    def run(step, state):
        state, _ = jax.lax.scan(lambda s, c: step(tables, s, c), state,
                                jnp.arange(80))
        return jax.device_get(state)

    _assert_states_equal(run(step_u, st0), run(step_p, st0),
                         f"blocked-pallas-interpret/{algo.name}")


@pytest.mark.parametrize("interpret", [False, True])
def test_blocked_telemetry_watchdog_parity(interpret):
    """Telemetry rings and watchdog counters cross tile boundaries (the
    epilogue owns them): parity with observability fully enabled, on
    both the compiled vmap realization and the Pallas interpreter."""
    topo = TOPOS["mesh4x4"]
    tm = traffic.uniform(topo)
    cfg_u = SimConfig(algo=Algo.BIDOR, cycles=4000, warmup=50,
                      use_kernel=False, telemetry=True, watchdog=True)
    table = build_plan(topo, tm).table
    tables, meta = sim.build_tables(topo, tm, table, cfg_u.num_vcs)
    cfg_b = cfg_u.replace(sim_tile_nodes=4)
    step_u = sim._make_step(meta, cfg_u)
    step_b = simstep.make_step(meta, cfg_b, interpret=interpret)
    st0 = sim.fresh_state(meta, cfg_u)
    st0["rate"] = jnp.float32(0.9)
    st0["key"] = sim.point_key(5, 0.9)

    def run(step, state):
        state, _ = jax.lax.scan(lambda s, c: step(tables, s, c), state,
                                jnp.arange(100))
        return jax.device_get(state)

    _assert_states_equal(run(step_u, st0), run(step_b, st0),
                         f"blocked-obs interpret={interpret}")


def test_resolve_path_dispatch_ladder():
    """The whole/blocked/dense ladder around the VMEM gate: generous
    budget → whole-array, budget under the footprint → largest fitting
    node tile, starved budget → dense; CPU auto → dense; explicit pins
    beat everything."""
    from repro.kernels.simstep import ops as simstep_ops

    cfg = SimConfig()
    _, meta = sim.build_tables(TOPOS["mesh4x4"],
                               traffic.uniform(TOPOS["mesh4x4"]),
                               None, cfg.num_vcs)
    foot = simstep_ops.state_footprint_bytes(meta, cfg)
    assert simstep_ops.resolve_path(
        meta, cfg, supported=True, budget=foot) == ("whole", 0, False)
    path, tile, interp = simstep_ops.resolve_path(
        meta, cfg, supported=True, budget=foot - 1)
    assert path == "blocked" and tile > 0 and meta["N"] % tile == 0
    assert not interp
    assert simstep_ops.blocked_tile_bytes(meta, cfg, tile) <= foot - 1
    assert simstep_ops.resolve_path(
        meta, cfg, supported=True, budget=64) == ("dense", 0, False)
    assert simstep_ops.resolve_path(
        meta, cfg, supported=False)[0] == "dense"
    assert simstep_ops.resolve_path(
        meta, cfg.replace(sim_tile_nodes=8),
        supported=False) == ("blocked", 8, False)
    assert simstep_ops.resolve_path(
        meta, cfg.replace(sim_tile_nodes=8), use_pallas=False,
        supported=True) == ("dense", 0, False)


def test_resolve_path_64x64_runs_blocked_on_pallas_backends():
    """At 64x64 the whole-array state is ~50x the VMEM budget; the auto
    ladder must land on the blocked kernel with a tile that divides the
    network (meta built symbolically — the gate only reads shapes)."""
    from repro.kernels.simstep import ops as simstep_ops

    cfg = SimConfig()
    n = 64 * 64
    meta = dict(N=n, P=5, V=cfg.num_vcs, NIN=n * 5 * cfg.num_vcs,
                P_LOCAL=4, NDIM=2, O=1, C=4 * 64 * 63)
    assert (simstep_ops.state_footprint_bytes(meta, cfg)
            > simstep_ops.VMEM_BUDGET_BYTES)
    path, tile, interp = simstep_ops.resolve_path(meta, cfg,
                                                  supported=True)
    assert path == "blocked" and tile > 0 and n % tile == 0
    assert (simstep_ops.blocked_tile_bytes(meta, cfg, tile)
            <= simstep_ops.VMEM_BUDGET_BYTES)


def test_vmem_budget_env_override(monkeypatch):
    """SIMSTEP_VMEM_BUDGET rebinds the gate without code changes: a
    tiny budget pushes the 4x4 auto path off the whole-array kernel."""
    from repro.kernels.simstep import ops as simstep_ops

    cfg = SimConfig()
    _, meta = sim.build_tables(TOPOS["mesh4x4"],
                               traffic.uniform(TOPOS["mesh4x4"]),
                               None, cfg.num_vcs)
    monkeypatch.delenv("SIMSTEP_VMEM_BUDGET", raising=False)
    assert simstep_ops.vmem_budget_bytes() == \
        simstep_ops.VMEM_BUDGET_BYTES
    monkeypatch.setenv("SIMSTEP_VMEM_BUDGET", "4096")
    assert simstep_ops.vmem_budget_bytes() == 4096
    path, tile, _ = simstep_ops.resolve_path(meta, cfg, supported=True)
    assert path != "whole"


def test_footprint_matches_retired_formula():
    """One-time cross-check of the eval_shape-derived footprint against
    the retired hand-maintained byte formula (deleted from ops.py in
    favor of deriving from the real state).  The formula ignored a few
    small vectors by design, so the derived count sits within 1% —
    close enough to prove the derivation counts the same state, exact
    enough to catch a unit slip (words vs bytes, a dropped array)."""
    from repro.kernels.simstep import ops as simstep_ops

    def retired_formula(meta, cfg):  # frozen verbatim from PR 5's ops.py
        n, p, v, nin, c = (meta["N"], meta["P"], meta["V"], meta["NIN"],
                           meta["C"])
        o = meta["O"]
        words = (nin * cfg.buf_per_vc * 10
                 + n * cfg.src_queue_pkts * 5
                 + 3 * n * n
                 + n * p * v + n * p
                 + 8 * nin + 10 * n + 5 * c
                 + o * n * n + 3 * n * n)
        if cfg.telemetry:
            words += cfg.tel_slots * (c + 1 + 4 + cfg.tel_occ_bins
                                      + cfg.lat_bins)
        if cfg.watchdog:
            words += nin + n + 2
        return 4 * words

    for topo_name in sorted(TOPOS):
        topo = TOPOS[topo_name]
        for cfg in (SimConfig(),
                    SimConfig(telemetry=True, watchdog=True)):
            _, meta = sim.build_tables(topo, traffic.uniform(topo),
                                       None, cfg.num_vcs)
            derived = simstep_ops.state_footprint_bytes(meta, cfg)
            frozen = retired_formula(meta, cfg)
            assert abs(derived - frozen) / frozen < 0.01, \
                (topo_name, cfg.telemetry, derived, frozen)


def test_abstract_tables_match_build_tables():
    """The symbolic table mirror the capacity math sizes against the
    arrays cells actually trace: every field's shape and dtype, across
    the topology zoo (with and without a BiDOR plan table)."""
    for topo_name in sorted(TOPOS):
        topo = TOPOS[topo_name]
        tm = traffic.uniform(topo)
        for table in (None, build_plan(topo, tm).table):
            tables, meta = sim.build_tables(topo, tm, table,
                                            SimConfig().num_vcs)
            abstract = sim.abstract_tables(meta)
            for field, real, spec in zip(tables._fields, tables,
                                         abstract):
                assert real.shape == spec.shape, (topo_name, field)
                assert real.dtype == spec.dtype, (topo_name, field)


def test_split_rand_matches_unfused_key_schedule():
    """The hoisted RNG consumes the lane key exactly like the unfused
    step: new key == first subkey of the 5-way split, and the draws
    come from the same subkeys."""
    key = jax.random.PRNGKey(7)
    new, rand = simstep.split_rand(key, Algo.O1TURN, 16, 2)
    k, kg, kd, km, _ = jax.random.split(key, 5)
    k1, _, _ = jax.random.split(km, 3)
    assert np.array_equal(new, k)
    assert np.array_equal(rand["u"], jax.random.uniform(kg, (16,)))
    assert np.array_equal(rand["ud"], jax.random.uniform(kd, (16,)))
    assert np.array_equal(rand["ob"],
                          jax.random.bernoulli(k1, 0.5, (16,)))
