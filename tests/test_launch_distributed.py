"""Distributed launcher on a multi-device CPU mesh (subprocess)."""

import os
import subprocess
import sys



def _run(cmd, devices=8):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH="src")
    return subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=1200, cwd=os.path.dirname(
                              os.path.dirname(os.path.abspath(__file__))))


def test_train_launcher_on_4x2_mesh(tmp_path):
    res = _run([sys.executable, "-m", "repro.launch.train",
                "--arch", "internlm2-1.8b", "--smoke", "--steps", "6",
                "--mesh", "4x2", "--grad-accum", "2",
                "--ckpt-dir", str(tmp_path)])
    assert res.returncode == 0, res.stdout + res.stderr
    assert "done" in res.stdout
    assert "loss" in res.stdout


def test_train_launcher_elastic_resume(tmp_path):
    """Train on 4x2, then resume the checkpoint on a SMALLER 2x2 mesh —
    the elastic lost-host scenario."""
    r1 = _run([sys.executable, "-m", "repro.launch.train",
               "--arch", "internlm2-1.8b", "--smoke", "--steps", "4",
               "--mesh", "4x2", "--ckpt-dir", str(tmp_path)])
    assert r1.returncode == 0, r1.stdout + r1.stderr
    r2 = _run([sys.executable, "-m", "repro.launch.train",
               "--arch", "internlm2-1.8b", "--smoke", "--steps", "6",
               "--mesh", "2x2", "--ckpt-dir", str(tmp_path)], devices=4)
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "resumed from step 4" in r2.stdout


def test_dryrun_entrypoint_small_cell(tmp_path):
    """The dry-run driver end-to-end on one real cell (subprocess owns its
    own 512 placeholder devices)."""
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-base", "--shape", "decode_32k", "--mesh", "single",
         "--out", str(tmp_path)],
        env=dict(os.environ, PYTHONPATH="src"),
        capture_output=True, text=True, timeout=1200,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "compiled successfully" in res.stdout
    import json, glob
    (art,) = glob.glob(str(tmp_path / "*.json"))
    rec = json.load(open(art))
    assert rec["roofline"]["dominant"] in ("compute", "memory",
                                           "collective")
    assert rec["memory"]["peak_gb"] < 16.0
