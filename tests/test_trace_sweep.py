"""run_trace_sweep coverage: multi-seed batching must equal single-seed
runs point-for-point, and the per-segment LCV bookkeeping must survive
segments that swap the traffic matrix (and hence rebuild the tables)."""

import numpy as np
import pytest

from repro.core import build_plan, mesh2d, traffic
from repro.noc import Algo, SimConfig, run_trace_sweep
from repro.noc.workload import clos_leaf_trace

TOPO = mesh2d(4, 4)
UNI = traffic.uniform(TOPO)
TOR = traffic.tornado(TOPO)
TRA = traffic.transpose(TOPO)
CFG = SimConfig(cycles=800, warmup=200)


@pytest.mark.slow
def test_multi_seed_batch_equals_single_seed_runs():
    """Each lane of the batched trace replay must reproduce the
    stand-alone single-seed replay exactly (same PRNG fold per segment)."""
    segments = [(UNI, 0.2), (TOR, 0.3), (UNI, 0.15)]
    seeds = [0, 3, 11]
    batched = run_trace_sweep(TOPO, segments, CFG, seeds=seeds)
    assert len(batched) == len(seeds)
    for seed, (res_b, lcvs_b) in zip(seeds, batched):
        (res_s, lcvs_s), = run_trace_sweep(TOPO, segments, CFG,
                                           seeds=[seed])
        assert res_b.injected_flits == res_s.injected_flits
        assert res_b.ejected_flits == res_s.ejected_flits
        assert res_b.in_flight_flits == res_s.in_flight_flits
        assert res_b.reorder_value == res_s.reorder_value
        assert np.isclose(res_b.avg_latency, res_s.avg_latency)
        np.testing.assert_allclose(lcvs_b, lcvs_s)
        assert res_b.seed == seed


def test_segment_lcvs_survive_traffic_matrix_change():
    """A mid-trace matrix swap rebuilds the generation tables; the
    per-segment LCV deltas must still be per-segment (not cumulative):
    the shared prefix of two traces that diverge at segment 1 must match
    exactly, and only the divergent segment's LCV may differ."""
    base = [(UNI, 0.25), (UNI, 0.25), (UNI, 0.25)]
    swap = [(UNI, 0.25), (TRA, 0.25), (UNI, 0.25)]
    (res_a, lcvs_a), = run_trace_sweep(TOPO, base, CFG, seeds=[0])
    (res_b, lcvs_b), = run_trace_sweep(TOPO, swap, CFG, seeds=[0])
    assert len(lcvs_a) == len(lcvs_b) == 3
    # identical prefix: segment 0 is bit-identical across the two traces
    assert lcvs_a[0] == lcvs_b[0]
    # the swapped segment changes its own LCV delta
    assert lcvs_a[1] != lcvs_b[1]
    # conservation over the whole trace
    for res in (res_a, res_b):
        assert res.injected_flits == res.ejected_flits + res.in_flight_flits


def test_bidor_trace_uses_aggregate_plan():
    """BiDOR replays a fixed offline plan across drifting segments —
    the paper's quasi-static contrast — and must stay in-order."""
    segments, agg = clos_leaf_trace(TOPO, num_epochs=3, base_rate=0.2)
    plan = build_plan(TOPO, agg)
    cfg = CFG.replace(algo=Algo.BIDOR)
    runs = run_trace_sweep(TOPO, segments, cfg, bidor_table=plan.table,
                           seeds=[0, 1])
    for res, lcvs in runs:
        assert res.reorder_value == 0
        assert len(lcvs) == len(segments)
        assert res.injected_flits == res.ejected_flits + res.in_flight_flits
