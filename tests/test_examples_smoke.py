"""Tier-1 smoke: the examples must import and dry-run against the
current sim/campaign API (they broke silently once; never again)."""

import importlib.util
import os

import pytest

EXAMPLES = os.path.join(os.path.dirname(__file__), "..", "examples")


def _load(name):
    spec = importlib.util.spec_from_file_location(
        f"example_{name}", os.path.join(EXAMPLES, f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_quickstart_dry_run(capsys):
    mod = _load("quickstart")
    mod.main(cycles=1500)
    out = capsys.readouterr().out
    assert "N-Rank iterations:" in out
    assert "load-balance LCV" in out


def test_ici_demo_dry_run(capsys):
    mod = _load("qstar_ici_demo")
    mod.main(side=6, greedy_sweeps=1)
    out = capsys.readouterr().out
    assert "Q-StaR BiDOR" in out
    assert "replanned" in out


def test_train_lm_tiny(tmp_path, capsys):
    mod = _load("train_lm")
    mod.main(["--preset", "tiny", "--steps", "2", "--batch", "2",
              "--seq", "16", "--ckpt-every", "100",
              "--ckpt-dir", str(tmp_path / "ckpt")])
    out = capsys.readouterr().out
    assert "step    0 loss" in out
    assert "done; final loss" in out


def test_serve_decode_tiny(capsys):
    mod = _load("serve_decode")
    mod.main(["--arch", "internlm2-1.8b", "--batch", "1",
              "--prompt-len", "4", "--tokens", "3"])
    out = capsys.readouterr().out
    assert "generated 3 tokens/seq" in out
    assert "determinism check passed" in out


@pytest.mark.parametrize("name", ["quickstart", "qstar_ici_demo",
                                  "train_lm", "serve_decode"])
def test_examples_importable(name):
    assert _load(name).main is not None
