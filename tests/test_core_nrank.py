"""Unit + property tests for the Q-StaR core (paper §3.2–§3.3)."""

import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core import (
    mesh2d, mesh2d_edge_io, torus, multipod, traffic,
    nrank, bidor, bidor_k, build_plan, dimension_orders, route_nodes,
    predicted_node_load,
)
from repro.core.nrank import (
    possibility_weights, transition_probabilities, initial_weights,
)
from repro.core.routes import (
    min_rect_contains_channel, next_hop_table,
)


# --------------------------------------------------------------------- #
# topology
# --------------------------------------------------------------------- #
def test_mesh_basic_counts():
    t = mesh2d(5, 5)
    assert t.num_nodes == 25
    # 2 * (W-1)*H + 2 * W*(H-1) directed channels
    assert t.num_channels == 2 * (4 * 5) * 2
    assert t.num_ports == 5  # 4 directions + local (paper §4.1)


def test_mesh_distances_are_manhattan():
    t = mesh2d(4, 3)
    for s in range(t.num_nodes):
        for d in range(t.num_nodes):
            manh = np.abs(t.coords[s] - t.coords[d]).sum()
            assert t.distances[s, d] == manh


def test_torus_distances_wrap():
    t = torus(8, 8)
    s = t.node_id((0, 0))
    d = t.node_id((7, 0))
    assert t.distances[s, d] == 1


def test_edge_io_weights():
    t = mesh2d_edge_io(5, 5)
    w = t.io_weights.reshape(5, 5)
    assert w[0, 0] == 2 and w[2, 2] == 0 and w[0, 2] == 1
    # 20 I/O ports total (paper §4.1)
    assert t.io_weights.sum() == 20


def test_neighbor_and_port_tables_are_consistent():
    t = mesh2d(5, 5)
    for c, (u, n) in enumerate(t.channels):
        p = t.channel_port[c]
        assert t.neighbor_table[u, p] == n


def test_multipod_has_slow_interpod_links():
    t = multipod(2, 4, 4, interpod_bw=0.5)
    assert t.num_nodes == 32
    interpod = t.channel_bw < 1.0
    assert interpod.sum() == 2 * 16  # one link pair per chip pair
    assert np.allclose(t.channel_bw[interpod], 0.5)


# --------------------------------------------------------------------- #
# traffic
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("pattern", ["uniform", "shuffle", "permutation",
                                     "overturn", "transpose", "tornado",
                                     "hotspot"])
def test_traffic_matrices_are_normalized(pattern):
    t = mesh2d(5, 5)
    m = traffic.PATTERNS[pattern](t)
    assert m.shape == (25, 25)
    assert np.isclose(m.sum(), 1.0)
    assert np.all(np.diag(m) == 0)
    assert np.all(m >= 0)


def test_edge_io_traffic_has_no_interior_endpoints():
    t = mesh2d_edge_io(5, 5)
    m = traffic.uniform(t)
    interior = np.nonzero(t.io_weights == 0)[0]
    assert np.all(m[interior, :] == 0) and np.all(m[:, interior] == 0)


def test_overturn_is_coordinate_complement():
    t = mesh2d(5, 5)
    m = traffic.overturn(t)
    s = t.node_id((1, 2))
    d = t.node_id((3, 2))
    assert m[s, d] > 0


# --------------------------------------------------------------------- #
# possibility sets (eq. 4): graph predicate ≡ literal MinRect on meshes
# --------------------------------------------------------------------- #
@settings(max_examples=25, deadline=None)
@given(st.integers(3, 6), st.integers(3, 6), st.randoms(use_true_random=False))
def test_minimal_path_predicate_matches_minrect(w, h, rnd):
    topo = mesh2d(w, h)
    dist = topo.distances
    for _ in range(20):
        c = rnd.randrange(topo.num_channels)
        u, n = map(int, topo.channels[c])
        s = rnd.randrange(topo.num_nodes)
        d = rnd.randrange(topo.num_nodes)
        if s == d:
            continue
        graph_pred = dist[s, u] + 1 + dist[n, d] == dist[s, d]
        assert graph_pred == min_rect_contains_channel(topo, s, d, u, n)


def test_possibility_weights_manual_3x3():
    """Hand-checked possibility weight on a 3×3 mesh, single-pair traffic."""
    topo = mesh2d(3, 3)
    T = np.zeros((9, 9))
    T[0, 8] = 1.0  # corner (0,0) → corner (2,2)
    w, w_drn = possibility_weights(topo.distances, T, topo.channels)
    cid = topo.chan_id
    # channel (0→1) is on minimal paths; (1→0) is not
    assert w[cid[(0, 1)]] == 1.0
    assert w[cid[(1, 0)]] == 0.0
    # channels entering 8 drain everything
    assert w_drn[cid[(5, 8)]] == 1.0 and w[cid[(5, 8)]] == 1.0
    assert w_drn[cid[(7, 8)]] == 1.0
    # channel (4→5) center→right is on minimal paths, no draining
    assert w[cid[(4, 5)]] == 1.0 and w_drn[cid[(4, 5)]] == 0.0


def test_transition_probabilities_normalize():
    topo = mesh2d(5, 5)
    T = traffic.uniform(topo)
    p, p_drn, a, a_drn = transition_probabilities(topo, T)
    assert np.all(p >= 0) and np.all(p <= 1)
    assert np.all(p_drn >= 0) and np.all(p_drn <= 1 + 1e-12)
    # outgoing transfer probabilities sum to 1 at every node with traffic
    row_sums = a.sum(axis=1)
    assert np.allclose(row_sums, 1.0)


# --------------------------------------------------------------------- #
# N-Rank evolution (eq. 1–3, termination §3.2.1)
# --------------------------------------------------------------------- #
def test_initial_weights_are_row_sums():
    topo = mesh2d(4, 4)
    T = traffic.uniform(topo)
    assert np.allclose(initial_weights(T), T.sum(1))


def test_nrank_converges_and_is_symmetric_on_uniform_mesh():
    topo = mesh2d(5, 5)
    r = nrank(topo, traffic.uniform(topo))
    assert r.iterations <= 100
    assert r.w_final.sum() < 0.01 or r.iterations == 100
    g = r.w_nr.reshape(5, 5)
    # full symmetry group of the square
    assert np.allclose(g, g.T, atol=1e-9)
    assert np.allclose(g, g[::-1, :], atol=1e-9)
    assert np.allclose(g, g[:, ::-1], atol=1e-9)
    # paper Fig. 1a: central nodes are more likely to be heavily loaded
    assert g[2, 2] == r.w_nr.max()
    assert g[0, 0] == r.w_nr.min()


def test_nrank_residual_monotone_decreasing():
    topo = mesh2d(4, 4)
    T = traffic.uniform(topo)
    _, _, a, a_drn = transition_probabilities(topo, T)
    w = initial_weights(T)
    prev = w.sum()
    for _ in range(30):
        w = w @ a_drn
        assert w.sum() <= prev + 1e-12
        prev = w.sum()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_nrank_invariants_random_traffic(seed):
    """Property: for any traffic matrix, N-Rank terminates and w_NR ≥ w0."""
    topo = mesh2d(4, 4)
    rng = np.random.default_rng(seed)
    T = rng.random((16, 16))
    np.fill_diagonal(T, 0)
    T /= T.sum()
    r = nrank(topo, T)
    assert r.iterations <= 100
    assert np.all(r.w_nr >= r.w0 - 1e-12)
    assert np.all(np.isfinite(r.w_nr))


# --------------------------------------------------------------------- #
# routes + BiDOR (eq. 10–11)
# --------------------------------------------------------------------- #
def test_dor_routes_on_paper_mesh():
    topo = mesh2d(5, 5)
    # XY: x first. node 11 = (1,2), node 4 = (4,0)
    assert route_nodes(topo, 11, 4, (0, 1)) == [11, 12, 13, 14, 9, 4]
    assert route_nodes(topo, 11, 4, (1, 0)) == [11, 6, 1, 2, 3, 4]


def test_next_hop_reaches_destination():
    topo = torus(6, 6)
    for order in dimension_orders(2):
        nh = next_hop_table(topo, order)
        for s in [0, 7, 35]:
            for d in range(topo.num_nodes):
                cur, hops = s, 0
                while cur != d:
                    cur = int(nh[cur, d])
                    hops += 1
                    assert hops <= 12
                assert hops == topo.distances[s, d]  # DOR is minimal


def test_bidor_choice_is_argmin_of_route_costs():
    topo = mesh2d(5, 5)
    r = nrank(topo, traffic.uniform(topo))
    tab = bidor(topo, r.w_nr)
    for s in range(0, 25, 7):
        for d in range(25):
            if s == d:
                continue
            cxy = sum(r.w_nr[n] for n in route_nodes(topo, s, d, (0, 1)))
            cyx = sum(r.w_nr[n] for n in route_nodes(topo, s, d, (1, 0)))
            assert np.isclose(tab.costs[0, s, d], cxy)
            assert np.isclose(tab.costs[1, s, d], cyx)
            if np.isclose(cxy, cyx, rtol=1e-5, atol=1e-5):
                expect = 0  # tie → XY
            else:
                expect = 0 if cxy < cyx else 1
            assert tab.choice[s, d] == expect


def test_bidor_bitmaps_pack():
    topo = mesh2d(5, 5)
    r = nrank(topo, traffic.uniform(topo))
    tab = bidor(topo, r.w_nr)
    bm = tab.packed_bitmaps()
    assert bm.shape == (25, 4)  # ceil(25/8) bytes per node (eq. 11)
    unpacked = np.unpackbits(bm, axis=1)[:, :25]
    assert np.array_equal(unpacked, tab.choice)


def test_bidor_zero_weights_degenerates_to_xy():
    topo = mesh2d(5, 5)
    tab = bidor(topo, np.zeros(25))
    assert np.all(tab.choice == 0)


def test_bidor_k_on_multipod():
    topo = multipod(2, 4, 4)
    plan = build_plan(topo, traffic.uniform(topo), k_orders=True)
    assert plan.table.choice.max() < len(plan.table.orders)
    # every chosen route must still be minimal
    assert plan.nrank.iterations <= 100


def test_same_row_pairs_are_tie_and_xy():
    topo = mesh2d(5, 5)
    r = nrank(topo, traffic.uniform(topo))
    tab = bidor(topo, r.w_nr)
    # s and d in the same row: XY and YX coincide → tie → XY (choice 0)
    assert tab.choice[5, 9] == 0
    assert tab.choice[3, 23] == 0  # same column


def test_bidor_hash_tie_break_splits_ties():
    from repro.core.bidor import bidor_k
    from repro.core.routes import dimension_orders
    topo = mesh2d(5, 5)
    tab = bidor_k(topo, np.zeros(25),
                  dimension_orders(2, binary_only=True), tie_break="hash")
    frac_yx = float((tab.choice == 1).mean())
    assert 0.2 < frac_yx < 0.8


def test_predicted_load_conserves_traffic_weighted_hops():
    """Σ_n load[n] must equal Σ_{s,d} T[s,d]·(hops+1) for minimal routes."""
    topo = mesh2d(5, 5)
    T = traffic.uniform(topo)
    plan = build_plan(topo, T)
    load = predicted_node_load(topo, T, plan.table)
    expect = (T * (topo.distances + 1)).sum()
    assert np.isclose(load.sum(), expect)


# --------------------------------------------------------------------- #
# channel-level evolution (primary interpretation — see DESIGN.md §5)
# --------------------------------------------------------------------- #
def test_nrank_channel_mesh_center_heavy():
    from repro.core import nrank_channel
    topo = mesh2d(5, 5)
    r = nrank_channel(topo, traffic.uniform(topo))
    g = r.w_nr.reshape(5, 5)
    # the fp64 evolution leaves the four corners 1 ulp apart (summation
    # order), so extrema are compared at ulp tolerance, not bitwise
    assert g[2, 2] == r.w_nr.max()
    assert np.isclose(g[0, 0], r.w_nr.min(), rtol=1e-12, atol=0)
    assert (g[0, 0] <= g + 1e-12).all()
    assert np.allclose(g, g.T, atol=1e-6)
    assert r.iterations <= 100


def test_nrank_channel_edgeio_matches_runtime_trend():
    """On edge-I/O + uniform the true forwarding load is boundary-heavy;
    the channel evolution must reproduce that (the node-level literal
    evolution inverts it — kept as documented baseline)."""
    from repro.core import nrank_channel
    topo = mesh2d_edge_io(5, 5)
    r = nrank_channel(topo, traffic.uniform(topo))
    g = r.w_nr.reshape(5, 5)
    boundary_mean = np.concatenate([g[0], g[-1], g[1:-1, 0], g[1:-1, -1]]).mean()
    interior_mean = g[1:-1, 1:-1].mean()
    assert boundary_mean > interior_mean


def test_bidor_channel_mode_reduces_max_link_load_on_edgeio():
    from repro.core import link_load, bidor
    topo = mesh2d_edge_io(5, 5)
    T = traffic.uniform(topo)
    plan = build_plan(topo, T)  # channel mode default
    xy = bidor(topo, np.zeros(25))
    assert link_load(topo, T, plan.table).max() < link_load(topo, T, xy).max()


def test_joint_possibility_consistency():
    """J[c1, c2] ≤ min(W[c1], W[c2]) and only consecutive channels."""
    from repro.core.nrank import joint_possibility
    topo = mesh2d(4, 4)
    T = traffic.uniform(topo)
    J = joint_possibility(topo, T)
    W, _ = possibility_weights(topo.distances, T, topo.channels)
    for c1 in range(topo.num_channels):
        for c2 in range(topo.num_channels):
            if J[c1, c2] > 0:
                assert topo.channels[c1, 1] == topo.channels[c2, 0]
                assert J[c1, c2] <= min(W[c1], W[c2]) + 1e-12
