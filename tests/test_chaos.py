"""Chaos schedules and the control plane's safety rails under them.

Covers (ISSUE 8 tentpole layer 3 + satellites b/c):

* :mod:`repro.noc.chaos` — seeded compound schedules are deterministic
  (same seed ⇒ identical events), always satisfy the ``Scenario``
  ordering contract, and compose the documented patterns (flap storms,
  region failures one epoch behind a drift, hotspot drifts);
* the hot-swap guard — a replan whose shed fraction exceeds
  ``ReplanConfig.max_shed`` is REJECTED: the previous certified table
  stays installed and no ``Replan`` is recorded (the silent-wedge fix);
* two disjoint dark regions — conservation holds on every lane and the
  recorded shed accounting matches ``BiDORTable.unroutable`` exactly,
  which itself matches an independent per-order route-feasibility walk.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import build_plan, mesh2d, traffic
from repro.core.bidor import route_feasibility
from repro.core.routes import dimension_orders
from repro.noc import (Algo, ChaosConfig, LinkFail, LinkRecover,
                       ReplanConfig, Scenario, SimConfig, TrafficDrift,
                       chaos_scenarios, chaos_schedule, hotspot_traffic,
                       region_links, run_controlled)
from repro.noc.ctrl import replan

TOPO = mesh2d(4, 4)
UNI = traffic.uniform(TOPO)
CFG = SimConfig(algo=Algo.BIDOR, cycles=3000, warmup=500,
                injection_rate=0.35)
PLAN = build_plan(TOPO, UNI)


def _event_tuple(ev):
    d = {"kind": type(ev).__name__, "cycle": int(ev.cycle)}
    if hasattr(ev, "links"):
        d["links"] = tuple(map(tuple, ev.links))
    if hasattr(ev, "bw_scale"):
        d["bw_scale"] = float(ev.bw_scale)
    if hasattr(ev, "traffic"):
        d["traffic"] = np.asarray(ev.traffic).tobytes()
    if hasattr(ev, "rate_scale"):
        d["rate_scale"] = float(ev.rate_scale)
    return tuple(sorted(d.items()))


# --------------------------------------------------------------------- #
# schedule generation
# --------------------------------------------------------------------- #
def test_chaos_schedule_is_deterministic_per_seed():
    cc = ChaosConfig(seed=7)
    a = chaos_schedule(TOPO, cc)
    b = chaos_schedule(TOPO, cc)
    assert a.name == b.name == "chaos-s7"
    assert [_event_tuple(e) for e in a.events] \
        == [_event_tuple(e) for e in b.events]
    c = chaos_schedule(TOPO, dataclasses.replace(cc, seed=8))
    assert [_event_tuple(e) for e in a.events] \
        != [_event_tuple(e) for e in c.events]


@pytest.mark.parametrize("cc", [
    ChaosConfig(),
    ChaosConfig(seed=3, flap_storms=0, region_failures=2),
    ChaosConfig(seed=4, drift_events=0, flap_bursts=5, flap_period=90),
    ChaosConfig(seed=5, start=100, horizon=700),   # tight window
    ChaosConfig(seed=6, flap_storms=4, region_failures=0,
                drift_events=3, bw_scale=0.25),
])
def test_chaos_schedule_satisfies_scenario_contract(cc):
    """Scenario.__post_init__ enforces sortedness and cycle >= 1; every
    config shape must construct, with all cycles inside the window."""
    scen = chaos_schedule(TOPO, cc)       # would raise on a violation
    cycles = [e.cycle for e in scen.events]
    assert cycles == sorted(cycles)
    assert all(1 <= c < cc.horizon for c in cycles)
    fails = sum(isinstance(e, LinkFail) for e in scen.events)
    recs = sum(isinstance(e, LinkRecover) for e in scen.events)
    drifts = sum(isinstance(e, TrafficDrift) for e in scen.events)
    assert fails >= recs                  # every recover had a fail
    assert drifts <= cc.drift_events
    assert scen.policy == "online" and scen.replan is None


def test_chaos_schedule_composes_the_documented_patterns():
    rc = ReplanConfig(epoch=400)
    cc = ChaosConfig(seed=1, flap_storms=1, flap_links=2, flap_bursts=2,
                     region_failures=1, region_radius=1, drift_events=1)
    scen = chaos_schedule(TOPO, cc, policy="oracle", replan=rc)
    assert scen.replan is rc and scen.policy == "oracle"
    flaps = [e for e in scen.events if isinstance(e, LinkFail)
             and len(e.links) == 2 * cc.flap_links]
    assert len(flaps) == cc.flap_bursts
    # every flap burst fails both directions of each picked link
    for f in flaps:
        pairs = set(map(tuple, f.links))
        assert all((v, u) in pairs for (u, v) in pairs)
    # the region failure is the remaining LinkFail: a radius-1 region on
    # a 4x4 mesh has far more incident channels than a 2-link flap
    regions = [e for e in scen.events if isinstance(e, LinkFail)
               and len(e.links) > 2 * cc.flap_links]
    assert len(regions) == cc.region_failures
    drift = next(e for e in scen.events if isinstance(e, TrafficDrift))
    assert np.isclose(drift.traffic.sum(), 1.0)
    assert (np.diag(drift.traffic) == 0).all()


def test_chaos_scenarios_one_per_seed():
    scens = chaos_scenarios(TOPO, [0, 1, 2])
    assert [s.name for s in scens] == ["chaos-s0", "chaos-s1", "chaos-s2"]
    assert [_event_tuple(e) for e in scens[0].events] \
        != [_event_tuple(e) for e in scens[1].events]


def test_region_links_covers_the_chebyshev_region_both_directions():
    links = region_links(TOPO, center=5, radius=1)
    coords = np.asarray(TOPO.coords)
    region = {i for i in range(TOPO.num_nodes)
              if np.abs(coords[i] - coords[5]).max() <= 1}
    assert len(region) == 9               # full 3x3 block around (1,1)
    for (u, v) in links:
        assert u in region or v in region
        assert (v, u) in links            # fully dark, both directions
    # every channel incident to the region is present
    expect = {(u, v) for (u, v) in TOPO.chan_id
              if u in region or v in region}
    assert set(links) == expect


def test_hotspot_traffic_is_a_valid_matrix():
    rng = np.random.default_rng(0)
    m = hotspot_traffic(16, rng, hotspots=3, weight=9.0)
    assert m.shape == (16, 16)
    assert np.isclose(m.sum(), 1.0)
    assert (np.diag(m) == 0).all()
    hot = np.argsort(m.sum(axis=0))[-3:]
    cold = np.argsort(m.sum(axis=0))[:3]
    assert m.sum(axis=0)[hot].min() > 5 * m.sum(axis=0)[cold].max()


# --------------------------------------------------------------------- #
# hot-swap guard (satellite b: the silent-wedge fix)
# --------------------------------------------------------------------- #
def test_hot_swap_guard_rejects_mostly_shed_emergency_table():
    """A radius-1 region loss sheds most demanded pairs.  With a tight
    ``max_shed`` the emergency replan must be REJECTED — previous table
    kept, no Replan recorded — while a permissive guard installs it.
    Flits are conserved either way."""
    dark = (LinkFail(cycle=1000, links=region_links(TOPO, 5, 1),
                     bw_scale=0.0),)
    guarded = run_controlled(
        TOPO, UNI, CFG,
        Scenario("dark", events=dark, policy="online",
                 replan=ReplanConfig(epoch=500, max_shed=0.05)),
        bidor_table=PLAN.table)
    assert guarded.replans == []          # rejected, old table kept
    permissive = run_controlled(
        TOPO, UNI, CFG,
        Scenario("dark", events=dark, policy="online",
                 replan=ReplanConfig(epoch=500, max_shed=0.95)),
        bidor_table=PLAN.table)
    assert permissive.replans
    assert permissive.replans[0].unroutable_pairs > 0
    for res in (guarded, permissive):
        r = res.results[0]
        assert r.injected_flits == r.ejected_flits + r.in_flight_flits
        assert r.ejected_flits > 0


def test_hot_swap_guard_does_not_block_moderate_sheds():
    """The guard is a backstop, not a brake: a single dead link (small
    shed fraction) replans normally under the default max_shed."""
    fail = (LinkFail(cycle=1000, links=((5, 6), (6, 5)), bw_scale=0.0),)
    res = run_controlled(
        TOPO, UNI, CFG,
        Scenario("hard", events=fail, policy="online",
                 replan=ReplanConfig(epoch=500)),
        bidor_table=PLAN.table)
    assert res.replans and res.replans[0].unroutable_pairs > 0


# --------------------------------------------------------------------- #
# two disjoint dark regions (satellite c: shed accounting)
# --------------------------------------------------------------------- #
def test_two_disjoint_regions_conserve_and_shed_exactly():
    """Fail two disjoint single-node regions (opposite corners) in
    sequence; every lane conserves flits, and the final replan's shed
    count equals BiDORTable.unroutable from an identical offline replan
    — which itself equals the independent route-feasibility walk."""
    regions = (region_links(TOPO, 0, 0), region_links(TOPO, 15, 0))
    assert not (set(regions[0]) & set(regions[1]))   # genuinely disjoint
    ev = (LinkFail(cycle=1000, links=regions[0], bw_scale=0.0),
          LinkFail(cycle=1800, links=regions[1], bw_scale=0.0))
    res = run_controlled(
        TOPO, UNI, CFG,
        Scenario("2regions", events=ev, policy="oracle",
                 replan=ReplanConfig(epoch=400, max_shed=0.9)),
        rates=[0.2, 0.35], seeds=[0, 1], bidor_table=PLAN.table)
    assert [r.cycle for r in res.replans] == [1000, 1800]
    for r in res.results:
        assert r.injected_flits == r.ejected_flits + r.in_flight_flits
        assert r.ejected_flits > 0

    # offline replan against the same degraded bandwidth vector
    down = np.array(sorted(TOPO.chan_id[(u, v)]
                           for reg in regions for (u, v) in reg))
    bw = np.asarray(TOPO.channel_bw, np.float64).copy()
    bw[down] = 0.0
    table, _ = replan(TOPO, UNI, bw, None)
    assert table.unroutable is not None
    assert res.replans[-1].unroutable_pairs == int(table.unroutable.sum())

    # and the mask is exactly the pairs no dimension order can serve
    feas = route_feasibility(TOPO, dimension_orders(TOPO.ndim), down)
    expect = ~feas.any(axis=0)
    np.fill_diagonal(expect, False)
    assert np.array_equal(table.unroutable, expect)
    # both dark nodes are fully cut off, in both directions
    assert expect[0, 1:].all() and expect[1:, 0].all()
    assert expect[15, :15].all() and expect[:15, 15].all()


# --------------------------------------------------------------------- #
# chaos end to end through the control loop
# --------------------------------------------------------------------- #
def test_chaos_schedule_runs_through_the_control_loop():
    """A compact storm (flaps + drift + region loss) through the online
    policy with the watchdog armed: the run completes, conserves flits,
    and keeps delivering."""
    cc = ChaosConfig(seed=2, start=600, horizon=2600, flap_storms=1,
                     flap_links=2, flap_bursts=2, flap_period=200,
                     region_failures=1, region_radius=1, drift_events=1)
    rc = ReplanConfig(epoch=400, max_shed=0.5)
    scen = chaos_schedule(TOPO, cc, replan=rc)
    cfg = CFG.replace(watchdog=True)
    res = run_controlled(TOPO, UNI, cfg, scen, bidor_table=PLAN.table)
    r = res.results[0]
    assert r.injected_flits == r.ejected_flits + r.in_flight_flits
    assert r.ejected_flits > 0
    assert res.watchdog is not None       # armed and reported
