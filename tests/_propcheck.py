"""Property-testing facade: Hypothesis when installed, else a deterministic
fallback sampler.

The tier-1 suite must *collect and run* in a bare environment (no network,
no ``pip install``), yet we still want property tests with real Hypothesis
shrinking wherever dev deps are installed (CI, laptops).  Test modules do

    from _propcheck import given, settings, st, HAVE_HYPOTHESIS

and get the real library when available.  Otherwise ``@given`` degrades to
a fixed-budget sampler: each strategy draws ``max_examples`` deterministic
examples from a seed derived from the test's qualified name, so failures
are reproducible run-to-run (no shrinking, but the sampled inputs are
printed on failure).

Only the strategy combinators this repo actually uses are emulated:
``integers``, ``floats``, ``sampled_from``, ``booleans``, ``lists``,
``randoms``.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _St:
        """Deterministic stand-ins for the strategies used in this repo."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def draw(rng):
                k = rng.randint(min_size, max_size)
                return [elem.draw(rng) for _ in range(k)]

            return _Strategy(draw)

        @staticmethod
        def randoms(use_true_random=False):
            del use_true_random  # the fallback is always seeded
            return _Strategy(lambda rng: random.Random(rng.getrandbits(64)))

    st = _St()

    def given(*strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_pc_max_examples",
                            _DEFAULT_MAX_EXAMPLES)
                seed = zlib.crc32(
                    f"{fn.__module__}.{fn.__qualname__}".encode())
                rng = random.Random(seed)
                for i in range(n):
                    drawn = tuple(s.draw(rng) for s in strategies)
                    try:
                        fn(*args, *drawn, **kwargs)
                    except Exception:
                        print(f"_propcheck fallback: example {i}/{n} "
                              f"failed with inputs {drawn!r}")
                        raise

            # Hide the original parameters from pytest's fixture resolution
            # (they are filled by the sampler, not by fixtures).
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            wrapper._pc_is_given = True
            return wrapper

        return decorate

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        def decorate(fn):
            fn._pc_max_examples = max_examples
            return fn

        return decorate
