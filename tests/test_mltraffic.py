"""ML-traffic derivation: byte conservation (HLO totals == flow-matrix
sums, per kind and per phase), rank-permutation equivariance, mesh-axis
relabel invariance, embedding, and the ``CampaignSpec.workloads`` axis.

The conservation property is checked twice: against randomized synthetic
collective-op sets (property test, first-principles byte accounting
re-derived in the test) and against REAL post-SPMD HLO of a sharded MoE
model (subprocess derivation, like ``test_hlo_analysis``'s collective
test)."""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))
from _propcheck import given, settings, st

from repro.analysis.hlo import CollectiveOp, collective_flow_totals
from repro.core import torus
from repro.noc import Algo, CampaignSpec, SimConfig, run_campaign
from repro.noc.mltraffic import (MLWorkload, WorkloadSpec, collective_flows,
                                 embed_ranks)

KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")


def _random_ops(rng, num_devices):
    """A randomized collective-op set over ``num_devices`` ranks: random
    kinds, sizes, while-loop counts, and group partitions (group size a
    random divisor of the rank count), plus permutes with random pairs."""
    ops = []
    divisors = [g for g in range(1, num_devices + 1)
                if num_devices % g == 0]
    for i in range(rng.randint(1, 8)):
        kind = KINDS[rng.randrange(len(KINDS))]
        size = float(rng.randint(1, 1 << 20))
        count = float(rng.randint(1, 4))
        if kind == "collective-permute":
            ranks = list(range(num_devices))
            rng.shuffle(ranks)
            pairs = tuple((s, t) for s, t in zip(ranks, ranks[1:]))
            ops.append(CollectiveOp(
                name=f"op{i}", kind=kind, size_bytes=size,
                wire_bytes=size, groups=(), pairs=pairs, count=count))
            continue
        g = divisors[rng.randrange(len(divisors))]
        ranks = list(range(num_devices))
        rng.shuffle(ranks)
        groups = tuple(tuple(ranks[j:j + g])
                       for j in range(0, num_devices, g))
        ops.append(CollectiveOp(
            name=f"op{i}", kind=kind, size_bytes=size, wire_bytes=size,
            groups=groups, count=count))
    return ops


def _expected_totals(ops):
    """First-principles per-kind fabric bytes, re-derived independently of
    ``CollectiveOp.fabric_bytes``: ring all-reduce moves 2(g-1)·size per
    group, all-gather/reduce-scatter/all-to-all (g-1)·size, permute size
    per pair."""
    want = {}
    for op in ops:
        if op.kind == "collective-permute":
            tot = op.count * len(op.pairs) * op.size_bytes
        else:
            f = 2.0 if op.kind == "all-reduce" else 1.0
            tot = op.count * sum(f * (len(g) - 1) * op.size_bytes
                                 for g in op.groups if len(g) > 1)
        want[op.kind] = want.get(op.kind, 0.0) + tot
    return want


@settings(max_examples=25)
@given(st.randoms(), st.sampled_from([2, 4, 6, 8]))
def test_flow_matrices_conserve_hlo_byte_totals(rng, num_devices):
    """Σ of each kind's (rank, rank) flow matrix must equal that kind's
    HLO-side fabric byte total EXACTLY (ring accounting is closed-form,
    so exact float equality of sums of identical terms holds)."""
    ops = _random_ops(rng, num_devices)
    mats = collective_flows(ops, num_devices)
    want = _expected_totals(ops)
    got_hlo = collective_flow_totals(ops)
    for kind, tot in want.items():
        assert got_hlo.get(kind, 0.0) == pytest.approx(tot, rel=1e-12)
        assert mats[kind].sum() == pytest.approx(tot, rel=1e-12)
    # no traffic invented for kinds never emitted
    assert set(mats) <= set(want)


@settings(max_examples=15)
@given(st.randoms(), st.sampled_from([4, 8]))
def test_flows_equivariant_under_rank_permutation(rng, num_devices):
    """Relabeling mesh axes permutes the ranks; the flow matrices must
    permute with them (no derivation step may key on literal rank ids)."""
    ops = _random_ops(rng, num_devices)
    perm = list(range(num_devices))
    rng.shuffle(perm)
    perm_ops = [CollectiveOp(
        name=op.name, kind=op.kind, size_bytes=op.size_bytes,
        wire_bytes=op.wire_bytes,
        groups=tuple(tuple(perm[r] for r in g) for g in op.groups),
        pairs=tuple((perm[s], perm[t]) for s, t in op.pairs),
        count=op.count) for op in ops]
    mats = collective_flows(ops, num_devices)
    mats_p = collective_flows(perm_ops, num_devices)
    ix = np.ix_(perm, perm)
    for kind in mats:
        np.testing.assert_array_equal(mats_p[kind][ix], mats[kind])


def _fake_workload(spec, flows_by_phase):
    totals = {ph: {k: float(m.sum()) for k, m in kinds.items()}
              for ph, kinds in flows_by_phase.items()}
    return MLWorkload(spec=spec, flows=flows_by_phase, totals=totals)


def _dense_flows(d, seed=0):
    rng = np.random.default_rng(seed)
    m = rng.random((d, d)) * 1e6
    np.fill_diagonal(m, 0.0)
    return m


def test_matrix_invariant_under_mesh_axis_relabeling():
    """The mesh-axis NAMES are pure metadata: a workload with axes
    ("data", "model") and one with ("x", "y") but identical flows must
    produce identical campaign matrices."""
    d = 8
    flows = {"decode": {"all-to-all": _dense_flows(d)}}
    t = torus(2, 4)
    a = _fake_workload(WorkloadSpec(arch="m", data=2, model=4,
                                    phases=("decode",)), flows)
    b = _fake_workload(WorkloadSpec(arch="m", data=2, model=4,
                                    phases=("decode",),
                                    axes=("x", "y")), flows)
    np.testing.assert_array_equal(a.matrix_for(t), b.matrix_for(t))


def test_embedding_preserves_bytes_and_normalizes():
    d = 8
    flows = {"decode": {"all-to-all": _dense_flows(d, seed=3)}}
    for topo, mesh in [(torus(2, 4), (2, 4)),   # coordinate embedding
                       (torus(4, 4), (2, 4))]:  # flat embedding
        wl = _fake_workload(
            WorkloadSpec(arch="m", data=mesh[0], model=mesh[1],
                         phases=("decode",)), flows)
        emb = embed_ranks(topo, mesh)
        assert len(set(emb.tolist())) == d          # injective
        counts = np.zeros((topo.num_nodes,) * 2)
        counts[np.ix_(emb, emb)] = wl.campaign_flows()
        # embedding moves bytes between node ids, never creates/destroys
        assert counts.sum() == pytest.approx(
            wl.campaign_flows().sum(), rel=1e-12)
        tm = wl.matrix_for(topo)
        assert tm.shape == (topo.num_nodes, topo.num_nodes)
        assert np.abs(np.diag(tm)).max() == 0.0
        assert tm.sum() == pytest.approx(1.0, rel=1e-9)


def test_embedding_rejects_small_topology():
    with pytest.raises(ValueError, match="cannot embed"):
        embed_ranks(torus(2, 2), (2, 4))


def test_workload_spec_validates_phases():
    with pytest.raises(ValueError, match="unknown phases"):
        WorkloadSpec(arch="m", phases=("train", "warp"))


def test_campaign_flows_skip_fwd_when_train_present():
    d = 4
    spec = WorkloadSpec(arch="m", data=1, model=4,
                        phases=("fwd", "train", "decode"))
    fwd = {"all-reduce": _dense_flows(d, 1)}
    train = {"all-reduce": _dense_flows(d, 1) * 3}
    dec = {"collective-permute": _dense_flows(d, 2)}
    wl = _fake_workload(spec, {"fwd": fwd, "train": train, "decode": dec})
    # fwd is folded into train (a train step re-runs it) — not added twice
    want = train["all-reduce"] + dec["collective-permute"]
    np.testing.assert_allclose(wl.campaign_flows(), want)
    # the derived backward residual
    np.testing.assert_allclose(wl.phase_flows("bwd"),
                               _dense_flows(d, 1) * 2)


def test_workloads_are_a_first_class_campaign_axis():
    """A (name, matrix) workload entry must flow through the campaign
    grid: enumerated like a pattern, selectable by ``workload=``, and
    carried as its own CSV column."""
    topo = torus(2, 4)
    counts = _dense_flows(topo.num_nodes, seed=5)
    base = SimConfig(cycles=200, warmup=50, drain=20)
    spec = CampaignSpec(topo=topo, algos=(Algo.XY,), patterns=(),
                        workloads=(("mlwl", counts),),
                        rates=(0.2,), seeds=(0,), base=base)
    assert spec.num_points == 1
    res = run_campaign(spec)
    (pt,) = res.points
    assert pt.workload == "mlwl" and pt.pattern == "mlwl"
    assert res.select(workload="mlwl") == [pt]
    assert res.select(workload="other") == []
    hdr = res.CSV_HEADER
    row = res.to_rows()[0]
    assert row[hdr.index("workload")] == "mlwl"
    # mixed axis: synthetic patterns keep an empty workload column
    mixed = CampaignSpec(topo=topo, algos=(Algo.XY,),
                         patterns=("uniform",),
                         workloads=(("mlwl", counts),),
                         rates=(0.2,), seeds=(0,), base=base)
    mres = run_campaign(mixed)
    assert mixed.num_points == 2
    by_pat = {p.pattern: p for p in mres.points}
    assert by_pat["uniform"].workload == ""
    assert by_pat["mlwl"].workload == "mlwl"


@pytest.mark.slow
def test_real_hlo_conservation_end_to_end(tmp_path):
    """The satellite invariant on REAL post-SPMD HLO: derive a sharded
    MoE decode workload (subprocess — the test session only has one host
    device) and check per-phase, per-kind conservation plus campaign
    matrix sanity on the exact torus."""
    from repro.noc import derive_workload

    spec = WorkloadSpec(arch="qwen2-moe-a2.7b", data=1, model=8,
                        moe_pad_to=8, phases=("decode",))
    wl = derive_workload(spec, cache_dir=str(tmp_path))
    assert set(wl.flows) == {"decode"}
    kinds = wl.flows["decode"]
    assert kinds, "sharded MoE decode lowered without any collectives"
    for kind, m in kinds.items():
        assert m.sum() == pytest.approx(wl.totals["decode"][kind],
                                        rel=1e-9), kind
    # expert parallelism must surface as all-to-all on the fabric
    assert "all-to-all" in kinds
    tm = wl.matrix_for(torus(2, 4))
    assert tm.sum() == pytest.approx(1.0, rel=1e-9)
    assert np.abs(np.diag(tm)).max() == 0.0
    # a second call is served from the npz cache with identical bytes
    wl2 = derive_workload(spec, cache_dir=str(tmp_path))
    np.testing.assert_array_equal(wl2.flows["decode"]["all-to-all"],
                                  kinds["all-to-all"])
