"""Simulator invariants, exercised through short 4×4-mesh campaigns:
flit conservation (with a real drain phase), per-VC FIFO ordering, and
XY/YX symmetry under transposed traffic."""

import numpy as np
import pytest

from repro.core import mesh2d, traffic
from repro.noc import (Algo, CampaignSpec, SimConfig, run_campaign)

TOPO = mesh2d(4, 4)
UNI = traffic.uniform(TOPO)


def _campaign(algos, rates=(0.1, 0.4), seeds=(0, 1), *, base=None,
              patterns=(("uniform", UNI),), topo=TOPO, **kw):
    spec = CampaignSpec(
        topo=topo, algos=tuple(algos), patterns=tuple(patterns),
        rates=tuple(rates), seeds=tuple(seeds),
        base=base or SimConfig(cycles=1500, warmup=400, drain=100), **kw)
    return run_campaign(spec)


@pytest.mark.slow
def test_no_flit_loss_across_campaign():
    """injected == ejected + in-flight at every grid point, any algo."""
    res = _campaign([Algo.XY, Algo.O1TURN, Algo.ODDEVEN, Algo.BIDOR])
    assert len(res.points) == 4 * 2 * 2
    for p in res.points:
        r = p.result
        assert r.injected_flits == r.ejected_flits + r.in_flight_flits, p
        assert r.ejected_flits > 0, p


def test_drain_phase_empties_network_at_low_load():
    """Below saturation, a sufficient drain phase lands every in-flight
    packet: injected == ejected exactly, nothing left buffered."""
    base = SimConfig(cycles=2000, warmup=400, drain=600)
    res = _campaign([Algo.XY, Algo.BIDOR], rates=(0.05, 0.15), base=base)
    for p in res.points:
        r = p.result
        assert r.in_flight_flits == 0, p
        assert r.injected_flits == r.ejected_flits, p


@pytest.mark.slow
def test_per_vc_fifo_ordering_deterministic_algos():
    """Quasi-static routing (one path per flow, per-VC FIFOs) must deliver
    every flow in order: reorder-buffer occupancy stays 0 (§3.3.2)."""
    res = _campaign([Algo.XY, Algo.YX, Algo.BIDOR],
                    rates=(0.1, 0.3, 0.6))
    for p in res.points:
        assert p.result.reorder_value == 0, p


def test_oblivious_routing_breaks_fifo_ordering():
    """Control for the test above: per-packet random path choice (O1Turn)
    must produce out-of-order arrivals under load."""
    res = _campaign([Algo.O1TURN], rates=(0.5,), seeds=(0,))
    assert res.points[0].result.reorder_value > 0


def _transpose_relabel(topo):
    """Node permutation swapping the x/y coordinates."""
    sigma = np.empty(topo.num_nodes, dtype=np.int64)
    for s in range(topo.num_nodes):
        x, y = topo.coords[s]
        sigma[s] = topo.node_id((y, x))
    return sigma


@pytest.mark.slow
def test_xy_yx_symmetry_under_transposed_traffic():
    """XY on T and YX on the coordinate-transposed T' are the same system
    mirrored along the diagonal, so aggregate statistics must agree (up
    to RNG noise — streams do not follow the relabeling)."""
    t = traffic.hotspot(TOPO, hot_frac=0.4, num_hot=2, seed=3)
    sigma = _transpose_relabel(TOPO)
    t_flip = t[np.ix_(sigma, sigma)]
    base = SimConfig(cycles=4000, warmup=1000)
    res = _campaign([Algo.XY], rates=(0.2,), seeds=(0, 1, 2),
                    patterns=(("t", t),), base=base)
    res_flip = _campaign([Algo.YX], rates=(0.2,), seeds=(0, 1, 2),
                         patterns=(("t_flip", t_flip),), base=base)
    thr = np.mean([p.result.throughput for p in res.points])
    thr_f = np.mean([p.result.throughput for p in res_flip.points])
    lat = np.mean([p.result.avg_latency for p in res.points])
    lat_f = np.mean([p.result.avg_latency for p in res_flip.points])
    assert abs(thr - thr_f) / thr < 0.05, (thr, thr_f)
    assert abs(lat - lat_f) / lat < 0.10, (lat, lat_f)
    # and the node-load fields are each other's relabeling, statistically:
    load = np.mean([p.result.node_load for p in res.points], axis=0)
    load_f = np.mean([p.result.node_load for p in res_flip.points], axis=0)
    corr = np.corrcoef(load, load_f[sigma])[0, 1]
    assert corr > 0.95, corr


def test_latency_percentiles_are_ordered_and_bracket_mean():
    res = _campaign([Algo.XY], rates=(0.3,), seeds=(0,))
    r = res.points[0].result
    assert 0 < r.p50_latency <= r.p90_latency <= r.p99_latency
    # p99 can only exceed max by the histogram bin granularity
    assert r.p99_latency <= r.max_latency + 8  # default lat_bin_width
    assert r.p50_latency <= r.avg_latency * 2


def test_link_load_max_positive_and_bounded():
    """Channels move ≤ 1 flit/cycle, so normalized link load ≤ 1."""
    res = _campaign([Algo.XY, Algo.BIDOR], rates=(0.3, 1.0))
    for p in res.points:
        assert 0.0 < p.result.link_load_max <= 1.0 + 1e-9, p


@pytest.mark.slow
def test_table_routed_sim_beyond_2d():
    """The tentpole contract: the simulator is plan-table-driven, so the
    zoo topologies (3D torus, concentrated mesh, express mesh) run through
    the same compiled pipeline — with flit conservation, in-order delivery
    for quasi-static algos, and a full drain at low load."""
    from repro.core import cmesh, express_mesh, torus
    from repro.noc import CampaignSpec, run_campaign

    base = SimConfig(cycles=1500, warmup=400, drain=500)
    spec = CampaignSpec(
        topo=TOPO, topos=(torus(3, 3, 3), cmesh(3, 3, 2),
                          express_mesh(6, 6, 2)),
        algos=(Algo.XY, Algo.YX, Algo.BIDOR),
        patterns=("uniform",), rates=(0.08,), seeds=(0,), base=base)
    res = run_campaign(spec)
    assert len(res.points) == 3 * 3
    for p in res.points:
        r = p.result
        assert r.injected_flits == r.ejected_flits + r.in_flight_flits, p
        assert r.in_flight_flits == 0, p        # drained at low load
        assert r.ejected_flits > 0, p
        assert r.reorder_value == 0, p          # quasi-static => in order
        assert p.topo in {"torus_3x3x3", "cmesh_3x3c2", "express_6x6i2"}


def test_oddeven_rejects_non_2d():
    from repro.core import torus
    from repro.noc.sim import run_sweep

    with pytest.raises(ValueError, match="2D turn model"):
        run_sweep(torus(3, 3, 3), traffic.uniform(torus(3, 3, 3)),
                  SimConfig(algo=Algo.ODDEVEN, cycles=300, warmup=100),
                  None, seeds=[0])


# --------------------------------------------------------------------- #
# large-mesh invariants under the fused kernel path (the regime the
# simstep kernel exists for: load-balance conclusions only firm up at
# 16x16+, so the classic 4x4 invariants are re-pinned there)
# --------------------------------------------------------------------- #
TOPO16 = mesh2d(16, 16)


@pytest.mark.slow
def test_16x16_kernel_conservation_fifo_and_drain():
    """16x16 mesh through the fused kernel path: flit conservation at
    every point, a full drain at low load (every in-flight packet
    lands), and per-VC FIFO ordering for the quasi-static algorithms
    (reorder-buffer occupancy pinned at 0)."""
    base = SimConfig(cycles=1400, warmup=300, drain=600)
    assert base.use_kernel, "fused kernel must be the default"
    spec = CampaignSpec(
        topo=TOPO16, algos=(Algo.XY, Algo.YX, Algo.BIDOR),
        patterns=("uniform",), rates=(0.05, 0.25), seeds=(0,),
        base=base)
    res = run_campaign(spec)
    assert len(res.points) == 3 * 2
    for p in res.points:
        r = p.result
        assert r.injected_flits == r.ejected_flits + r.in_flight_flits, p
        assert r.ejected_flits > 0, p
        assert r.reorder_value == 0, p          # quasi-static => in order
        if p.rate == 0.05:                      # below saturation: drained
            assert r.in_flight_flits == 0, p


@pytest.mark.slow
def test_16x16_kernel_xy_yx_transpose_symmetry():
    """XY on T and YX on the coordinate-transposed T' are the same
    system mirrored along the diagonal on 16x16 too — aggregate
    statistics agree up to RNG noise under the kernel path."""
    # mild hotspot: 16x16 ejection ports saturate fast, and at
    # saturation RNG noise swamps the symmetry being tested
    t = traffic.hotspot(TOPO16, hot_frac=0.15, num_hot=8, seed=5)
    sigma = _transpose_relabel(TOPO16)
    t_flip = t[np.ix_(sigma, sigma)]
    base = SimConfig(cycles=2500, warmup=600)
    spec = dict(rates=(0.1,), seeds=(0, 1), base=base, topo=TOPO16)
    res = _campaign([Algo.XY], patterns=(("t", t),), **spec)
    res_flip = _campaign([Algo.YX], patterns=(("t_flip", t_flip),),
                         **spec)
    thr = np.mean([p.result.throughput for p in res.points])
    thr_f = np.mean([p.result.throughput for p in res_flip.points])
    lat = np.mean([p.result.avg_latency for p in res.points])
    lat_f = np.mean([p.result.avg_latency for p in res_flip.points])
    assert abs(thr - thr_f) / thr < 0.05, (thr, thr_f)
    assert abs(lat - lat_f) / lat < 0.10, (lat, lat_f)
    load = np.mean([p.result.node_load for p in res.points], axis=0)
    load_f = np.mean([p.result.node_load for p in res_flip.points],
                     axis=0)
    corr = np.corrcoef(load, load_f[sigma])[0, 1]
    assert corr > 0.95, corr
