"""Flight recorder: trace-writer schema, structured logging, in-sim
telemetry probes (bit-identity off AND on, fused/unfused/Pallas parity),
ctrl-plane tracing, and the probes-reproduce-the-dynamics-gap check."""

import json

import numpy as np
import pytest

from repro.core import build_plan, mesh2d, traffic
from repro.kernels import simstep
from repro.noc import (Algo, LinkFail, ReplanConfig, Scenario, SimConfig,
                       run_controlled)
from repro.noc.sim import (build_tables, fresh_state,
                           run_sim, run_sweep, static_bw_slots)
from repro.obs import (EventLog, TEL_COUNT_FIELDS, TEL_KEYS, Telemetry,
                       TraceWriter, read_trace, resolved_epoch,
                       telemetry_state, validate_events)

TOPO = mesh2d(3, 3)
UNI = traffic.uniform(TOPO)
CFG = SimConfig(cycles=400, warmup=100, drain=50, injection_rate=0.2)

SCALAR_FIELDS = ("injected_flits", "ejected_flits", "in_flight_flits",
                 "reorder_value", "meas_cycles", "saturated",
                 "avg_latency", "max_latency", "throughput", "offered",
                 "lcv", "p50_latency", "p90_latency", "p99_latency",
                 "link_load_max")


# ------------------------------------------------------------------ #
# trace writer
# ------------------------------------------------------------------ #
def test_trace_writer_roundtrip_schema_and_kill_safety(tmp_path):
    path = str(tmp_path / "t" / "trace.jsonl")
    w = TraceWriter(path)
    w.instant("drift_detected", cat="ctrl", args={"cycle": 100})
    w.counter("drift_tv", {"tv": 0.12}, cat="ctrl")
    t0 = w.now_us()
    w.complete("replan", t0, 1234.5, cat="ctrl", args={"trigger": "fault"})
    with w.span("build", cat="plan", args={"nodes": 9}):
        pass
    with pytest.raises(RuntimeError):
        with w.span("boom", cat="plan"):
            raise RuntimeError("x")
    # NO close(): the stream must parse as written (kill safety)
    events = read_trace(path)
    assert [e["name"] for e in events] == [
        "drift_detected", "drift_tv", "replan", "build", "boom"]
    assert validate_events(events) == []
    assert events[2]["dur"] == 1234.5
    assert events[4]["args"]["error"] is True
    # Chrome trace-event JSON Array Format: Perfetto accepts the raw
    # file with the unterminated array closed
    raw = open(path).read()
    assert raw.startswith("[\n")
    parsed = json.loads(raw.rstrip().rstrip(",") + "]")
    assert len(parsed) == len(events)
    # appending (a resumed job) keeps the stream one valid array
    w2 = TraceWriter(path)
    w2.instant("resumed", cat="log")
    assert [e["name"] for e in read_trace(path)][-1] == "resumed"

    problems = validate_events([{"ph": "X", "ts": 1, "pid": "p"}])
    assert problems, "missing name/dur must be reported"


def test_event_log_quiet_verbose_and_trace_forwarding(tmp_path, capsys):
    quiet = EventLog(verbose=False)
    quiet.event("replan", "should not print", cycle=1)
    assert capsys.readouterr().out == ""

    path = str(tmp_path / "trace.jsonl")
    w = TraceWriter(path)
    loud = EventLog(verbose=True, tracer=w)
    loud.event("replan", "ctrl[x] replan @ 100", cycle=100)
    loud.event("cell_done", cell="c0", wall_s=1.5)   # default message
    out = capsys.readouterr().out
    assert "ctrl[x] replan @ 100" in out
    assert "cell_done" in out and "cell=c0" in out
    events = read_trace(path)
    assert [e["name"] for e in events] == ["replan", "cell_done"]
    assert events[0]["args"]["cycle"] == 100


# ------------------------------------------------------------------ #
# telemetry probes
# ------------------------------------------------------------------ #
def test_telemetry_state_shapes_and_epoch_resolution():
    cfg = CFG.replace(telemetry=True, tel_slots=8)
    tables, meta = build_tables(TOPO, UNI, None, cfg.num_vcs)
    st = telemetry_state(meta, cfg)
    assert set(st) == set(TEL_KEYS)
    assert st["tel_chan"].shape == (8, meta["C"])
    assert st["tel_counts"].shape == (8, len(TEL_COUNT_FIELDS))
    # auto epoch covers the whole run: ceil(400 / 8) = 50
    assert resolved_epoch(cfg) == 50
    assert resolved_epoch(cfg.replace(tel_epoch=25)) == 25
    assert resolved_epoch(cfg.replace(telemetry=False)) == 0
    # off -> no telemetry keys in the state pytree at all
    off = fresh_state(meta, CFG)
    assert not any(k in off for k in TEL_KEYS)


def test_telemetry_off_on_bit_identity_and_fused_unfused_parity():
    """Switching probes on must not move a single bit of the core
    statistics, on either per-cycle path; the probe arrays themselves
    must agree bit-for-bit between the fused and unfused paths."""
    plan = build_plan(TOPO, UNI)
    tels = {}
    for uk in (False, True):
        cfg = CFG.replace(algo=Algo.BIDOR, use_kernel=uk)
        off = run_sweep(TOPO, UNI, cfg, [0.1, 0.2], plan.table,
                        seeds=[0])
        on, tel = run_sweep(TOPO, UNI,
                            cfg.replace(telemetry=True, tel_slots=8),
                            [0.1, 0.2], plan.table, seeds=[0],
                            return_telemetry=True)
        for a, b in zip(off, on):
            for f in SCALAR_FIELDS:
                assert getattr(a, f) == getattr(b, f), (uk, f)
            assert np.array_equal(a.node_load, b.node_load)
        assert tel is not None
        tels[uk] = tel
    for arr in ("chan", "counts", "cycles", "lat", "qocc"):
        assert np.array_equal(getattr(tels[False], arr),
                              getattr(tels[True], arr)), arr


def test_telemetry_content_invariants_and_accessors():
    cfg = CFG.replace(telemetry=True, tel_slots=8)
    res, tel = run_sim(TOPO, UNI, cfg, return_telemetry=True)
    assert tel.num_lanes == 1 and tel.num_slots == 8
    # every cycle lands in exactly one slot
    assert tel.cycles.sum() == cfg.cycles
    assert np.array_equal(tel.active_slots(), np.arange(8))
    offered, accepted = tel.count("offered"), tel.count("accepted")
    shed, delivered = tel.count("shed"), tel.count("delivered")
    assert (accepted <= offered).all()
    assert np.array_equal(shed, offered - accepted)
    assert delivered.sum() <= accepted.sum()
    assert delivered.sum() > 0, "nothing delivered in 400 cycles?"
    # per-slot latency histograms: one tail per delivered packet, minus
    # any beyond the histogram range (mode='drop')
    assert tel.lat.sum() <= delivered.sum()
    assert tel.latency_percentile(0.5).shape == (1, 8)
    occ = tel.occupancy_mean()
    assert ((0 <= occ) & (occ <= 1)).all()
    # static bw normalization: loads are finite, dead-free, plausible
    tel = tel.with_bw(static_bw_slots(TOPO, cfg))
    peak = tel.peak_link_load()
    assert peak.shape == (1, 8)
    assert (peak >= 0).all() and np.isfinite(peak).all()
    assert peak.max() <= 1.5, "normalized link load implausibly high"

    # save/load round-trip
    import os
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "tel.npz")
        tel.save(p)
        back = Telemetry.load(p)
    assert back.epoch_len == tel.epoch_len
    for arr in ("chan", "counts", "cycles", "lat", "qocc", "bw"):
        assert np.array_equal(getattr(back, arr), getattr(tel, arr)), arr


def test_pallas_interpret_parity_includes_telemetry():
    """The generic Pallas kernel carries the probe rings through
    untouched: interpret-mode fused step == unfused oracle on every
    state array, telemetry included."""
    import jax

    cfg = CFG.replace(cycles=60, warmup=0, drain=0, telemetry=True,
                      tel_slots=4, tel_epoch=16)
    tables, meta = build_tables(TOPO, UNI, None, cfg.num_vcs)
    from repro.noc.sim import _make_step
    oracle = _make_step(meta, cfg)
    fused = simstep.make_step(meta, cfg, use_pallas=True, interpret=True)
    s_a = fresh_state(meta, cfg)
    s_b = {k: v.copy() for k, v in s_a.items()}
    for cyc in range(20):
        s_a, _ = oracle(tables, s_a, cyc)
        s_b, _ = fused(tables, s_b, cyc)
    s_a, s_b = jax.device_get(s_a), jax.device_get(s_b)
    for k in s_a:
        assert np.array_equal(s_a[k], s_b[k]), k
    assert s_a["tel_cycles"].sum() == 20


# ------------------------------------------------------------------ #
# controlled runs: ctrl-plane tracing + fault-aware bw timeline
# ------------------------------------------------------------------ #
LINK01 = ((0, 1), (1, 0))


def _linkfail_run(policy: str, tracer=None):
    cfg = SimConfig(algo=Algo.BIDOR, cycles=1200, warmup=200, drain=200,
                    injection_rate=0.25, telemetry=True, tel_slots=12)
    scen = Scenario("fail", events=(LinkFail(400, LINK01),),
                    policy=policy, replan=ReplanConfig(epoch=200))
    tm = traffic.transpose(TOPO)
    plan = build_plan(TOPO, tm)
    return run_controlled(TOPO, tm, cfg, scen, rates=[0.25], seeds=[0],
                          bidor_table=plan.table, nrank0=plan.nrank,
                          tracer=tracer)


def test_run_controlled_trace_events_and_bw_timeline(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    res = _linkfail_run("online", tracer=TraceWriter(path))
    events = read_trace(path)
    assert validate_events(events) == []
    names = [e["name"] for e in events]
    assert "LinkFail" in names and "epoch" in names
    assert "replan" in names and "hot_swap" in names
    # the replan span carries the decision context and real wall time
    (rp,) = [e for e in events if e["name"] == "replan"]
    assert rp["ph"] == "X" and rp["dur"] > 0
    assert rp["args"]["trigger"] == "fault"
    assert rp["args"]["iterations"] >= 1
    # chronology: the fault instant precedes its replan span's end
    (lf,) = [e for e in events if e["name"] == "LinkFail"]
    assert lf["ts"] <= rp["ts"] + rp["dur"]

    # telemetry attached, with the fault-aware bw timeline: slots before
    # the failure normalize by full bw, slots after by the degraded bw
    tel = res.telemetry
    assert tel is not None and tel.bw is not None
    c01 = TOPO.channel_index(0, 1)
    starts = tel.slot_starts()
    assert (tel.bw[starts < 400, c01] > 0).all()
    assert (tel.bw[starts >= 400, c01] == 0).all()
    # dead-channel convention: failed link contributes zero load
    assert (tel.link_load()[:, starts >= 400, c01] == 0).all()


def test_probes_reproduce_online_vs_stale_gap():
    """The acceptance check: from the in-sim probe rings ALONE, the
    online policy's post-replan peak-link-load trajectory must drop
    below the stale policy's (pinned at the saturated degraded link)."""
    stale = _linkfail_run("stale").telemetry
    online = _linkfail_run("online").telemetry
    starts = stale.slot_starts()
    post = [int(s) for s in stale.active_slots() if starts[s] >= 600]
    assert post
    g_stale = float(stale.peak_link_load()[0][post].mean())
    g_online = float(online.peak_link_load()[0][post].mean())
    assert g_online < g_stale, (g_online, g_stale)


def test_run_controlled_without_tracer_is_unchanged():
    """tracer=None (the default) must leave results identical to the
    traced run — tracing is observation, never behavior."""
    import dataclasses
    a = _linkfail_run("online")
    b = _linkfail_run("online", tracer=None)
    assert [dataclasses.astuple(x) for x in a.replans] \
        == [dataclasses.astuple(x) for x in b.replans]
    for ra, rb in zip(a.results, b.results):
        for f in SCALAR_FIELDS:
            assert getattr(ra, f) == getattr(rb, f), f
    for arr in ("chan", "counts", "cycles", "lat", "qocc", "bw"):
        assert np.array_equal(getattr(a.telemetry, arr),
                              getattr(b.telemetry, arr)), arr
