"""Regenerate the golden campaign fixtures.

Usage:  PYTHONPATH=src python tests/goldens/regen.py
            [--out DIR] [--sim-path {blocked,fused,unfused}]

Writes ``campaign_4x4.json`` / ``ctrl_4x4.json`` next to this file — or
into ``--out DIR`` (e.g. in CI, which regenerates into a scratch dir and
uploads the diff against the committed fixtures as a workflow artifact).
``--sim-path`` selects the per-cycle transition (the fused flit-step
kernel, the default; the unfused oracle; or the blocked node-tile
kernel); CI regenerates with EACH and cross-diffs them, attesting the
bit-identity contract on the pinned fixtures themselves.
Overwrite the committed fixtures ONLY when a simulator change
intentionally alters behaviour, and say so in the commit message — the
golden test exists to make unintended changes loud.

The fixture pins integer flit counts exactly (they are deterministic
functions of the per-point PRNG stream) and float statistics to 6
significant digits.
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "campaign_4x4.json")
CTRL_GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "ctrl_4x4.json")


# --sim-path choices: every per-cycle transition must regenerate the
# SAME fixtures (the fused kernel — whole-array or blocked over node
# tiles — is bit-identical to the unfused oracle), so CI regenerates
# with each and cross-diffs them.  Each entry maps the base SimConfig
# onto that path; "blocked" pins two 8-node tiles on the 4x4 mesh.
SIM_PATHS = {
    "fused": lambda cfg: cfg.replace(use_kernel=True),
    "unfused": lambda cfg: cfg.replace(use_kernel=False),
    "blocked": lambda cfg: cfg.replace(use_kernel=True,
                                       sim_tile_nodes=8),
}


def golden_spec(to_path=SIM_PATHS["fused"]):
    from repro.core import mesh2d
    from repro.noc import Algo, CampaignSpec, SimConfig

    return CampaignSpec(
        topo=mesh2d(4, 4),
        algos=(Algo.XY, Algo.BIDOR),
        patterns=("uniform", "tornado"),
        rates=(0.15, 0.5),
        seeds=(0, 1),
        base=to_path(SimConfig(cycles=1000, warmup=300, drain=100)),
    )


def ctrl_spec(to_path=SIM_PATHS["fused"]):
    """Pinned fault-scenario campaign: one central link retrains at 25%
    width mid-measure; the stale and online control policies face it."""
    from repro.core import mesh2d
    from repro.noc import (Algo, CampaignSpec, LinkFail, ReplanConfig,
                           Scenario, SimConfig)

    fail = (LinkFail(cycle=1200, links=((5, 6), (6, 5)), bw_scale=0.25),)
    rc = ReplanConfig(epoch=400)
    return CampaignSpec(
        topo=mesh2d(4, 4),
        algos=(Algo.BIDOR,),
        patterns=("uniform",),
        rates=(0.35,),
        seeds=(0, 1),
        base=to_path(SimConfig(cycles=2400, warmup=400)),
        scenarios=(
            Scenario("linkfail_stale", events=fail, policy="stale",
                     replan=rc),
            Scenario("linkfail_online", events=fail, policy="online",
                     replan=rc),
        ),
    )


def compute_goldens(to_path=SIM_PATHS["fused"]) -> dict:
    from repro.noc import run_campaign

    res = run_campaign(golden_spec(to_path))
    points = {}
    for p in res.points:
        r = p.result
        key = f"{p.pattern}/{p.algo.name}/r{p.rate}/s{p.seed}"
        points[key] = {
            "injected": r.injected_flits,
            "ejected": r.ejected_flits,
            "in_flight": r.in_flight_flits,
            "reorder": r.reorder_value,
            "meas_cycles": r.meas_cycles,
            "throughput": round(r.throughput, 6),
            "avg_latency": round(r.avg_latency, 6),
            "p50_latency": round(r.p50_latency, 6),
            "p99_latency": round(r.p99_latency, 6),
            "link_load_max": round(r.link_load_max, 6),
            "lcv": round(r.lcv, 6),
        }
    return {
        "description": "4x4-mesh golden campaign (see tests/goldens/"
                       "regen.py); pins simulator behaviour across "
                       "refactors",
        "points": points,
    }


def compute_ctrl_goldens(to_path=SIM_PATHS["fused"]) -> dict:
    from repro.noc import run_campaign

    res = run_campaign(ctrl_spec(to_path))
    points = {}
    for p in res.points:
        r = p.result
        key = f"{p.scenario}/{p.algo.name}/r{p.rate}/s{p.seed}"
        points[key] = {
            "injected": r.injected_flits,
            "ejected": r.ejected_flits,
            "in_flight": r.in_flight_flits,
            "reorder": r.reorder_value,
            "meas_cycles": r.meas_cycles,
            "throughput": round(r.throughput, 6),
            "avg_latency": round(r.avg_latency, 6),
            "p50_latency": round(r.p50_latency, 6),
            "p99_latency": round(r.p99_latency, 6),
            "link_load_max": round(r.link_load_max, 6),
            "lcv": round(r.lcv, 6),
        }
    return {
        "description": "4x4-mesh fault-scenario campaign (one link "
                       "degraded to 25% width mid-measure; stale vs "
                       "online control policy; see tests/goldens/"
                       "regen.py); pins the control plane's event "
                       "application, hot swap and re-planning",
        "points": points,
    }


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, metavar="DIR",
                    help="write the fixtures into DIR instead of "
                         "overwriting the committed ones (CI diffing)")
    ap.add_argument("--sim-path", default="fused",
                    choices=sorted(SIM_PATHS),
                    help="per-cycle transition to regenerate with: the "
                         "fused kernel (default, the simulator "
                         "default), the unfused oracle, or the blocked "
                         "node-tile kernel — all must produce identical "
                         "fixtures, which CI attests by regenerating "
                         "with each and cross-diffing")
    args = ap.parse_args(argv)
    to_path = SIM_PATHS[args.sim_path]
    golden_path, ctrl_path = GOLDEN_PATH, CTRL_GOLDEN_PATH
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        golden_path = os.path.join(args.out,
                                   os.path.basename(GOLDEN_PATH))
        ctrl_path = os.path.join(args.out,
                                 os.path.basename(CTRL_GOLDEN_PATH))
    goldens = compute_goldens(to_path)
    with open(golden_path, "w") as f:
        json.dump(goldens, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {len(goldens['points'])} golden points to "
          f"{golden_path} ({args.sim_path} sim path)")
    ctrl = compute_ctrl_goldens(to_path)
    with open(ctrl_path, "w") as f:
        json.dump(ctrl, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {len(ctrl['points'])} ctrl golden points to "
          f"{ctrl_path} ({args.sim_path} sim path)")


if __name__ == "__main__":
    main()
