"""Tier-1 collection hygiene.

The suite must collect with zero errors in a bare environment (no
``pip install`` possible), and the multi-device parity tests need fake
host devices injected before jax initializes.  Three mechanisms:

* ``src`` is prepended to ``sys.path`` so ``python -m pytest`` works even
  without ``PYTHONPATH=src``.
* Modules with genuinely optional dependencies guard them with
  ``pytest.importorskip`` at import time (e.g.
  ``test_qstar_collectives.py`` until the ``repro.dist`` subsystem
  lands), so they collect as skipped instead of erroring.  Property tests
  do NOT require hypothesis: they run through the ``_propcheck`` facade,
  which falls back to a deterministic sampler (see
  ``tests/_propcheck.py``).
* ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` is injected at
  conftest import time (iff jax is not yet imported and the user did
  not set a count); the ``multi_device_count`` fixture skips with the
  reason when the injection could not happen.
"""

from __future__ import annotations

import os
import sys

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.isdir(_SRC):
    sys.path.insert(0, os.path.abspath(_SRC))

# Expose fake host devices for the multi-device campaign tests
# (tests/test_multidevice.py).  The flag only takes effect if it lands
# before the first jax import of the process, so it is set here at
# conftest import time — before any test module imports — and only when
# nothing imported jax yet and the user has not chosen a count.  Lane
# sharding is exact (bit-identical states, asserted by the parity
# tests), so the rest of the suite is unaffected by running on 8
# devices.  _FAKE_DEVICES records whether the flag landed; the fixture
# below turns a miss into a skip-with-reason rather than a bogus pass.
_FORCE = "--xla_force_host_platform_device_count"
_FAKE_DEVICES = False
if "jax" not in sys.modules and _FORCE not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FORCE}=8").strip()
    _FAKE_DEVICES = True


@pytest.fixture
def multi_device_count() -> int:
    """Device count for multi-device tests; skips (with the reason) when
    the fake-device flag could not be injected or did not take."""
    import jax

    n = jax.device_count()
    if n < 2:
        why = ("jax was imported before conftest could set "
               f"XLA_FLAGS={_FORCE}" if not _FAKE_DEVICES
               else "the forced host-device count did not take effect")
        pytest.skip(f"needs >1 jax device: {why}")
    return n
