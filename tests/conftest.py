"""Tier-1 collection hygiene.

The suite must collect with zero errors in a bare environment (no
``pip install`` possible).  Two mechanisms:

* ``src`` is prepended to ``sys.path`` so ``python -m pytest`` works even
  without ``PYTHONPATH=src``.
* Modules with genuinely optional dependencies guard them with
  ``pytest.importorskip`` at import time (e.g.
  ``test_qstar_collectives.py`` until the ``repro.dist`` subsystem
  lands), so they collect as skipped instead of erroring.  Property tests
  do NOT require hypothesis: they run through the ``_propcheck`` facade,
  which falls back to a deterministic sampler (see
  ``tests/_propcheck.py``).
"""

from __future__ import annotations

import os
import sys

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.isdir(_SRC):
    sys.path.insert(0, os.path.abspath(_SRC))
