"""Campaign service: kill-and-resume bit-identity, persistent plan
caching, mid-cell control-loop checkpointing, and the multi-axis result
accessors the service streams into."""

import copy
import json

import numpy as np
import pytest

from repro.core import build_plan, mesh2d, torus, traffic
from repro.noc import (Algo, CampaignSpec, LinkFail, ReplanConfig,
                       Scenario, SimConfig, TrafficDrift, run_campaign,
                       run_campaign_service, run_controlled)
from repro.noc.service import CampaignJob, CellCheckpoint, spec_fingerprint

TOPO = mesh2d(3, 3)
UNI = traffic.uniform(TOPO)
BASE = SimConfig(cycles=1200, warmup=300, drain=100)

# full bidirectional link between nodes 0 and 1
LINK01 = ((0, 1), (1, 0))

SCALAR_FIELDS = ("injected_flits", "ejected_flits", "in_flight_flits",
                 "reorder_value", "meas_cycles", "saturated",
                 "avg_latency", "max_latency", "throughput", "offered",
                 "lcv", "p50_latency", "p90_latency", "p99_latency",
                 "link_load_max")


def _spec(**kw):
    d = dict(
        topo=TOPO, algos=(Algo.XY, Algo.BIDOR),
        patterns=(("uni", UNI),), rates=(0.1, 0.3), seeds=(0,),
        base=BASE,
        scenarios=(Scenario("calm"),
                   Scenario("fail", events=(LinkFail(600, LINK01),),
                            policy="oracle",
                            replan=ReplanConfig(epoch=400))))
    d.update(kw)
    return CampaignSpec(**d)


def _assert_points_identical(pts_a, pts_b):
    assert len(pts_a) == len(pts_b)
    for p, q in zip(pts_a, pts_b):
        assert (p.algo, p.pattern, p.rate, p.seed, p.scenario, p.topo) \
            == (q.algo, q.pattern, q.rate, q.seed, q.scenario, q.topo)
        for f in SCALAR_FIELDS:
            assert getattr(p.result, f) == getattr(q.result, f), f
        assert np.array_equal(p.result.node_load, q.result.node_load)


def test_kill_and_resume_is_bit_identical(tmp_path):
    """A job interrupted after every single cell and resumed to the end
    must produce the same CSV byte-for-byte, and the same result
    bit-for-bit, as an uninterrupted job and as plain run_campaign."""
    spec = _spec()
    root = str(tmp_path)
    runs = 0
    while True:
        res, job = run_campaign_service(spec, root=root, job_id="itr",
                                        max_cells=1)
        runs += 1
        assert runs <= 16, "job failed to converge"
        if res is not None:
            break
    # exactly one executed cell per invocation
    assert runs == len(job.cells)

    fres, fjob = run_campaign_service(spec, root=root, job_id="fresh")
    with open(job.csv_path, "rb") as a, open(fjob.csv_path, "rb") as b:
        assert a.read() == b.read()
    _assert_points_identical(res.points, fres.points)
    # the job directory alone reconstructs the result
    _assert_points_identical(res.points, job.result().points)
    # and the service is transparent w.r.t. the blocking engine
    ref = run_campaign(spec)
    _assert_points_identical(res.points, ref.points)


def test_job_refuses_foreign_spec_and_fingerprint_is_content_keyed(
        tmp_path):
    spec = _spec()
    root = str(tmp_path)
    CampaignJob(spec, root=root, job_id="j")
    # same content -> same fingerprint, even through a copy
    assert spec_fingerprint(copy.deepcopy(spec)) == spec_fingerprint(spec)
    # different content (one extra rate) -> refused in the same dir
    other = _spec(rates=(0.1, 0.3, 0.5))
    assert spec_fingerprint(other) != spec_fingerprint(spec)
    with pytest.raises(ValueError, match="different campaign"):
        CampaignJob(other, root=root, job_id="j")


def test_warm_plan_cache_skips_all_plan_builds(tmp_path, monkeypatch):
    """Re-running a spec against a warm shared plan cache must make ZERO
    build_plans_batched calls — the campaign pre-screens every needed
    plan against the cache before batching the misses."""
    import repro.noc.campaign as campaign_mod

    calls = []
    real = campaign_mod.build_plans_batched

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(campaign_mod, "build_plans_batched", counting)
    spec = CampaignSpec(topo=TOPO, algos=(Algo.BIDOR,),
                        patterns=(("uni", UNI),), rates=(0.1,),
                        seeds=(0,), base=BASE)
    root = str(tmp_path)
    res_cold, job_cold = run_campaign_service(spec, root=root,
                                              job_id="cold")
    assert calls, "cold run must build plans on device"
    assert job_cold.plan_cache.stats.stores > 0

    calls.clear()
    res_warm, job_warm = run_campaign_service(spec, root=root,
                                              job_id="warm")
    assert calls == [], "warm run re-built plans despite the cache"
    st = job_warm.plan_cache.stats.as_dict()
    assert st["device_builds"] == 0
    assert st["misses"] == 0
    assert st["hits"] > 0
    # cached plans route identically to freshly built ones
    _assert_points_identical(res_cold.points, res_warm.points)


def test_midcell_checkpoint_resumes_bit_identically(tmp_path):
    """Interrupting a controlled run at an epoch boundary and resuming
    from the snapshot must reproduce the uninterrupted run exactly —
    every lane statistic, the link peaks, and the replan log."""
    topo = mesh2d(3, 3)
    tm = traffic.uniform(topo)
    drift = traffic.tornado(topo)
    cfg = SimConfig(algo=Algo.BIDOR, cycles=2000, warmup=400, drain=200)
    scen = Scenario("dyn",
                    events=(LinkFail(700, LINK01),
                            TrafficDrift(1200, drift)),
                    policy="oracle", replan=ReplanConfig(epoch=400))
    plan = build_plan(topo, tm)
    kw = dict(rates=[0.1, 0.3], seeds=[0], bidor_table=plan.table)

    class Rec:
        """In-memory checkpointer: records every snapshot, optionally
        preloaded with one to resume from."""

        def __init__(self, preload=None):
            self.snaps = []
            self.preload = preload

        def save(self, arrays, meta):
            self.snaps.append(
                ({k: np.array(v) for k, v in arrays.items()},
                 json.loads(json.dumps(meta))))

        def load(self):
            return self.preload

    rec = Rec()
    base = run_controlled(topo, tm, cfg, scen, checkpoint=rec, **kw)
    assert len(rec.snaps) >= 3
    assert base.replans, "oracle policy must have replanned"

    plain = run_controlled(topo, tm, cfg, scen, **kw)

    def check(r):
        assert r.epoch_bounds == base.epoch_bounds
        assert [dataclasses_tuple(x) for x in r.replans] \
            == [dataclasses_tuple(x) for x in base.replans]
        assert np.array_equal(r.link_peak, base.link_peak)
        for a, b in zip(r.results, base.results):
            for f in SCALAR_FIELDS:
                assert getattr(a, f) == getattr(b, f), f
            assert np.array_equal(a.node_load, b.node_load)

    import dataclasses as _dc

    def dataclasses_tuple(x):
        return _dc.astuple(x)

    check(plain)  # recording a snapshot must not perturb the run
    # resume from a mid-run snapshot (after the fault replan) and from
    # the last one — both land on the identical final state
    for snap in (rec.snaps[1], rec.snaps[-1]):
        r = run_controlled(topo, tm, cfg, scen, checkpoint=Rec(snap),
                           **kw)
        check(r)
    # and through the on-disk npz round-trip the service actually uses
    ck = CellCheckpoint(str(tmp_path / "snap.npz"))
    ck.save(*rec.snaps[1])
    r = run_controlled(topo, tm, cfg, scen, checkpoint=ck, **kw)
    check(r)
    ck.clear()
    assert ck.load() is None


def test_multi_axis_grid_matches_per_axis_recomputation():
    """grid()/mean_over_seeds()/saturation_throughput() on a 2-topo ×
    2-scenario campaign agree with manual recomputation from select()
    on every (scenario, topo) pair."""
    spec = CampaignSpec(
        topo=None, topos=(TOPO, torus(3, 3)), algos=(Algo.XY,),
        patterns=("uniform",), rates=(0.1, 0.3), seeds=(0, 1),
        base=BASE,
        scenarios=(Scenario("calm"),
                   Scenario("fail", events=(LinkFail(600, LINK01),))))
    res = run_campaign(spec)
    assert len(res.points) == 2 * 2 * 2 * 2  # topo x scen x rate x seed
    for tname in res.topo_names:
        for sname in res.scenario_names:
            g = res.grid("throughput", Algo.XY, "uniform",
                         scenario=sname, topo=tname)
            assert g.shape == (2, 2)
            for i, rate in enumerate(spec.rates):
                for j, seed in enumerate(spec.seeds):
                    (p,) = res.select(algo=Algo.XY, pattern="uniform",
                                      rate=rate, seed=seed,
                                      scenario=sname, topo=tname)
                    assert g[i, j] == p.result.throughput
            m = res.mean_over_seeds("throughput", Algo.XY, "uniform",
                                    scenario=sname, topo=tname)
            assert np.array_equal(m, g.mean(axis=1))
            sat = res.saturation_throughput(Algo.XY, "uniform",
                                            scenario=sname, topo=tname)
            assert sat == g.mean(axis=1).max()
    # the two topologies genuinely differ (guards against the pooled
    # last-write-wins bug resurfacing as identical grids)
    g_mesh = res.grid("avg_latency", Algo.XY, "uniform",
                      scenario="calm", topo=TOPO.name)
    g_torus = res.grid("avg_latency", Algo.XY, "uniform",
                       scenario="calm", topo=torus(3, 3).name)
    assert not np.array_equal(g_mesh, g_torus)


# ------------------------------------------------------------------ #
# flight recorder: metrics stream, live status, telemetry persistence
# ------------------------------------------------------------------ #
def _metrics(job):
    from repro.obs.report import load_metrics
    return load_metrics(job.metrics_path)


def test_metrics_stream_survives_kill_and_resume(tmp_path):
    """metrics.jsonl is a truthful progress stream: a budget-paused job
    records job_pause; the resume rewrites the stream with the completed
    cells marked cached and ends in job_done with done == total."""
    spec = _spec(base=BASE.replace(telemetry=True, tel_slots=6))
    root = str(tmp_path)
    res, job = run_campaign_service(spec, root=root, job_id="m",
                                    max_cells=2)
    assert res is None
    m = _metrics(job)
    assert m[0]["event"] == "job_start"
    assert m[-1]["event"] == "job_pause" and m[-1]["executed"] == 2
    cells = [r for r in m if r["event"] == "cell"]
    assert len(cells) == 2 and not any(r["cached"] for r in cells)
    assert [r["done"] for r in cells] == [1, 2]
    assert all(r["wall_s"] > 0 and "lanes_per_s" in r for r in cells)

    res, job = run_campaign_service(spec, root=root, job_id="m")
    assert res is not None
    m = _metrics(job)
    assert m[-1]["event"] == "job_done"
    cells = [r for r in m if r["event"] == "cell"]
    assert len(cells) == len(job.cells)
    assert [r["cached"] for r in cells[:2]] == [True, True]
    assert cells[-1]["done"] == len(job.cells)
    # plan-cache stats ride each record
    assert all("plan_cache" in r for r in cells)
    # ETA appears once a wall sample exists and cells remain
    fresh = [r for r in cells if not r["cached"]]
    assert all("eta_s" in r for r in fresh[1:-1])


def test_telemetry_persisted_per_cell_and_fingerprint_excludes_obs(
        tmp_path):
    """Telemetry rides the job as per-cell npz artifacts, and toggling
    it must NOT change the spec fingerprint — probe collection is
    bit-identity-neutral, so the same job resumes either way."""
    import os
    base_on = BASE.replace(telemetry=True, tel_slots=6)
    spec_on = _spec(base=base_on)
    assert spec_fingerprint(spec_on) == spec_fingerprint(_spec())
    assert spec_fingerprint(_spec(base=BASE.replace(tel_slots=99))) \
        == spec_fingerprint(_spec())
    # telemetry-off cells completed earlier must satisfy a telemetry-on
    # resume without re-running: results are the bit-identical truth
    root = str(tmp_path)
    res_off, job_off = run_campaign_service(_spec(), root=root,
                                            job_id="t", max_cells=2)
    res_on, job_on = run_campaign_service(spec_on, root=root, job_id="t")
    assert res_on is not None
    done = {k.slug for k in job_on.completed_cells()}
    assert len(done) == len(job_on.cells)
    for i, key in enumerate(job_on.cells):
        tel = job_on.cell_telemetry(key)
        if i < 2:       # ran with telemetry off: no probe artifact
            assert tel is None
        else:
            assert tel is not None
            assert tel.num_lanes == len(job_on.executor.points)
            assert tel.cycles.sum(axis=1).tolist() \
                == [BASE.cycles] * tel.num_lanes
            assert tel.bw is not None
    # telemetry-on results equal the telemetry-off reference
    ref = run_campaign(_spec())
    _assert_points_identical(res_on.points, ref.points)
    # resume=False clears telemetry artifacts too
    CampaignJob(spec_on, root=root, job_id="t", resume=False)
    for key in job_on.cells:
        assert job_on.cell_telemetry(key) is None
    assert not os.path.exists(job_on.metrics_path)


def test_status_is_live_and_safe_during_background_run(tmp_path):
    """status() concurrent with start(): monotone done counts, in_flight
    visibility, and no torn reads; errors surface in both wait() and
    status()."""
    import time as time_mod

    spec = _spec()
    job = CampaignJob(spec, root=str(tmp_path), job_id="bg")
    seen_done = []
    seen_flight = set()
    job.start()
    while True:
        st = job.status()
        assert 0 <= st.done_cells <= st.total_cells
        seen_done.append(st.done_cells)
        if st.in_flight is not None:
            seen_flight.add(st.in_flight)
        assert st.error is None
        if not st.running:
            break
        time_mod.sleep(0.01)
    final = job.wait()
    assert final.complete and final.done_cells == len(job.cells)
    assert seen_done == sorted(seen_done), "done count went backwards"
    assert seen_flight <= {k.slug for k in job.cells}
    # a second start() after completion is well-defined (no-op run)
    job.start()
    assert job.wait().complete

    # error path: a persistently failing cell is isolated — the run
    # loop exhausts its retry budget, records cell_error, and the job
    # finishes (incomplete, not crashed); wait() does NOT re-raise
    boom = CampaignJob(_spec(rates=(0.2,)), root=str(tmp_path),
                       job_id="boom", max_retries=0)

    def explode(key, checkpoint=None):
        raise RuntimeError("cell exploded")

    boom.executor.run_cell = explode
    boom.start()
    st = boom.wait()
    assert not st.running and not st.complete
    assert st.done_cells == 0
    errs = [r for r in _metrics(boom) if r["event"] == "cell_error"]
    assert len(errs) == len(boom.cells)
    assert all("cell exploded" in r["error"] for r in errs)

    # run()-level failures (not cell execution) still re-raise
    crash = CampaignJob(_spec(rates=(0.2,)), root=str(tmp_path),
                        job_id="crash")
    crash._run_cell_with_retry = None      # type: ignore[assignment]
    crash.start()
    with pytest.raises(TypeError):
        crash.wait()
    st = crash.status()
    assert st.error is not None and not st.running


# ------------------------------------------------------------------ #
# chaos hardening: corrupt checkpoints, poisoned cells
# ------------------------------------------------------------------ #
def test_corrupt_cell_npz_quarantined_and_recomputed(tmp_path):
    """Truncate a completed cell's npz: the resume must detect it via
    the sha256 sidecar, move it to cells/quarantine/, record the event,
    recompute the cell, and still emit a byte-identical CSV."""
    import os

    spec = _spec()
    root = str(tmp_path)
    res, job = run_campaign_service(spec, root=root, job_id="q")
    with open(job.csv_path, "rb") as f:
        ref_csv = f.read()
    victim = job.cells[1]
    path = job._cell_path(victim)
    with open(path, "rb") as f:
        blob = f.read()
    with open(path, "wb") as f:
        f.write(blob[: len(blob) // 2])

    res2, job2 = run_campaign_service(spec, root=root, job_id="q")
    assert res2 is not None
    m = _metrics(job2)
    quar = [r for r in m if r["event"] == "cell_quarantined"]
    assert [r["cell"] for r in quar] == [victim.slug]
    assert os.path.exists(
        os.path.join(job2.quarantine_dir, f"{victim.slug}.npz"))
    # the recomputed cell re-verifies; results and CSV are unchanged
    with open(job2.csv_path, "rb") as f:
        assert f.read() == ref_csv
    _assert_points_identical(res.points, res2.points)
    # a third run is clean: no quarantine events, everything cached
    res3, job3 = run_campaign_service(spec, root=root, job_id="q")
    m3 = _metrics(job3)
    assert not [r for r in m3 if r["event"] == "cell_quarantined"]
    assert all(r["cached"] for r in m3 if r["event"] == "cell")


def test_poisoned_cell_is_isolated_and_resume_completes(tmp_path):
    """One persistently failing cell: bounded retries with the error in
    metrics.jsonl, every other cell completes, and an un-poisoned
    resume finishes the job byte-identically to a clean reference."""
    spec = _spec()
    root = str(tmp_path)
    _, ref_job = run_campaign_service(spec, root=root, job_id="ref")

    job = CampaignJob(spec, root=root, job_id="p", max_retries=1,
                      retry_backoff_s=0.0)
    victim = job.cells[0].slug
    real = job.executor.run_cell

    def flaky(key, checkpoint=None):
        if key.slug == victim:
            raise RuntimeError("poisoned cell")
        return real(key, checkpoint=checkpoint)

    job.executor.run_cell = flaky
    assert job.run() is False             # incomplete, not crashed
    m = _metrics(job)
    retries = [r for r in m if r["event"] == "cell_retry"]
    assert len(retries) == 2              # max_retries + 1 attempts
    assert all(r["cell"] == victim and "poisoned" in r["error"]
               for r in retries)
    errs = [r for r in m if r["event"] == "cell_error"]
    assert [r["cell"] for r in errs] == [victim]
    assert m[-1]["event"] == "job_done"
    assert m[-1]["failed"] == 1
    assert m[-1]["done"] == len(job.cells) - 1
    done = {k.slug for k in job.completed_cells()}
    assert done == {k.slug for k in job.cells} - {victim}

    res, job2 = run_campaign_service(spec, root=root, job_id="p")
    assert res is not None
    with open(job2.csv_path, "rb") as a, \
            open(ref_job.csv_path, "rb") as b:
        assert a.read() == b.read()


def test_cell_checkpoint_corruption_sets_aside_and_restarts(tmp_path):
    """A mid-cell snapshot that fails its sha256 (or fails to parse) is
    *no checkpoint*: set aside as .corrupt, load() returns None, and the
    cell restarts from cycle 0 — slower, never wrong."""
    import os

    ck = CellCheckpoint(str(tmp_path / "c.npz"))
    ck.save({"a": np.arange(3)}, {"cycle": 7})
    assert os.path.exists(ck.path + ".sha256")
    arrays, meta = ck.load()
    assert meta == {"cycle": 7} and np.array_equal(arrays["a"],
                                                   np.arange(3))
    with open(ck.path, "r+b") as f:
        f.write(b"xx")
    assert ck.load() is None
    assert os.path.exists(ck.path + ".corrupt")
    assert not os.path.exists(ck.path)
    assert not os.path.exists(ck.path + ".sha256")
    assert ck.load() is None              # stays gone
    ck.clear()                            # idempotent on the empty state


def test_job_trace_records_cells_and_is_perfetto_parseable(tmp_path):
    from repro.obs.trace import read_trace, validate_events

    spec = _spec(base=BASE.replace(telemetry=True, tel_slots=6))
    res, job = run_campaign_service(spec, root=str(tmp_path),
                                    job_id="tr", trace=True)
    assert res is not None
    events = read_trace(job.trace_path)
    assert validate_events(events) == []
    names = [e["name"] for e in events]
    # one cell span per cell, and the scenario cells' ctrl-plane chain
    assert names.count("cell") == len(job.cells)
    assert "LinkFail" in names and "replan" in names
    assert "build_plans_batched" in names
    slugs = {e["args"]["slug"] for e in events if e["name"] == "cell"}
    assert slugs == {k.slug for k in job.cells}
