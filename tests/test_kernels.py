"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps
+ hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core import mesh2d, mesh2d_edge_io, torus, traffic
from repro.core.nrank import possibility_weights as possibility_oracle
from repro.kernels.possibility import ops as poss_ops
from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.flash_attention.ref import flash_attention as flash_ref
from repro.kernels.mamba_scan import ops as scan_ops
from repro.kernels.mamba_scan.ref import selective_scan as scan_ref


# --------------------------------------------------------------------- #
# possibility weights (N-Rank hot spot)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("topo_fn,pattern", [
    (lambda: mesh2d(5, 5), "uniform"),
    (lambda: mesh2d_edge_io(5, 5), "overturn"),
    (lambda: torus(8, 8), "uniform"),
    (lambda: mesh2d(4, 7), "shuffle"),
])
def test_possibility_kernel_matches_core_oracle(topo_fn, pattern):
    """Defaults = the compiled path for the current backend (dense jnp on
    CPU, compiled Pallas on TPU/GPU) — never the interpreter."""
    topo = topo_fn()
    t = traffic.PATTERNS[pattern](topo)
    w_ref, wd_ref = possibility_oracle(topo.distances, t, topo.channels)
    w, wd = poss_ops.possibility_weights(topo.distances, t, topo.channels)
    np.testing.assert_allclose(np.asarray(w), w_ref, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(wd), wd_ref, rtol=1e-5, atol=1e-7)


def test_possibility_pallas_kernel_itself_matches_oracle():
    """The Pallas kernel proper (interpret mode where it cannot compile,
    e.g. CPU CI) against the numpy oracle, both offsets."""
    interpret = not poss_ops.backend_supports_pallas()
    topo = torus(8, 8)
    t = traffic.uniform(topo)
    w_ref, wd_ref = possibility_oracle(topo.distances, t, topo.channels)
    w, wd = poss_ops.possibility_weights(topo.distances, t, topo.channels,
                                         use_pallas=True,
                                         interpret=interpret)
    np.testing.assert_allclose(np.asarray(w), w_ref, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(wd), wd_ref, rtol=1e-5, atol=1e-7)
    # offset=2: the consecutive-pair predicate on (u, n2) index pairs
    from repro.core.nrank import joint_possibility
    j = joint_possibility(topo, t)
    chans = topo.channels
    pairs = np.argwhere(j > 0)
    ab = np.stack([chans[pairs[:, 0], 0], chans[pairs[:, 1], 1]], axis=1)
    w2, _ = poss_ops.possibility_weights(topo.distances, t, ab,
                                         use_pallas=True,
                                         interpret=interpret, offset=2)
    np.testing.assert_allclose(np.asarray(w2), j[pairs[:, 0], pairs[:, 1]],
                               rtol=1e-5, atol=1e-7)


def test_possibility_v_pallas_matches_dense():
    """The per-destination V kernel feeding the fused planner: row sums
    are eq. 5, the d = n gather is eq. 7."""
    from repro.kernels.possibility.kernel import possibility_v_pallas
    from repro.kernels.possibility.ops import _prepare
    interpret = not poss_ops.backend_supports_pallas()
    topo = mesh2d(6, 5)
    t = traffic.uniform(topo)
    du, dn, dsn, tn, tm, dist = _prepare(topo.distances, t, topo.channels)
    v = possibility_v_pallas(du, dn, tm, dist, interpret=interpret)
    w_ref, wd_ref = possibility_oracle(topo.distances, t, topo.channels)
    np.testing.assert_allclose(np.asarray(v).sum(1), w_ref,
                               rtol=1e-5, atol=1e-7)
    ns = topo.channels[:, 1]
    np.testing.assert_allclose(
        np.asarray(v)[np.arange(topo.num_channels), ns], wd_ref,
        rtol=1e-5, atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(st.integers(3, 6), st.integers(3, 5), st.integers(0, 2**31 - 1))
def test_possibility_kernel_random_traffic(w, h, seed):
    topo = mesh2d(w, h)
    rng = np.random.default_rng(seed)
    t = rng.random((topo.num_nodes,) * 2)
    np.fill_diagonal(t, 0)
    t /= t.sum()
    w_ref, wd_ref = possibility_oracle(topo.distances, t, topo.channels)
    wk, wdk = poss_ops.possibility_weights(topo.distances, t, topo.channels)
    np.testing.assert_allclose(np.asarray(wk), w_ref, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(wdk), wd_ref, rtol=1e-4, atol=1e-6)


def test_possibility_kernel_block_sweep():
    interpret = not poss_ops.backend_supports_pallas()
    topo = torus(8, 8)
    t = traffic.uniform(topo)
    w_ref, _ = possibility_oracle(topo.distances, t, topo.channels)
    from repro.kernels.possibility.ops import _prepare
    from repro.kernels.possibility.kernel import possibility_weights_pallas
    args = _prepare(topo.distances, t, topo.channels)
    for bc, bs in [(32, 16), (64, 64), (256, 64), (128, 128)]:
        w, _ = possibility_weights_pallas(*args, block_c=bc, block_s=bs,
                                          interpret=interpret)
        np.testing.assert_allclose(np.asarray(w), w_ref, rtol=1e-5,
                                   atol=1e-7)


# --------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("sq,skv,h,kv,d,causal,dtype", [
    (128, 128, 4, 4, 64, True, jnp.float32),
    (256, 256, 4, 2, 64, True, jnp.float32),
    (128, 256, 2, 1, 32, False, jnp.float32),
    (200, 200, 4, 2, 64, True, jnp.float32),     # non-multiple of block
    (128, 128, 4, 4, 64, True, jnp.bfloat16),
    (64, 512, 8, 2, 128, False, jnp.float32),
])
def test_flash_kernel_matches_ref(sq, skv, h, kv, d, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b = 2
    q = jax.random.normal(ks[0], (b, sq, h, d), dtype)
    k = jax.random.normal(ks[1], (b, skv, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, skv, kv, d), dtype)
    out = flash_ops.flash_attention(q, k, v, causal=causal, block_q=64,
                                    block_kv=64)
    ref = flash_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                    v.transpose(0, 2, 1, 3),
                    causal=causal).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.sampled_from([32, 64, 96]),
       st.sampled_from([1, 2, 4]), st.booleans(),
       st.integers(0, 2**31 - 1))
def test_flash_kernel_property(b, sq, g, causal, seed):
    kv, d = 2, 32
    h = kv * g
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, sq, kv, d))
    v = jax.random.normal(ks[2], (b, sq, kv, d))
    out = flash_ops.flash_attention(q, k, v, causal=causal, block_q=32,
                                    block_kv=32)
    ref = flash_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                    v.transpose(0, 2, 1, 3),
                    causal=causal).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_kernel_output_is_convex_combination():
    """Attention outputs lie in the convex hull of V rows (max bound)."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))
    out = flash_ops.flash_attention(q, k, v, causal=False)
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-5


# --------------------------------------------------------------------- #
# mamba selective scan
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("b,s,di,ds,chunk", [
    (2, 64, 128, 16, 16),
    (1, 96, 256, 8, 32),     # s not a chunk multiple of block
    (2, 64, 100, 16, 64),    # di not a block multiple
    (1, 33, 64, 4, 16),
])
def test_mamba_scan_kernel_matches_ref(b, s, di, ds, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    delta = jax.nn.softplus(jax.random.normal(ks[0], (b, s, di))) * 0.1
    a = -jnp.exp(jax.random.normal(ks[1], (di, ds)) * 0.2)
    bm = jax.random.normal(ks[2], (b, s, ds))
    cm = jax.random.normal(ks[3], (b, s, ds))
    x = jax.random.normal(ks[4], (b, s, di))
    y = scan_ops.selective_scan(delta, a, bm, cm, x, block_d=64,
                                chunk=chunk)
    y_ref, _ = scan_ref(delta, a, bm, cm, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 48, 64]))
def test_mamba_scan_property(seed, s):
    b, di, ds = 1, 64, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    delta = jax.nn.softplus(jax.random.normal(ks[0], (b, s, di))) * 0.1
    a = -jnp.exp(jax.random.normal(ks[1], (di, ds)) * 0.2)
    bm = jax.random.normal(ks[2], (b, s, ds))
    cm = jax.random.normal(ks[3], (b, s, ds))
    x = jax.random.normal(ks[4], (b, s, di))
    y = scan_ops.selective_scan(delta, a, bm, cm, x, block_d=32, chunk=16)
    y_ref, _ = scan_ref(delta, a, bm, cm, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


def test_mamba_scan_decays_to_zero_with_large_negative_a():
    """Stability: strongly negative A forgets history ⇒ y tracks only the
    instantaneous input."""
    b, s, di, ds = 1, 32, 32, 4
    delta = jnp.ones((b, s, di)) * 5.0
    a = -jnp.ones((di, ds)) * 10.0
    bm = jnp.ones((b, s, ds))
    cm = jnp.ones((b, s, ds))
    x = jnp.ones((b, s, di))
    y = scan_ops.selective_scan(delta, a, bm, cm, x, block_d=32, chunk=8)
    # steady state: h ≈ Δ·x·B (previous h fully decayed)
    np.testing.assert_allclose(np.asarray(y[0, -1]), 5.0 * ds,
                               rtol=1e-3)
