"""Campaign engine: grid semantics, batched-equals-sequential, phasing,
saturation early-exit, and result accessors."""

import numpy as np
import pytest

from repro.core import mesh2d, traffic, build_plan
from repro.noc import (Algo, CampaignSpec, SimConfig, run_campaign)
from repro.noc.sim import run_sweep

TOPO = mesh2d(4, 4)
UNI = traffic.uniform(TOPO)
BASE = SimConfig(cycles=1200, warmup=300, drain=100)


@pytest.mark.slow
def test_grid_is_fully_enumerated():
    spec = CampaignSpec(
        topo=TOPO, algos=(Algo.XY, Algo.YX), patterns=(("uni", UNI),),
        rates=(0.1, 0.3), seeds=(0, 1, 2), base=BASE)
    res = run_campaign(spec)
    assert spec.num_points == 12
    assert len(res.points) == 12
    combos = {(p.algo, p.pattern, p.rate, p.seed) for p in res.points}
    assert len(combos) == 12
    g = res.grid("throughput", Algo.XY, "uni")
    assert g.shape == (2, 3)
    assert (g > 0).all()
    assert res.mean_over_seeds("throughput", Algo.XY, "uni").shape == (2,)


@pytest.mark.slow
def test_batched_campaign_matches_sequential_sweep_exactly():
    """Every lane of the vmapped batch must reproduce the stand-alone
    run bit-for-bit (same per-point PRNG stream, same integer stats)."""
    rates, seeds = (0.15, 0.45), (0, 7)
    plan = build_plan(TOPO, UNI)
    spec = CampaignSpec(
        topo=TOPO, algos=(Algo.XY, Algo.BIDOR), patterns=(("uni", UNI),),
        rates=rates, seeds=seeds, base=BASE)
    res = run_campaign(spec, bidor_tables={"uni": plan.table.choice})
    for algo in (Algo.XY, Algo.BIDOR):
        cfg = BASE.replace(algo=algo)
        for rate in rates:
            for seed in seeds:
                seq = run_sweep(TOPO, UNI, cfg, [rate],
                                bidor_table=plan.table, seeds=[seed])[0]
                (pt,) = res.select(algo=algo, rate=rate, seed=seed)
                bat = pt.result
                assert bat.injected_flits == seq.injected_flits
                assert bat.ejected_flits == seq.ejected_flits
                assert bat.in_flight_flits == seq.in_flight_flits
                assert bat.reorder_value == seq.reorder_value
                assert np.isclose(bat.avg_latency, seq.avg_latency)
                assert np.isclose(bat.throughput, seq.throughput)


@pytest.mark.slow
def test_chunked_execution_matches_single_call():
    """Slicing the cycle loop for the early-exit detector must not change
    any statistic when no lane saturates."""
    common = dict(topo=TOPO, algos=(Algo.XY,), patterns=(("uni", UNI),),
                  rates=(0.1, 0.3), seeds=(0,), base=BASE)
    whole = run_campaign(CampaignSpec(**common, chunk=0))
    sliced = run_campaign(CampaignSpec(**common, chunk=250))
    for pw, ps in zip(whole.points, sliced.points):
        assert pw.result.injected_flits == ps.result.injected_flits
        assert pw.result.ejected_flits == ps.result.ejected_flits
        assert np.isclose(pw.result.avg_latency, ps.result.avg_latency)
        assert pw.result.meas_cycles == ps.result.meas_cycles


def test_saturation_early_exit():
    """All-saturated lanes end the cell early: saturated flags set, fewer
    cycles measured than configured."""
    base = SimConfig(cycles=6000, warmup=500, src_queue_pkts=16)
    spec = CampaignSpec(
        topo=TOPO, algos=(Algo.XY,), patterns=(("uni", UNI),),
        rates=(2.0, 3.0), seeds=(0,), base=base, chunk=500,
        sat_occupancy=0.8)
    res = run_campaign(spec)
    for p in res.points:
        assert p.result.saturated, p
        assert p.result.meas_cycles < base.measure, p
        # statistics stay exactly normalized under the early exit
        assert p.result.injected_flits == (p.result.ejected_flits
                                           + p.result.in_flight_flits)
        assert 0.5 < p.result.throughput < 1.2


def test_unsaturated_lane_prevents_early_exit():
    base = SimConfig(cycles=2500, warmup=400, src_queue_pkts=16)
    spec = CampaignSpec(
        topo=TOPO, algos=(Algo.XY,), patterns=(("uni", UNI),),
        rates=(0.05, 3.0), seeds=(0,), base=base, chunk=400)
    res = run_campaign(spec)
    low = res.select(rate=0.05)[0].result
    high = res.select(rate=3.0)[0].result
    assert not low.saturated
    assert high.saturated
    assert low.meas_cycles == base.measure  # ran to completion


def test_warmup_spike_does_not_latch_saturation():
    """The saturation latch must only accumulate post-warmup occupancy
    reads: at rate 3.0 the source queues overflow during warmup, but
    injection stops at cycle 1100 and the long drain empties them before
    any post-warmup read — the sticky-latch bug reported them saturated
    forever."""
    base = SimConfig(cycles=2600, warmup=1000, drain=1500,
                     src_queue_pkts=16)
    spec = CampaignSpec(
        topo=TOPO, algos=(Algo.XY,), patterns=(("uni", UNI),),
        rates=(3.0,), seeds=(0,), base=base, chunk=500,
        sat_occupancy=0.5)
    res = run_campaign(spec)
    (p,) = res.points
    assert not p.result.saturated
    assert p.result.meas_cycles == base.measure  # no early exit either


def test_accessors_refuse_ambiguous_axes():
    """grid()/mean_over_seeds()/saturation_throughput() on a campaign
    with >1 scenario or topology must demand the axis explicitly —
    pooling would overlay every scenario/topo last-write-wins."""
    from repro.core import torus
    from repro.noc import Scenario
    from repro.noc.campaign import CampaignResult

    spec = CampaignSpec(
        topo=None, topos=(TOPO, torus(4, 4)), algos=(Algo.XY,),
        patterns=(("uni", UNI),), rates=(0.1,), seeds=(0,), base=BASE,
        scenarios=(Scenario("a"), Scenario("b")))
    res = CampaignResult(spec=spec, points=[], wall_clock_s={},
                         total_wall_clock_s=0.0)
    with pytest.raises(ValueError, match="ambiguous scenario"):
        res.grid("throughput", Algo.XY, "uni")
    with pytest.raises(ValueError, match="ambiguous topo"):
        res.grid("throughput", Algo.XY, "uni", scenario="a")
    with pytest.raises(KeyError, match="unknown scenario"):
        res.grid("throughput", Algo.XY, "uni", scenario="nope",
                 topo=TOPO.name)
    # fully qualified but absent points: missing-cell error, not zeros
    with pytest.raises(ValueError, match="missing"):
        res.grid("throughput", Algo.XY, "uni", scenario="a",
                 topo=TOPO.name)


def test_pattern_names_resolve_through_registry():
    spec = CampaignSpec(
        topo=TOPO, algos=(Algo.XY,), patterns=("uniform", "tornado"),
        rates=(0.2,), seeds=(0,), base=BASE)
    res = run_campaign(spec)
    assert {p.pattern for p in res.points} == {"uniform", "tornado"}


def test_csv_rows_match_header():
    spec = CampaignSpec(
        topo=TOPO, algos=(Algo.XY,), patterns=(("uni", UNI),),
        rates=(0.2,), seeds=(0,), base=BASE)
    res = run_campaign(spec)
    rows = res.to_rows()
    assert len(rows) == 1
    assert len(rows[0]) == len(res.CSV_HEADER)


def test_empty_axis_rejected():
    with pytest.raises(ValueError):
        CampaignSpec(topo=TOPO, algos=(), patterns=("uniform",),
                     rates=(0.1,), seeds=(0,), base=BASE)
