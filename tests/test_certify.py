"""Deadlock certifier: Tarjan vs brute-force oracle, zoo/fallback table
certification, cyclic-fixture rejection + repair, certificate round-trip.

Covers (ISSUE 8 satellite a):
  * property test — the iterative-Tarjan cyclicity verdict agrees with an
    independent brute-force DFS cycle enumeration on small random graphs
    (via the ``_propcheck`` facade: Hypothesis when installed, else the
    deterministic fallback sampler);
  * every zoo plan table AND the control plane's DOR-only shed fallback
    certify clean (verdict "clean", zero prohibited turns);
  * a hand-built cyclic ring table is rejected with ``repair=False`` and
    repaired (prohibitions + shed, re-verified acyclic) with the default;
  * ``Certificate.as_arrays``/``from_arrays`` round-trips through the
    plan-cache payload convention.
"""

from __future__ import annotations


import numpy as np
import pytest

from _propcheck import given, settings, st

from repro.core import (BiDORTable, build_plan_fast, cmesh, express_mesh,
                        fault_region_mesh, mesh2d, torus, traffic)
from repro.core.certify import (Certificate,
                                apply_repair, build_cdg, certify_ports,
                                certify_table, cyclic_scc_nodes,
                                has_cycle_bruteforce)
from repro.core.routes import dimension_orders, next_port_table

ZOO = {
    "mesh": lambda: mesh2d(4, 4),
    "torus3d": lambda: torus(4, 4, 4),
    "cmesh": lambda: cmesh(4, 4, concentration=4),
    "express": lambda: express_mesh(6, 6, interval=2),
    "fault_region": lambda: fault_region_mesh(6, 6, (2, 2, 3, 3)),
}


def _cyclic_ring_table(topo) -> BiDORTable:
    """All traffic routed clockwise around the 2x2 ring 0→1→3→2→0 —
    the canonical cyclic channel dependency."""
    n = topo.num_nodes
    ring = [0, 1, 3, 2]
    nxt = {ring[i]: ring[(i + 1) % 4] for i in range(4)}
    neigh = np.asarray(topo.neighbor_table)
    p = neigh.shape[1]
    pt = np.zeros((1, n, n), np.int8)
    for cur in range(n):
        for dst in range(n):
            pt[0, cur, dst] = (
                topo.port_local if cur == dst else
                [k for k in range(p) if neigh[cur, k] == nxt[cur]][0])
    return BiDORTable(choice=np.zeros((n, n), np.int8), orders=((0, 1),),
                      costs=np.zeros((1, n, n), np.float32),
                      port_tables=pt)


# --------------------------------------------------------------------- #
# Tarjan vs brute force (property test)
# --------------------------------------------------------------------- #
@settings(max_examples=60, deadline=None)
@given(st.integers(2, 24), st.floats(0.0, 0.35), st.integers(0, 10_000))
def test_scc_cyclicity_matches_bruteforce(num_nodes, density, seed):
    rng = np.random.default_rng(seed)
    m = rng.random((num_nodes, num_nodes)) < density
    edges = np.argwhere(m).astype(np.int64)
    tarjan = bool(cyclic_scc_nodes(num_nodes, edges).any())
    brute = has_cycle_bruteforce(num_nodes, edges)
    assert tarjan == brute, (num_nodes, seed, edges.tolist())


def test_scc_marks_exactly_the_cycle_nodes():
    # 0→1→2→0 cycle plus a 3→0 tail and an isolated 4
    edges = np.array([[0, 1], [1, 2], [2, 0], [3, 0]], np.int64)
    cyc = cyclic_scc_nodes(5, edges)
    assert cyc.tolist() == [True, True, True, False, False]
    assert has_cycle_bruteforce(5, edges)


def test_self_loop_is_cyclic():
    edges = np.array([[2, 2]], np.int64)
    assert cyclic_scc_nodes(3, edges)[2]
    assert has_cycle_bruteforce(3, edges)


def test_empty_graph_is_clean():
    edges = np.zeros((0, 2), np.int64)
    assert not cyclic_scc_nodes(4, edges).any()
    assert not has_cycle_bruteforce(4, edges)


# --------------------------------------------------------------------- #
# real plan tables certify clean
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name", sorted(ZOO))
def test_zoo_plan_tables_certify_clean(name):
    topo = ZOO[name]()
    plan = build_plan_fast(topo, traffic.uniform(topo))
    cert = certify_table(topo, plan.table, traffic=plan.traffic,
                        w_nr=plan.nrank.w_nr)
    assert cert.ok, f"{name}: {cert.verdict}"
    assert cert.verdict == "clean"
    assert cert.prohibited_turns.shape[0] == 0
    assert cert.cdg_edges > 0          # the CDG is not vacuous


@pytest.mark.parametrize("name", sorted(ZOO))
def test_dor_fallback_tables_certify_clean(name):
    """The control plane's escape/fallback: plain DOR under every order
    must be acyclic on every zoo topology (incl. wrap datelines)."""
    topo = ZOO[name]()
    n = topo.num_nodes
    for order in dimension_orders(topo.ndim):
        pt = next_port_table(topo, order).astype(np.int8)[None]
        cert = certify_ports(topo, pt, np.zeros((n, n), np.int8),
                             repair=False)
        assert cert.ok, f"{name} DOR{order}: {cert.verdict}"


def test_gated_plan_carries_clean_certificate():
    topo = mesh2d(4, 4)
    plan = build_plan_fast(topo, traffic.uniform(topo))
    assert plan.cert is not None and plan.cert.verdict == "clean"


# --------------------------------------------------------------------- #
# cyclic fixture: rejection and repair
# --------------------------------------------------------------------- #
def test_cyclic_table_rejected_without_repair():
    topo = mesh2d(2, 2)
    table = _cyclic_ring_table(topo)
    cert = certify_table(topo, table, repair=False)
    assert not cert.ok and cert.verdict == "rejected"
    assert cert.cyclic_nodes >= 4      # the whole ring participates


def test_cyclic_table_repaired_and_reverified():
    topo = mesh2d(2, 2)
    table = _cyclic_ring_table(topo)
    cert = certify_table(topo, table)
    assert cert.ok and cert.verdict == "repaired"
    assert cert.prohibited_turns.shape[0] >= 1
    repaired = apply_repair(table, cert)
    assert repaired.unroutable is not None and repaired.unroutable.any()
    # the repaired artifact certifies clean on its own
    cert2 = certify_table(topo, repaired, repair=False)
    assert cert2.ok and cert2.verdict == "clean"


def test_gate_raises_on_unrepairable():
    """certify_ports with repair budget 0 must refuse, not pass."""
    topo = mesh2d(2, 2)
    table = _cyclic_ring_table(topo)
    cert = certify_ports(topo, table.port_tables, table.choice,
                         repair=True, max_repair_rounds=0)
    assert not cert.ok and cert.verdict == "rejected"
    with pytest.raises(ValueError):
        apply_repair(table, cert)


# --------------------------------------------------------------------- #
# certificate round-trip (the plan-cache payload convention)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("fixture", ["clean", "repaired"])
def test_certificate_round_trip(fixture):
    if fixture == "clean":
        topo = mesh2d(3, 3)
        plan = build_plan_fast(topo, traffic.uniform(topo))
        cert = plan.cert
    else:
        topo = mesh2d(2, 2)
        cert = certify_table(topo, _cyclic_ring_table(topo))
    arrays = cert.as_arrays()
    back = Certificate.from_arrays(arrays)
    assert back is not None
    assert back.verdict == cert.verdict
    assert back.cdg_nodes == cert.cdg_nodes
    assert back.cdg_edges == cert.cdg_edges
    assert np.array_equal(back.prohibited_turns, cert.prohibited_turns)
    assert (back.choice is None) == (cert.choice is None)
    if cert.choice is not None:
        assert np.array_equal(back.choice, cert.choice)
    if cert.shed is not None:
        assert np.array_equal(back.shed, cert.shed)
    # absent payload ⇒ None (pre-certifier cache entries)
    assert Certificate.from_arrays({}) is None


def test_build_cdg_counts_real_dependencies():
    """Adjacent-channel turns of a straight XY route appear as edges."""
    topo = mesh2d(3, 3)
    pt = next_port_table(topo, (0, 1)).astype(np.int8)[None]
    n = topo.num_nodes
    edges, weights, invalid = build_cdg(
        topo, pt, np.zeros((n, n), np.int8))
    assert not invalid.any()
    assert edges.shape[0] > 0 and weights.shape[0] == edges.shape[0]
    num_cdg_nodes = 2 * pt.shape[0] * topo.num_channels
    assert not cyclic_scc_nodes(num_cdg_nodes, edges).any()
