"""Subprocess body: validate decomposed all-to-all semantics on 16 CPU devs.

Run by tests/test_qstar_collectives.py with
XLA_FLAGS=--xla_force_host_platform_device_count=16.
"""
import numpy as np
import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.qstar_collectives import bidor_all_to_all, dor_all_to_all

NX = NY = 4
C = 3


def main():
    assert len(jax.devices()) == 16, jax.devices()
    mesh = Mesh(np.array(jax.devices()).reshape(NX, NY), ("ex", "ey"))
    rng = np.random.default_rng(0)
    a = rng.normal(size=(NX, NY, NX, NY, C)).astype(np.float32)
    expect = np.transpose(a, (2, 3, 0, 1, 4))  # out[d..., s...] = in[s..., d...]

    def run(order):
        def f(x):
            x = x[0, 0]  # local block (NX, NY, C)
            out = dor_all_to_all(x, ("ex", "ey"), order, (NX, NY))
            return out[None, None]
        return jax.jit(jax.shard_map(
            f, mesh=mesh, in_specs=P("ex", "ey"),
            out_specs=P("ex", "ey")))(a)

    for order in [(0, 1), (1, 0)]:
        out = np.asarray(run(order))
        np.testing.assert_allclose(out, expect, rtol=1e-6)
        print(f"order {order} OK")

    # BiDOR-scheduled: random per-(src,dst) choice must still be exact
    choice = rng.integers(0, 2, size=(NX, NY, NX, NY)).astype(bool)

    def f(x, m):
        out = bidor_all_to_all(x[0, 0], ("ex", "ey"), (NX, NY), m[0, 0])
        return out[None, None]

    out = np.asarray(jax.jit(jax.shard_map(
        f, mesh=mesh, in_specs=(P("ex", "ey"), P("ex", "ey")),
        out_specs=P("ex", "ey")))(a, choice))
    np.testing.assert_allclose(out, expect, rtol=1e-6)
    print("bidor OK")

    # cross-check against jax.lax.all_to_all on a flattened single axis
    mesh1 = Mesh(np.array(jax.devices()), ("p",))
    b = rng.normal(size=(16, 16, C)).astype(np.float32)

    def g(x):
        y = jax.lax.all_to_all(x[0], "p", split_axis=0, concat_axis=0,
                               tiled=True)
        return y[None]

    ref = np.asarray(jax.jit(jax.shard_map(
        g, mesh=mesh1, in_specs=P("p"), out_specs=P("p")))(b))
    exp1 = np.transpose(b, (1, 0, 2))
    np.testing.assert_allclose(ref, exp1, rtol=1e-6)
    print("lax.all_to_all semantics cross-checked")


if __name__ == "__main__":
    main()
