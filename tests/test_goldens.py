"""Golden-regression harness: a pinned 4×4-mesh campaign.

The simulator is refactored aggressively (batching, packed state, device
sharding); this test makes any behavioural drift loud.  Integer flit
counts must match exactly — they are deterministic functions of the
per-point PRNG streams, which are platform-stable (threefry).  Float
statistics get a small tolerance for summation-order differences.

To update after an INTENTIONAL behaviour change:
    PYTHONPATH=src python tests/goldens/regen.py
"""

import json
import os

import numpy as np
import pytest

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "goldens",
                           "campaign_4x4.json")

INT_FIELDS = ("injected", "ejected", "in_flight", "reorder", "meas_cycles")
FLOAT_FIELDS = ("throughput", "avg_latency", "p50_latency", "p99_latency",
                "link_load_max", "lcv")


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def computed():
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "golden_regen", os.path.join(os.path.dirname(GOLDEN_PATH),
                                     "regen.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.compute_goldens()


def test_golden_point_set_matches(golden, computed):
    assert set(computed["points"]) == set(golden["points"])


def test_golden_campaign_matches(golden, computed):
    mismatches = []
    for key, want in golden["points"].items():
        got = computed["points"][key]
        for f in INT_FIELDS:
            if got[f] != want[f]:
                mismatches.append(f"{key}.{f}: {got[f]} != {want[f]}")
        for f in FLOAT_FIELDS:
            if not np.isclose(got[f], want[f], rtol=1e-5, atol=1e-6):
                mismatches.append(f"{key}.{f}: {got[f]} != {want[f]}")
    assert not mismatches, (
        "golden campaign drifted (intentional? regen with "
        "`PYTHONPATH=src python tests/goldens/regen.py`):\n  "
        + "\n  ".join(mismatches))


def test_golden_conservation(computed):
    """The pinned campaign itself satisfies flit conservation."""
    for key, pt in computed["points"].items():
        assert pt["injected"] == pt["ejected"] + pt["in_flight"], key
        assert pt["reorder"] == 0, key  # XY and BiDOR are in-order
