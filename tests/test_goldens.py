"""Golden-regression harness: a pinned 4×4-mesh campaign.

The simulator is refactored aggressively (batching, packed state, device
sharding); this test makes any behavioural drift loud.  Integer flit
counts must match exactly — they are deterministic functions of the
per-point PRNG streams, which are platform-stable (threefry).  Float
statistics get a small tolerance for summation-order differences.

To update after an INTENTIONAL behaviour change:
    PYTHONPATH=src python tests/goldens/regen.py
"""

import json
import os

import numpy as np
import pytest

# long campaign runs; CI's golden job (and tier-1) always run them
pytestmark = pytest.mark.slow

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "goldens",
                           "campaign_4x4.json")
CTRL_GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "goldens",
                                "ctrl_4x4.json")

INT_FIELDS = ("injected", "ejected", "in_flight", "reorder", "meas_cycles")
FLOAT_FIELDS = ("throughput", "avg_latency", "p50_latency", "p99_latency",
                "link_load_max", "lcv")


def _regen_module():
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "golden_regen", os.path.join(os.path.dirname(GOLDEN_PATH),
                                     "regen.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def computed():
    return _regen_module().compute_goldens()


@pytest.fixture(scope="module")
def ctrl_golden():
    with open(CTRL_GOLDEN_PATH) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def ctrl_computed():
    return _regen_module().compute_ctrl_goldens()


def _compare(golden, computed):
    mismatches = []
    for key, want in golden["points"].items():
        got = computed["points"][key]
        for f in INT_FIELDS:
            if got[f] != want[f]:
                mismatches.append(f"{key}.{f}: {got[f]} != {want[f]}")
        for f in FLOAT_FIELDS:
            if not np.isclose(got[f], want[f], rtol=1e-5, atol=1e-6):
                mismatches.append(f"{key}.{f}: {got[f]} != {want[f]}")
    return mismatches


def test_golden_point_set_matches(golden, computed):
    assert set(computed["points"]) == set(golden["points"])


def test_golden_campaign_matches(golden, computed):
    mismatches = _compare(golden, computed)
    assert not mismatches, (
        "golden campaign drifted (intentional? regen with "
        "`PYTHONPATH=src python tests/goldens/regen.py`):\n  "
        + "\n  ".join(mismatches))


def test_golden_conservation(computed):
    """The pinned campaign itself satisfies flit conservation."""
    for key, pt in computed["points"].items():
        assert pt["injected"] == pt["ejected"] + pt["in_flight"], key
        assert pt["reorder"] == 0, key  # XY and BiDOR are in-order


def test_ctrl_golden_point_set_matches(ctrl_golden, ctrl_computed):
    assert set(ctrl_computed["points"]) == set(ctrl_golden["points"])


def test_ctrl_golden_campaign_matches(ctrl_golden, ctrl_computed):
    mismatches = _compare(ctrl_golden, ctrl_computed)
    assert not mismatches, (
        "fault-scenario golden drifted (intentional? regen with "
        "`PYTHONPATH=src python tests/goldens/regen.py`):\n  "
        + "\n  ".join(mismatches))


def test_ctrl_golden_online_beats_stale(ctrl_computed):
    """The pinned scenario reproduces the headline property: the online
    re-planner's time-resolved peak max link load stays below the stale
    plan's for every seed, at no delivered-throughput cost, and both
    policies conserve flits and stay in-order."""
    pts = ctrl_computed["points"]
    for key, pt in pts.items():
        assert pt["injected"] == pt["ejected"] + pt["in_flight"], key
        assert pt["reorder"] == 0, key
    for seed in (0, 1):
        stale = pts[f"linkfail_stale/BIDOR/r0.35/s{seed}"]
        online = pts[f"linkfail_online/BIDOR/r0.35/s{seed}"]
        assert online["link_load_max"] < stale["link_load_max"], seed
        assert online["throughput"] >= stale["throughput"] * 0.98, seed
