"""Deliverable (g): roofline table from the dry-run artifacts.

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and emits
the per-(arch × shape × mesh) three-term roofline table with dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS ratio, and roofline-implied MFU bound.
Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os

from .common import write_csv

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_records(mesh: str | None = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh is None or r["mesh"] == mesh:
            recs.append(r)
    return recs


def main():
    recs = load_records()
    if not recs:
        print("roofline: no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --arch all --mesh "
              "single,multi` first")
        return []
    rows = []
    header = ["arch", "shape", "mesh", "kind", "compute_ms", "memory_ms",
              "collective_ms", "dominant", "useful_flops", "mfu_bound",
              "peak_GiB"]
    print(f"{'arch':22s} {'shape':12s} {'mesh':6s} {'comp(ms)':>9s} "
          f"{'mem(ms)':>9s} {'coll(ms)':>9s} {'dom':>6s} {'useful':>7s} "
          f"{'MFU≤':>7s} {'GiB':>7s}")
    for r in recs:
        rl = r["roofline"]
        row = [r["arch"], r["shape"], r["mesh"], r["kind"],
               f"{rl['compute_s'] * 1e3:.1f}",
               f"{rl['memory_s'] * 1e3:.1f}",
               f"{rl['collective_s'] * 1e3:.1f}",
               rl["dominant"],
               f"{rl['useful_flops_ratio']:.3f}",
               f"{rl['mfu_bound']:.4f}",
               f"{r['memory']['peak_gb']:.2f}"]
        rows.append(row)
        print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
              f"{row[4]:>9s} {row[5]:>9s} {row[6]:>9s} "
              f"{rl['dominant'][:6]:>6s} {row[8]:>7s} {row[9]:>7s} "
              f"{row[10]:>7s}")
    write_csv("roofline.csv", header, rows)

    singles = [r for r in recs if r["mesh"] == "single"]
    if singles:
        worst = min(singles, key=lambda r: r["roofline"]["mfu_bound"])
        coll = max(singles, key=lambda r: (
            r["roofline"]["collective_s"]
            / max(max(r["roofline"]["compute_s"],
                      r["roofline"]["memory_s"]), 1e-12)))
        print(f"\nroofline: worst MFU-bound cell: {worst['arch']} × "
              f"{worst['shape']} ({worst['roofline']['mfu_bound']:.4f})")
        print(f"roofline: most collective-bound cell: {coll['arch']} × "
              f"{coll['shape']} (coll {coll['roofline']['collective_s']*1e3:.1f} ms)")
    return rows


if __name__ == "__main__":
    main()
