"""Paper Fig. 9: realistic (Clos leaf switch) workload replay.

The ns-3 trace is synthesized with matched statistics (skewed Zipf flows,
on/off epochs — repro.noc.workload); BiDOR's plan is built from the
aggregate statistics only, adaptive routing reacts per cycle.  Reported:
mean/max latency, LCV dispersion across epochs, reorder value.
"""

from __future__ import annotations

import numpy as np

from repro.core import build_plan, mesh2d_edge_io
from repro.noc import Algo, SimConfig
from repro.noc.sim import run_trace
from repro.noc.workload import clos_leaf_trace
from .common import QUICK, write_csv

ALGOS = [Algo.XY, Algo.O1TURN, Algo.VALIANT, Algo.ROMM, Algo.ODDEVEN,
         Algo.BIDOR]


def main():
    topo = mesh2d_edge_io(5, 5)
    epochs = 4 if QUICK else 10
    segments, agg = clos_leaf_trace(topo, num_epochs=epochs,
                                    base_rate=0.3)
    plan = build_plan(topo, agg)
    cycles = 4000 if QUICK else 10000
    rows = []
    base = {}
    for algo in ALGOS:
        cfg = SimConfig(algo=algo, cycles=cycles, warmup=cycles // 4)
        res, lcvs = run_trace(topo, segments, cfg, bidor_table=plan.table)
        rows.append([algo.name, f"{res.avg_latency:.1f}",
                     f"{res.max_latency:.0f}",
                     f"{np.mean(lcvs):.3f}", f"{np.std(lcvs):.3f}",
                     res.reorder_value])
        base[algo.name] = res
        print(f"fig9 {algo.name:8s} lat={res.avg_latency:7.1f} "
              f"max={res.max_latency:6.0f} lcv={np.mean(lcvs):.3f}"
              f"±{np.std(lcvs):.3f} reorder={res.reorder_value}")
    xy, bd = base["XY"], base["BIDOR"]
    print(f"fig9 SUMMARY: mean latency {xy.avg_latency:.1f} → "
          f"{bd.avg_latency:.1f} "
          f"({(1 - bd.avg_latency / xy.avg_latency) * 100:.1f}% lower), "
          f"max {xy.max_latency:.0f} → {bd.max_latency:.0f} "
          f"({(1 - bd.max_latency / max(xy.max_latency, 1)) * 100:.1f}% "
          f"lower)")
    write_csv("fig9_realistic.csv",
              ["algo", "mean_lat", "max_lat", "lcv_mean", "lcv_std",
               "reorder"], rows)
    return base


if __name__ == "__main__":
    main()
