"""Paper Fig. 9: realistic (Clos leaf switch) workload replay.

The ns-3 trace is synthesized with matched statistics (skewed Zipf flows,
on/off epochs — repro.noc.workload); BiDOR's plan is built from the
aggregate statistics only, adaptive routing reacts per cycle.  Reported:
mean/max latency (+ p50/p99 from the in-simulator histograms), LCV
dispersion across epochs, reorder value.

Seeds run batched: each algorithm's trace replays all seeds as lanes of a
single vmapped state through :func:`repro.noc.sim.run_trace_sweep` (the
trace-driven face of the campaign engine).
"""

from __future__ import annotations

import numpy as np

from repro.core import build_plan, mesh2d_edge_io
from repro.noc import Algo, SimConfig, run_trace_sweep
from repro.noc.workload import clos_leaf_trace
from .common import QUICK, write_csv

ALGOS = (Algo.XY, Algo.O1TURN, Algo.VALIANT, Algo.ROMM, Algo.ODDEVEN,
         Algo.BIDOR)
SEEDS = (0,) if QUICK else (0, 1, 2)


def main():
    topo = mesh2d_edge_io(5, 5)
    epochs = 4 if QUICK else 10
    segments, agg = clos_leaf_trace(topo, num_epochs=epochs,
                                    base_rate=0.3)
    plan = build_plan(topo, agg)
    cycles = 4000 if QUICK else 10000
    rows = []
    base = {}
    for algo in ALGOS:
        # trace latencies reach thousands of cycles: widen histogram bins
        cfg = SimConfig(algo=algo, cycles=cycles, warmup=cycles // 4,
                        lat_bins=128, lat_bin_width=32)
        runs = run_trace_sweep(topo, segments, cfg,
                               bidor_table=plan.table, seeds=list(SEEDS))
        # seed-averaged statistics; LCV dispersion pooled across epochs
        lat = float(np.mean([r.avg_latency for r, _ in runs]))
        maxlat = float(np.max([r.max_latency for r, _ in runs]))
        p99 = float(np.mean([r.p99_latency for r, _ in runs]))
        all_lcvs = [v for _, lcvs in runs for v in lcvs]
        reorder = max(r.reorder_value for r, _ in runs)
        rows.append([algo.name, f"{lat:.1f}", f"{maxlat:.0f}",
                     f"{p99:.1f}",
                     f"{np.mean(all_lcvs):.3f}", f"{np.std(all_lcvs):.3f}",
                     reorder])
        base[algo.name] = (lat, maxlat)
        print(f"fig9 {algo.name:8s} lat={lat:7.1f} max={maxlat:6.0f} "
              f"p99={p99:7.1f} lcv={np.mean(all_lcvs):.3f}"
              f"±{np.std(all_lcvs):.3f} reorder={reorder} "
              f"(seeds={len(SEEDS)})")
    (xy_lat, xy_max), (bd_lat, bd_max) = base["XY"], base["BIDOR"]
    print(f"fig9 SUMMARY: mean latency {xy_lat:.1f} → {bd_lat:.1f} "
          f"({(1 - bd_lat / xy_lat) * 100:.1f}% lower), "
          f"max {xy_max:.0f} → {bd_max:.0f} "
          f"({(1 - bd_max / max(xy_max, 1)) * 100:.1f}% lower)")
    write_csv("fig9_realistic.csv",
              ["algo", "mean_lat", "max_lat", "p99_lat", "lcv_mean",
               "lcv_std", "reorder"], rows)
    return base


if __name__ == "__main__":
    main()
