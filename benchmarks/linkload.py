"""Beyond-paper: Q-StaR on the TPU ICI fabric (DESIGN.md §3).

Max/CV link load of decomposed collectives on the production meshes —
completion time of a bandwidth-bound collective ∝ max link load.  Scenarios:
balanced MoE all-to-all, hot-expert skew, and the multi-pod fabric with
BiDOR-k (dimension-order choice over 3 axes).
"""

from __future__ import annotations

import numpy as np

from repro.core import bidor, bidor_k, multipod, torus
from repro.core.bidor import greedy_refine
from repro.dist.qstar_collectives import (alltoall_traffic, build_ici_plan,
                                          ici_link_loads)
from .common import write_csv


def main():
    rng = np.random.default_rng(0)
    rows = []

    def report(name, topo, t, k_orders=False):
        n = topo.num_nodes
        xy = bidor(topo, np.zeros(n)) if not k_orders else \
            bidor_k(topo, np.zeros(n), orders=None)
        nr, tab = build_ici_plan(topo, t, k_orders=k_orders)
        tab_g = greedy_refine(topo, t, tab, sweeps=3)
        l_xy = ici_link_loads(topo, t, xy)
        l_bd = ici_link_loads(topo, t, tab)
        l_g = ici_link_loads(topo, t, tab_g)
        gain = (1 - l_bd["max"] / l_xy["max"]) * 100
        gain_g = (1 - l_g["max"] / l_xy["max"]) * 100
        rows.append([name, f"{l_xy['max']:.5f}", f"{l_bd['max']:.5f}",
                     f"{gain:+.1f}%", f"{l_g['max']:.5f}",
                     f"{gain_g:+.1f}%", f"{l_xy['cv']:.3f}",
                     f"{l_bd['cv']:.3f}"])
        print(f"linkload {name:26s} maxload XY={l_xy['max']:.5f} → "
              f"BiDOR={l_bd['max']:.5f} ({gain:+.1f}%) → "
              f"BiDOR-G={l_g['max']:.5f} ({gain_g:+.1f}%)")

    pod = torus(16, 16)
    report("pod16x16_uniform_a2a", pod, alltoall_traffic(pod))
    skew = 1.0 + 4.0 * (rng.random(256) < 0.10)
    report("pod16x16_hot_experts", pod, alltoall_traffic(pod, skew=skew))
    hot2 = np.ones(256)
    hot2[rng.choice(256, 16, replace=False)] = 8.0
    report("pod16x16_8x_hotspots", pod, alltoall_traffic(pod, skew=hot2))

    mp = multipod(2, 8, 8)
    t = alltoall_traffic(mp, skew=1.0 + 4.0 * (rng.random(128) < 0.10))
    report("multipod2x8x8_hot(bin)", mp, t)
    report("multipod2x8x8_hot(k!)", mp, t, k_orders=True)

    write_csv("linkload_ici.csv",
              ["scenario", "max_xy", "max_bidor", "gain_bidor",
               "max_bidor_g", "gain_bidor_g", "cv_xy", "cv_bidor"], rows)
    return rows


if __name__ == "__main__":
    main()
