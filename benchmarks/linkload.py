"""Beyond-paper: Q-StaR on the TPU ICI fabric (DESIGN.md §3).

Max/CV link load of decomposed collectives on the production meshes —
completion time of a bandwidth-bound collective ∝ max link load.  Scenarios:
balanced MoE all-to-all, hot-expert skew, and the multi-pod fabric with
BiDOR-k (dimension-order choice over 3 axes).

The static analysis runs on :func:`repro.core.qstar.link_load`
(bandwidth-normalized per-channel loads of a routing table); a closing
campaign cell replays the skewed all-to-all through the flit simulator on
a small torus (:func:`repro.noc.campaign.run_campaign`) and cross-checks
that the simulated ``link_load_max`` ordering (BiDOR ≤ XY) matches the
offline prediction.
"""

from __future__ import annotations

import numpy as np

from repro.core import (bidor, bidor_k, build_plan, multipod, torus,
                        traffic)
from repro.core.bidor import greedy_refine
from repro.core.qstar import link_load_stats as ici_link_loads
from repro.noc import Algo, CampaignSpec, SimConfig, run_campaign
from .common import QUICK, write_csv


def main():
    rng = np.random.default_rng(0)
    rows = []
    side = 8 if QUICK else 16
    n_pod = side * side

    def report(name, topo, t, k_orders=False):
        n = topo.num_nodes
        xy = bidor(topo, np.zeros(n)) if not k_orders else \
            bidor_k(topo, np.zeros(n), orders=None)
        plan = build_plan(topo, t, k_orders=k_orders)
        tab = plan.table
        tab_g = greedy_refine(topo, t, tab, sweeps=3)
        l_xy = ici_link_loads(topo, t, xy)
        l_bd = ici_link_loads(topo, t, tab)
        l_g = ici_link_loads(topo, t, tab_g)
        gain = (1 - l_bd["max"] / l_xy["max"]) * 100
        gain_g = (1 - l_g["max"] / l_xy["max"]) * 100
        rows.append([name, f"{l_xy['max']:.5f}", f"{l_bd['max']:.5f}",
                     f"{gain:+.1f}%", f"{l_g['max']:.5f}",
                     f"{gain_g:+.1f}%", f"{l_xy['cv']:.3f}",
                     f"{l_bd['cv']:.3f}"])
        print(f"linkload {name:26s} maxload XY={l_xy['max']:.5f} → "
              f"BiDOR={l_bd['max']:.5f} ({gain:+.1f}%) → "
              f"BiDOR-G={l_g['max']:.5f} ({gain_g:+.1f}%)")

    pod = torus(side, side)
    report(f"pod{side}x{side}_uniform_a2a", pod, traffic.alltoall(pod))
    skew = 1.0 + 4.0 * (rng.random(n_pod) < 0.10)
    report(f"pod{side}x{side}_hot_experts", pod,
           traffic.alltoall(pod, skew=skew))
    hot2 = np.ones(n_pod)
    hot2[rng.choice(n_pod, n_pod // 16, replace=False)] = 8.0
    report(f"pod{side}x{side}_8x_hotspots", pod,
           traffic.alltoall(pod, skew=hot2))

    mp = multipod(2, side // 2, side // 2)
    n_mp = mp.num_nodes
    t = traffic.alltoall(mp, skew=1.0 + 4.0 * (rng.random(n_mp) < 0.10))
    report(f"multipod2x{side//2}x{side//2}_hot(bin)", mp, t)
    report(f"multipod2x{side//2}x{side//2}_hot(k!)", mp, t, k_orders=True)

    # flit-sim cross-check on a small torus: the simulated max link load
    # must preserve the offline ordering (BiDOR ≤ XY under skew)
    sim_topo = torus(4, 4) if QUICK else torus(8, 8)
    ns = sim_topo.num_nodes
    sskew = 1.0 + 4.0 * (rng.random(ns) < 0.15)
    st = traffic.alltoall(sim_topo, skew=sskew)
    cycles = 3000 if QUICK else 6000
    spec = CampaignSpec(
        topo=sim_topo, algos=(Algo.XY, Algo.BIDOR),
        patterns=(("a2a_skew", st),), rates=(0.3,),
        base=SimConfig(cycles=cycles, warmup=cycles // 3))
    res = run_campaign(spec)
    s_xy = res.select(algo=Algo.XY)[0].result.link_load_max
    s_bd = res.select(algo=Algo.BIDOR)[0].result.link_load_max
    print(f"linkload sim-check torus{sim_topo.dims}: simulated max link "
          f"load XY={s_xy:.4f} BiDOR={s_bd:.4f} "
          f"(offline ordering {'preserved' if s_bd <= s_xy * 1.05 else 'VIOLATED'})")
    rows.append(["sim_check_" + "x".join(map(str, sim_topo.dims)),
                 f"{s_xy:.5f}", f"{s_bd:.5f}",
                 f"{(1 - s_bd / s_xy) * 100:+.1f}%", "", "", "", ""])

    write_csv("linkload_ici.csv",
              ["scenario", "max_xy", "max_bidor", "gain_bidor",
               "max_bidor_g", "gain_bidor_g", "cv_xy", "cv_bidor"], rows)
    return rows


if __name__ == "__main__":
    main()
