"""Paper Table 1: LCVs of routing algorithms across scenarios.

Paper values for reference: 2DMesh+UN: XY .29 O1Turn .28 Valiant .35
ROMM .46 BiDOR .20 | EdgeIO+UN: .28 .36 .33 .19 .08 | EdgeIO+OV: .36 .63
.37 .30 .17.

One campaign per scenario: all six algorithms run as cells of a single
declarative grid (the per-(algo, pattern) batched path of
:func:`repro.noc.campaign.run_campaign`).
"""

from __future__ import annotations

from repro.core import build_plan, mesh2d, mesh2d_edge_io, traffic
from repro.noc import Algo, CampaignSpec, SimConfig, run_campaign
from .common import QUICK, write_csv

SCENARIOS = [
    ("2DMesh+UN", mesh2d(5, 5), "uniform", 0.45),
    ("EdgeIO+UN", mesh2d_edge_io(5, 5), "uniform", 0.4),
    ("EdgeIO+OV", mesh2d_edge_io(5, 5), "overturn", 0.3),
]
ALGOS = (Algo.XY, Algo.O1TURN, Algo.VALIANT, Algo.ROMM, Algo.ODDEVEN,
         Algo.BIDOR)


def main():
    cycles = 6000 if QUICK else 16000
    rows = []
    header = ["scenario"] + [a.name for a in ALGOS]
    for name, topo, pattern, rate in SCENARIOS:
        t = traffic.PATTERNS[pattern](topo)
        plan = build_plan(topo, t)
        spec = CampaignSpec(
            topo=topo, algos=ALGOS, patterns=((pattern, t),),
            rates=(rate,),
            base=SimConfig(cycles=cycles, warmup=cycles // 3))
        res = run_campaign(spec,
                           bidor_tables={pattern: plan.table.choice})
        row = [name]
        for algo in ALGOS:
            row.append(f"{res.select(algo=algo)[0].result.lcv:.3f}")
        rows.append(row)
        print("table1", " ".join(f"{h}={v}" for h, v in zip(header, row)))
    write_csv("table1_lcv.csv", header, rows)
    return rows


if __name__ == "__main__":
    main()
