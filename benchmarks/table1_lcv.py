"""Paper Table 1: LCVs of routing algorithms across scenarios.

Paper values for reference: 2DMesh+UN: XY .29 O1Turn .28 Valiant .35
ROMM .46 BiDOR .20 | EdgeIO+UN: .28 .36 .33 .19 .08 | EdgeIO+OV: .36 .63
.37 .30 .17.
"""

from __future__ import annotations

from repro.core import build_plan, mesh2d, mesh2d_edge_io, traffic
from repro.noc import Algo, SimConfig, run_sim
from .common import QUICK, write_csv

SCENARIOS = [
    ("2DMesh+UN", mesh2d(5, 5), "uniform", 0.45),
    ("EdgeIO+UN", mesh2d_edge_io(5, 5), "uniform", 0.4),
    ("EdgeIO+OV", mesh2d_edge_io(5, 5), "overturn", 0.3),
]
ALGOS = [Algo.XY, Algo.O1TURN, Algo.VALIANT, Algo.ROMM, Algo.ODDEVEN,
         Algo.BIDOR]


def main():
    cycles = 6000 if QUICK else 16000
    rows = []
    header = ["scenario"] + [a.name for a in ALGOS]
    for name, topo, pattern, rate in SCENARIOS:
        t = traffic.PATTERNS[pattern](topo)
        plan = build_plan(topo, t)
        row = [name]
        for algo in ALGOS:
            cfg = SimConfig(algo=algo, cycles=cycles, warmup=cycles // 3,
                            injection_rate=rate)
            r = run_sim(topo, t, cfg, bidor_table=plan.table)
            row.append(f"{r.lcv:.3f}")
        rows.append(row)
        print("table1", " ".join(f"{h}={v}" for h, v in zip(header, row)))
    write_csv("table1_lcv.csv", header, rows)
    return rows


if __name__ == "__main__":
    main()
