"""Paper Fig. 8: throughput / latency / reorder vs injection rate under
Uniform, Shuffle, Permutation, Overturn on the edge-I/O 5×5 NoC (§4.1)."""

from __future__ import annotations

import numpy as np

from repro.core import build_plan, mesh2d_edge_io, traffic
from repro.noc import Algo, SimConfig
from repro.noc.sim import run_sweep
from .common import QUICK, write_csv

PATTERNS = ["uniform", "shuffle", "permutation", "overturn"]
ALGOS = [Algo.XY, Algo.O1TURN, Algo.VALIANT, Algo.ROMM, Algo.ODDEVEN,
         Algo.BIDOR]


def main():
    topo = mesh2d_edge_io(5, 5)
    rates = ([0.2, 0.4, 0.55, 0.7] if QUICK
             else [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.85, 1.0])
    cycles = 6000 if QUICK else 14000
    rows = []
    summary = {}
    for pattern in PATTERNS:
        t = traffic.PATTERNS[pattern](topo)
        plan = build_plan(topo, t)
        for algo in ALGOS:
            cfg = SimConfig(algo=algo, cycles=cycles, warmup=cycles // 3)
            rs = run_sweep(topo, t, cfg, rates, bidor_table=plan.table)
            sat = max(r.throughput for r in rs)
            summary[(pattern, algo.name)] = sat
            for r in rs:
                rows.append([pattern, algo.name, r.injection_rate,
                             f"{r.throughput:.4f}", f"{r.avg_latency:.1f}",
                             f"{r.max_latency:.0f}", r.reorder_value,
                             f"{r.lcv:.3f}"])
            print(f"fig8 {pattern:12s} {algo.name:8s} sat={sat:.4f} "
                  f"reorder@max={rs[-1].reorder_value}")
    for pattern in PATTERNS:
        xy = summary[(pattern, "XY")]
        bd = summary[(pattern, "BIDOR")]
        print(f"fig8 SUMMARY {pattern:12s}: BiDOR/XY saturation throughput "
              f"= {bd / xy:.3f} ({(bd / xy - 1) * 100:+.1f}%)")
    write_csv("fig8_synthetic.csv",
              ["pattern", "algo", "rate", "throughput", "avg_lat",
               "max_lat", "reorder", "lcv"], rows)
    return summary


if __name__ == "__main__":
    main()
