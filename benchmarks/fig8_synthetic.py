"""Paper Fig. 8: throughput / latency / reorder vs injection rate under
Uniform, Shuffle, Permutation, Overturn on the edge-I/O 5×5 NoC (§4.1).

Implemented as ONE declarative campaign: the full
(pattern × algorithm × rate) grid runs as a resumable campaign-service
job (``repro.noc.service``); every (rate, seed) point of a cell executes
inside a single jitted, vmapped call, each completed cell checkpoints to
``artifacts/campaigns/`` and streams its CSV rows, and an interrupted
run (``--max-cells``) continues bit-identically with ``--resume``.
"""

from __future__ import annotations

from repro.core import mesh2d_edge_io
from repro.noc import Algo, CampaignSpec, SimConfig
from .common import QUICK, run_service_campaign, write_csv

PATTERNS = ("uniform", "shuffle", "permutation", "overturn")
ALGOS = (Algo.XY, Algo.O1TURN, Algo.VALIANT, Algo.ROMM, Algo.ODDEVEN,
         Algo.BIDOR)


def main():
    topo = mesh2d_edge_io(5, 5)
    rates = ((0.2, 0.4, 0.55, 0.7) if QUICK
             else (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.85, 1.0))
    cycles = 6000 if QUICK else 14000
    spec = CampaignSpec(
        topo=topo, algos=ALGOS, patterns=PATTERNS, rates=rates,
        base=SimConfig(cycles=cycles, warmup=cycles // 3),
        chunk=cycles // 4)
    res, _job = run_service_campaign(spec, name="fig8")
    if res is None:          # cell budget hit; resume to finish
        return None
    for pattern in PATTERNS:
        for algo in ALGOS:
            sat = res.saturation_throughput(algo, pattern)
            reorder = max(p.result.reorder_value
                          for p in res.select(algo=algo, pattern=pattern))
            print(f"fig8 {pattern:12s} {algo.name:8s} sat={sat:.4f} "
                  f"reorder@max={reorder}")
    for pattern in PATTERNS:
        xy = res.saturation_throughput(Algo.XY, pattern)
        bd = res.saturation_throughput(Algo.BIDOR, pattern)
        print(f"fig8 SUMMARY {pattern:12s}: BiDOR/XY saturation throughput "
              f"= {bd / xy:.3f} ({(bd / xy - 1) * 100:+.1f}%)")
    print(res.summary())
    write_csv("fig8_synthetic.csv", res.CSV_HEADER, res.to_rows())
    return res


if __name__ == "__main__":
    main()
