"""Benchmark orchestrator — one entry per paper table/figure + the
beyond-paper ICI analyses.

  fig1      paper Fig. 1  — load distribution vs N-Rank prediction
  table1    paper Table 1 — LCV per algorithm × scenario
  fig8      paper Fig. 8  — throughput/latency/reorder vs injection rate
  fig9      paper Fig. 9  — realistic Clos-leaf workload
  linkload  DESIGN §3     — Q-StaR on the TPU ICI fabric
  roofline  deliverable g — per-(arch × shape × mesh) roofline table
  nrank     offline cost  — N-Rank wall time (the quasi-static budget)

Set BENCH_QUICK=0 for full-length simulations.  Run as
``PYTHONPATH=src python -m benchmarks.run [names...]``.
"""

from __future__ import annotations

import sys
import time

import numpy as np


def bench_nrank():
    """Offline pipeline cost: N-Rank + BiDOR wall time per topology —
    the 'ample time offline' budget of paper §3.1."""
    from repro.core import build_plan, mesh2d, mesh2d_edge_io, torus, traffic
    from .common import write_csv
    rows = []
    for name, topo in [("mesh5x5", mesh2d(5, 5)),
                       ("edgeio5x5", mesh2d_edge_io(5, 5)),
                       ("torus16x16", torus(16, 16))]:
        t = traffic.uniform(topo)
        t0 = time.time()
        plan = build_plan(topo, t)
        dt = time.time() - t0
        rows.append([name, topo.num_nodes, f"{dt * 1e3:.1f}",
                     plan.nrank.iterations])
        print(f"nrank,{name},{dt * 1e6:.0f}us_per_call,"
              f"iters={plan.nrank.iterations}")
    write_csv("nrank_cost.csv", ["topology", "nodes", "ms", "iters"], rows)


STAGES = ["fig1", "table1", "fig8", "fig9", "linkload", "roofline",
          "nrank"]


def main() -> None:
    want = sys.argv[1:] or STAGES
    t_all = time.time()
    for name in want:
        print(f"\n================ {name} ================", flush=True)
        t0 = time.time()
        if name == "fig1":
            from . import fig1_load
            fig1_load.main()
        elif name == "table1":
            from . import table1_lcv
            table1_lcv.main()
        elif name == "fig8":
            from . import fig8_synthetic
            fig8_synthetic.main()
        elif name == "fig9":
            from . import fig9_realistic
            fig9_realistic.main()
        elif name == "linkload":
            from . import linkload
            linkload.main()
        elif name == "roofline":
            from . import roofline
            roofline.main()
        elif name == "nrank":
            bench_nrank()
        else:
            raise SystemExit(f"unknown benchmark {name}")
        print(f"[{name} done in {time.time() - t0:.1f}s]", flush=True)
    print(f"\nall benchmarks done in {time.time() - t_all:.1f}s")


if __name__ == "__main__":
    main()
